"""Render EXPERIMENTS.md tables from dry-run JSON records.

    PYTHONPATH=src python experiments/make_tables.py > experiments/tables.md
"""
import glob
import json
import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))


def load(dirname):
    recs = {}
    for p in sorted(glob.glob(os.path.join(HERE, dirname, "*.json"))):
        r = json.load(open(p))
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt(x, nd=2):
    if x is None:
        return "—"
    if isinstance(x, float):
        if x == 0:
            return "0"
        if abs(x) < 1e-3 or abs(x) >= 1e4:
            return f"{x:.2e}"
        return f"{x:.{nd}f}"
    return str(x)


def roofline_table(rolled, unrolled):
    """Single-pod roofline: exact flops/bytes from unrolled lowers; memory
    footprint + multi-pod check from rolled."""
    print("| arch | shape | c (s) | m (s) | coll (s) | dominant | "
          "MODEL/HLO | mem/dev GiB | 2-pod | exact |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for (arch, shape, mesh), r in sorted(rolled.items()):
        if mesh != "16x16" or r.get("status") != "ok":
            continue
        u = unrolled.get((arch, shape, "16x16"), None)
        exact = u is not None and u.get("status") == "ok"
        rf = (u if exact else r)["roofline"]
        mp = rolled.get((arch, shape, "2x16x16"), {})
        mp_s = "ok" if mp.get("status") == "ok" else mp.get("status", "—")
        # 'exact' rows come from fully-unrolled lowers (scan bodies counted
        # per trip); rolled rows undercount c/m by ~n_layers (collective
        # term is always trip-weighted by the HLO parser).
        print(f"| {arch} | {shape} | {fmt(rf['compute_s'])} | "
              f"{fmt(rf['memory_s'])} | {fmt(rf['collective_s'])} | "
              f"{rf['dominant']} | {fmt(rf['useful_ratio'], 3)} | "
              f"{r['mem']['peak_per_device'] / 2**30:.1f} | {mp_s} | "
              f"{'✓' if exact else 'scan'} |")


def skipped(rolled):
    for (arch, shape, mesh), r in sorted(rolled.items()):
        if r.get("status") == "skipped":
            print(f"* {arch} × {shape}: {r['note']}")


if __name__ == "__main__":
    rolled = load("dryrun")
    unrolled = load("dryrun_unroll")
    which = sys.argv[1] if len(sys.argv) > 1 else "roofline"
    if which == "roofline":
        roofline_table(rolled, unrolled)
    elif which == "skipped":
        skipped(rolled)
