"""Summarize §Perf hillclimb: baseline vs override records, per pair.

    PYTHONPATH=src python experiments/hillclimb_summary.py
"""
import glob
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))
PAIRS = [("mixtral-8x7b", "train_4k"), ("deepseek-moe-16b", "prefill_32k"),
         ("llama3-8b", "train_4k")]


def load(path):
    try:
        return json.load(open(path))
    except FileNotFoundError:
        return None


def row(r):
    if not r or r.get("status") != "ok":
        return None
    rf = r["roofline"]
    return {"c": rf["compute_s"], "m": rf["memory_s"],
            "coll": rf["collective_s"], "dom": rf["dominant"],
            "mem_GiB": r["mem"]["peak_per_device"] / 2**30}


def main():
    print("| pair | variant | c (s) | m (s) | coll (s) | mem GiB | Δcoll |")
    print("|---|---|---|---|---|---|---|")
    for arch, shape in PAIRS:
        base = row(load(os.path.join(HERE, "dryrun",
                                     f"{arch}__{shape}__16x16.json")))
        if not base:
            continue
        print(f"| {arch} × {shape} | baseline | {base['c']:.3f} | "
              f"{base['m']:.3f} | {base['coll']:.3f} | "
              f"{base['mem_GiB']:.1f} | — |")
        for ov in ("seqpar", "ep", "ep_seqpar", "moe_w", "moe_ragged",
                   "seqpar_dots"):
            r = row(load(os.path.join(
                HERE, "hillclimb", f"{arch}__{shape}__16x16__{ov}.json")))
            if not r:
                continue
            d = (base["coll"] - r["coll"]) / base["coll"] * 100
            print(f"| | {ov} | {r['c']:.3f} | {r['m']:.3f} | "
                  f"{r['coll']:.3f} | {r['mem_GiB']:.1f} | {d:+.1f}% |")


if __name__ == "__main__":
    main()
