"""Minimal single-host swarm-serving example: stage-shard a tiny decoder
over a simulated 4-device LAN, replay a Poisson request trace through the
continuous-batching runtime, and print the closed-loop report — then do it
again with a scripted mid-session failure to show the router re-routing
around the dead replica with bit-identical output.

    PYTHONPATH=src python examples/serving.py

Everything runs in one process on one host: the "devices" are rows of a
simulated cluster spec; the model math is real JAX.  See docs/serving.md
for the full guide and ``python -m repro.launch.serve`` for the CLI.
"""
import jax

from repro.configs.base import ModelCfg
from repro.core.network import homogeneous_lan
from repro.elastic.membership import ChurnTrace, MembershipView
from repro.models import causal_lm
from repro.serving import (ServingCostModel, ServingRuntime,
                           churn_trace_for, derive_midsession_failure,
                           plan_serving, poisson_trace)


def main() -> None:
    cfg = ModelCfg(name="tiny", family="dense", n_layers=4, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab=97)
    params = causal_lm.init(cfg, jax.random.PRNGKey(0))
    cluster = homogeneous_lan(4)
    costs = ServingCostModel(cfg, cluster)
    plan = plan_serving(cfg, costs, alive=[0, 1, 2, 3], n_stages=2,
                        cache_len=64, max_batch=3)
    print(plan.describe())

    requests = poisson_trace(5, rate=200.0, vocab=cfg.vocab,
                             gen_len=(24, 32), seed=3)

    # leg 1: no churn
    view = MembershipView(4, ChurnTrace(()), lease_s=1e-5)
    report = ServingRuntime(cfg, params, plan, view).run(list(requests))
    print("no churn:", report.to_dict())

    # leg 2: same offered load, one stage replica dies mid-session
    victim, at, _, _ = derive_midsession_failure(cfg, params, plan,
                                                 requests, 4)
    print(f"killing device {victim} at t={at:.4f}s (mid-session)")
    view = MembershipView(4, churn_trace_for(victim, at), lease_s=1e-5)
    report = ServingRuntime(cfg, params, plan, view).run(list(requests))
    print("with churn:", report.to_dict())
    assert report.all_completed and report.n_reroutes >= 1


if __name__ == "__main__":
    main()
