"""Batched serving demo: prefill a batch of prompts, decode with the KV
cache, report tokens/s.

    PYTHONPATH=src python examples/serving.py [--arch zamba2-7b]
"""
import subprocess
import sys

if __name__ == "__main__":
    arch = "llama3-8b"
    if "--arch" in sys.argv:
        arch = sys.argv[sys.argv.index("--arch") + 1]
    raise SystemExit(subprocess.call(
        [sys.executable, "-m", "repro.launch.serve",
         "--arch", arch, "--size", "smoke",
         "--batch", "4", "--prompt-len", "16", "--gen", "24"]))
