"""Quickstart: define a model as an OP-DAG, let the broker schedule it onto
a simulated geo-distributed cluster, and train with AdaTopK compression.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DecentralizedRuntime, network, plan_adatopk,
                        schedule_opfence)
from repro.core.opgraph import OpGraph, OpNode, OpType

# --- 1. define a model as an OP-DAG (paper Fig. 7 style) -------------------
d, vocab, seq, batch = 64, 64, 32, 8
g = OpGraph("tiny-lm")
g.add(OpNode("tokens", OpType.PLACEHOLDER))
g.add(OpNode("labels", OpType.PLACEHOLDER))
g.add(OpNode("embed", OpType.PARAMETRIC, args=("tokens",),
             init_fn=lambda r, s: {"t": jax.random.normal(r, (vocab, d)) * .02},
             apply_fn=lambda p, t: p["t"][t],
             out_shape_fn=lambda s: (s[0], s[1], d),
             n_params_fn=lambda s: vocab * d))
prev = "embed"
for i in range(4):
    def mk(i):
        def init(r, s):
            k1, k2 = jax.random.split(r)
            return {"w1": jax.random.normal(k1, (d, 4 * d)) * d ** -0.5,
                    "w2": jax.random.normal(k2, (4 * d, d)) * (4 * d) ** -0.5}

        def apply(p, x):
            return x + jnp.tanh(x @ p["w1"]) @ p["w2"]
        return init, apply
    init, apply = mk(i)
    g.add(OpNode(f"block_{i}", OpType.PARAMETRIC, args=(prev,),
                 init_fn=init, apply_fn=apply, out_shape_fn=lambda s: s,
                 flops_fn=lambda s: 2 * np.prod(s) * 4 * d * 2,
                 n_params_fn=lambda s: 8 * d * d))
    prev = f"block_{i}"
g.add(OpNode("head", OpType.PARAMETRIC, args=(prev,),
             init_fn=lambda r, s: {"w": jax.random.normal(r, (d, vocab)) * .02},
             apply_fn=lambda p, x: x @ p["w"],
             out_shape_fn=lambda s: (s[0], s[1], vocab),
             flops_fn=lambda s: 2 * np.prod(s) * vocab,
             n_params_fn=lambda s: d * vocab))


def ce(p, logits, y):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[..., None], -1))


g.add(OpNode("loss", OpType.LOSS, args=("head", "labels"), apply_fn=ce,
             out_shape_fn=lambda a, b: ()))

# --- 2. the broker profiles + schedules onto a geo cluster -----------------
shapes = {"tokens": (batch, seq), "labels": (batch, seq)}
profiles = g.annotate(shapes)
cluster = network.geo_random(n=6, n_sites=2, seed=0)
schedule = schedule_opfence(g, profiles, cluster)
print("OP-Fence clusters:", [len(c) for c in schedule.clusters])
print("stage devices:", schedule.stage_devices())

# --- 3. AdaTopK plan (Eq. 7) + decentralized training ----------------------
plan = plan_adatopk(g, profiles, cluster, schedule.placement, ratio=10)
print("per-edge ratios:", {e: round(r, 1) for e, r in plan.edge_ratio.items()})
runtime = DecentralizedRuntime(g, schedule, plan)
params = g.init(jax.random.PRNGKey(0), shapes)

rng = np.random.default_rng(0)
table = rng.integers(0, vocab, size=vocab)
for step in range(20):
    toks = np.empty((batch, seq + 1), np.int32)
    toks[:, 0] = rng.integers(0, vocab, batch)
    for t in range(1, seq + 1):
        toks[:, t] = table[toks[:, t - 1]]
    inputs = {"tokens": jnp.asarray(toks[:, :-1]),
              "labels": jnp.asarray(toks[:, 1:])}
    loss, grads = runtime.train_step(params, [inputs])
    params = jax.tree_util.tree_map(lambda p, gr: p - 0.1 * gr, params, grads)
    if step % 5 == 0:
        print(f"step {step:3d}  loss {float(loss):.3f}")
print(f"traffic: {len(runtime.traffic)} OpData messages exchanged")
