"""FusionLLM-on-a-pod: the shard_map GPipe pipeline with AdaTopK-compressed
pod-boundary edges, on 8 simulated devices (2 'pods' x 4 stages).

Verifies that the pipeline loss matches the single-device loss when
compression is off, then shows the compressed variant running.

    PYTHONPATH=src python examples/pipeline_pod.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import resolve
from repro.distributed.pipeline import (make_pipeline_train_fn, microbatch,
                                        n_stages, pod_edge_ratios)
from repro.models import causal_lm

mesh = jax.make_mesh((2, 4), ("pod", "model"))
cfg = resolve("gpt2-xl").smoke.replace(n_layers=8, max_seq=64)
print(f"stages: {n_stages(mesh)} (pod-crossing edge gets compressed)")
print("edge ratios (Eq. 7):", pod_edge_ratios(mesh, base_ratio=10.0))

params = causal_lm.init(cfg, jax.random.PRNGKey(0))
B, S, n_micro = 8, 64, 4
rng = jax.random.PRNGKey(1)
batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab),
         "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab)}
mb = microbatch(batch, n_micro)

# reference: single-device loss
ref_loss, _ = causal_lm.train_loss(cfg, params, batch)

loss_fn = jax.jit(make_pipeline_train_fn(cfg, mesh, n_micro, base_ratio=1.0))
loss = loss_fn(params, mb)
print(f"pipeline loss {float(loss):.4f}  vs single-device "
      f"{float(ref_loss):.4f}")
assert abs(float(loss) - float(ref_loss)) < 1e-2

loss_c_fn = jax.jit(make_pipeline_train_fn(cfg, mesh, n_micro,
                                           base_ratio=10.0))
loss_c = loss_c_fn(params, mb)
print(f"with AdaTopK on the pod boundary: loss {float(loss_c):.4f}")

# gradients flow through the compressed pipeline (RAD through shard_map)
g = jax.grad(lambda p: loss_c_fn(p, mb))(params)
gn = float(jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                        for x in jax.tree_util.tree_leaves(g))))
print(f"grad norm through compressed pipeline: {gn:.4f}")
