"""End-to-end driver: train a GPT-2-family LM for a few hundred steps with
the full FusionLLM stack (OP-Fence scheduling on the paper's 24-GPU testbed,
RAD executor, AdaTopK compression) and report both the real loss curve and
the simulated decentralized wall-clock.

    PYTHONPATH=src python examples/decentralized_training.py [--steps 200]
"""
import subprocess
import sys

if __name__ == "__main__":
    steps = "200" if "--steps" not in sys.argv else \
        sys.argv[sys.argv.index("--steps") + 1]
    raise SystemExit(subprocess.call(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "gpt2-xl", "--size", "smoke", "--mode", "fusion",
         "--steps", steps, "--batch", "16", "--seq", "64",
         "--compress", "adatopk", "--ratio", "10", "--testbed", "1"]))
