"""Elastic end-to-end demo: a GPT LM keeps training through a node failure.

Runs real RAD numerics (DecentralizedRuntime) for a small GPT on the paper's
testbed-1 topology (Cluster A: RTX4090s, Cluster B: RTX2080s) with a
scripted churn trace: one CompNode dies mid-run.  The ElasticController
detects the loss at lease expiry (stragglers it detects from executor
telemetry — StepTiming samples aggregated by the broker's TelemetryLog, not
estimator predictions), re-plans via OP-Fence on the survivors, migrates
parameters + AdamW state bit-exactly through the checkpoint wire format, and
continues — the printed loss curve is continuous through the fail-over
(identical, step for step, to a run with no failure).

``--migration-mode overlap`` recovers without stopping the world: only the
dead shard's checkpoint restore blocks, training resumes on the interim
schedule, and any survivor bulk streams in the background over
bandwidth-shared links (or is skipped outright when the re-planned pace
would not pay for the stream).  In overlap mode boundary pinning is the
default: no re-plan moves state across the WAN.

``--planner joint`` (the default) puts the OP-Fence × AdaTopK co-planner in
charge of every epoch plan — initial schedule, full re-plan candidate, and
the AdaTopK plan that follows each re-cut — so compression-aware co-planning
is what actually trains, end to end.  ``--ratio`` sets the AdaTopK target
(compressed boundary gradients change the numerics: the loss stays
continuous across the fail-over, but differs from a dense run;
``--planner opfence`` reproduces the dense behaviour).

    PYTHONPATH=src python examples/elastic_training.py [--steps 30]
    PYTHONPATH=src python examples/elastic_training.py --migration-mode overlap
    PYTHONPATH=src python examples/elastic_training.py --planner opfence
"""
import argparse
import sys

import jax
import jax.numpy as jnp

from repro.configs.base import ModelCfg
from repro.core import network
from repro.data.synthetic import SyntheticLM
from repro.elastic import ChurnTrace, ElasticController, single_failure_trace
from repro.models.opgraph_models import gpt_opgraph
from repro.optim.optimizers import adamw


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--fail-at-step", type=float, default=0.4,
                    help="failure time as a fraction of the run")
    ap.add_argument("--migration-mode", default="stop",
                    choices=["stop", "overlap"],
                    help="stop-the-world vs overlapped recovery")
    ap.add_argument("--planner", default="joint",
                    choices=["joint", "opfence"],
                    help="joint = OP-Fence x AdaTopK co-planner drives every "
                         "epoch plan (compressed boundaries); opfence = "
                         "dense scheduling")
    ap.add_argument("--ratio", type=float, default=8.0,
                    help="AdaTopK target ratio for --planner joint")
    args = ap.parse_args()

    cfg = ModelCfg(name="gpt-elastic-demo", family="dense", n_layers=6,
                   d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
                   rope_fraction=0.0, max_seq=args.seq, norm="layernorm",
                   act="gelu")
    graph = gpt_opgraph(cfg, args.batch, args.seq)
    shapes = {"tokens": (args.batch, args.seq),
              "labels": (args.batch, args.seq)}
    profiles = graph.annotate(shapes)
    params = graph.init(jax.random.PRNGKey(0), shapes)
    cluster = network.paper_testbed(1, seed=0)

    n_micro = 2
    ds = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq, seed=0, order=1)

    def data_fn(step):
        b = ds.batch(args.batch, step)
        mb = args.batch // n_micro
        return [{"tokens": jnp.asarray(b["tokens"][i * mb:(i + 1) * mb]),
                 "labels": jnp.asarray(b["labels"][i * mb:(i + 1) * mb])}
                for i in range(n_micro)]

    # probe the churn-free pace to place the failure mid-run
    probe = ElasticController(graph, profiles, cluster, ChurnTrace(()),
                              n_micro=n_micro, planner=args.planner,
                              joint_ratio=args.ratio)
    t_iter = probe.run(steps=1).steps[0].step_seconds
    victim = probe.schedule.stage_devices()[2]
    trace = single_failure_trace(victim,
                                 at=args.fail_at_step * args.steps * t_iter)
    print(f"churn trace: {trace.to_json()}")
    print(f"victim CompNode {victim} ({cluster.devices[victim].name}), "
          f"iteration ~{t_iter:.2f}s simulated, planner={args.planner}"
          + (f" (AdaTopK ratio {args.ratio:g})"
             if args.planner == "joint" else ""))

    ctrl = ElasticController(graph, profiles, cluster, trace,
                             optimizer=adamw(lr=3e-3), n_micro=n_micro,
                             lease_s=1.5 * t_iter,
                             migration_mode=args.migration_mode,
                             planner=args.planner, joint_ratio=args.ratio)
    res = ctrl.run(steps=args.steps, data_fn=data_fn, params=params)

    print("\nstep  epoch  loss     sim_clock")
    for r in res.steps:
        mark = "  (lost, replayed)" if r.lost \
            else ("  (migrating in background)" if r.overlapping else "")
        print(f"{r.step:4d}  {r.epoch:5d}  {r.loss:.4f}  "
              f"{r.clock:9.1f}s{mark}")
    print("\nepochs:")
    for e in res.epochs:
        extra = f" bg={e.background_bytes / 1e6:.1f}MB" \
            if e.background_bytes else ""
        print(f"  epoch {e.epoch}: cause={e.cause} mode={e.replan_mode or '-'} "
              f"stages={len(e.stage_devices)} moves={e.n_moves} "
              f"moved={e.moved_bytes / 1e6:.1f}MB "
              f"detect={e.detect_seconds:.1f}s "
              f"migrate={e.migrate_seconds:.1f}s "
              f"refill={e.refill_seconds:.1f}s "
              f"rollback={e.rollback_steps} steps{extra}")
    print(f"\ntelemetry: {ctrl.telemetry.n_samples} StepTiming samples "
          f"aggregated this epoch; detector observes the "
          f"median-of-{ctrl.telemetry.window} window")
    losses = [l for _, l in res.losses]
    ok = any(e.cause == "failure" for e in res.epochs) \
        and losses[-1] < losses[0]
    print(f"\nfinal loss {losses[-1]:.4f} (from {losses[0]:.4f}); "
          f"simulated wall-clock {res.total_seconds:.1f}s; "
          f"throughput {res.samples_per_second(args.batch):.3f} samples/s")
    print("PASS: loss continuous across fail-over" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
