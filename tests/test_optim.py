"""Optimizers descend; schedules and clipping behave."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adafactor, adamw, clip_by_global_norm,
                         cosine_schedule, linear_warmup_cosine, sgd)


def quad_problem(d=8, seed=0):
    A = jax.random.normal(jax.random.PRNGKey(seed), (d, d)) * 0.3
    A = A @ A.T + jnp.eye(d)
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (d,))

    def loss(p):
        x = p["x"]
        return 0.5 * x @ A @ x - b @ x

    return loss, {"x": jnp.zeros(d)}


@pytest.mark.parametrize("opt", [sgd(5e-2, momentum=0.9),
                                 adamw(5e-2, weight_decay=0.0),
                                 adafactor(5e-1)])
def test_optimizers_descend(opt):
    loss, params = quad_problem()
    state = opt.init(params)
    l0 = float(loss(params))
    for _ in range(60):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params)
    assert float(loss(params)) < l0 - 0.1


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 10.0, "b": jnp.ones(2) * 10.0}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(10 * np.sqrt(6), rel=1e-5)
    total = np.sqrt(sum(float(jnp.sum(v ** 2)) for v in clipped.values()))
    assert total == pytest.approx(1.0, rel=1e-4)
    # no-op when under the bound
    small, _ = clip_by_global_norm({"a": jnp.ones(2) * 0.1}, 1.0)
    np.testing.assert_allclose(np.asarray(small["a"]), 0.1, rtol=1e-6)


def test_schedules():
    cos = cosine_schedule(1.0, 100, final_frac=0.1)
    assert float(cos(jnp.int32(0))) == pytest.approx(1.0)
    assert float(cos(jnp.int32(100))) == pytest.approx(0.1)
    wc = linear_warmup_cosine(1.0, warmup=10, total_steps=110)
    assert float(wc(jnp.int32(0))) == pytest.approx(0.1)
    assert float(wc(jnp.int32(9))) == pytest.approx(1.0)
    assert float(wc(jnp.int32(110))) < 0.2


def test_adamw_state_dtype_fp32_even_for_bf16_params():
    opt = adamw(1e-3)
    params = {"w": jnp.zeros((4, 4), jnp.bfloat16)}
    state = opt.init(params)
    assert state.inner["m"]["w"].dtype == jnp.float32
    grads = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    new_p, state = opt.update(grads, state, params)
    assert new_p["w"].dtype == jnp.bfloat16
