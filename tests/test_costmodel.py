"""Unified EdgeCostModel: single source of truth for per-edge bytes/seconds.

Pins the estimator and the discrete-event executor to the *same* byte
arithmetic (the pre-cost-model code carried three inconsistent models:
stage-indexed scales in partition.py, a smooth 3/r approximation in
estimator.py, and the exact integer wire encoding in compression.py)."""
import numpy as np
import pytest

from repro.core import (EdgeCostModel, fit_link_corrections, network,
                        plan_adatopk, plan_uniform, schedule_opfence,
                        simulate_iteration)
from repro.core.compression import plan_none, wire_bytes
from repro.core.estimator import predict_step_time_components
from repro.core.executor import TelemetrySink
from repro.core.opgraph import OpType
from helpers import mlp_chain


def _setup(ratio=None, n_layers=12, d=64, batch=8, itemsize=4):
    g, shapes, params, inputs = mlp_chain(n_layers=n_layers, d=d, batch=batch)
    prof = g.annotate(shapes, activation_itemsize=itemsize)
    cluster = network.paper_testbed(1, seed=0)
    sch = schedule_opfence(g, prof, cluster)
    plan = plan_adatopk(g, prof, cluster, sch.placement, ratio) \
        if ratio else None
    return g, prof, cluster, sch, plan


# ----------------------------------------------------------- model basics --
def test_model_dense_matches_profiles_and_alpha_beta():
    g, prof, cluster, sch, _ = _setup()
    m = EdgeCostModel(g, prof, cluster)
    placement = sch.placement
    for (a, n) in m.cross_edges(placement):
        assert m.edge_wire_bytes(a, n) == prof[a].out_bytes
        src, dst = placement[a], placement[n]
        assert m.edge_seconds(a, n, src, dst) == pytest.approx(
            cluster.comm_time(src, dst, prof[a].out_bytes))
    # co-located edges transport nothing
    some_op = next(iter(g.nodes))
    assert m.edge_seconds(some_op, some_op, 3, 3) == 0.0


def test_model_plan_view_uses_exact_wire_encoding():
    g, prof, cluster, sch, plan = _setup(ratio=100.0)
    assert plan.edge_ratio            # something actually compressed
    m = EdgeCostModel(g, prof, cluster, plan)
    for (a, n), r in plan.edge_ratio.items():
        numel = int(np.prod(prof[a].out_shape))
        assert m.edge_wire_bytes(a, n) == wire_bytes(numel, r, plan.encoding)
        assert m.edge_wire_bytes(a, n) < prof[a].out_bytes
    # with_plan derives a variant without mutating the original
    dense = m.with_plan(None)
    (a, n) = next(iter(plan.edge_ratio))
    assert dense.edge_wire_bytes(a, n) == prof[a].out_bytes
    assert m.edge_wire_bytes(a, n) < prof[a].out_bytes


def test_model_itemsize_derived_from_profile():
    g, prof, cluster, sch, _ = _setup(itemsize=2)     # bf16 annotation
    m = EdgeCostModel(g, prof, cluster)
    op = [n for n, node in g.nodes.items()
          if node.op_type is OpType.PARAMETRIC][0]
    assert m.itemsize(op) == 2
    assert m.dense_bytes(op) == prof[op].out_bytes


def test_link_corrections_scale_seconds():
    g, prof, cluster, sch, _ = _setup()
    placement = sch.placement
    m = EdgeCostModel(g, prof, cluster)
    (a, n) = next(iter(m.cross_edges(placement)))
    src, dst = placement[a], placement[n]
    m2 = m.with_link_corrections({(src, dst): 2.0})
    assert m2.edge_seconds(a, n, src, dst) == pytest.approx(
        2.0 * m.edge_seconds(a, n, src, dst))
    # other links untouched
    others = [(p, c) for (p, c) in m.cross_edges(placement)
              if (placement[p], placement[c]) != (src, dst)]
    for (p, c) in others[:3]:
        assert m2.edge_seconds(p, c, placement[p], placement[c]) == \
            m.edge_seconds(p, c, placement[p], placement[c])


def test_fit_link_corrections_recovers_known_scale():
    cluster = network.homogeneous_lan(n=2, bandwidth_Bps=1e9, alpha=1e-3)
    sizes = [1e6, 4e6, 16e6]
    # the real link is 1.7x slower than the α–β fit believes
    measured = {(0, 1): [(s, 1.7 * cluster.comm_time(0, 1, s))
                         for s in sizes]}
    corr = fit_link_corrections(measured, cluster)
    assert corr[(0, 1)] == pytest.approx(1.7, rel=1e-9)
    # clamped against pathological samples
    wild = {(0, 1): [(s, 1e3 * cluster.comm_time(0, 1, s)) for s in sizes]}
    assert fit_link_corrections(wild, cluster)[(0, 1)] == 4.0


# ------------------------------------------- estimator/executor parity -----
@pytest.mark.parametrize("ratio", [None, 100.0])
def test_simulated_comm_seconds_pin_to_model_prediction(ratio):
    """Acceptance: the executor's per-node simulated comm seconds equal the
    estimator's prediction exactly — both read EdgeCostModel, so the old
    drift between the smooth 3/r estimate and the integer wire encoding is
    structurally gone (dense AND compressed)."""
    g, prof, cluster, sch, plan = _setup(ratio=ratio)
    n_micro = 2
    sink = TelemetrySink()
    simulate_iteration(g, prof, sch, cluster, plan, n_micro=n_micro,
                       telemetry=sink)
    obs_comm: dict = {}
    for s in sink.samples:
        obs_comm[s.node] = obs_comm.get(s.node, 0.0) + s.comm_seconds
    model = EdgeCostModel(g, prof, cluster, plan)
    pred = predict_step_time_components(g, prof, cluster, sch.placement,
                                        cost_model=model)
    for node, (comp, recv) in pred.items():
        assert obs_comm.get(node, 0.0) / n_micro == pytest.approx(
            recv, rel=1e-9, abs=1e-15), node


def test_simulated_comm_bytes_pin_to_model(ratio=100.0):
    g, prof, cluster, sch, plan = _setup(ratio=ratio)
    n_micro = 3
    sim = simulate_iteration(g, prof, sch, cluster, plan, n_micro=n_micro)
    model = EdgeCostModel(g, prof, cluster, plan)
    placement = sch.placement
    expect = sum(model.edge_wire_bytes(a, n)
                 for (a, n) in model.cross_edges(placement)
                 if g.nodes[a].op_type not in (OpType.PLACEHOLDER,
                                               OpType.VARIABLE))
    assert sim.comm_bytes == pytest.approx(2 * n_micro * expect)  # FP + BP


def test_stage_pace_matches_dp_objective():
    """The model's derived stage view reproduces the DP's predicted pace on
    the schedule the DP itself produced (chain graph: boundary edges are the
    only cross-stage edges, so the two views coincide)."""
    g, prof, cluster, sch, _ = _setup()
    m = EdgeCostModel(g, prof, cluster)
    assert m.stage_pace(sch) == pytest.approx(sch.predicted_pace, rel=1e-9)


def test_uniform_plan_model_monotone_in_ratio():
    g, prof, cluster, sch, _ = _setup()
    placement = sch.placement
    m100 = EdgeCostModel(g, prof, cluster,
                         plan_uniform(g, placement, 100.0))
    m1000 = EdgeCostModel(g, prof, cluster,
                          plan_uniform(g, placement, 1000.0))
    for (a, n) in m100.cross_edges(placement):
        assert m1000.edge_wire_bytes(a, n) <= m100.edge_wire_bytes(a, n)
