"""Shared test fixtures: a small OP-DAG MLP chain (stand-in for a model)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.opgraph import OpGraph, OpNode, OpType


def linear_node(name, arg, din, dout):
    def init(rng, in_shape):
        return {"w": jax.random.normal(rng, (din, dout)) * (din ** -0.5),
                "b": jnp.zeros(dout)}

    def apply(p, x):
        return jnp.tanh(x @ p["w"] + p["b"])

    return OpNode(name=name, op_type=OpType.PARAMETRIC, args=(arg,),
                  init_fn=init, apply_fn=apply,
                  out_shape_fn=lambda s: (s[0], dout),
                  flops_fn=lambda s: 2.0 * s[0] * din * dout,
                  n_params_fn=lambda s: din * dout + dout)


def mlp_chain(n_layers=6, d=16, batch=4, seed=0):
    g = OpGraph("mlp")
    g.add(OpNode("x", OpType.PLACEHOLDER))
    prev = "x"
    for i in range(n_layers):
        g.add(linear_node(f"l{i}", prev, d, d))
        prev = f"l{i}"
    g.add(OpNode("y", OpType.PLACEHOLDER))
    g.add(OpNode("loss", OpType.LOSS, args=(prev, "y"),
                 apply_fn=lambda p, a, b: jnp.mean((a - b) ** 2),
                 out_shape_fn=lambda *s: (),
                 flops_fn=lambda *s: float(np.prod(s[0]))))
    shapes = {"x": (batch, d), "y": (batch, d)}
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    params = g.init(k1, shapes)
    inputs = {"x": jax.random.normal(k2, (batch, d)),
              "y": jax.random.normal(k3, (batch, d))}
    return g, shapes, params, inputs
