"""Observability layer: span recorder determinism, Perfetto export schema,
JSONL round-trips, the telemetry bus parity guarantee, metrics registry,
structured logging, the run report, and the flight-recorder acceptance on
the closed-loop slowlink scenario — including the pinned invariant that
tracing never changes simulated numerics."""
import dataclasses
import json

import numpy as np
import pytest

from repro.core import network
from repro.core.executor import (LinkTiming, StepTiming, TelemetrySink,
                                 simulate_iteration)
from repro.core.scheduler import schedule_opfence
from repro.elastic import ChurnEvent, ChurnTrace, ElasticController, TelemetryLog
from repro.obs import (FlightRecorder, MetricsRegistry, MetricsTelemetrySink,
                       TelemetryBus, TraceRecorder)
from repro.obs import export as obs_export
from repro.obs import report as obs_report
from repro.obs import slog
from repro.obs.record import CandidateScore, ReplanRecord, links_to_str
from repro.obs.trace import (CAT_BWD, CAT_DECODE, CAT_FWD, CAT_TRANSFER,
                             CLOCK_SIM, CLOCK_WALL)
from helpers import mlp_chain


def _sim_setup(n_layers=6, d=32, batch=4):
    g, shapes, params, inputs = mlp_chain(n_layers=n_layers, d=d, batch=batch)
    prof = g.annotate(shapes)
    cluster = network.homogeneous_lan(n=4)
    sch = schedule_opfence(g, prof, cluster)
    return g, prof, cluster, sch


# ----------------------------------------------------------- trace recorder
def test_sim_span_ordering_deterministic():
    """Two identical simulations produce byte-identical event lists: events()
    sorts by (clock, ts, seq) and sim seq numbers are assigned in the same
    deterministic program order."""
    g, prof, cluster, sch = _sim_setup()
    lists = []
    for _ in range(2):
        tr = TraceRecorder()
        simulate_iteration(g, prof, sch, cluster, n_micro=2, trace=tr)
        lists.append(tr.events())
    assert lists[0] == lists[1]
    assert lists[0], "simulation emitted no spans"
    # sorted by ts within the sim clock, ties broken by seq
    sim = [e for e in lists[0] if e.clock == CLOCK_SIM]
    keys = [(e.ts, e.seq) for e in sim]
    assert keys == sorted(keys)
    cats = {e.cat for e in sim}
    assert {CAT_FWD, CAT_BWD, CAT_TRANSFER} <= cats


def test_recorder_disabled_is_noop_and_ring_bounds():
    off = TraceRecorder(enabled=False)
    off.span(CAT_FWD, "F0", "dev0", 0.0, 1.0)
    off.instant(CAT_DECODE, "x", "dev0", t=0.5)
    with off.region(CAT_FWD, "r", "dev0"):
        pass
    assert off.events() == []
    assert off.n_dropped == 0
    ring = TraceRecorder(capacity=4)
    for i in range(10):
        ring.span(CAT_FWD, f"F{i}", "dev0", float(i), float(i) + 0.5)
    evs = ring.events()
    assert len(evs) == 4
    assert ring.n_dropped == 6
    assert [e.name for e in evs] == ["F6", "F7", "F8", "F9"]


def test_traced_simulation_bit_identical_to_untraced():
    """Tracing is observation only: every SimResult field is equal (==, not
    approx) with the recorder on, off, or absent."""
    g, prof, cluster, sch = _sim_setup()
    base = simulate_iteration(g, prof, sch, cluster, n_micro=3)
    traced = simulate_iteration(g, prof, sch, cluster, n_micro=3,
                                trace=TraceRecorder())
    disabled = simulate_iteration(g, prof, sch, cluster, n_micro=3,
                                  trace=TraceRecorder(enabled=False))
    for other in (traced, disabled):
        assert dataclasses.asdict(other) == dataclasses.asdict(base)


def test_replay_shifts_and_stamps():
    tr = TraceRecorder()
    tr.span(CAT_FWD, "F0", "dev0", 0.0, 1.0, args={"stage": 0})
    cached = tuple(tr.events())
    sink = TraceRecorder()
    sink.replay(cached, dt=10.0, extra_args={"step": 7})
    sink.replay(cached, dt=20.0, extra_args={"step": 8})
    evs = sink.events()
    assert [e.ts for e in evs] == [10.0, 20.0]
    assert [e.args["step"] for e in evs] == [7, 8]
    assert all(e.args["stage"] == 0 for e in evs)


# ------------------------------------------------------------ export schema
def test_chrome_trace_schema_valid_and_violations_caught():
    g, prof, cluster, sch = _sim_setup()
    tr = TraceRecorder()
    simulate_iteration(g, prof, sch, cluster, n_micro=2, trace=tr)
    tr.instant(CAT_DECODE, "decode", "dev0", t=0.0, clock=CLOCK_SIM)
    out = obs_export.to_trace_events(tr)
    assert obs_export.validate_trace_events(out) == []
    # every emitted record satisfies the trace_event contract directly
    for ev in out:
        assert ev["ph"] in ("X", "i", "M")
        if ev["ph"] == "M":
            continue
        assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
        assert isinstance(ev["ts"], (int, float)) and ev["ts"] >= 0.0
        if ev["ph"] == "X":
            assert ev["dur"] >= 0.0
    # sim and wall clocks export as distinct Perfetto processes
    pids = {ev["pid"] for ev in out if ev["ph"] != "M"}
    assert len(pids) >= 1
    # corruptions are reported, not silently passed
    bad = [dict(ev) for ev in out]
    bad[0] = {"name": "broken"}                       # missing ph/pid/tid/ts
    assert obs_export.validate_trace_events(bad)
    assert obs_export.validate_trace_events([]) != []  # empty trace = broken


def test_jsonl_round_trip_lossless(tmp_path):
    g, prof, cluster, sch = _sim_setup()
    tr = TraceRecorder()
    simulate_iteration(g, prof, sch, cluster, n_micro=2, trace=tr)
    path = str(tmp_path / "trace.jsonl")
    n = obs_export.write_jsonl(tr, path)
    back = obs_export.events_from_dicts(obs_export.read_jsonl(path))
    assert n == len(tr.events())
    assert back == tr.events()
    # chrome export of the round-tripped events still validates
    chrome = str(tmp_path / "trace.json")
    obs_export.write_chrome_trace(back, chrome)
    assert obs_export.validate_trace_events(
        obs_export.load_trace_file(chrome)) == []
    assert obs_export.main(["--validate", chrome]) == 0


# ------------------------------------------------------------- bus parity
def test_telemetry_bus_parity_with_direct_feed():
    """A TelemetryLog fed through the bus equals one fed directly, bit for
    bit — subscribing the log to the bus cannot perturb the closed loop."""
    g, prof, cluster, sch = _sim_setup()
    sink = TelemetrySink()
    simulate_iteration(g, prof, sch, cluster, n_micro=2, telemetry=sink)
    direct = TelemetryLog(window=5)
    bused = TelemetryLog(window=5)
    bus = TelemetryBus([bused])
    for step in range(4):
        direct.record_step(sink.samples, step)
        direct.record_link_step(sink.link_samples, step)
        bus.record_step(sink.samples, step)
        bus.record_link_step(sink.link_samples, step)
    assert bused.node_step_times() == direct.node_step_times()
    assert bused.link_samples(min_steps=3) == direct.link_samples(min_steps=3)
    assert bused.n_samples == direct.n_samples
    assert bused.latest_step() == direct.latest_step() == 3


def test_bus_fans_out_to_metrics_sink():
    metrics = MetricsRegistry()
    bus = TelemetryBus([MetricsTelemetrySink(metrics)])
    bus.record(StepTiming(node=3, stage=0, micro_batch=0, backward=False,
                          compute_seconds=0.5, comm_seconds=0.25, step=0))
    bus.record_link(LinkTiming(src=0, dst=1, nbytes=1e6, seconds=0.125,
                               step=0))
    snap = metrics.snapshot()
    assert snap["stage_compute_seconds{node=3}"] == pytest.approx(0.5)
    assert snap["stage_comm_seconds{node=3}"] == pytest.approx(0.25)
    assert snap["wire_bytes{link=0->1}"] == pytest.approx(1e6)
    assert snap["link_seconds{link=0->1}"] == pytest.approx(0.125)


# ---------------------------------------------------------------- metrics
def test_metrics_registry_counters_gauges_histograms():
    m = MetricsRegistry()
    m.counter("steps").inc()
    m.counter("steps").inc(2)
    m.gauge("pace", plan="joint").set(1.5)
    h = m.histogram("step_seconds")
    for v in (1.0, 2.0, 4.0):
        h.observe(v)
    assert m.counter("steps").value == 3
    assert h.count == 3 and h.total == pytest.approx(7.0)
    assert h.min == 1.0 and h.max == 4.0 and h.mean == pytest.approx(7 / 3)
    snap = m.snapshot()
    assert snap["steps"] == 3
    assert snap["pace{plan=joint}"] == 1.5
    # same (name, labels) -> same instrument instance
    assert m.counter("steps") is m.counter("steps")
    assert m.gauge("pace", plan="joint") is not m.gauge("pace", plan="full")


# ------------------------------------------------------- structured logging
def test_structured_logging_levels_and_metric_mirror(capsys):
    metrics = MetricsRegistry()
    slog.configure("info")
    log = slog.get_logger("test", metrics=metrics)
    log.debug("hidden", x=1)
    log.event("step_done", seconds=0.5, mode="joint")
    err = capsys.readouterr().err
    assert "hidden" not in err
    assert "step_done" in err and "seconds=0.5" in err and "mode=joint" in err
    assert metrics.snapshot()["step_done{field=seconds}"] == 0.5
    slog.configure("quiet")
    log.event("silenced", y=2)
    assert "silenced" not in capsys.readouterr().err
    log.warn("still_shown")
    assert "still_shown" in capsys.readouterr().err
    slog.configure("info")


def test_logging_args_round_trip():
    import argparse
    ap = argparse.ArgumentParser()
    slog.add_logging_args(ap)
    assert slog.level_from_args(ap.parse_args([])) == "info"
    assert slog.level_from_args(ap.parse_args(["--quiet"])) == "warning"
    assert slog.level_from_args(
        ap.parse_args(["--log-level", "debug"])) == "debug"


# ------------------------------------------------------------------ report
def test_overlap_fraction_interval_math():
    tr = TraceRecorder()
    tr.span(CAT_FWD, "F0", "dev0", 0.0, 2.0)
    tr.span(CAT_TRANSFER, "x0", "link 0->1", 1.0, 3.0)   # 1s of 2s hidden
    assert obs_report.overlap_fraction(tr.events()) == pytest.approx(0.5)
    empty = TraceRecorder()
    empty.span(CAT_FWD, "F0", "dev0", 0.0, 1.0)
    assert obs_report.overlap_fraction(empty.events()) is None


def test_report_renders_from_jsonl_round_trip(tmp_path):
    g, prof, cluster, sch = _sim_setup()
    tr = TraceRecorder()
    sim = simulate_iteration(g, prof, sch, cluster, n_micro=2,
                             trace=TraceRecorder())
    per_step = TraceRecorder()
    simulate_iteration(g, prof, sch, cluster, n_micro=2, trace=per_step)
    for step in range(3):
        tr.replay(tuple(per_step.events()), dt=step * sim.iteration_time,
                  extra_args={"step": step})
    trace_path = str(tmp_path / "t.jsonl")
    obs_export.write_jsonl(tr, trace_path)
    flight = FlightRecorder()
    flight.log(ReplanRecord(
        step=2, clock=1.0, cause="straggler", reason="detector flagged",
        dead=[], joined=[],
        candidates=[CandidateScore("keep", 1.0, 0.0, 0.0, 30.0),
                    CandidateScore("full", 0.8, 2e6, 1.0, 25.0, winner=True)],
        winner="full"))
    flight_path = str(tmp_path / "f.jsonl")
    flight.to_jsonl(flight_path)
    events = obs_export.events_from_dicts(obs_export.read_jsonl(trace_path))
    from repro.obs.record import read_jsonl as read_flight
    rep = obs_report.build_report(events, read_flight(flight_path), width=60)
    assert "comm/compute overlap" in rep
    assert "% of wire seconds overlapped" in rep
    assert "straggler heatmap" in rep and "steps 0..2" in rep
    assert "cause=straggler" in rep and "full*" in rep and "keep(" in rep
    tracks, steps, matrix = obs_report.straggler_matrix(events)
    assert steps == [0, 1, 2]
    assert len(tracks) == len(sch.stage_devices())
    # CLI wrapper over the same pure renderers
    assert obs_report.main([trace_path, "--flight", flight_path,
                            "--width", "60"]) == 0


def test_links_to_str_keys():
    assert links_to_str({(0, 1): 2.0, (3, 2): 1.0}) \
        == {"0->1": 2.0, "3->2": 1.0}


# ------------------------------------------- flight recorder closed loop --
@pytest.fixture(scope="module")
def slowlink_runs():
    """The closed-loop slowlink scenario (see test_closed_loop) run twice:
    once fully instrumented, once bare — shared by the acceptance asserts."""
    from test_closed_loop import _fat_pipe_victim, _setup
    g, prof, cluster = _setup()
    common = dict(n_micro=2, planner="joint", joint_ratio=64.0,
                  detector_threshold=20.0, calibrate_min_samples=3,
                  replan_pace_margin=0.2, calibrate_interval=3)
    probe = ElasticController(g, prof, cluster, ChurnTrace(()), **common)
    t1 = probe.run(steps=1).steps[0].step_seconds
    victim = _fat_pipe_victim(probe, cluster)
    churn = ChurnTrace((ChurnEvent(time=4.0 * t1, kind="slowlink",
                                   node=victim, factor=0.5),))
    tracer, flight, metrics = (TraceRecorder(), FlightRecorder(),
                               MetricsRegistry())
    ctrl = ElasticController(g, prof, cluster, churn, tracer=tracer,
                             flight=flight, metrics=metrics, **common)
    res = ctrl.run(steps=30)
    bare = ElasticController(g, prof, cluster, churn, **common)
    bare_res = bare.run(steps=30)
    return dict(ctrl=ctrl, res=res, tracer=tracer, flight=flight,
                metrics=metrics, bare=bare, bare_res=bare_res)


def test_tracing_does_not_change_sim_metrics(slowlink_runs):
    """Acceptance: the instrumented run is bit-identical in simulated
    metrics to the uninstrumented one."""
    res, bare = slowlink_runs["res"], slowlink_runs["bare_res"]
    assert [s.step_seconds for s in res.steps] \
        == [s.step_seconds for s in bare.steps]
    assert [s.clock for s in res.steps] == [s.clock for s in bare.steps]
    assert [e.cause for e in res.epochs] == [e.cause for e in bare.epochs]
    assert res.total_seconds == bare.total_seconds
    assert slowlink_runs["ctrl"].link_corrections \
        == slowlink_runs["bare"].link_corrections


def test_flight_recorder_explains_slowlink_recovery(slowlink_runs):
    """The decision log alone reconstructs the recovery: the ≈2.0 fit with
    'adopted' verdicts, the calibration re-plan trigger, and the candidate
    scores (keep included) that picked the installed winner."""
    flight, res = slowlink_runs["flight"], slowlink_runs["res"]
    cals = flight.records("calibration")
    assert cals, "no calibration records"
    adopted = [c for c in cals if "adopted" in c.verdicts.values()]
    assert adopted, "no adopted correction in the log"
    for c in adopted:
        for link, verdict in c.verdicts.items():
            if verdict == "adopted":
                assert c.fitted[link] == pytest.approx(2.0, rel=0.15)
                assert c.installed[link] == pytest.approx(2.0, rel=0.15)
    trigger = [c for c in cals if c.diverged]
    assert trigger, "no calibration record flagged pace divergence"
    assert trigger[0].calibrated_pace > trigger[0].installed_pace
    replans = flight.records("replan")
    cal_rp = [r for r in replans if r.cause == "calibration"]
    assert cal_rp, "no calibration re-plan recorded"
    rp = cal_rp[0]
    assert "diverged" in rp.reason
    names = [c.name for c in rp.candidates]
    assert "keep" in names and len(names) >= 2
    winners = [c for c in rp.candidates if c.winner]
    assert len(winners) == 1 and winners[0].name == rp.winner
    assert winners[0].score == min(c.score for c in rp.candidates)
    assert rp.winner in [e.replan_mode for e in flight.records("epoch")] \
        or rp.plan_only
    assert "calibration" in [e.cause for e in res.epochs]
    # the log round-trips and renders
    assert "adopted" in obs_report.render_flight(flight.to_dicts())


def test_controller_trace_is_schema_valid_and_stamped(slowlink_runs):
    tracer = slowlink_runs["tracer"]
    out = obs_export.to_trace_events(tracer)
    assert obs_export.validate_trace_events(out) == []
    evs = tracer.events()
    steps = {e.args.get("step") for e in evs
             if e.clock == CLOCK_SIM and e.phase == "X"
             and e.cat in (CAT_FWD, CAT_BWD)}
    assert len(steps) > 10, "per-step replay did not stamp compute spans"
    assert any(e.cat == "controller" and e.name.startswith("replan:")
               for e in evs)
    assert any(e.cat == "controller" and e.name == "calibration"
               for e in evs)
    ov = obs_report.overlap_fraction(evs)
    assert ov is not None and 0.0 <= ov <= 1.0


def test_controller_metrics_snapshot(slowlink_runs):
    snap = slowlink_runs["metrics"].snapshot()
    assert snap.get("replan_count{cause=initial}") == 1
    assert snap.get("replan_count{cause=calibration}", 0) >= 1
    assert snap.get("calibration_fits", 0) >= 1
    assert any(k.startswith("link_correction{") for k in snap)
    assert any(k.startswith("stage_compute_seconds{") for k in snap)
    hist = slowlink_runs["metrics"].histogram("step_seconds")
    assert hist.count == len(slowlink_runs["res"].steps)
