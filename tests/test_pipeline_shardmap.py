"""shard_map pipeline (paper runtime on a pod): correctness requires >1
device, so the check runs in a subprocess with forced host devices (the
main pytest process must keep seeing 1 device)."""
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from repro.configs import resolve
    from repro.distributed.pipeline import (make_pipeline_train_fn,
                                            microbatch, pod_edge_ratios)
    from repro.models import causal_lm

    mesh = jax.make_mesh((2, 4), ("pod", "model"))
    cfg = resolve("gpt2-xl").smoke.replace(n_layers=8, max_seq=32)
    params = causal_lm.init(cfg, jax.random.PRNGKey(0))
    B, S, n_micro = 8, 32, 4
    rng = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab)}
    mb = microbatch(batch, n_micro)
    ref, _ = causal_lm.train_loss(cfg, params, batch)
    loss = jax.jit(make_pipeline_train_fn(cfg, mesh, n_micro, 1.0))(params, mb)
    assert abs(float(loss) - float(ref)) < 2e-2, (float(loss), float(ref))
    # Eq.7 ratios: only the stage-3->4 edge (pod crossing) compresses
    r = pod_edge_ratios(mesh, 10.0)
    assert r[3] == 30.0 and all(x == 1.0 for i, x in enumerate(r) if i != 3)
    # grads flow through the compressed pipeline
    lc = make_pipeline_train_fn(cfg, mesh, n_micro, base_ratio=10.0)
    g = jax.grad(lambda p: lc(p, mb))(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in
             jax.tree_util.tree_leaves(g))
    assert gn > 0 and jnp.isfinite(jnp.asarray(gn))
    print("PIPELINE_OK")
""")


@pytest.mark.slow
def test_pipeline_matches_single_device_and_compresses():
    res = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, timeout=560,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "PIPELINE_OK" in res.stdout, res.stdout + res.stderr
