"""Checkpointing round-trips; synthetic data is actually learnable."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_checkpoint, load_checkpoint, \
    save_checkpoint
from repro.data import SyntheticImages, SyntheticLM, SyntheticSeq2Seq


def test_checkpoint_roundtrip(tmp_path):
    params = {"a": {"w": jnp.arange(6.0).reshape(2, 3)},
              "blocks": {"k": jnp.ones((4, 2), jnp.bfloat16)}}
    opt = {"m": jnp.zeros((2, 3)), "step": jnp.int32(7)}
    p = save_checkpoint(str(tmp_path), 42, params, opt, {"arch": "test"})
    assert os.path.exists(p)
    p2, o2, meta = load_checkpoint(p, params, opt)
    np.testing.assert_array_equal(np.asarray(p2["a"]["w"]),
                                  np.asarray(params["a"]["w"]))
    assert p2["blocks"]["k"].dtype == jnp.bfloat16
    assert meta["step"] == 42 and meta["arch"] == "test"
    assert latest_checkpoint(str(tmp_path)) == p


def test_checkpoint_shape_mismatch_raises(tmp_path):
    params = {"w": jnp.ones((2, 2))}
    p = save_checkpoint(str(tmp_path), 0, params)
    with pytest.raises(ValueError, match="shape"):
        load_checkpoint(p, {"w": jnp.ones((3, 3))})


def test_synthetic_lm_is_markov_learnable():
    ds = SyntheticLM(vocab=32, seq_len=64, seed=0, noise=0.1)
    b = ds.batch(16, step=0)
    assert b["tokens"].shape == (16, 64) and b["labels"].shape == (16, 64)
    # the oracle (transition table) predicts ~90% of labels — far above chance
    toks, labels = b["tokens"], b["labels"]
    pred = ds.table[toks[:, :-1], toks[:, 1:]]
    acc = float(np.mean(pred == labels[:, 1:]))
    assert acc > 0.8
    # different steps give different data
    b2 = ds.batch(16, step=1)
    assert not np.array_equal(b["tokens"], b2["tokens"])


def test_synthetic_images_separable():
    ds = SyntheticImages(n_classes=4, hw=8, seed=0, noise=0.3)
    b = ds.batch(64, 0)
    # nearest-template classification recovers labels
    flat = b["images"].reshape(64, -1)
    temps = ds.templates.reshape(4, -1)
    pred = np.argmin(((flat[:, None] - temps[None]) ** 2).sum(-1), axis=1)
    assert (pred == b["labels"]).mean() > 0.95


def test_synthetic_seq2seq_shapes():
    ds = SyntheticSeq2Seq(vocab=50, src_len=16, tgt_len=32, d_frontend=8)
    b = ds.batch(4, 0)
    assert b["src_embeds"].shape == (4, 16, 8)
    assert b["tokens"].shape == (4, 32) and b["labels"].shape == (4, 32)
