"""The compression-pricing loop: KernelTiming telemetry -> fit_kernel_costs
-> EdgeCostModel.compress_seconds -> planner profitability guard / checker
invariant / simulated codec stream / controller calibration.

The §6 premise under test: compression must outrun the bandwidth it buys
back.  A plan whose fused-encode seconds exceed the wire seconds saved is
rejected at every layer — plan_adatopk skips the edge, ``repro.check``
flags a surviving one, and the simulator prices the codec span so the
throughput numbers say the same thing."""
import numpy as np
import pytest

from repro.core import (EdgeCostModel, network, plan_adatopk,
                        schedule_opfence, simulate_iteration)
from repro.core.compression import CompressionPlan
from repro.core.costmodel import KernelCostModel, fit_kernel_costs
from repro.core.executor import KernelTiming, TelemetrySink
from repro.check.costs import (check_compression_plan, check_cost_model,
                               verify_plan)
from repro.check.errors import CompressionCheckError
from repro.check.lint import lint_source
from repro.elastic import (ChurnTrace, ElasticController, TelemetryLog)
from helpers import mlp_chain


def _setup(n_layers=12, d=64, batch=8):
    g, shapes, params, inputs = mlp_chain(n_layers=n_layers, d=d, batch=batch)
    prof = g.annotate(shapes)
    cluster = network.paper_testbed(1, seed=0)
    sch = schedule_opfence(g, prof, cluster)
    return g, prof, cluster, sch


def _all_devices(cluster):
    return range(len(cluster.devices))


# ------------------------------------------------------------ fitting ----
def test_fit_kernel_costs_recovers_throughput():
    bps = 2.0e9
    window = {0: [(b, b / bps) for b in (1e6, 4e6, 16e6)]}
    fit = fit_kernel_costs(window)
    assert fit[0].bytes_per_second == pytest.approx(bps, rel=1e-12)
    assert fit[0].alpha == 0.0
    # degenerate devices are skipped, never priced as garbage
    assert fit_kernel_costs({1: [(0.0, 1.0)]}) == {}
    assert fit_kernel_costs({2: [(1e6, 0.0)]}) == {}


def test_kernel_cost_model_seconds():
    kc = KernelCostModel(alpha=1e-4, bytes_per_second=1e9)
    assert kc.seconds(1e9) == pytest.approx(1.0 + 1e-4)
    free = KernelCostModel()      # legacy default: compression is free
    assert free.seconds(1e12) == 0.0


# ---------------------------------------------------------- telemetry ----
def test_telemetry_log_windows_kernel_samples():
    log = TelemetryLog(window=5, mad_k=3.5)
    bps = 1.0e9
    for step in range(4):
        # two invocations per step fold into one per-step entry
        log.record_kernel_step(
            [KernelTiming(node=0, nbytes=1e6, seconds=1e6 / bps),
             KernelTiming(node=0, nbytes=3e6, seconds=3e6 / bps)],
            step=step)
    win = log.kernel_samples(min_steps=3)
    assert set(win) == {0}
    fit = fit_kernel_costs(win)
    assert fit[0].bytes_per_second == pytest.approx(bps, rel=1e-9)
    # below min_steps the device is withheld entirely
    log2 = TelemetryLog(window=5)
    log2.record_kernel_step([KernelTiming(node=1, nbytes=1e6,
                                          seconds=1e-3)], step=0)
    assert log2.kernel_samples(min_steps=3) == {}
    log2.clear()
    assert log2.n_kernel_samples == 0


def test_kernel_window_mad_rejects_spike():
    log = TelemetryLog(window=8, mad_k=3.5)
    bps = 1.0e9
    for step in range(7):
        log.record_kernel_step([KernelTiming(node=0, nbytes=1e6,
                                             seconds=1e6 / bps)], step=step)
    # one 100x-pace GC hiccup must not tilt the fit
    log.record_kernel_step([KernelTiming(node=0, nbytes=1e6,
                                         seconds=100e6 / bps)], step=7)
    fit = fit_kernel_costs(log.kernel_samples(min_steps=3))
    assert fit[0].bytes_per_second == pytest.approx(bps, rel=1e-6)


# ----------------------------------------------------------- pricing ----
def test_compress_seconds_zero_without_plan_or_costs():
    g, prof, cluster, sch = _setup()
    placement = sch.placement
    plan = plan_adatopk(g, prof, cluster, placement, 100.0)
    kcs = {d: KernelCostModel(bytes_per_second=1e9)
           for d in _all_devices(cluster)}
    dense_m = EdgeCostModel(g, prof, cluster, kernel_costs=kcs)
    no_kc_m = EdgeCostModel(g, prof, cluster, plan)
    priced = EdgeCostModel(g, prof, cluster, plan, kernel_costs=kcs)
    hits = 0
    for (a, n) in priced.cross_edges(placement):
        src = placement[a]
        assert dense_m.compress_seconds(a, n, src) == 0.0   # dense edge
        assert no_kc_m.compress_seconds(a, n, src) == 0.0   # legacy free
        got = priced.compress_seconds(a, n, src)
        if priced.ratio(a, n) > 1.0:
            hits += 1
            assert got == pytest.approx(
                kcs[src].seconds(priced.dense_bytes(a)), rel=1e-12)
    assert hits > 0


def test_stage_pace_includes_codec_stream():
    g, prof, cluster, sch = _setup()
    plan = plan_adatopk(g, prof, cluster, sch.placement, 100.0)
    base = EdgeCostModel(g, prof, cluster, plan)
    pace0 = base.stage_pace(sch)
    # a pathologically slow codec must dominate Eq. 3's max(C, R, E)
    slow = base.with_kernel_costs(
        {d: KernelCostModel(bytes_per_second=1.0)
         for d in _all_devices(cluster)})
    assert slow.stage_pace(sch) > 10.0 * pace0


# ----------------------------------------------- planner profitability ----
def test_plan_adatopk_drops_unprofitable_edges():
    g, prof, cluster, sch = _setup()
    placement = sch.placement
    free = plan_adatopk(g, prof, cluster, placement, 100.0)
    assert free.edge_ratio, "baseline plan compresses nothing"
    # codec slower than the wire: every edge fails §6's premise
    slow_m = EdgeCostModel(g, prof, cluster, kernel_costs={
        d: KernelCostModel(bytes_per_second=1.0)
        for d in _all_devices(cluster)})
    guarded = plan_adatopk(g, prof, cluster, placement, 100.0,
                           cost_model=slow_m)
    assert guarded.edge_ratio == {}
    # fast codec: the guard never fires, plan identical to the free one
    fast_m = EdgeCostModel(g, prof, cluster, kernel_costs={
        d: KernelCostModel(bytes_per_second=1e15)
        for d in _all_devices(cluster)})
    assert plan_adatopk(g, prof, cluster, placement, 100.0,
                        cost_model=fast_m).edge_ratio == free.edge_ratio


# ------------------------------------------------------- check gates ----
def test_check_rejects_unprofitable_plan():
    """Regression pin (ISSUE 8 acceptance): a plan whose encode cost
    exceeds the wire seconds saved must be rejected by repro.check."""
    g, prof, cluster, sch = _setup()
    placement = sch.placement
    plan = plan_adatopk(g, prof, cluster, placement, 100.0)
    assert plan.edge_ratio
    slow_m = EdgeCostModel(g, prof, cluster, kernel_costs={
        d: KernelCostModel(bytes_per_second=1.0)
        for d in _all_devices(cluster)})
    findings = check_compression_plan(g, prof, plan, placement,
                                      cost_model=slow_m)
    codes = {f.code for f in findings}
    assert "compression-unprofitable" in codes
    with pytest.raises(CompressionCheckError):
        verify_plan(g, prof, plan, placement=placement, cost_model=slow_m)
    # the installed-model view flags the same edges
    model_findings = check_cost_model(slow_m.with_plan(plan), placement)
    assert "compression-unprofitable" in {f.code for f in model_findings}
    # a profitable codec passes every gate
    fast_m = slow_m.with_kernel_costs(
        {d: KernelCostModel(bytes_per_second=1e15)
         for d in _all_devices(cluster)})
    assert verify_plan(g, prof, plan, placement=placement,
                       cost_model=fast_m) == []
    assert not [f for f in check_cost_model(fast_m.with_plan(plan),
                                            placement)
                if f.code == "compression-unprofitable"]


def test_check_flags_garbage_kernel_cost():
    g, prof, cluster, sch = _setup()
    bad = EdgeCostModel(g, prof, cluster, kernel_costs={
        0: KernelCostModel(alpha=float("nan"), bytes_per_second=1e9)})
    assert "bad-kernel-cost" in {
        f.code for f in check_cost_model(bad, sch.placement)}


# --------------------------------------------------------- simulation ----
def test_sim_codec_stream_emits_samples_and_busy():
    g, prof, cluster, sch = _setup()
    placement = sch.placement
    plan = plan_adatopk(g, prof, cluster, placement, 100.0)
    kcs = {d: KernelCostModel(bytes_per_second=5e8)
           for d in _all_devices(cluster)}
    model = EdgeCostModel(g, prof, cluster, plan, kernel_costs=kcs)
    sink = TelemetrySink()
    n_micro = 2
    res = simulate_iteration(g, prof, sch, cluster, plan, n_micro=n_micro,
                             telemetry=sink, cost_model=model)
    assert res.compress_busy > 0.0
    assert sink.kernel_samples
    # each sample prices exactly the model's compress_seconds for its edge
    per_dev = {}
    for s in sink.kernel_samples:
        assert s.seconds == pytest.approx(
            kcs[s.node].seconds(s.nbytes), rel=1e-12)
        per_dev[s.node] = per_dev.get(s.node, 0.0) + s.seconds
    assert res.compress_busy == pytest.approx(sum(per_dev.values()),
                                              rel=1e-12)
    # FP + BP, n_micro each, per compressed cross edge
    n_compressed = sum(1 for e in model.cross_edges(placement)
                       if model.ratio(*e) > 1.0)
    assert len(sink.kernel_samples) == 2 * n_micro * n_compressed
    # legacy model (no kernel costs): codec is free, no samples
    res0 = simulate_iteration(g, prof, sch, cluster, plan, n_micro=n_micro,
                              telemetry=TelemetrySink())
    assert res0.compress_busy == 0.0
    # the codec span sits on the step's critical path only via overlap:
    # a priced step is never faster, and never slower than fully serial
    assert res0.iteration_time <= res.iteration_time \
        <= res0.iteration_time + res.compress_busy + 1e-12


def test_sim_codec_span_double_buffers():
    """A moderately slow codec hides behind next-micro-batch compute (the
    overlap discount): iteration time grows by less than the full codec
    busy seconds."""
    g, prof, cluster, sch = _setup(n_layers=12, d=256)
    placement = sch.placement
    plan = plan_adatopk(g, prof, cluster, placement, 100.0)
    base = simulate_iteration(g, prof, sch, cluster, plan, n_micro=4)
    kcs = {d: KernelCostModel(bytes_per_second=2e10)
           for d in _all_devices(cluster)}
    model = EdgeCostModel(g, prof, cluster, plan, kernel_costs=kcs)
    res = simulate_iteration(g, prof, sch, cluster, plan, n_micro=4,
                             cost_model=model)
    assert res.compress_busy > 0.0
    delta = res.iteration_time - base.iteration_time
    assert delta < res.compress_busy      # some codec time was overlapped


# --------------------------------------------------- controller loop ----
def test_controller_calibrates_kernel_cost_belief():
    """Ground-truth kernel costs in the sim surface as KernelTiming
    telemetry; the controller's calibration fits them back into
    kernel_cost_belief and plans against the belief."""
    g, prof, cluster, sch = _setup()
    bps = 1.0e9
    kcs = {d: KernelCostModel(bytes_per_second=bps)
           for d in _all_devices(cluster)}
    ctrl = ElasticController(g, prof, cluster, ChurnTrace(()), n_micro=2,
                             planner="joint", joint_ratio=64.0,
                             calibrate_interval=3, calibrate_min_samples=3,
                             kernel_costs=kcs)
    assert ctrl.kernel_cost_belief == {}
    ctrl.run(steps=8)
    assert ctrl.kernel_cost_belief, "no kernel cost fitted"
    for dev, kc in ctrl.kernel_cost_belief.items():
        assert kc.bytes_per_second == pytest.approx(bps, rel=1e-6), dev
    believed = ctrl.believed_model()
    assert believed.kernel_costs == ctrl.kernel_cost_belief


# ---------------------------------------------------------------- lint ----
def test_lint_flags_kernel_dispatch_bypass():
    src = "def f(x, k):\n    return topk_mask(x, k)\n"
    hits = [f for f in lint_source(src, "core/rad.py")
            if f.code == "kernel-dispatch-bypass"]
    assert len(hits) == 1 and hits[0].where == "core/rad.py:2"
    # threading the policy through satisfies the rule
    ok = "def f(x, k, uk):\n    return topk_mask(x, k, use_kernel=uk)\n"
    assert not [f for f in lint_source(ok, "distributed/pipeline.py")
                if f.code == "kernel-dispatch-bypass"]
    # outside the hot-path scopes the rule does not apply
    assert not [f for f in lint_source(src, "core/compression.py")
                if f.code == "kernel-dispatch-bypass"]
