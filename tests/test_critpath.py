"""Critical-path analyzer, what-if re-pricing, and watchdog tests.

The heavyweight fixture mirrors ``benchmarks/churn.py::closed_loop``
(fat-pipe topology, slowlink degradation, calibrated joint controller) and
pins the PR's acceptance story end to end:

* the degradation-window blame table names the degraded link as the top
  bottleneck;
* the what-if engine's best link fix is the pair whose fitted correction
  the controller adopts at the calibration re-plan;
* what-if predictions land within 5% of ground-truth simulations;
* the watchdog trips steps *before* the controller re-plans;
* trace-derived busy accounting agrees with the controller's
  ``sim_*_busy_seconds`` counters (the CI attribution gate).
"""
import json
import math

import pytest

from repro.configs.base import ModelCfg
from repro.core import network
from repro.core.compression import plan_adatopk
from repro.core.costmodel import EdgeCostModel
from repro.core.executor import LinkTiming, StepTiming, simulate_iteration
from repro.core.network import with_link_slowdowns
from repro.core.scheduler import schedule_joint, schedule_opfence
from repro.elastic import ChurnEvent, ChurnTrace, ElasticController
from repro.models.opgraph_models import profile_opgraph
from repro.obs import (FlightRecorder, Histogram, MetricsRegistry,
                       TraceRecorder, Watchdog)
from repro.obs import critpath, whatif
from repro.obs import export as obs_export
from repro.obs import report as obs_report
from repro.obs.trace import (CAT_ENCODE, CAT_FWD, CAT_TRANSFER, CLOCK_SIM,
                             TraceEvent)
from helpers import mlp_chain


# ---------------------------------------------------------- hand-built DAG --
def _span(seq, cat, name, track, ts, dur, **args):
    return TraceEvent(seq=seq, clock=CLOCK_SIM, phase="X", cat=cat,
                      name=name, track=track, ts=ts, dur=dur,
                      args={"step": 0, "epoch": 0, **args})


def test_critpath_hand_built_chain():
    # compute -> encode -> transfer -> compute, back-to-back (no stalls)
    events = [
        _span(0, CAT_FWD, "F0.mb0", "dev0", 0.0, 1.0),
        _span(1, CAT_ENCODE, "Fenc.mb0", "codec0", 1.0, 0.5),
        _span(2, CAT_TRANSFER, "Fxfer.mb0", "link 0->1", 1.5, 1.0),
        _span(3, CAT_FWD, "F1.mb0", "dev1", 2.5, 1.5),
    ]
    decomps = critpath.analyze(events)
    assert len(decomps) == 1
    d = decomps[0]
    assert d.attempt == (0, 0)
    assert d.makespan == pytest.approx(4.0)
    assert d.compute == pytest.approx({"dev0": 1.0, "dev1": 1.5})
    assert d.codec == pytest.approx({"codec0": 0.5})
    assert d.wire == pytest.approx({"link 0->1": 1.0})
    assert d.stall == pytest.approx(0.0)
    assert d.total() == pytest.approx(d.makespan)
    # path is rendered in execution order
    assert [s.name for s in d.segments] == \
        ["F0.mb0", "Fenc.mb0", "Fxfer.mb0", "F1.mb0"]
    assert critpath.audit(decomps) == []


def test_critpath_stall_gap():
    # a gap no span covers becomes an explicit stall segment
    events = [
        _span(0, CAT_FWD, "F0.mb0", "dev0", 0.0, 1.0),
        _span(1, CAT_FWD, "F1.mb0", "dev1", 2.0, 1.0),
    ]
    d = critpath.analyze(events)[0]
    assert d.stall == pytest.approx(1.0)
    assert d.total() == pytest.approx(d.makespan) == pytest.approx(3.0)
    kinds = [s.kind for s in d.segments]
    assert kinds == [critpath.KIND_COMPUTE, critpath.KIND_STALL,
                     critpath.KIND_COMPUTE]


def test_critpath_prefers_causal_feed_over_tie():
    # two spans end exactly when the transfer starts; the causal producer
    # (same tag/mb, on the transfer's source device) must win the tie
    events = [
        _span(0, CAT_FWD, "F0.mb0", "dev0", 0.0, 1.0),
        _span(1, CAT_FWD, "F5.mb0", "dev5", 0.0, 1.0),   # bystander
        _span(2, CAT_TRANSFER, "Fxfer.mb0", "link 0->1", 1.0, 1.0),
        _span(3, CAT_FWD, "F1.mb0", "dev1", 2.0, 1.0),
    ]
    d = critpath.analyze(events)[0]
    assert "dev0" in d.compute and "dev5" not in d.compute


def test_blame_aggregation_shares():
    events = [
        _span(0, CAT_FWD, "F0.mb0", "dev0", 0.0, 1.0),
        _span(1, CAT_TRANSFER, "Fxfer.mb0", "link 0->1", 1.0, 3.0),
        _span(2, CAT_FWD, "F1.mb0", "dev1", 4.0, 1.0),
    ]
    rows = critpath.blame(critpath.analyze(events))
    assert rows[0].kind == "wire" and rows[0].track == "link 0->1"
    assert rows[0].share == pytest.approx(3.0 / 5.0)
    assert sum(r.share for r in rows) == pytest.approx(1.0)
    assert all(rows[i].crit_seconds >= rows[i + 1].crit_seconds
               for i in range(len(rows) - 1))


def test_sim_trace_decomposition_is_exact():
    # a real simulator trace decomposes with zero stall and busy totals
    # matching the SimResult's own accounting
    g, shapes, _, _ = mlp_chain(n_layers=6, d=16, batch=4)
    prof = g.annotate(shapes)
    cluster = network.homogeneous_lan(n=4)
    sch = schedule_opfence(g, prof, cluster)
    rec = TraceRecorder()
    sim = simulate_iteration(g, prof, sch, cluster, n_micro=4, trace=rec)
    events = list(rec.events())
    decomps = critpath.analyze(events)
    assert len(decomps) == 1
    d = decomps[0]
    assert d.makespan == pytest.approx(sim.iteration_time, rel=1e-9)
    assert critpath.audit(decomps) == []
    busy = critpath.busy_accounting(events)
    assert busy["compute"] == pytest.approx(sum(sim.device_busy), rel=1e-9)
    assert busy["wire"] == pytest.approx(sim.link_busy, rel=1e-9)
    totals = {"sim_device_busy_seconds": sum(sim.device_busy),
              "sim_link_busy_seconds": sim.link_busy,
              "sim_compress_busy_seconds": sim.compress_busy}
    assert critpath.check_sim_busy(busy, totals) == []


# ------------------------------------------------- closed-loop acceptance --
@pytest.fixture(scope="module")
def closed_loop():
    """The churn closed-loop scenario, calibrated controller only, with the
    full obs kit attached (14 steps: degradation at 4*t1, calibration
    re-plan around step 9)."""
    cfg = ModelCfg(name="gpt-churn-tiny", family="dense", n_layers=4,
                   d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                   vocab=128, rope_fraction=0.0, max_seq=64,
                   norm="layernorm", act="gelu")
    batch, seq = 2, 64
    cluster = network.fat_pipe_sites(n=8, n_sites=2, seed=0)
    graph = profile_opgraph(cfg, batch, seq)
    prof = graph.annotate({"tokens": (batch, seq), "labels": (batch, seq)})
    common = dict(n_micro=8, planner="joint", joint_ratio=16.0,
                  detector_threshold=20.0, calibrate_min_samples=3,
                  replan_pace_margin=0.2)
    probe = ElasticController(graph, prof, cluster, ChurnTrace(()),
                              calibrate_interval=0, **common)
    t1 = probe.run(steps=1).steps[0].step_seconds
    # victim selection identical to benchmarks/churn.py::closed_loop
    devs = probe.schedule.stage_devices()
    model = EdgeCostModel(graph, prof, cluster, probe.plan)
    placement = probe.schedule.placement
    boundary_s = {}
    for (a, n) in model.cross_edges(placement):
        key = (placement[a], placement[n])
        boundary_s[key] = boundary_s.get(key, 0.0) + \
            model.edge_seconds(a, n, *key)
    wan_bw = min(cluster.link(a, b).bandwidth for a, b in zip(devs, devs[1:]))
    adjacent = {d: [] for d in devs}
    for a, b in zip(devs, devs[1:]):
        adjacent[a].append((a, b))
        adjacent[b].append((a, b))
    eligible = [d for d in devs
                if all(cluster.link(i, j).bandwidth > 10.0 * wan_bw
                       for (i, j) in adjacent[d])]
    victim = max(eligible, key=lambda d: sum(boundary_s.get(p, 0.0)
                                             for p in adjacent[d]))
    t_deg = 4.0 * t1
    churn = ChurnTrace((ChurnEvent(time=t_deg, kind="slowlink",
                                   node=victim, factor=0.5),))
    kit = dict(tracer=TraceRecorder(), flight=FlightRecorder(),
               metrics=MetricsRegistry(), watchdog=Watchdog())
    ctrl = ElasticController(graph, prof, cluster, churn,
                             calibrate_interval=3, **kit, **common)
    res = ctrl.run(steps=14)
    replans = [r for r in kit["flight"].records("replan")
               if r.cause == "calibration"]
    assert replans, "the closed loop must re-plan on calibration"
    replan_step = replans[0].step
    # StepRecord.step is 1-based; the trace stamps 0-based data steps, so
    # attempt k corresponds to record step k+1 (the replan record already
    # speaks attempt numbering: the attempt at replan_step runs the new plan)
    first_deg = min(s.step for s in res.steps if s.clock > t_deg) - 1
    events = list(kit["tracer"].events())
    decomps = critpath.analyze(events)
    window = [d for d in decomps
              if d.attempt[0] is not None
              and first_deg <= d.attempt[0] < replan_step]
    assert window, "degradation window must contain analyzed attempts"
    return dict(graph=graph, prof=prof, cluster=cluster, victim=victim,
                t_deg=t_deg, ctrl=ctrl, res=res, kit=kit, events=events,
                decomps=decomps, window=window, replan_step=replan_step,
                first_deg=first_deg, n_micro=common["n_micro"],
                joint_ratio=common["joint_ratio"])


def test_trace_decompositions_match_step_times(closed_loop):
    by_step = {s.step - 1: s.step_seconds for s in closed_loop["res"].steps}
    for d in closed_loop["decomps"]:
        assert d.makespan == pytest.approx(by_step[d.attempt[0]], rel=1e-9)
        assert d.total() == pytest.approx(d.makespan, rel=1e-6)
    assert critpath.audit(closed_loop["decomps"]) == []


def test_blame_names_degraded_link(closed_loop):
    """Acceptance: in the degradation window the blame table's top row is a
    link adjacent to the slowlink victim."""
    rows = critpath.blame(closed_loop["window"])
    top = rows[0]
    assert top.kind == "wire"
    m = whatif._LINK_TRACK_RE.match(top.track)
    assert m, top.track
    pair = (int(m.group(1)), int(m.group(2)))
    assert closed_loop["victim"] in pair
    # the degraded pair dominates: on the path every window step, with a
    # larger share than any other single resource
    assert top.steps_on_path == top.n_steps == len(closed_loop["window"])
    assert top.share > rows[2].share * 2


def test_watchdog_fires_before_replan(closed_loop):
    """Acceptance: the symptom (watchdog trip) lands steps before the cure
    (the calibration re-plan)."""
    wd = closed_loop["kit"]["watchdog"]
    first = wd.first_trip()
    assert first is not None
    assert first.step < closed_loop["replan_step"]
    # the per-link detectors name a degraded wire, the same label the
    # calibrator corrects
    link_trip = wd.first_trip(signal_prefix="link ")
    assert link_trip is not None and link_trip.step < closed_loop["replan_step"]
    m = whatif._LINK_TRACK_RE.match(link_trip.signal)
    assert m and closed_loop["victim"] in (int(m.group(1)), int(m.group(2)))
    # trips reached flight log and metrics too
    kinds = [r.kind for r in closed_loop["kit"]["flight"].records("watchdog")]
    assert kinds and set(kinds) == {"watchdog"}
    snap = closed_loop["kit"]["metrics"].snapshot()
    assert any(k.startswith("watchdog_trips") and v > 0
               for k, v in snap.items())


def _degraded_scenario(cl):
    """The pre-replan window as a what-if Scenario: spec-planned joint
    schedule, degraded ground-truth cluster (lazy cost model)."""
    joint = schedule_joint(cl["graph"], cl["prof"], cl["cluster"],
                          cl["joint_ratio"])
    degraded = with_link_slowdowns(cl["cluster"], {cl["victim"]: 0.5})
    sc = whatif.Scenario(graph=cl["graph"], profiles=cl["prof"],
                         schedule=joint.schedule, cluster=degraded,
                         plan=joint.plan, n_micro=cl["n_micro"])
    return sc, joint


def test_scenario_reprices_recorded_window(closed_loop):
    # the Scenario reconstruction reproduces the recorded degraded step time
    sc, _ = _degraded_scenario(closed_loop)
    window_secs = [s.step_seconds for s in closed_loop["res"].steps
                   if closed_loop["first_deg"] <= s.step - 1
                   < closed_loop["replan_step"]]
    assert sc.price() == pytest.approx(window_secs[0], rel=1e-9)


def test_whatif_top_link_matches_adopted_replan(closed_loop):
    """Acceptance: the best link fix the what-if engine ranks is a pair the
    calibration re-plan actually adopted a correction for."""
    sc, _ = _degraded_scenario(closed_loop)
    rows = critpath.blame(closed_loop["window"])
    ranked = whatif.rank(sc, whatif.default_interventions(sc, rows))
    assert all(r.baseline_seconds == pytest.approx(sc.price(), rel=1e-9)
               for r in ranked)
    fitted = closed_loop["ctrl"].link_corrections
    assert fitted, "calibration must have adopted corrections"
    top_link = next(r for r in ranked if r.name.startswith("link "))
    # parse "link a->b 2x"
    a, b = top_link.name.split()[1].split("->")
    pair = (int(a), int(b))
    assert pair in fitted
    assert top_link.delta_seconds > 0
    # and it is the *heaviest* fitted pair (largest adopted correction)
    assert fitted[pair] == pytest.approx(max(fitted.values()))


def test_whatif_within_5pct_of_simulation(closed_loop):
    """Acceptance: what-if predictions within 5% of ground-truth sims on
    >= 3 scenarios."""
    cl = closed_loop
    sc, joint = _degraded_scenario(cl)
    spec_truth = whatif.Scenario(
        graph=cl["graph"], profiles=cl["prof"], schedule=joint.schedule,
        cluster=cl["cluster"], plan=joint.plan, n_micro=cl["n_micro"]).price()

    # 1. restore every link touching the victim (2x corrections) vs the
    #    ground-truth spec cluster: corrections scale alpha+beta while the
    #    degradation scaled beta only, hence the 5% budget
    pred = whatif.node_links_speedup(cl["victim"], 2.0).apply(sc).price()
    assert pred == pytest.approx(spec_truth, rel=0.05)

    # 2. restore only the victim's pipeline-adjacent directed pairs (the
    #    exact pairs calibration corrected); non-pipeline links carry no
    #    traffic, so spec-cluster simulation is still the ground truth
    restored = sc
    for (i, j) in cl["ctrl"].link_corrections:
        restored = whatif.link_speedup(i, j, 2.0).apply(restored)
    assert restored.price() == pytest.approx(spec_truth, rel=0.05)

    # 3. codec free: prediction must equal an independently built sim with
    #    the kernel costs stripped
    truth3 = simulate_iteration(
        cl["graph"], cl["prof"], sc.schedule, sc.cluster, plan=sc.plan,
        n_micro=sc.n_micro,
        cost_model=sc.model().with_kernel_costs({})).iteration_time
    assert whatif.codec_free().apply(sc).price() == \
        pytest.approx(truth3, rel=0.05)

    # 4. ratio change: prediction must equal a sim under an independently
    #    re-planned AdaTopK allocation at the new ratio
    new_ratio = 2.0 * cl["joint_ratio"]
    plan4 = plan_adatopk(cl["graph"], cl["prof"], sc.cluster,
                         sc.schedule.placement, new_ratio,
                         cost_model=sc.model().with_plan(None))
    truth4 = simulate_iteration(
        cl["graph"], cl["prof"], sc.schedule, sc.cluster, plan=plan4,
        n_micro=sc.n_micro,
        cost_model=sc.model().with_plan(plan4)).iteration_time
    assert whatif.ratio_change(new_ratio).apply(sc).price() == \
        pytest.approx(truth4, rel=0.05)


def test_trace_busy_matches_sim_counters(closed_loop):
    """The CI attribution gate: trace busy accounting vs the controller's
    streamed SimResult counters, 1% budget."""
    snap = closed_loop["kit"]["metrics"].snapshot()
    totals = {k: snap[k] for k in ("sim_device_busy_seconds",
                                   "sim_link_busy_seconds",
                                   "sim_compress_busy_seconds") if k in snap}
    assert "sim_device_busy_seconds" in totals
    busy = critpath.busy_accounting(closed_loop["events"])
    assert critpath.check_sim_busy(busy, totals, rel=0.01) == []


def test_report_renders_critpath_sections(closed_loop):
    text = obs_report.build_report(
        closed_loop["events"],
        [r.to_dict() for r in closed_loop["kit"]["flight"].records()])
    assert "== critical path ==" in text
    assert "== top interventions ==" in text
    assert "watchdog" in text


# ------------------------------------------------------------- watchdogs --
def test_watchdog_warmup_then_trip():
    wd = Watchdog()
    for i in range(8):
        wd.observe_step(i, float(i), 1.0)
    assert wd.records == []
    wd.observe_step(8, 8.0, 2.0)
    rules = {r.rule for r in wd.records}
    assert {"ewma", "mad"} <= rules
    assert wd.first_trip().signal == "step_seconds"


def test_watchdog_no_trip_during_warmup():
    wd = Watchdog(warmup=3)
    wd.observe_step(0, 0.0, 1.0)
    wd.observe_step(1, 1.0, 50.0)   # wild, but still warming up
    assert wd.records == []


def test_watchdog_holdoff_dedupes():
    wd = Watchdog(holdoff=8)
    for i in range(8):
        wd.observe_step(i, float(i), 1.0)
    for i in range(8, 13):
        wd.observe_step(i, float(i), 2.0)
    ewma_trips = [r for r in wd.records if r.rule == "ewma"]
    assert len(ewma_trips) == 1   # one incident, one record


def test_watchdog_step_slo_p99():
    wd = Watchdog(step_slo_p99=1.5)
    for i in range(5):
        wd.observe_step(i, float(i), 1.0)
    assert wd.first_trip(rule="slo") is None
    for i in range(5, 10):
        wd.observe_step(i, float(i), 2.0)
    slo = wd.first_trip(rule="slo")
    assert slo is not None and slo.signal == "step_seconds_p99"
    assert slo.value > 1.5 and slo.reference == pytest.approx(1.5)


def test_watchdog_tokens_floor():
    wd = Watchdog(tokens_floor=10.0)
    for i in range(3):
        wd.observe_tokens(i, float(i), 20.0)
    assert wd.first_trip(rule="slo") is None
    wd.observe_tokens(3, 3.0, 5.0)
    slo = wd.first_trip(rule="slo")
    assert slo is not None and slo.signal == "tokens_per_s"


def test_watchdog_bus_sink_protocol():
    wd = Watchdog()
    for step in range(8):
        wd.record(StepTiming(node=3, stage=0, micro_batch=0, backward=False,
                             compute_seconds=1.0, comm_seconds=0.0,
                             step=step))
        wd.record_link(LinkTiming(src=0, dst=1, nbytes=100.0, seconds=1e-3,
                                  step=step))
    wd.record(StepTiming(node=3, stage=0, micro_batch=0, backward=False,
                         compute_seconds=2.0, comm_seconds=0.0, step=8))
    wd.record_link(LinkTiming(src=0, dst=1, nbytes=100.0, seconds=2e-3,
                              step=8))
    signals = {r.signal for r in wd.records}
    assert "stage3_seconds" in signals
    assert "link 0->1" in signals


def test_watchdog_link_normalizes_per_byte():
    # doubled payload at the same bandwidth is NOT an anomaly
    wd = Watchdog()
    for step in range(8):
        wd.record_link(LinkTiming(src=0, dst=1, nbytes=100.0, seconds=1e-3,
                                  step=step))
    wd.record_link(LinkTiming(src=0, dst=1, nbytes=200.0, seconds=2e-3,
                              step=8))
    assert wd.records == []


# -------------------------------------------------- histogram percentile --
def test_histogram_percentile_error_bound():
    h = Histogram(base=1.01)
    values = [float(v) for v in range(1, 101)]
    for v in values:
        h.observe(v)
    for q in (50.0, 90.0, 99.0):
        true = sorted(values)[max(0, math.ceil(q / 100 * len(values)) - 1)]
        got = h.percentile(q)
        # documented bound: within one bucket factor above the truth
        assert true <= got <= true * 1.01 * 1.0001


def test_histogram_percentile_clamps_to_observed_range():
    h = Histogram(base=1.01)
    h.observe(5.0)
    h.observe(7.0)
    assert h.percentile(100.0) == pytest.approx(7.0)
    assert h.percentile(0.001) >= 5.0


def test_histogram_percentile_rejects_bad_input():
    h = Histogram()
    with pytest.raises(ValueError):
        h.percentile(50.0)          # empty
    h.observe(1.0)
    with pytest.raises(ValueError):
        h.percentile(0.0)
    with pytest.raises(ValueError):
        h.percentile(101.0)


# ---------------------------------------------------- report interval math --
def test_union_merges_and_drops_degenerate():
    u = obs_report._union([(3.0, 4.0), (0.0, 1.0), (2.0, 2.0), (0.5, 1.5)])
    assert u == [(0.0, 1.5), (3.0, 4.0)]
    # touching intervals merge
    assert obs_report._union([(0.0, 1.0), (1.0, 2.0)]) == [(0.0, 2.0)]
    assert obs_report._union([]) == []
    # zero-length only
    assert obs_report._union([(1.0, 1.0)]) == []


def test_intersect_edges():
    a = obs_report._union([(0.0, 2.0), (4.0, 6.0)])
    b = obs_report._union([(1.0, 5.0)])
    assert obs_report._intersect(a, b) == pytest.approx(2.0)
    # touching but disjoint
    assert obs_report._intersect([(0.0, 1.0)], [(1.0, 2.0)]) == \
        pytest.approx(0.0)
    assert obs_report._intersect([], [(0.0, 1.0)]) == pytest.approx(0.0)


def test_overlap_fraction_on_synthetic_trace():
    events = [
        _span(0, CAT_FWD, "F0.mb0", "dev0", 0.0, 2.0),
        _span(1, CAT_TRANSFER, "Fxfer.mb0", "link 0->1", 1.0, 2.0),
    ]
    # transfer [1,3], compute [0,2]: 1s of 2s wire time overlapped
    assert obs_report.overlap_fraction(events) == pytest.approx(0.5)
    assert obs_report.overlap_fraction(
        [_span(0, CAT_FWD, "F0.mb0", "dev0", 0.0, 1.0)]) is None


# -------------------------------------------------- truncation surfacing --
def _overflowed_recorder():
    rec = TraceRecorder(capacity=4)
    for i in range(8):
        rec.span(CAT_FWD, f"F0.mb{i}", "dev0", float(i), float(i) + 0.5,
                 args={"step": 0, "epoch": 0, "mb": i})
    return rec


def test_jsonl_header_stamps_drops(tmp_path):
    rec = _overflowed_recorder()
    path = str(tmp_path / "TRACE_t.jsonl")
    metrics = MetricsRegistry()
    obs_export.write_jsonl(rec, path, metrics=metrics)
    dicts = obs_export.read_jsonl(path)
    header = obs_export.read_header(dicts)
    assert header is not None
    assert header["n_dropped"] == 4 and header["n_events"] == 4
    snap = metrics.snapshot()
    assert snap.get("trace_dropped_events") == 4
    # idempotent: re-export does not double count
    obs_export.write_jsonl(rec, path, metrics=metrics)
    assert metrics.snapshot().get("trace_dropped_events") == 4
    # events still load (header skipped)
    assert len(obs_export.events_from_dicts(dicts)) == 4


def test_critpath_cli_refuses_truncated(tmp_path, capsys):
    path = str(tmp_path / "TRACE_t.jsonl")
    obs_export.write_jsonl(_overflowed_recorder(), path)
    assert critpath.main([path]) == 2
    assert "dropped" in capsys.readouterr().err
    assert critpath.main([path, "--allow-truncated"]) == 0


def test_report_cli_refuses_truncated(tmp_path, capsys):
    path = str(tmp_path / "TRACE_t.jsonl")
    obs_export.write_jsonl(_overflowed_recorder(), path)
    assert obs_report.main([path]) == 2
    assert "dropped" in capsys.readouterr().err
    assert obs_report.main([path, "--allow-truncated"]) == 0


def test_critpath_cli_busy_gate(tmp_path, capsys):
    # a fabricated METRICS file that disagrees with the trace fails the gate
    g, shapes, _, _ = mlp_chain(n_layers=6, d=16, batch=4)
    prof = g.annotate(shapes)
    cluster = network.homogeneous_lan(n=4)
    sch = schedule_opfence(g, prof, cluster)
    rec = TraceRecorder()
    sim = simulate_iteration(g, prof, sch, cluster, n_micro=4, trace=rec)
    trace_path = str(tmp_path / "TRACE_s.jsonl")
    obs_export.write_jsonl(rec, trace_path)
    good = {"sim_device_busy_seconds": sum(sim.device_busy),
            "sim_link_busy_seconds": sim.link_busy,
            "sim_compress_busy_seconds": sim.compress_busy}
    good_path = str(tmp_path / "METRICS_good.json")
    with open(good_path, "w") as f:
        json.dump(good, f)
    assert critpath.main([trace_path, "--expect-busy", good_path]) == 0
    bad = dict(good, sim_link_busy_seconds=good["sim_link_busy_seconds"] * 2)
    bad_path = str(tmp_path / "METRICS_bad.json")
    with open(bad_path, "w") as f:
        json.dump(bad, f)
    assert critpath.main([trace_path, "--expect-busy", bad_path]) == 1
