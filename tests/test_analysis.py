"""HLO analysis: collective parsing + trip-count weighting; roofline math;
model FLOPs consistency with 6ND."""
import numpy as np
import pytest

from repro.analysis.hlo import (collective_breakdown, collective_bytes,
                                parse_hlo_computations, while_trip_counts)
from repro.analysis.model_flops import forward_flops, model_flops, six_nd
from repro.analysis.roofline import HW, roofline_terms
from repro.configs import INPUT_SHAPES, resolve

SYNTH_HLO = """
HloModule test, entry_computation_layout={()->f32[]}

%region_cond.1 (arg.1: (s32[], f32[8,16])) -> pred[] {
  %arg.1 = (s32[], f32[8,16]) parameter(0)
  %gte = s32[] get-tuple-element(%arg.1), index=0
  %constant.5 = s32[] constant(12)
  ROOT %cmp = pred[] compare(%gte, %constant.5), direction=LT
}

%region_body.2 (arg.2: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %arg.2 = (s32[], f32[8,16]) parameter(0)
  %x = f32[8,16]{1,0} get-tuple-element(%arg.2), index=1
  %ag = f32[8,16]{1,0} all-gather(%x), channel_id=1, dimensions={0}
  %ar = f32[8,16]{1,0} all-reduce(%ag), channel_id=2, to_apply=%add_comp.9
  ROOT %t = (s32[], f32[8,16]) tuple(%arg.2, %ar)
}

%add_comp.9 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main.3 (p0: f32[8,16]) -> f32[] {
  %p0 = f32[8,16]{1,0} parameter(0)
  %cp = f32[8,16]{1,0} collective-permute(%p0), channel_id=3
  %w = (s32[], f32[8,16]) while(%cp), condition=%region_cond.1, body=%region_body.2
  ROOT %r = f32[] constant(0)
}
"""


def test_parse_and_trip_counts():
    comps = parse_hlo_computations(SYNTH_HLO)
    assert "region_body.2" in comps and "main.3" in comps
    trips = while_trip_counts(comps)
    assert trips["region_body.2"] == 12


def test_collective_bytes_weighted_by_trips():
    per_tensor = 8 * 16 * 4
    # body: all-gather + all-reduce, x12; entry: collective-permute x1
    want = per_tensor * 2 * 12 + per_tensor
    assert collective_bytes(SYNTH_HLO) == pytest.approx(want)
    bd = collective_breakdown(SYNTH_HLO)
    assert bd["all-gather"] == pytest.approx(per_tensor * 12)
    assert bd["collective-permute"] == pytest.approx(per_tensor)


def test_roofline_terms_and_dominance():
    t = roofline_terms(per_device_flops=197e12, per_device_bytes=819e9,
                       per_device_collective_bytes=0.0,
                       model_flops_total=197e12 * 256 * 0.5, chips=256)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.dominant in ("compute", "memory")
    assert t.useful_flops_ratio == pytest.approx(0.5)


@pytest.mark.parametrize("arch_id", ["llama3-8b", "granite-3-8b",
                                     "mistral-nemo-12b"])
def test_model_flops_close_to_6nd_for_dense_train(arch_id):
    """Our per-block accounting should land within ~35% of classic 6ND for
    dense archs at train_4k (6ND ignores attention scores and causal
    halving; both effects are O(10%) here)."""
    cfg = resolve(arch_id).full
    shape = INPUT_SHAPES["train_4k"]
    ours = model_flops(cfg, shape)
    nd = six_nd(cfg, shape.seq_len * shape.global_batch)
    assert 0.65 < ours / nd < 1.35, (ours, nd)


def test_decode_flops_much_smaller_than_train():
    cfg = resolve("llama3-8b").full
    f_train = model_flops(cfg, INPUT_SHAPES["train_4k"])
    f_dec = model_flops(cfg, INPUT_SHAPES["decode_32k"])
    assert f_dec < f_train / 1000


def test_window_caps_decode_attention_flops():
    cfg = resolve("llama3-8b").full
    full = forward_flops(cfg, 1, 1, kv_len=524_288, decode=True)
    cfg_w = cfg.replace(window=4096)
    win = forward_flops(cfg_w, 1, 1, kv_len=524_288, decode=True)
    assert win < full
