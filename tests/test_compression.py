"""Top-K / AdaTopK compression: exactness, Eq. 7 + break-even clamp,
gradient transport, wire-byte regression on a tiered network, hypothesis
property tests on the system invariants (skipped individually when
hypothesis is absent — the plain tests always run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:              # tier-1 image has no hypothesis: property
    def given(*args, **kwargs):  # tests skip, everything else still runs
        def deco(fn):
            return pytest.mark.skip(reason="needs hypothesis")(fn)
        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    class _NullStrategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _NullStrategies()

from repro.core import network
from repro.core.compression import (adaptive_ratios, boundary_compress,
                                    ef_compress, encoding_break_even,
                                    ErrorFeedbackState, plan_adatopk,
                                    ratio_to_k, topk_decode, topk_mask,
                                    topk_select, wire_bytes)


@given(st.integers(1, 400), st.floats(1.0, 1000.0))
def test_ratio_to_k_bounds(numel, ratio):
    k = ratio_to_k(numel, ratio)
    assert 1 <= k <= numel


@given(st.integers(2, 200), st.integers(1, 50))
@settings(max_examples=30, deadline=None)
def test_topk_mask_keeps_at_least_k_and_is_idempotent(n, k):
    x = jnp.asarray(np.random.default_rng(n * 31 + k).standard_normal(n),
                    jnp.float32)
    k = min(k, n)
    y = topk_mask(x, k)
    kept = int(jnp.sum(y != 0))
    assert kept >= min(k, int(jnp.sum(x != 0)))
    np.testing.assert_array_equal(np.asarray(topk_mask(y, k)), np.asarray(y))


def test_select_decode_roundtrip_equals_mask():
    x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 13)),
                    jnp.float32)
    vals, idx = topk_select(x, 10)
    dec = topk_decode(vals, idx, x.shape)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(topk_mask(x, 10)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_decode_preserves_input_dtype(dtype):
    """Regression: topk_decode hard-coded float32 and silently upcast bf16
    boundaries; the decoded tensor must default to the wire values' dtype."""
    x = jnp.asarray(np.random.default_rng(1).standard_normal(32)).astype(dtype)
    vals, idx = topk_select(x, 8)
    assert vals.dtype == dtype
    dec = topk_decode(vals, idx, x.shape)
    assert dec.dtype == dtype
    np.testing.assert_array_equal(np.asarray(dec, np.float32),
                                  np.asarray(topk_mask(x, 8), np.float32))
    # explicit override still honoured
    assert topk_decode(vals, idx, x.shape, jnp.float32).dtype == jnp.float32


def test_wire_bytes_paper_eq7_coefficient():
    # ratio r with float32 values + int64 indexes: 3/r of the original —
    # paper's "actual compressed data is 33.3x less at ratio 100"
    numel = 100_000
    assert wire_bytes(numel, 100, "paper") == pytest.approx(
        numel * 4 * 3 / 100)
    assert wire_bytes(numel, 1.0, "paper") == numel * 4
    # mask (bitmap) encoding beats the paper's int64 indexes below the
    # crossover ratio ~64 (k·8 bytes of indexes vs numel/8 of bitmap);
    # above it the bitmap floor dominates.
    assert wire_bytes(numel, 10, "mask") < wire_bytes(numel, 10, "paper")
    assert wire_bytes(numel, 32, "mask") < wire_bytes(numel, 32, "paper")
    assert wire_bytes(numel, 200, "mask") > wire_bytes(numel, 200, "paper")


def test_encoding_break_even_matches_wire_model():
    """The analytic break-even is exactly where wire_bytes crosses dense."""
    numel = 3 * 5 * 7 * 64        # divisible by the ratios probed below
    for enc in ("paper", "mask"):
        be = encoding_break_even(enc)
        assert wire_bytes(numel, be * 1.25, enc) < numel * 4
        # at (or below) break-even the encoding cannot beat dense
        assert wire_bytes(numel, be, enc) >= numel * 4 * 0.999
    assert encoding_break_even("none") == float("inf")


@given(st.lists(st.floats(0.0, 100.0), min_size=1, max_size=20),
       st.floats(1.0, 200.0))
@settings(max_examples=50, deadline=None)
def test_adaptive_ratios_eq7_properties(times, r):
    ratios = adaptive_ratios(times, r)
    assert all(ri >= 1.0 for ri in ratios)           # never inflate
    # the break-even clamp leaves no ratio in the inflating band (1, 3]
    assert all(ri == 1.0 or ri > 3.0 for ri in ratios)
    if max(times) > 0:
        # the slowest link gets exactly 3r (Eq. 7 at R_i = max) — unless 3r
        # sits at/below the encoding break-even, where dense wins outright
        i = int(np.argmax(times))
        expect = 3 * r if 3 * r > 3.0 else 1.0
        assert ratios[i] == pytest.approx(expect)
        # monotone: slower links never compress less (clamping is monotone)
        order = np.argsort(times)
        rs = np.asarray(ratios)[order]
        assert all(rs[i] <= rs[i + 1] + 1e-9 for i in range(len(rs) - 1))


def _three_tier_chain(n_ops=12, d=64, batch=8):
    """An op chain scheduled over a 3-tier topology (intra-machine 10 Gbps,
    intra-cluster 1 Gbps, WAN 8 Mbps) so AdaTopK's Eq. 7 lands ratios in all
    three regimes: ~1 on fast links, mid-range on the 1 Gbps tier (the band
    the break-even clamp exists for), 3r on the WAN."""
    import sys
    sys.path.insert(0, "tests")
    from helpers import mlp_chain
    from repro.core.scheduler import schedule_opfence
    g, shapes, params, inputs = mlp_chain(n_layers=n_ops, d=d, batch=batch)
    prof = g.annotate(shapes)
    cluster = network.paper_testbed(1, seed=0)
    sch = schedule_opfence(g, prof, cluster)
    return g, prof, cluster, sch


@pytest.mark.parametrize("encoding", ["paper", "mask"])
def test_adatopk_never_inflates_wire_bytes(encoding):
    """Regression (wire inflation): pre-clamp, mid-speed links got ratios in
    (1, 3) where k·12 > d·4 — 'compression' that grew traffic.  Every edge
    the plan emits must now carry at most the dense payload, checked with
    the exact integer wire model over a multi-ratio sweep."""
    g, prof, cluster, sch = _three_tier_chain()
    placement = sch.placement
    for ratio in (2.0, 5.0, 20.0, 100.0):
        plan = plan_adatopk(g, prof, cluster, placement, ratio,
                            encoding=encoding)
        all_cross = [(a, n) for n, node in g.nodes.items()
                     for a in node.args if placement[a] != placement[n]]
        for (a, n) in all_cross:
            numel = int(np.prod(prof[a].out_shape))
            dense = numel * 4
            r_i = plan.ratio(a, n)
            assert wire_bytes(numel, r_i, plan.encoding) <= dense, \
                (a, n, r_i, ratio)
        # the clamp never touches genuinely-compressing edges: everything
        # the plan kept sits strictly above the encoding's break-even
        be = encoding_break_even(encoding)
        assert all(r_i > be for r_i in plan.edge_ratio.values())


def test_adatopk_bf16_dense_guard_uses_producer_itemsize():
    """Regression (dtype hard-coding): the dense-payload guard compared the
    wire size against ``numel * 4``, so a bf16 boundary (2 bytes/elem) kept
    ratios in (3, 5] whose paper encoding — k·(2+8) bytes — *inflates* wire
    traffic past the 2-byte dense payload.  Itemsize now comes from the
    producer's profile: with the legacy uniform ``index_overhead=3.0`` knob
    the inflating band is clamped to dense, and with the default per-edge
    coefficient a bf16 edge gets Eq. 7's overhead·r at ITS overhead (5), so
    it both compresses and hits the requested wire-byte target."""
    from repro.core.costmodel import EdgeCostModel
    import sys
    sys.path.insert(0, "tests")
    from helpers import mlp_chain
    g, shapes, _, _ = mlp_chain(n_layers=6, d=64, batch=8)
    prof16 = g.annotate(shapes, activation_itemsize=2)     # bf16 boundaries
    prof32 = g.annotate(shapes, activation_itemsize=4)
    cluster = network.homogeneous_lan(n=2, bandwidth_Bps=1e8, alpha=1e-3)
    order = [n for n in g.topo_order()]
    placement = {n: (0 if i < len(order) // 2 else 1)
                 for i, n in enumerate(order)}
    # legacy fp32 coefficient: the slowest edge's raw ratio is 3r = 4.2 —
    # genuinely compressing for fp32, inside the inflating band for bf16
    r = 1.4
    plan32 = plan_adatopk(g, prof32, cluster, placement, r,
                          index_overhead=3.0)
    plan16 = plan_adatopk(g, prof16, cluster, placement, r,
                          index_overhead=3.0)
    assert plan32.edge_ratio            # fp32 genuinely compresses at 4.2
    assert plan16.edge_ratio == {}      # bf16 must send dense instead
    # default per-edge coefficient: the same bf16 edge is planned at 5r = 7
    # (its own overhead factor) and shrinks below its 2-byte dense payload
    plan16d = plan_adatopk(g, prof16, cluster, placement, r)
    assert plan16d.edge_ratio
    m = EdgeCostModel(g, prof16, cluster, plan16d)
    for (a, n), r_i in plan16d.edge_ratio.items():
        assert r_i == pytest.approx(5.0 * r)
        assert m.edge_wire_bytes(a, n) < prof16[a].out_bytes
    # and at any ratio, planned bf16 edges never exceed their dense size
    plan16b = plan_adatopk(g, prof16, cluster, placement, 10.0)
    assert plan16b.edge_ratio
    m = EdgeCostModel(g, prof16, cluster, plan16b)
    for (a, n) in plan16b.edge_ratio:
        assert m.edge_wire_bytes(a, n) < prof16[a].out_bytes


def test_boundary_compress_gradient_is_sparsified():
    x = jnp.asarray(np.random.default_rng(1).standard_normal(32), jnp.float32)

    def f(x):
        return jnp.sum(boundary_compress(x, 8, 4) ** 2)

    g = jax.grad(f)(x)
    # backward transports Top-4 of the cotangent
    assert int(jnp.sum(g != 0)) <= 8  # ties aside, ≈4; bounded by k_fwd set


def test_error_feedback_conserves_signal():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal(64), jnp.float32)
    st_ = ErrorFeedbackState.init(x)
    sent_total = jnp.zeros_like(x)
    for _ in range(50):
        sent, st_ = ef_compress(x, st_, k=4)
        sent_total = sent_total + sent
    # EF eventually transmits everything: residual bounded, mean signal flows
    assert float(jnp.linalg.norm(st_.residual)) < 50 * float(
        jnp.linalg.norm(x))
    corr = float(jnp.dot(sent_total / 50, x)
                 / (jnp.linalg.norm(sent_total / 50) * jnp.linalg.norm(x)))
    assert corr > 0.9
