"""Top-K / AdaTopK compression: exactness, Eq. 7, gradient transport,
hypothesis property tests on the system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.compression import (adaptive_ratios, boundary_compress,
                                    ef_compress, ErrorFeedbackState,
                                    ratio_to_k, topk_decode, topk_mask,
                                    topk_select, wire_bytes)


@given(st.integers(1, 400), st.floats(1.0, 1000.0))
def test_ratio_to_k_bounds(numel, ratio):
    k = ratio_to_k(numel, ratio)
    assert 1 <= k <= numel


@given(st.integers(2, 200), st.integers(1, 50))
@settings(max_examples=30, deadline=None)
def test_topk_mask_keeps_at_least_k_and_is_idempotent(n, k):
    x = jnp.asarray(np.random.default_rng(n * 31 + k).standard_normal(n),
                    jnp.float32)
    k = min(k, n)
    y = topk_mask(x, k)
    kept = int(jnp.sum(y != 0))
    assert kept >= min(k, int(jnp.sum(x != 0)))
    np.testing.assert_array_equal(np.asarray(topk_mask(y, k)), np.asarray(y))


def test_select_decode_roundtrip_equals_mask():
    x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 13)),
                    jnp.float32)
    vals, idx = topk_select(x, 10)
    dec = topk_decode(vals, idx, x.shape)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(topk_mask(x, 10)))


def test_wire_bytes_paper_eq7_coefficient():
    # ratio r with float32 values + int64 indexes: 3/r of the original —
    # paper's "actual compressed data is 33.3x less at ratio 100"
    numel = 100_000
    assert wire_bytes(numel, 100, "paper") == pytest.approx(
        numel * 4 * 3 / 100)
    assert wire_bytes(numel, 1.0, "paper") == numel * 4
    # mask (bitmap) encoding beats the paper's int64 indexes below the
    # crossover ratio ~64 (k·8 bytes of indexes vs numel/8 of bitmap);
    # above it the bitmap floor dominates.
    assert wire_bytes(numel, 10, "mask") < wire_bytes(numel, 10, "paper")
    assert wire_bytes(numel, 32, "mask") < wire_bytes(numel, 32, "paper")
    assert wire_bytes(numel, 200, "mask") > wire_bytes(numel, 200, "paper")


@given(st.lists(st.floats(0.0, 100.0), min_size=1, max_size=20),
       st.floats(1.0, 200.0))
@settings(max_examples=50, deadline=None)
def test_adaptive_ratios_eq7_properties(times, r):
    ratios = adaptive_ratios(times, r)
    assert all(ri >= 1.0 for ri in ratios)           # never inflate
    if max(times) > 0:
        # the slowest link gets exactly 3r (Eq. 7 at R_i = max)
        i = int(np.argmax(times))
        assert ratios[i] == pytest.approx(max(1.0, 3 * r))
        # monotone: slower links never compress less
        order = np.argsort(times)
        rs = np.asarray(ratios)[order]
        assert all(rs[i] <= rs[i + 1] + 1e-9 for i in range(len(rs) - 1))


def test_boundary_compress_gradient_is_sparsified():
    x = jnp.asarray(np.random.default_rng(1).standard_normal(32), jnp.float32)

    def f(x):
        return jnp.sum(boundary_compress(x, 8, 4) ** 2)

    g = jax.grad(f)(x)
    # backward transports Top-4 of the cotangent
    assert int(jnp.sum(g != 0)) <= 8  # ties aside, ≈4; bounded by k_fwd set


def test_error_feedback_conserves_signal():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal(64), jnp.float32)
    st_ = ErrorFeedbackState.init(x)
    sent_total = jnp.zeros_like(x)
    for _ in range(50):
        sent, st_ = ef_compress(x, st_, k=4)
        sent_total = sent_total + sent
    # EF eventually transmits everything: residual bounded, mean signal flows
    assert float(jnp.linalg.norm(st_.residual)) < 50 * float(
        jnp.linalg.norm(x))
    corr = float(jnp.dot(sent_total / 50, x)
                 / (jnp.linalg.norm(sent_total / 50) * jnp.linalg.norm(x)))
    assert corr > 0.9
