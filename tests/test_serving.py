"""Swarm serving: stage sharding, routing, continuous batching, churn.

The load-bearing invariant is **bit-exactness**: a chain of stage replicas
must reproduce the monolithic decoder exactly, and a mid-session re-route
(KV replay onto the replacement) must not change a single greedy token.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelCfg
from repro.core.costmodel import EdgeCostModel
from repro.core.network import homogeneous_lan
from repro.elastic.membership import ChurnTrace, MembershipView
from repro.models import causal_lm
from repro.obs import FlightRecorder, MetricsRegistry, TraceRecorder
from repro.obs.record import RouteRecord
from repro.obs.report import render_flight
from repro.serving import (NoChainError, Request, RequestQueue,
                           ServingCostModel, ServingPlanError,
                           ServingRuntime, SessionRouter, StageExecutor,
                           check_shardable, churn_trace_for,
                           derive_midsession_failure, plan_serving,
                           poisson_trace, split_stages, stage_params)


def dense_cfg(**kw):
    base = dict(name="serve-dense", family="dense", n_layers=5, d_model=48,
                n_heads=4, n_kv_heads=2, d_ff=96, vocab=89)
    base.update(kw)
    return ModelCfg(**base)


def moe_cfg(**kw):
    base = dict(name="serve-moe", family="moe", n_layers=4, d_model=48,
                n_heads=4, n_kv_heads=2, d_ff=64, vocab=89,
                n_experts=4, top_k=2, tie_embeddings=True)
    base.update(kw)
    return ModelCfg(**base)


# ------------------------------------------------------------- stage split --
def test_split_stages_contiguous_cover():
    cfg = dense_cfg()
    specs = split_stages(cfg, 3)
    assert [s.index for s in specs] == [0, 1, 2]
    assert specs[0].lo == 0 and specs[-1].hi == cfg.n_layers
    for a, b in zip(specs, specs[1:]):
        assert a.hi == b.lo
    # near-equal: earlier stages take the remainder
    assert [s.n_layers for s in specs] == [2, 2, 1]
    assert specs[0].first and specs[-1].last and not specs[1].first


def test_split_stages_validates():
    cfg = dense_cfg()
    with pytest.raises(ValueError):
        split_stages(cfg, 0)
    with pytest.raises(ValueError):
        split_stages(cfg, cfg.n_layers + 1)


def test_check_shardable_rejects_non_kv_families():
    with pytest.raises(ValueError, match="stage-sharded"):
        check_shardable(ModelCfg(name="h", family="hybrid", n_layers=4,
                                 d_model=32, n_heads=4, n_kv_heads=2,
                                 d_ff=64, vocab=89, attn_every=2))
    with pytest.raises(ValueError, match="prefix-fed"):
        check_shardable(dense_cfg(n_prefix=2))


@pytest.mark.parametrize("make_cfg,n_stages",
                         [(dense_cfg, 3), (moe_cfg, 3)])
def test_stage_chain_bit_exact(make_cfg, n_stages):
    """Chained stage prefill+decode == monolithic prefill+decode_step."""
    cfg = make_cfg()
    params = causal_lm.init(cfg, jax.random.PRNGKey(0))
    cache_len = 24
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0, cfg.vocab)

    # jit the monolithic reference: the executors are jitted, and compiled
    # vs eager MoE routing differs by 1 ulp — parity is compiled-to-compiled
    mono_prefill = jax.jit(lambda p, t: causal_lm.prefill(
        cfg, p, t, cache_len=cache_len))
    mono_decode = jax.jit(lambda p, c, t: causal_lm.decode_step(cfg, p, c, t))
    logits_ref, cache = mono_prefill(params, prompt)

    specs = split_stages(cfg, n_stages)
    execs = [StageExecutor(cfg, s, stage_params(cfg, params, s), cache_len)
             for s in specs]
    x = prompt
    kvs = []
    for ex in execs:
        x, kv = ex.prefill(x)
        kvs.append(kv)
    np.testing.assert_array_equal(np.asarray(x),
                                  np.asarray(logits_ref[:, -1:, :]))

    tok_ref = jnp.argmax(logits_ref[:, -1, :], axis=-1)[:, None]
    tok = tok_ref
    for step in range(4):
        logits_ref, cache = mono_decode(params, cache, tok_ref)
        pos = prompt.shape[1] + step
        y = tok
        for i, ex in enumerate(execs):
            y, kvs[i] = ex.decode(y, kvs[i], pos)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(logits_ref))
        tok_ref = jnp.argmax(logits_ref[:, -1, :], axis=-1)[:, None]
        tok = jnp.argmax(y[:, -1, :], axis=-1)[:, None]
        assert int(tok[0, 0]) == int(tok_ref[0, 0])


def test_stage_params_subtrees():
    cfg = moe_cfg()   # tied embeddings
    params = causal_lm.init(cfg, jax.random.PRNGKey(0))
    s0, s1 = split_stages(cfg, 2)
    p0, p1 = stage_params(cfg, params, s0), stage_params(cfg, params, s1)
    assert "embed" in p0 and "final_norm" not in p0
    # tied head: last stage re-hosts the embed table instead of "head"
    assert "embed" in p1 and "head" not in p1 and "final_norm" in p1
    lead = jax.tree_util.tree_leaves(p0["blocks"])[0]
    assert lead.shape[0] == s0.n_layers


# -------------------------------------------------------------------- costs --
def test_kv_and_wire_byte_accounting():
    cfg = dense_cfg(dtype="bfloat16")
    cluster = homogeneous_lan(4)
    costs = ServingCostModel(cfg, cluster)
    spec = split_stages(cfg, 2)[1]
    per_tok = 2 * cfg.n_kv_heads * cfg.head_dim * 2     # bf16 itemsize = 2
    assert costs.kv_bytes_per_token(spec) == spec.n_layers * per_tok
    assert costs.kv_bytes(spec, 64) == 64 * costs.kv_bytes_per_token(spec)
    assert costs.act_bytes_per_token() == cfg.d_model * 2
    # first stage receives int32 token ids, later stages boundary hiddens
    first = split_stages(cfg, 2)[0]
    assert costs.stage_in_bytes_per_token(first) == 4
    assert costs.stage_in_bytes_per_token(spec) == \
        costs.act_bytes_per_token()


def test_link_seconds_matches_training_semantics():
    """Serving prices a corrected link exactly like EdgeCostModel."""
    cfg = dense_cfg()
    cluster = homogeneous_lan(4)
    corr = {(0, 1): 2.5}
    serving = ServingCostModel(cfg, cluster, corr)
    nbytes = 4096
    assert serving.link_seconds(0, 1, nbytes) == pytest.approx(
        cluster.comm_time(0, 1, nbytes) * 2.5)
    assert serving.link_seconds(2, 3, nbytes) == pytest.approx(
        cluster.comm_time(2, 3, nbytes))
    assert serving.link_seconds(1, 1, nbytes) == 0.0


def test_from_cost_model_lifts_corrections():
    """A training loop's calibrated belief reprices serving for free."""
    from helpers import mlp_chain
    graph, shapes, _, _ = mlp_chain(n_layers=3)
    profiles = graph.annotate(shapes)
    cfg = dense_cfg()
    cluster = homogeneous_lan(4)
    edge = EdgeCostModel(graph, profiles, cluster,
                         link_corrections={(1, 2): 1.7})
    serving = ServingCostModel.from_cost_model(cfg, edge)
    assert serving.link_corrections == {(1, 2): 1.7}
    assert serving.cluster is cluster


def test_stage_param_bytes_match_real_subtree():
    """The analytic memory gate must equal the bytes a replica hosts."""
    for cfg in (dense_cfg(), moe_cfg()):
        params = causal_lm.init(cfg, jax.random.PRNGKey(0))
        costs = ServingCostModel(cfg, homogeneous_lan(2))
        for spec in split_stages(cfg, 2):
            real = sum(a.size * a.dtype.itemsize for a in
                       jax.tree_util.tree_leaves(
                           stage_params(cfg, params, spec)))
            assert costs.stage_param_bytes(spec) == real, str(spec)


# --------------------------------------------------------------------- plan --
def test_plan_serving_places_replicas_round_robin():
    cfg = dense_cfg()
    costs = ServingCostModel(cfg, homogeneous_lan(5))
    plan = plan_serving(cfg, costs, alive=[0, 1, 2, 3, 4], n_stages=2,
                        cache_len=32, max_batch=2)
    assert plan.n_stages == 2
    assert sorted(plan.devices()) == [0, 1, 2, 3, 4]
    # 5 devices over 2 stages: one stage gets 3 replicas, the other 2
    sizes = sorted(len(plan.replicas[i]) for i in range(2))
    assert sizes == [2, 3]
    assert "stage0" in plan.describe()


def test_plan_serving_raises_when_underprovisioned():
    cfg = dense_cfg()
    costs = ServingCostModel(cfg, homogeneous_lan(4))
    with pytest.raises(ServingPlanError):
        plan_serving(cfg, costs, alive=[0], n_stages=2, cache_len=32,
                     max_batch=2)


# ------------------------------------------------------------------- router --
def _tiny_plan(n_dev=4, max_batch=1):
    cfg = dense_cfg()
    costs = ServingCostModel(cfg, homogeneous_lan(n_dev))
    return cfg, plan_serving(cfg, costs, alive=list(range(n_dev)),
                             n_stages=2, cache_len=32, max_batch=max_batch)


def test_router_capacity_and_load():
    _, plan = _tiny_plan(n_dev=4, max_batch=1)
    router = SessionRouter(plan)
    alive = plan.devices()
    assert router.has_capacity(alive)
    c1 = router.pick_chain(alive)
    router.acquire(c1)
    c2 = router.pick_chain(alive)
    router.acquire(c2)
    # two replicas per stage, max_batch=1: now saturated
    assert set(c1).isdisjoint(c2)
    assert not router.has_capacity(alive)
    router.release(c1)
    assert router.has_capacity(alive)


def test_router_no_chain_when_stage_dark():
    _, plan = _tiny_plan(n_dev=4)
    router = SessionRouter(plan)
    stage0 = set(plan.replicas[0])
    alive = [d for d in plan.devices() if d not in stage0]
    with pytest.raises(NoChainError):
        router.pick_chain(alive)


# ------------------------------------------------------- queue + req trace --
def test_request_queue_order_and_due():
    reqs = [Request(rid="b", arrival=2.0, prompt=(1, 2), max_new_tokens=3),
            Request(rid="a", arrival=0.5, prompt=(3,), max_new_tokens=2)]
    q = RequestQueue(reqs)
    assert len(q) == 2 and not q.empty
    assert not q.due(0.1)
    assert q.next_arrival() == 0.5
    assert q.pop(1.0).rid == "a"
    with pytest.raises(RuntimeError):
        q.pop(1.0)    # "b" not due yet
    assert q.pop(2.0).rid == "b"
    assert q.empty


def test_poisson_trace_deterministic_and_bounded():
    a = poisson_trace(6, rate=50.0, vocab=97, prompt_len=(2, 5),
                      gen_len=(3, 7), seed=11)
    b = poisson_trace(6, rate=50.0, vocab=97, prompt_len=(2, 5),
                      gen_len=(3, 7), seed=11)
    assert a == b
    arr = [r.arrival for r in a]
    assert arr == sorted(arr)
    for r in a:
        assert 2 <= r.prompt_len <= 5
        assert 3 <= r.max_new_tokens <= 7
        assert all(0 <= t < 97 for t in r.prompt)


# ------------------------------------------------------------ closed loop --
def _closed_loop(cfg, params, plan, requests, trace_events, n_dev,
                 lease=1e-5, with_obs=False):
    view = MembershipView(n_dev, trace_events, lease_s=lease)
    tr = TraceRecorder() if with_obs else None
    fl = FlightRecorder() if with_obs else None
    mx = MetricsRegistry() if with_obs else None
    tokens = {}
    rt = ServingRuntime(cfg, params, plan, view, trace=tr, metrics=mx,
                        flight=fl,
                        on_token=lambda rid, t, now:
                            tokens.setdefault(rid, []).append(t))
    report = rt.run(list(requests))
    return report, tokens, tr, fl, mx


def test_continuous_batching_admits_on_slot_free():
    """More offered sessions than slots: later requests wait for a free
    slot instead of being dropped."""
    cfg = dense_cfg()
    params = causal_lm.init(cfg, jax.random.PRNGKey(0))
    costs = ServingCostModel(cfg, homogeneous_lan(2))
    plan = plan_serving(cfg, costs, alive=[0, 1], n_stages=2,
                        cache_len=32, max_batch=1)   # one slot total
    reqs = [Request(rid=f"r{i}", arrival=0.0,
                    prompt=(1 + i, 2 + i), max_new_tokens=4)
            for i in range(3)]
    report, tokens, *_ = _closed_loop(cfg, params, plan, reqs,
                                      ChurnTrace(()), 2)
    assert report.all_completed and report.n_completed == 3
    assert all(len(tokens[f"r{i}"]) == 4 for i in range(3))
    # serialized through the single slot: strictly more rounds than one
    # session alone needs
    assert report.rounds > 4


def test_midsession_reroute_bit_exact_with_full_observability():
    """The PR's acceptance test: a stage replica dies mid-decode; every
    session completes, greedy tokens are bit-identical to the no-churn
    run, the router's decision is in the flight log, and the replay span
    is on the replacement's track."""
    cfg = dense_cfg()
    params = causal_lm.init(cfg, jax.random.PRNGKey(0))
    costs = ServingCostModel(cfg, homogeneous_lan(6))
    plan = plan_serving(cfg, costs, alive=list(range(6)), n_stages=2,
                        cache_len=64, max_batch=3)
    reqs = poisson_trace(5, rate=200.0, vocab=cfg.vocab,
                         gen_len=(30, 40), seed=3)

    victim, at, base_report, base_tokens = derive_midsession_failure(
        cfg, params, plan, reqs, 6)
    assert base_report.all_completed and base_report.n_reroutes == 0

    report, tokens, tr, fl, mx = _closed_loop(
        cfg, params, plan, reqs, churn_trace_for(victim, at), 6,
        with_obs=True)

    assert report.all_completed, "a session was dropped under churn"
    assert report.n_reroutes >= 1, "scripted failure missed every session"
    assert tokens == base_tokens, "KV replay is not bit-exact"

    reroutes = [r for r in fl.records("route") if r.cause == "reroute"]
    assert reroutes, "router decision missing from the flight log"
    rec = reroutes[0]
    assert isinstance(rec, RouteRecord)
    assert victim in rec.dead and victim in rec.old_chain
    assert victim not in rec.chain
    assert rec.replay_tokens > 0 and rec.kv_ship_bytes > 0

    replays = [e for e in tr.events() if e.cat == "serve.replay"]
    assert replays, "replay span missing from the trace"
    assert all(e.track != f"dev{victim}" for e in replays)

    # serving spans satisfy the same happens-before gate as training
    from repro.check.traceorder import check_trace_order
    assert check_trace_order(tr.events()) == []

    assert mx.counter("serve.tokens").value == report.tokens


def test_tracing_is_observation_only():
    """Traced and untraced churn runs report identical simulated metrics."""
    cfg = dense_cfg()
    params = causal_lm.init(cfg, jax.random.PRNGKey(0))
    costs = ServingCostModel(cfg, homogeneous_lan(6))
    plan = plan_serving(cfg, costs, alive=list(range(6)), n_stages=2,
                        cache_len=64, max_batch=3)
    reqs = poisson_trace(4, rate=200.0, vocab=cfg.vocab,
                         gen_len=(20, 28), seed=5)
    r1, t1, *_ = _closed_loop(cfg, params, plan, reqs, ChurnTrace(()), 6,
                              with_obs=False)
    r2, t2, *_ = _closed_loop(cfg, params, plan, reqs, ChurnTrace(()), 6,
                              with_obs=True)
    assert r1 == r2 and t1 == t2


# -------------------------------------------------------------- obs render --
def test_report_renders_route_records():
    recs = [RouteRecord(step=1, clock=0.01, session="r0", cause="admit",
                        dead=[], old_chain=[0, 1], chain=[0, 1],
                        replay_tokens=0, kv_ship_bytes=0).to_dict(),
            RouteRecord(step=4, clock=0.02, session="r0", cause="reroute",
                        dead=[1], old_chain=[0, 1], chain=[0, 2],
                        replay_tokens=9, kv_ship_bytes=4608).to_dict()]
    out = render_flight(recs)
    assert "admit" in out and "reroute" in out
    assert "[0, 1] -> chain=[0, 2]" in out
    assert "replay=9tok" in out


# ---------------------------------------------------------------- lint/docs --
def test_lint_flags_missing_serving_docstring():
    from repro.check.lint import lint_source
    bad = lint_source("x = 1\n", "serving/foo.py")
    assert any(f.code == "missing-module-docstring" for f in bad)
    good = lint_source('"""Docs."""\nx = 1\n', "serving/foo.py")
    assert not any(f.code == "missing-module-docstring" for f in good)
    other = lint_source("x = 1\n", "core/foo.py")
    assert not any(f.code == "missing-module-docstring" for f in other)


def test_docs_checker_finds_dead_links(tmp_path):
    from repro.check.docs import check_markdown_file
    target = tmp_path / "real.md"
    target.write_text("# Real Heading\n\nbody\n")
    doc = tmp_path / "doc.md"
    doc.write_text(
        "[ok](real.md)\n"
        "[ok anchor](real.md#real-heading)\n"
        "[dead](missing.md)\n"
        "[dead anchor](real.md#nope)\n"
        "[external](https://example.com/x.md)\n"
        "```\n[inside fence](also-missing.md)\n```\n")
    findings = check_markdown_file(str(doc), str(tmp_path))
    codes = sorted(f.code for f in findings)
    assert codes == ["dead-anchor", "dead-link"]


def test_repo_docs_have_no_dead_links():
    from repro.check.docs import check_docs
    assert check_docs() == []


# ---------------------------------------------------------------- benchmark --
def test_serving_bench_smoke():
    import benchmarks.serving as bench
    rows = []
    result = bench.run(lambda *a: rows.append(a), profile="tiny")
    assert set(result) == {"no_churn", "one_failure", "scripted_failure"}
    churn = result["one_failure"]
    assert churn["all_completed"] == 1
    assert churn["n_reroutes"] >= 1
    assert churn["tokens_per_s"] > 0
    assert result["no_churn"]["n_reroutes"] == 0
    assert len(rows) == 2
