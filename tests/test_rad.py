"""Remote automatic differentiation: the correctness contract is that the
stage-chained VJP pipeline reproduces single-device jax.grad exactly when
compression is off (paper §3.3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DecentralizedRuntime, PipelineProgram, network,
                        pipeline_loss_and_grad, pipeline_train_step,
                        plan_adatopk, plan_uniform,
                        schedule_equal_compute, schedule_equal_number,
                        schedule_opfence, single_device_loss_and_grad)
from helpers import mlp_chain


@pytest.fixture(scope="module")
def setup():
    g, shapes, params, inputs = mlp_chain(n_layers=8, d=16)
    prof = g.annotate(shapes)
    cluster = network.paper_testbed(1, seed=0)
    return g, shapes, params, inputs, prof, cluster


@pytest.mark.parametrize("scheduler", ["equal_number", "equal_compute",
                                       "opfence"])
def test_rad_matches_single_device(setup, scheduler):
    g, shapes, params, inputs, prof, cluster = setup
    sch = {"equal_number": lambda: schedule_equal_number(g, cluster),
           "equal_compute": lambda: schedule_equal_compute(g, prof, cluster),
           "opfence": lambda: schedule_opfence(g, prof, cluster)}[scheduler]()
    prog = PipelineProgram.build(g, sch.pipeline_subdags(g))
    ref_loss, ref_grads = single_device_loss_and_grad(g, params, inputs)
    loss, grads = pipeline_loss_and_grad(prog, params, inputs)
    assert np.allclose(loss, ref_loss, rtol=1e-6)
    for op in ref_grads:
        for k in ref_grads[op]:
            np.testing.assert_allclose(grads[op][k], ref_grads[op][k],
                                       atol=1e-6)


def test_compression_changes_transport_but_stays_finite(setup):
    """Compressed transport yields finite loss/grads and a ratio-1 plan is
    bit-identical to dense.  (Whether compressed training still CONVERGES
    is the paper's Fig. 8 claim — reproduced at realistic scale in
    benchmarks/convergence.py, not at this 16-dim toy.)"""
    g, shapes, params, inputs, prof, cluster = setup
    sch = schedule_opfence(g, prof, cluster)
    prog = PipelineProgram.build(g, sch.pipeline_subdags(g))
    ref_loss, ref_grads = single_device_loss_and_grad(g, params, inputs)
    # ratio 1 == dense exactly
    plan1 = plan_uniform(g, sch.placement, ratio=1)
    loss1, grads1 = pipeline_loss_and_grad(prog, params, inputs, plan1)
    assert np.allclose(loss1, ref_loss, rtol=1e-6)
    # ratio 4: finite, nonzero, different
    plan = plan_uniform(g, sch.placement, ratio=4)
    loss_c, grads_c = pipeline_loss_and_grad(prog, params, inputs, plan)
    assert np.isfinite(float(loss_c))
    ga = np.concatenate([np.ravel(grads_c[o]["w"]) for o in grads_c])
    assert np.all(np.isfinite(ga)) and np.linalg.norm(ga) > 0
    gb = np.concatenate([np.ravel(ref_grads[o]["w"]) for o in ref_grads])
    assert not np.allclose(ga, gb)


def test_adatopk_leaves_fast_links_uncompressed(setup):
    g, shapes, params, inputs, prof, cluster = setup
    sch = schedule_opfence(g, prof, cluster)
    plan = plan_adatopk(g, prof, cluster, sch.placement, ratio=50)
    ratios = list(plan.edge_ratio.values())
    # 2-tier topology: slow edges get 3r, intra-cluster edges stay ~1
    assert any(r > 10 for r in ratios) or len(ratios) == 0
    all_edges = [(a, n) for n, node in g.nodes.items() for a in node.args
                 if sch.placement[a] != sch.placement[n]]
    assert len(plan.edge_ratio) <= len(all_edges)


def test_microbatch_accumulation_averages(setup):
    g, shapes, params, inputs, prof, cluster = setup
    sch = schedule_equal_number(g, cluster)
    prog = PipelineProgram.build(g, sch.pipeline_subdags(g))
    loss1, g1 = pipeline_train_step(prog, params, [inputs])
    loss2, g2 = pipeline_train_step(prog, params, [inputs, inputs])
    assert np.allclose(loss1, loss2, rtol=1e-6)
    for op in g1:
        np.testing.assert_allclose(g1[op]["w"], g2[op]["w"], atol=1e-6)


def test_decentralized_runtime_traffic_accounting(setup):
    g, shapes, params, inputs, prof, cluster = setup
    sch = schedule_opfence(g, prof, cluster)
    plan = plan_adatopk(g, prof, cluster, sch.placement, ratio=10)
    rt = DecentralizedRuntime(g, sch, plan)
    loss, grads = rt.train_step(params, [inputs, inputs])
    assert np.isfinite(float(loss))
    acti = [m for m in rt.traffic if m.actual_op_user is None]
    grad = [m for m in rt.traffic if m.actual_op_user is not None]
    assert len(acti) > 0 and len(grad) > 0
    # every gradient message is identified producer->user (paper Table 3)
    for m in grad:
        assert m.actual_op_user in g.users[m.name]
