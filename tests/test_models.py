"""Model-component oracles: chunked SSD vs literal recurrence, mLSTM
parallel vs recurrent, sLSTM scan vs stepping, MoE path equivalence,
attention masks/caches, analytic param counts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, resolve
from repro.models import attention as attn
from repro.models import causal_lm, encdec, moe as moe_mod, ssm, xlstm as xl


class TestSSD:
    def _inputs(self, B=2, S=32, nh=3, hd=8, ds=5, seed=0):
        ks = jax.random.split(jax.random.PRNGKey(seed), 6)
        return (jax.random.normal(ks[0], (B, S, nh, hd)),
                jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh))),
                -jnp.exp(jax.random.normal(ks[2], (nh,))),
                jax.random.normal(ks[3], (B, S, ds)),
                jax.random.normal(ks[4], (B, S, ds)),
                jax.random.normal(ks[5], (nh,)))

    @pytest.mark.parametrize("chunk", [4, 8, 16, 32])
    def test_chunked_equals_reference(self, chunk):
        x, dt, A, Bm, Cm, D = self._inputs()
        y_ref = ssm.ssd_reference(x, dt, A, Bm, Cm, D)
        y, _ = ssm.ssd_chunked(x, dt, A, Bm, Cm, D, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-4)

    def test_chunk_must_divide(self):
        x, dt, A, Bm, Cm, D = self._inputs(S=30)
        with pytest.raises(ValueError):
            ssm.ssd_chunked(x, dt, A, Bm, Cm, D, chunk=8)

    def test_state_continuation(self):
        """ssd(x, h0=state_after_prefix) == suffix of ssd(full)."""
        x, dt, A, Bm, Cm, D = self._inputs(S=32)
        y_full, h_full = ssm.ssd_chunked(x, dt, A, Bm, Cm, D, chunk=8)
        _, h_pre = ssm.ssd_chunked(x[:, :16], dt[:, :16], A, Bm[:, :16],
                                   Cm[:, :16], D, chunk=8)
        y_suf, h_end = ssm.ssd_chunked(x[:, 16:], dt[:, 16:], A, Bm[:, 16:],
                                       Cm[:, 16:], D, chunk=8, h0=h_pre)
        np.testing.assert_allclose(np.asarray(y_suf),
                                   np.asarray(y_full[:, 16:]), atol=1e-4)
        np.testing.assert_allclose(np.asarray(h_end), np.asarray(h_full),
                                   atol=1e-4)


class TestMambaBlock:
    def test_prefill_then_decode_matches_train(self):
        mc = ssm.MambaCfg(d_model=16, d_inner=32, n_heads=4, head_dim=8,
                          d_state=5, chunk=4)
        p = ssm.mamba_init(jax.random.PRNGKey(1), mc)
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 12, 16))
        y_full = ssm.mamba_train(p, x, mc)
        y_pre, st = ssm.mamba_prefill(p, x[:, :8], mc)
        np.testing.assert_allclose(np.asarray(y_pre),
                                   np.asarray(y_full[:, :8]), atol=1e-5)
        for t in range(8, 12):
            y_t, st = ssm.mamba_decode_step(p, x[:, t], st, mc)
            np.testing.assert_allclose(np.asarray(y_t),
                                       np.asarray(y_full[:, t]), atol=1e-4)


class TestXLSTM:
    def setup_method(self):
        self.cfg = xl.XLSTMCfg(d_model=16, n_heads=2)

    def test_mlstm_parallel_vs_recurrent(self):
        p = xl.mlstm_init(jax.random.PRNGKey(3), self.cfg)
        x = jax.random.normal(jax.random.PRNGKey(4), (2, 10, 16)) * 0.5
        y_par = xl.mlstm_block(p, x, self.cfg)
        y_pre, st = xl.mlstm_prefill(p, x[:, :6], self.cfg)
        np.testing.assert_allclose(np.asarray(y_pre),
                                   np.asarray(y_par[:, :6]), atol=1e-5)
        for t in range(6, 10):
            y_t, st = xl.mlstm_decode_step(p, x[:, t], st, self.cfg)
            np.testing.assert_allclose(np.asarray(y_t),
                                       np.asarray(y_par[:, t]), atol=1e-4)

    def test_slstm_scan_vs_stepping(self):
        p = xl.slstm_init(jax.random.PRNGKey(5), self.cfg)
        x = jax.random.normal(jax.random.PRNGKey(6), (2, 10, 16)) * 0.5
        y_blk = xl.slstm_block(p, x, self.cfg)
        st = xl.slstm_state_init(self.cfg, 2)
        outs = []
        for t in range(10):
            o, st = xl.slstm_decode_step(p, x[:, t], st, self.cfg)
            outs.append(o)
        np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                                   np.asarray(y_blk), atol=1e-5)

    def test_slstm_ffn_width_rounded_for_sharding(self):
        p = xl.slstm_init(jax.random.PRNGKey(7),
                          xl.XLSTMCfg(d_model=2048, n_heads=4))
        assert p["ffn_up"]["w"].shape[1] % 64 == 0


class TestMoE:
    def setup_method(self):
        self.p = moe_mod.moe_init(jax.random.PRNGKey(8), 16, 32,
                                  n_experts=4, n_shared=1)
        self.x = jax.random.normal(jax.random.PRNGKey(9), (2, 8, 16))

    def test_three_paths_agree(self):
        o1 = moe_mod.moe_loop(self.p, self.x, 2)
        o2 = moe_mod.moe_ragged(self.p, self.x, 2)
        o3 = moe_mod.moe_capacity(self.p, self.x, 2, capacity=16)
        np.testing.assert_allclose(np.asarray(o1.y), np.asarray(o2.y),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(o1.y), np.asarray(o3.y),
                                   atol=1e-5)
        assert float(o1.aux_loss) == pytest.approx(float(o2.aux_loss))

    def test_capacity_drops_tokens(self):
        full = moe_mod.moe_capacity(self.p, self.x, 2, capacity=16)
        tight = moe_mod.moe_capacity(self.p, self.x, 2, capacity=1)
        assert not np.allclose(np.asarray(full.y), np.asarray(tight.y))

    def test_router_gates_normalized(self):
        gates, idx, aux = moe_mod.route(self.p["router"],
                                        self.x.reshape(-1, 16), 2)
        np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-5)
        assert float(aux) >= 1.0 - 1e-5  # E * Σ f_e p_e >= 1 (Cauchy-Schwarz)


class TestAttention:
    def test_sliding_window_masks_far_tokens(self):
        p = attn.attn_init(jax.random.PRNGKey(10), 16, 2, 2, 8)
        x = jax.random.normal(jax.random.PRNGKey(11), (1, 12, 16))
        y_full = attn.attn_train(p, x, n_heads=2, n_kv=2, head_dim=8)
        y_win = attn.attn_train(p, x, n_heads=2, n_kv=2, head_dim=8, window=4)
        # early positions agree (window covers their whole history)
        np.testing.assert_allclose(np.asarray(y_win[:, :4]),
                                   np.asarray(y_full[:, :4]), atol=1e-5)
        assert not np.allclose(np.asarray(y_win[:, -1]),
                               np.asarray(y_full[:, -1]))

    def test_gqa_equals_mha_when_heads_repeat(self):
        """GQA grouped einsum == expanded-KV reference."""
        B, S, H, hkv, hd = 2, 6, 4, 2, 8
        ks = jax.random.split(jax.random.PRNGKey(12), 3)
        q = jax.random.normal(ks[0], (B, S, H, hd))
        k = jax.random.normal(ks[1], (B, S, hkv, hd))
        v = jax.random.normal(ks[2], (B, S, hkv, hd))
        mask = attn.make_mask(S, S, True, None)
        got = attn.sdpa(q, k, v, mask)
        want = attn.sdpa(q, jnp.repeat(k, H // hkv, 2),
                         jnp.repeat(v, H // hkv, 2), mask)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_param_count_analytic_matches_init(arch_id):
    cfg = resolve(arch_id).smoke
    mod = encdec if cfg.family == "encdec" else causal_lm
    params = mod.init(cfg, jax.random.PRNGKey(0))
    real = sum(int(np.prod(np.shape(l)))
               for l in jax.tree_util.tree_leaves(params))
    assert real == mod.count_params(cfg)


def test_full_config_param_counts_plausible():
    """Analytic N roughly matches the models' nominal sizes."""
    # xlstm: the assigned (48L, d=2048, 4H) with standard xLSTM block shapes
    # lands at ~2.0B — the "1.3b" card uses different internal ratios; the
    # assignment pins L/d/H, so we pin the derived count (DESIGN.md §5).
    approx = {"llama3-8b": 8.0e9, "mistral-nemo-12b": 12.2e9,
              "mixtral-8x7b": 46.7e9, "granite-3-8b": 8.2e9,
              "xlstm-1_3b": 2.0e9}
    for aid, n in approx.items():
        got = causal_lm.count_params(resolve(aid).full)
        assert 0.7 * n < got < 1.45 * n, (aid, got)
