"""Pallas Top-K kernels vs the pure-jnp oracle: shape/dtype/k sweeps in
interpret mode (deliverable c — per-kernel allclose)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ops, ref
from repro.kernels import topk_compress as tk


SHAPES = [(64,), (4096,), (5000,), (32, 257), (8, 128, 17), (3, 5, 7, 11)]
DTYPES = [jnp.float32, jnp.bfloat16, jnp.float16]
RATIOS = [2, 10, 100]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("ratio", RATIOS)
def test_blockwise_topk_exact_vs_oracle(shape, dtype, ratio):
    rng = np.random.default_rng(hash((shape, ratio)) % 2**32)
    x = jnp.asarray(rng.standard_normal(shape), dtype=dtype)
    n = int(np.prod(shape))
    block = 512
    kpb = max(1, (n // ratio) // max(1, -(-n // block)) or 1)
    got = tk.blockwise_topk_mask(x, kpb, block=block, interpret=True)
    want = ref.blockwise_topk_mask_ref(x, kpb, block=block)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(st.integers(8, 2000), st.integers(1, 64),
       st.sampled_from([128, 256, 512]))
@settings(max_examples=25, deadline=None)
def test_kernel_oracle_property(n, k, block):
    x = jnp.asarray(np.random.default_rng(n * 7 + k).standard_normal(n),
                    jnp.float32)
    got = tk.blockwise_topk_mask(x, k, block=block, interpret=True)
    want = ref.blockwise_topk_mask_ref(x, k, block=block)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_threshold_search_exact_at_duplicates():
    x = jnp.asarray([1.0, -1.0, 1.0, 0.5, -0.25, 1.0, 0.0, 0.1], jnp.float32)
    got = tk.blockwise_topk_mask(x, 2, block=8, interpret=True)
    # threshold = 1.0; ties keep all three 1.0-magnitude entries
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray([1.0, -1.0, 1.0, 0, 0, 1.0, 0, 0],
                                    dtype=np.float32))


def test_ef_topk_fused_matches_reference():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal(3000), jnp.float32)
    r = jnp.asarray(rng.standard_normal(3000) * 0.1, jnp.float32)
    s1, nr1 = tk.ef_topk(x, r, 8, block=512, interpret=True)
    s2, nr2 = ref.ef_topk_ref(x, r, 8, block=512)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_allclose(np.asarray(nr1), np.asarray(nr2), atol=1e-6)


def test_jit_wrappers():
    x = jnp.asarray(np.random.default_rng(4).standard_normal(2048),
                    jnp.float32)
    y = ops.topk_mask(x, 100)
    assert 100 <= int(np.sum(np.asarray(y) != 0)) <= 120
    y2 = ops.blockwise_topk_mask(x, 16, block=256)
    assert int(np.sum(np.asarray(y2) != 0)) == 16 * 8


def test_zero_input_keeps_everything_zero():
    x = jnp.zeros(1024, jnp.float32)
    y = tk.blockwise_topk_mask(x, 4, block=256, interpret=True)
    np.testing.assert_array_equal(np.asarray(y), np.zeros(1024))
