"""Pallas Top-K kernels vs the pure-jnp oracle: shape/dtype/k sweeps in
interpret mode (deliverable c — per-kernel allclose), plus the fused
wire-encode/decode round trip and the kernel dispatch policy.

Property tests run only when hypothesis is installed; the parametrized
parity sweeps always run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.kernels import ops, ref
from repro.kernels import topk_compress as tk


SHAPES = [(64,), (4096,), (5000,), (32, 257), (8, 128, 17), (3, 5, 7, 11)]
DTYPES = [jnp.float32, jnp.bfloat16, jnp.float16]
RATIOS = [2, 10, 100]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("ratio", RATIOS)
def test_blockwise_topk_exact_vs_oracle(shape, dtype, ratio):
    rng = np.random.default_rng(hash((shape, ratio)) % 2**32)
    x = jnp.asarray(rng.standard_normal(shape), dtype=dtype)
    n = int(np.prod(shape))
    block = 512
    kpb = max(1, (n // ratio) // max(1, -(-n // block)) or 1)
    got = tk.blockwise_topk_mask(x, kpb, block=block, interpret=True)
    want = ref.blockwise_topk_mask_ref(x, kpb, block=block)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_threshold_search_exact_at_duplicates():
    x = jnp.asarray([1.0, -1.0, 1.0, 0.5, -0.25, 1.0, 0.0, 0.1], jnp.float32)
    got = tk.blockwise_topk_mask(x, 2, block=8, interpret=True)
    # threshold = 1.0; ties keep all three 1.0-magnitude entries
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray([1.0, -1.0, 1.0, 0, 0, 1.0, 0, 0],
                                    dtype=np.float32))


def test_ef_topk_fused_matches_reference():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal(3000), jnp.float32)
    r = jnp.asarray(rng.standard_normal(3000) * 0.1, jnp.float32)
    s1, nr1 = tk.ef_topk(x, r, 8, block=512, interpret=True)
    s2, nr2 = ref.ef_topk_ref(x, r, 8, block=512)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_allclose(np.asarray(nr1), np.asarray(nr2), atol=1e-6)


def test_jit_wrappers():
    x = jnp.asarray(np.random.default_rng(4).standard_normal(2048),
                    jnp.float32)
    y = ops.topk_mask(x, 100)
    assert 100 <= int(np.sum(np.asarray(y) != 0)) <= 120
    y2 = ops.blockwise_topk_mask(x, 16, block=256)
    assert int(np.sum(np.asarray(y2) != 0)) == 16 * 8


def test_zero_input_keeps_everything_zero():
    x = jnp.zeros(1024, jnp.float32)
    y = tk.blockwise_topk_mask(x, 4, block=256, interpret=True)
    np.testing.assert_array_equal(np.asarray(y), np.zeros(1024))


# ------------------------------------------------- fused encode / decode --

ENC_CASES = [((4096,), 11), ((5000,), 13), ((33, 257), 17), ((64,), 9)]


@pytest.mark.parametrize("shape,kpb", ENC_CASES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_encode_kernel_matches_oracle(shape, kpb, dtype):
    rng = np.random.default_rng(hash((shape, kpb)) % 2**32)
    x = jnp.asarray(rng.standard_normal(shape), dtype=dtype)
    for block in (32, 512):
        v_k, m_k = tk.encode_topk(x, kpb, block=block, interpret=True)
        v_r, m_r = ref.encode_topk_ref(x, kpb, block=block)
        np.testing.assert_array_equal(np.asarray(v_k), np.asarray(v_r))
        np.testing.assert_array_equal(np.asarray(m_k), np.asarray(m_r))


@pytest.mark.parametrize("shape,kpb", ENC_CASES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_ef_encode_kernel_matches_oracle(shape, kpb, dtype):
    rng = np.random.default_rng(hash((shape, kpb, 1)) % 2**32)
    x = jnp.asarray(rng.standard_normal(shape), dtype=dtype)
    r = jnp.asarray(rng.standard_normal(shape) * 0.1, dtype=dtype)
    v_k, m_k, nr_k = tk.ef_encode_topk(x, r, kpb, block=512, interpret=True)
    v_r, m_r, nr_r = ref.ef_encode_topk_ref(x, r, kpb, block=512)
    np.testing.assert_array_equal(np.asarray(v_k), np.asarray(v_r))
    np.testing.assert_array_equal(np.asarray(m_k), np.asarray(m_r))
    np.testing.assert_array_equal(np.asarray(nr_k), np.asarray(nr_r))


@pytest.mark.parametrize("shape,kpb", ENC_CASES)
def test_encode_decode_round_trip(shape, kpb):
    """decode(encode(x)) reconstructs exactly the kept elements — i.e. the
    tie-capped keep set as a dense tensor — for kernel and oracle alike."""
    rng = np.random.default_rng(hash(shape) % 2**32)
    x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    v, m = tk.encode_topk(x, kpb, block=512, interpret=True)
    dense_k = tk.decode_topk(v, m, x.shape, interpret=True)
    dense_r = ref.decode_topk_ref(*ref.encode_topk_ref(x, kpb, block=512),
                                  shape=x.shape)
    np.testing.assert_array_equal(np.asarray(dense_k), np.asarray(dense_r))
    # every reconstructed nonzero matches the input at its position
    got = np.asarray(dense_k)
    want = np.asarray(x)
    nz = got != 0
    np.testing.assert_array_equal(got[nz], want[nz])


def test_encode_all_zeros_and_ties():
    # all-zeros: exactly kpb slots per block kept (wire capacity), all zero
    x0 = jnp.zeros(256, jnp.float32)
    v, m = tk.encode_topk(x0, 8, block=32, interpret=True)
    assert v.shape == (8, 8)
    np.testing.assert_array_equal(np.asarray(v), np.zeros((8, 8)))
    assert int(np.sum([bin(w).count("1") for w in np.asarray(m).ravel()])) \
        == 8 * 8
    rt = tk.decode_topk(v, m, x0.shape, interpret=True)
    np.testing.assert_array_equal(np.asarray(rt), np.zeros(256))
    # all-ones (every element ties at the threshold): capped at exactly kpb
    x1 = jnp.ones(256, jnp.float32)
    v1, m1 = tk.encode_topk(x1, 7, block=32, interpret=True)
    v1r, m1r = ref.encode_topk_ref(x1, 7, block=32)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v1r))
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m1r))
    rt1 = np.asarray(tk.decode_topk(v1, m1, x1.shape, interpret=True))
    assert int(np.sum(rt1 != 0)) == 7 * 8
    # ties keep the *first* k - n_above in index order
    assert np.all(rt1.reshape(8, 32)[:, :7] == 1.0)


def test_encode_capped_vs_mask_superset():
    """The dense kernels keep a tie-superset; the encode kernels cap at the
    wire capacity.  On a tie-heavy tensor the decode output must be a
    subset of the dense mask with exactly kpb survivors per block."""
    x = jnp.asarray(np.repeat([3.0, 1.0], 16), jnp.float32)   # 16-way ties
    mask = np.asarray(tk.blockwise_topk_mask(x, 4, block=32, interpret=True))
    v, m = tk.encode_topk(x, 4, block=32, interpret=True)
    enc = np.asarray(tk.decode_topk(v, m, x.shape, interpret=True))
    assert int(np.sum(mask != 0)) == 16      # superset: all 3.0-ties kept
    assert int(np.sum(enc != 0)) == 4        # capped at wire capacity
    assert np.all(mask[enc != 0] == enc[enc != 0])


def test_keep_capped_is_stable_topk():
    """_keep_capped (the executable spec) agrees with the stable-top_k
    formulation encode_topk_ref ships — including tie-heavy rows."""
    rng = np.random.default_rng(11)
    for row in [rng.standard_normal((4, 64)),
                np.repeat(rng.standard_normal((4, 8)), 8, axis=1),
                np.zeros((4, 64))]:
        tiles = jnp.asarray(row, jnp.float32)
        for k in (1, 5, 63):
            keep = np.asarray(ref._keep_capped(ref._mag_bits(tiles), k))
            idx = np.sort(np.asarray(
                jax.lax.top_k(jnp.abs(tiles), k)[1]), axis=1)
            want = np.zeros(keep.shape, bool)
            np.put_along_axis(want, idx, True, axis=1)
            np.testing.assert_array_equal(keep, want)


# --------------------------------------------------------- dispatch policy --

def test_resolve_policy():
    assert ops.resolve_policy(False) == "global"
    assert ops.resolve_policy(None) == "global"
    assert ops.resolve_policy("off") == "global"
    on_tpu = jax.default_backend() == "tpu"
    assert ops.resolve_policy("auto") == ("pallas" if on_tpu else "xla")
    assert ops.resolve_policy(True) == ("pallas" if on_tpu else "interpret")
    assert ops.resolve_policy("force") == ops.resolve_policy(True)
    with pytest.raises(ValueError):
        ops.resolve_policy("warp-speed")


def test_codec_modes_agree():
    """xla and interpret codec paths are bit-identical (the policy only
    changes where the math runs, never what it computes)."""
    x = jnp.asarray(np.random.default_rng(5).standard_normal(5000),
                    jnp.float32)
    a = ops.codec_topk_mask(x, 50, mode="xla")
    b = ops.codec_topk_mask(x, 50, mode="interpret")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    r = jnp.asarray(np.random.default_rng(6).standard_normal(5000) * 0.1,
                    jnp.float32)
    sa, ra = ops.codec_ef_topk(x, r, 50, mode="xla")
    sb, rb = ops.codec_ef_topk(x, r, 50, mode="interpret")
    np.testing.assert_array_equal(np.asarray(sa), np.asarray(sb))
    np.testing.assert_array_equal(np.asarray(ra), np.asarray(rb))


# ------------------------------------------------------- property tests --

if HAVE_HYPOTHESIS:
    @given(st.integers(8, 2000), st.integers(1, 64),
           st.sampled_from([128, 256, 512]))
    @settings(max_examples=25, deadline=None)
    def test_kernel_oracle_property(n, k, block):
        x = jnp.asarray(np.random.default_rng(n * 7 + k).standard_normal(n),
                        jnp.float32)
        got = tk.blockwise_topk_mask(x, k, block=block, interpret=True)
        want = ref.blockwise_topk_mask_ref(x, k, block=block)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @given(st.integers(8, 2000), st.integers(1, 48),
           st.sampled_from([32, 128, 512]),
           st.sampled_from(["normal", "zeros", "ties"]))
    @settings(max_examples=25, deadline=None)
    def test_encode_round_trip_property(n, k, block, regime):
        rng = np.random.default_rng(n * 13 + k)
        if regime == "zeros":
            x = jnp.zeros(n, jnp.float32)
        elif regime == "ties":
            x = jnp.asarray(rng.integers(0, 3, n).astype(np.float32))
        else:
            x = jnp.asarray(rng.standard_normal(n), jnp.float32)
        v_k, m_k = tk.encode_topk(x, k, block=block, interpret=True)
        v_r, m_r = ref.encode_topk_ref(x, k, block=block)
        np.testing.assert_array_equal(np.asarray(v_k), np.asarray(v_r))
        np.testing.assert_array_equal(np.asarray(m_k), np.asarray(m_r))
        rt = tk.decode_topk(v_k, m_k, x.shape, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(rt),
            np.asarray(ref.decode_topk_ref(v_r, m_r, x.shape)))
