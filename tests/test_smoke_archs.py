"""REQUIRED per-architecture smoke tests (deliverable f): reduced same-family
variant (≤2–4 layers, d_model ≤ 512, ≤4 experts) runs one forward/train step
on CPU; output shapes + no NaNs.  Full configs are exercised only by the
dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, resolve
from repro.models import causal_lm, encdec
from repro.optim import adamw

B, S = 2, 16


def _batch(cfg, rng):
    if cfg.family == "encdec":
        return {"src_embeds": jax.random.normal(rng, (B, 8, cfg.d_frontend)),
                "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab),
                "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab)}
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab)}
    if cfg.n_prefix:
        batch["prefix_embeds"] = jax.random.normal(
            rng, (B, cfg.n_prefix, cfg.d_frontend))
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_reduced_variant_limits(arch_id):
    cfg = resolve(arch_id).smoke
    assert cfg.n_layers <= 4 and cfg.d_model <= 512
    assert cfg.n_experts <= 4
    assert cfg.family == resolve(arch_id).full.family


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch_id):
    cfg = resolve(arch_id).smoke
    rng = jax.random.PRNGKey(0)
    mod = encdec if cfg.family == "encdec" else causal_lm
    params = mod.init(cfg, rng)
    batch = _batch(cfg, rng)
    if cfg.family == "encdec":
        memory = encdec.encode(cfg, params, batch["src_embeds"])
        assert memory.shape == (B, 8, cfg.d_model)
        loss, metrics = encdec.train_loss(cfg, params, batch)
    else:
        logits, aux = causal_lm.forward(cfg, params, batch["tokens"],
                                        batch.get("prefix_embeds"))
        assert logits.shape == (B, S + cfg.n_prefix, cfg.vocab_padded)
        assert bool(jnp.all(jnp.isfinite(logits[..., :cfg.vocab])))
        loss, metrics = causal_lm.train_loss(cfg, params, batch)
    assert np.isfinite(float(loss)) and float(loss) > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_one_train_step_improves_or_moves(arch_id):
    cfg = resolve(arch_id).smoke
    rng = jax.random.PRNGKey(1)
    mod = encdec if cfg.family == "encdec" else causal_lm
    params = mod.init(cfg, rng)
    batch = _batch(cfg, rng)
    opt = adamw(1e-3, weight_decay=0.0)
    state = opt.init(params)

    def loss_fn(p):
        return mod.train_loss(cfg, p, batch)[0]

    l0, grads = jax.value_and_grad(loss_fn)(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in
             jax.tree_util.tree_leaves(grads))
    assert np.isfinite(float(l0)) and gn > 0
    new_params, _ = opt.update(grads, state, params)
    l1 = loss_fn(new_params)
    assert np.isfinite(float(l1))
    assert float(l1) < float(l0)  # one AdamW step on the same batch descends


@pytest.mark.parametrize("arch_id", [a for a in ARCH_IDS
                                     if a != "seamless-m4t-large-v2"])
def test_smoke_decode_matches_forward(arch_id):
    """serve path: prefill 8 tokens then decode 1 == teacher-forced
    forward at that position."""
    cfg = resolve(arch_id).smoke
    rng = jax.random.PRNGKey(2)
    params = causal_lm.init(cfg, rng)
    batch = _batch(cfg, rng)
    logits_full, _ = causal_lm.forward(cfg, params, batch["tokens"],
                                       batch.get("prefix_embeds"))
    lg, cache = causal_lm.prefill(
        cfg, params, batch["tokens"][:, :8],
        cache_len=S + cfg.n_prefix + 8,
        prefix_embeds=batch.get("prefix_embeds"))
    lg2, cache = causal_lm.decode_step(cfg, params, cache,
                                       batch["tokens"][:, 8:9])
    np.testing.assert_allclose(
        np.asarray(lg[:, -1], np.float32),
        np.asarray(logits_full[:, cfg.n_prefix + 7], np.float32), atol=2e-2)
    np.testing.assert_allclose(
        np.asarray(lg2[:, 0], np.float32),
        np.asarray(logits_full[:, cfg.n_prefix + 8], np.float32), atol=2e-2)


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyper-parameters."""
    expect = {
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "mistral-nemo-12b": (40, 5120, 32, 8, 14336, 131072),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "xlstm-1_3b": (48, 2048, 4, 4, 0, 50304),
    }
    for aid, (L, d, H, kv, ff, V) in expect.items():
        cfg = resolve(aid).full
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, H, kv, ff, V), aid
    assert resolve("zamba2-7b").full.ssm_state == 64
    assert resolve("deepseek-moe-16b").full.n_experts == 64
    assert resolve("deepseek-moe-16b").full.top_k == 6
    assert resolve("deepseek-moe-16b").full.n_shared_experts == 2
    assert resolve("mixtral-8x7b").full.n_experts == 8
    assert resolve("mixtral-8x7b").full.top_k == 2
