"""Distribution layer: input specs for every (arch × shape), sharding rule
sanity, cache spec/tree congruence — all shape-level (no 512-device mesh
here; compile coverage lives in the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, resolve
from repro.distributed import steps as dsteps
from repro.distributed.params import batch_spec, generic_spec, row_spec
from repro.launch.mesh import make_local_mesh

ASSIGNED = [a for a in ARCH_IDS if a != "gpt2-xl"]


@pytest.mark.parametrize("arch_id", ASSIGNED)
@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_input_specs_all_combos(arch_id, shape_name):
    entry = resolve(arch_id)
    if shape_name not in entry.shapes:
        pytest.skip(entry.skip_notes)
    cfg = entry.full
    shape = INPUT_SHAPES[shape_name]
    spec = dsteps.input_specs(cfg, shape)
    assert spec["tokens"].dtype == jnp.int32
    B = shape.global_batch
    if cfg.family == "encdec":
        assert spec["tokens"].shape == (B, shape.seq_len)
        assert spec["src_embeds"].shape[0] == B
    elif cfg.n_prefix:
        assert spec["tokens"].shape == (B, shape.seq_len - cfg.n_prefix)
        assert spec["prefix_embeds"].shape == (B, cfg.n_prefix,
                                               cfg.d_frontend)
    else:
        assert spec["tokens"].shape == (B, shape.seq_len)
    if shape.kind == "train":
        assert spec["labels"].shape == spec["tokens"].shape


@pytest.mark.parametrize("arch_id", ASSIGNED)
def test_decode_cache_specs_match_cache_init(arch_id):
    """Abstract decode-cache specs must be tree-congruent with the real
    cache the model builds (structure + shapes)."""
    entry = resolve(arch_id)
    if "decode_32k" not in entry.shapes:
        pytest.skip("no decode shape")
    cfg = entry.smoke
    from repro.models import causal_lm, encdec
    if cfg.family == "encdec":
        real = encdec.cache_init(cfg, 2, 32, dsteps.src_len_for(cfg, 32))
    else:
        real = causal_lm.cache_init(cfg, 2, 32)

    abs_ = dsteps.decode_state_specs(
        cfg.replace(), type("S", (), {"seq_len": 32, "global_batch": 2,
                                      "kind": "decode",
                                      "name": "decode_32k"})())
    t1 = jax.tree_util.tree_structure(real)
    t2 = jax.tree_util.tree_structure(abs_)
    assert t1 == t2
    for a, b in zip(jax.tree_util.tree_leaves(real),
                    jax.tree_util.tree_leaves(abs_)):
        assert np.shape(a) == b.shape


def test_generic_and_row_specs():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # 1-sized axes -> everything replicated
    assert generic_spec((64, 128), mesh) == P(None, None)
    mesh2 = jax.make_mesh((1, 1), ("data", "model"))
    assert row_spec((64, 128), mesh2) == P(None, None)


def test_batch_spec_fallbacks():
    mesh = make_local_mesh()   # (n,1) over data/model
    assert batch_spec(1, mesh) in (P(None), P("data"))
    assert batch_spec(8, mesh) is not None


@pytest.mark.parametrize("arch_id", ["llama3-8b", "zamba2-7b",
                                     "deepseek-moe-16b", "xlstm-1_3b"])
def test_build_jitted_runs_on_local_mesh(arch_id):
    """End-to-end: the production step builders execute (not just lower)
    on the 1-device local mesh with a smoke config."""
    from repro.distributed.sharding import use_mesh
    from repro.models import causal_lm
    cfg = resolve(arch_id).smoke
    mesh = make_local_mesh()
    shape = type("S", (), {"seq_len": 16, "global_batch": 2, "kind": "train",
                           "name": "train_4k"})()
    with use_mesh(mesh):
        fn, args, _ = dsteps.build_jitted(cfg, mesh, shape)
        params = causal_lm.init(cfg, jax.random.PRNGKey(0))
        from repro.optim import adafactor
        opt_state = adafactor(1e-3).init(params)
        rng = jax.random.PRNGKey(1)
        batch = {"tokens": jax.random.randint(rng, (2, 16), 0, cfg.vocab),
                 "labels": jax.random.randint(rng, (2, 16), 0, cfg.vocab)}
        if cfg.n_prefix:
            batch["prefix_embeds"] = jax.random.normal(
                rng, (2, cfg.n_prefix, cfg.d_frontend))
        p2, o2, metrics = fn(params, opt_state, batch)
        assert np.isfinite(float(metrics["loss"]))
