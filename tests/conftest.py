import os
import sys

# Tests run on the single real CPU device — the 512-device dry-run flag must
# NOT be set here (smoke tests and benches should see 1 device).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
