"""Telemetry-driven straggler detection: sample emission from both
executors, TelemetryLog aggregation (median-of-window + MAD outlier
rejection), noiseless parity with the PR 1 estimator-fed detector path, and
false-positive suppression on noisy traces."""
import dataclasses

import numpy as np
import pytest

from repro.core import network
from repro.core.estimator import (predict_step_time_components,
                                  predict_step_times)
from repro.core.executor import (DecentralizedRuntime, StepTiming,
                                 TelemetrySink, simulate_iteration)
from repro.core.scheduler import schedule_opfence
from repro.elastic import StragglerDetector, TelemetryLog
from helpers import mlp_chain


def _setup(n_layers=10, n_dev=6, seed=3):
    g, shapes, params, inputs = mlp_chain(n_layers=n_layers, d=16, batch=4)
    prof = g.annotate(shapes)
    cluster = network.geo_random(n=n_dev, n_sites=2, seed=seed)
    sch = schedule_opfence(g, prof, cluster)
    return g, prof, cluster, sch, params, inputs


# ------------------------------------------------------------- emission ----
def test_simulator_emits_per_stage_per_microbatch_samples():
    g, prof, cluster, sch, _, _ = _setup()
    sink = TelemetrySink()
    n_micro = 3
    simulate_iteration(g, prof, sch, cluster, n_micro=n_micro,
                       telemetry=sink, step=7)
    stages = sch.stage_devices()
    # one sample per (stage, micro-batch, direction)
    assert len(sink.samples) == 2 * n_micro * len(stages)
    assert {s.node for s in sink.samples} == set(stages)
    assert {s.micro_batch for s in sink.samples} == set(range(n_micro))
    assert {s.backward for s in sink.samples} == {True, False}
    assert all(s.step == 7 for s in sink.samples)
    assert all(s.seconds >= 0.0 for s in sink.samples)


def test_simulator_samples_match_estimator_attribution():
    """Noiseless contract: per-node telemetry (Σ samples / n_micro) equals
    predict_step_times — compute exactly, comm charged to the consumer's
    stage in both directions."""
    g, prof, cluster, sch, _, _ = _setup()
    sink = TelemetrySink()
    n_micro = 2
    simulate_iteration(g, prof, sch, cluster, n_micro=n_micro, telemetry=sink)
    obs_comp: dict = {}
    obs_total: dict = {}
    for s in sink.samples:
        obs_comp[s.node] = obs_comp.get(s.node, 0.0) + s.compute_seconds
        obs_total[s.node] = obs_total.get(s.node, 0.0) + s.seconds
    comp_pred = predict_step_time_components(g, prof, cluster, sch.placement)
    for node in obs_total:
        comp, recv = comp_pred[node]
        assert obs_comp[node] / n_micro == pytest.approx(comp, rel=1e-9)
        assert obs_total[node] / n_micro == pytest.approx(comp + recv,
                                                          rel=1e-6, abs=1e-12)


def test_runtime_emits_wall_clock_samples():
    g, prof, cluster, sch, params, inputs = _setup(n_layers=6, n_dev=4)
    sink = TelemetrySink()
    rt = DecentralizedRuntime(g, sch, telemetry=sink)
    rt.train_step(params, [inputs, inputs])
    stages = sch.stage_devices()
    assert len(sink.samples) == 2 * 2 * len(stages)
    assert all(s.compute_seconds > 0.0 for s in sink.samples)  # measured
    assert {s.step for s in sink.samples} == {0}
    rt.train_step(params, [inputs])
    assert {s.step for s in sink.samples} == {0, 1}


# ---------------------------------------------------------- aggregation ----
def _sample(node, seconds, step, mb=0):
    return StepTiming(node=node, stage=0, micro_batch=mb, backward=False,
                      compute_seconds=seconds, step=step)


def test_telemetry_log_normalizes_per_micro_batch():
    log = TelemetryLog(window=4)
    # 2 micro-batches, FP+BP each 1.0s -> 2.0s per micro-batch
    for mb in range(2):
        for backward in (False, True):
            log.record(StepTiming(node=0, stage=0, micro_batch=mb,
                                  backward=backward, compute_seconds=1.0,
                                  step=0))
    assert log.node_step_times() == {0: pytest.approx(2.0)}


def test_telemetry_log_median_rejects_single_spike():
    log = TelemetryLog(window=5, mad_k=3.5)
    for t, s in enumerate([1.0, 1.01, 12.0, 0.99, 1.02]):   # one GC pause
        log.record(_sample(0, s, step=t))
    agg = log.node_step_times()[0]
    assert agg == pytest.approx(1.01, abs=0.02)              # spike gone


def test_telemetry_log_window_follows_sustained_shift():
    log = TelemetryLog(window=3)
    for t in range(4):
        log.record(_sample(1, 1.0, step=t))
    for t in range(4, 8):                       # genuine 4x slowdown
        log.record(_sample(1, 4.0, step=t))
    assert log.node_step_times()[1] == pytest.approx(4.0)


def test_telemetry_log_clear_drops_history():
    log = TelemetryLog(window=3)
    log.record(_sample(0, 5.0, step=0))
    log.clear()
    assert log.node_step_times() == {} and log.n_samples == 0


# --------------------------------------------------------------- parity ----
def test_telemetry_fed_detector_matches_estimator_fed_on_noiseless_traces():
    """The PR 1 path observed predict_step_times(true cluster); the telemetry
    path observes aggregated simulator samples.  On noiseless traces both
    detectors must flag the same straggler with matching severity."""
    g, prof, cluster, sch, _, _ = _setup()
    # the first stage has no inbound boundary edges, so its step time is
    # pure compute — a compute slowdown is fully visible there (a comm-
    # dominated stage hides it from *both* observation paths equally)
    victim = sch.stage_devices()[0]
    true_cl = network.with_slowdowns(cluster, {victim: 0.25})
    predicted = predict_step_times(g, prof, cluster, sch.placement)

    det_tele = StragglerDetector(predicted, min_observations=3)
    det_est = StragglerDetector(predicted, min_observations=3)
    log = TelemetryLog(window=5)
    estimator_obs = predict_step_times(g, prof, true_cl, sch.placement)
    for step in range(6):
        sink = TelemetrySink()
        simulate_iteration(g, prof, sch, true_cl, n_micro=2, telemetry=sink,
                           step=step)
        log.record_step(sink.samples, step=step)
        det_tele.observe(log.node_step_times())
        det_est.observe(estimator_obs)

    assert det_tele.flagged() == det_est.flagged() == [victim]
    for node in predicted:
        assert det_tele.severity(node) == pytest.approx(
            det_est.severity(node), rel=1e-6)


def test_aggregation_window_suppresses_false_positives_on_noisy_traces():
    """A healthy node with occasional timing spikes (GC pause, transient
    congestion) must NOT be flagged through the aggregation window, while
    feeding the same raw per-step times straight to the detector (window=1,
    the no-telemetry strawman) false-flags it."""
    rng = np.random.default_rng(0)
    predicted = {0: 1.0, 1: 1.0}
    det_windowed = StragglerDetector(predicted, min_observations=3)
    det_raw = StragglerDetector(predicted, min_observations=3)
    log = TelemetryLog(window=5, mad_k=3.5)
    raw_flagged = False
    for step in range(40):
        base = 1.0 + float(rng.uniform(-0.05, 0.05))
        spike = 8.0 if step % 10 == 3 else 0.0      # 1-in-10 step stall
        log.record(_sample(0, base + spike, step=step))
        log.record(_sample(1, base, step=step))
        det_windowed.observe(log.node_step_times())
        det_raw.observe({0: base + spike, 1: base})
        raw_flagged |= bool(det_raw.flagged())
    assert raw_flagged                     # the strawman cries wolf ...
    assert det_windowed.flagged() == []    # ... the window does not


def test_noisy_window_still_detects_real_straggler():
    """Robust aggregation must not hide a genuine slowdown: multiplicative
    jitter on every sample, node 1 runs 4x slow — only node 1 flags."""
    rng = np.random.default_rng(1)
    predicted = {0: 1.0, 1: 1.0}
    det = StragglerDetector(predicted, min_observations=3)
    log = TelemetryLog(window=5)
    for step in range(30):
        j0, j1 = (float(rng.uniform(0.9, 1.1)) for _ in range(2))
        log.record(_sample(0, 1.0 * j0, step=step))
        log.record(_sample(1, 4.0 * j1, step=step))
        det.observe(log.node_step_times())
    assert det.flagged() == [1]
