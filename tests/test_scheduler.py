"""OP-Fence scheduler: Louvain clustering, DP split optimality, and the
paper's headline claim — OP-Fence beats the naive baselines on clustered
(geo) topologies."""
import itertools

import numpy as np
import pytest

from repro.core import (EdgeCostModel, SCHEDULERS, estimate_iteration,
                        network, partition_min_bottleneck, plan_adatopk,
                        schedule_equal_compute, schedule_equal_number,
                        schedule_joint, schedule_opfence, simulate_iteration)
from repro.core.scheduler import louvain_communities, _order_clusters
from helpers import mlp_chain


def test_louvain_recovers_planted_blocks():
    rng = np.random.default_rng(0)
    n, blocks = 24, 4
    w = np.full((n, n), 0.01)
    for b in range(blocks):
        idx = slice(b * 6, (b + 1) * 6)
        w[idx, idx] = 1.0 + rng.random((6, 6)) * 0.1
    w = (w + w.T) / 2
    np.fill_diagonal(w, 0)
    comms = louvain_communities(w)
    assert len(comms) == blocks
    for c in comms:
        assert len(c) == 6 and max(c) - min(c) == 5  # contiguous planted block


def test_louvain_matches_networkx_partition_quality():
    """Cross-check modularity against networkx's reference implementation."""
    import networkx as nx
    rng = np.random.default_rng(1)
    w = np.full((16, 16), 0.02)
    w[:8, :8] = 1.0
    w[8:, 8:] = 1.0
    np.fill_diagonal(w, 0.0)
    G = nx.from_numpy_array(w)
    ours = louvain_communities(w, seed=0)
    q_ours = nx.algorithms.community.modularity(
        G, [set(c) for c in ours], weight="weight")
    theirs = nx.algorithms.community.louvain_communities(G, weight="weight",
                                                         seed=0)
    q_theirs = nx.algorithms.community.modularity(G, theirs, weight="weight")
    assert q_ours >= q_theirs - 1e-6


def test_paper_testbed_clusters_by_machine():
    cluster = network.paper_testbed(1, seed=0)  # 1×8 4090 + 4×4 2080
    bw = cluster.bandwidth_matrix()
    comms = louvain_communities(bw)
    # locality tiers: machines are the natural communities (5 machines)
    assert len(comms) == 5
    sizes = sorted(len(c) for c in comms)
    assert sizes == [4, 4, 4, 4, 8]


def test_min_bottleneck_dp_is_optimal_vs_bruteforce():
    g, shapes, params, inputs = mlp_chain(n_layers=6, d=8)
    prof = g.annotate(shapes)
    cluster = network.geo_random(n=3, n_sites=2, seed=3)
    order = [0, 1, 2]
    segs, pace = partition_min_bottleneck(g, prof, cluster, order)

    # brute force all contiguous splits of the 7-op chain into 3 parts
    from repro.core.opgraph import chain
    ops = chain(g)
    n = len(ops)
    best = np.inf
    for c1 in range(1, n - 1):
        for c2 in range(c1 + 1, n):
            segments = [ops[:c1], ops[c1:c2], ops[c2:]]
            pace_bf = 0.0
            for k, seg in enumerate(segments):
                comp = sum(prof[o].fwd_flops for o in seg) \
                    / cluster.devices[order[k]].speed
                recv = 0.0
                if k > 0:
                    prev_out = segments[k - 1][-1]
                    recv = cluster.comm_time(order[k - 1], order[k],
                                             prof[prev_out].out_bytes)
                pace_bf = max(pace_bf, comp, recv)
            best = min(best, pace_bf)
    assert pace == pytest.approx(best, rel=1e-9)


def test_opfence_beats_baselines_on_geo_topology():
    """The paper's Fig. 10 effect: bandwidth-aware placement reduces
    simulated iteration latency vs equal-number / equal-compute."""
    g, shapes, params, inputs = mlp_chain(n_layers=24, d=256, batch=32)
    prof = g.annotate(shapes)
    # shuffled-location topology: index order != locality order
    cluster = network.geo_random(n=8, n_sites=3, seed=7)
    t = {}
    sch_en = schedule_equal_number(g, cluster)
    sch_ec = schedule_equal_compute(g, prof, cluster)
    sch_of = schedule_opfence(g, prof, cluster)
    for name, sch in [("equal_number", sch_en), ("equal_compute", sch_ec),
                      ("opfence", sch_of)]:
        t[name] = simulate_iteration(g, prof, sch, cluster,
                                     n_micro=4).iteration_time
    assert t["opfence"] <= t["equal_number"] * 1.001
    assert t["opfence"] <= t["equal_compute"] * 1.001


def test_cluster_ordering_prefers_strong_links():
    bw = np.array([[0, 10, 1], [10, 0, 10], [1, 10, 0]], dtype=float)
    clusters = [[0], [1], [2]]
    order = _order_clusters(clusters, bw)
    assert order[1] == 1  # the well-connected cluster sits in the middle


# -------------------------------------------------- Louvain edge cases -----
def test_louvain_single_node():
    assert louvain_communities(np.zeros((1, 1))) == [[0]]


def test_louvain_fully_disconnected_matrix_yields_singletons():
    comms = louvain_communities(np.zeros((5, 5)))
    assert sorted(comms) == [[0], [1], [2], [3], [4]]


def test_louvain_deterministic_for_fixed_seed():
    rng = np.random.default_rng(42)
    w = rng.random((12, 12))
    w = (w + w.T) / 2
    np.fill_diagonal(w, 0.0)
    a = louvain_communities(w, seed=7)
    b = louvain_communities(w, seed=7)
    assert a == b
    # every node appears exactly once regardless of structure
    assert sorted(i for c in a for i in c) == list(range(12))


# -------------------------------------- SCHEDULERS registry honors kwargs --
def test_schedulers_registry_honors_device_subset():
    """Regression: the equal_number/equal_compute registry lambdas swallowed
    ``device_subset``, so churn baselines silently scheduled onto dead
    CompNodes."""
    g, shapes, _, _ = mlp_chain(n_layers=12, d=32, batch=4)
    prof = g.annotate(shapes)
    cluster = network.geo_random(n=8, n_sites=2, seed=1)
    subset = [2, 3, 5, 7]
    for name, sfn in SCHEDULERS.items():
        sch = sfn(g, prof, cluster, device_subset=subset)
        used = {d for d, seg in enumerate(sch.assignment) if seg}
        assert used <= set(subset), (name, used)
        placed = sorted(op for seg in sch.assignment for op in seg)
        assert placed == sorted(g.nodes), name


def test_schedule_equal_number_rejects_empty_subset():
    g, shapes, _, _ = mlp_chain(n_layers=6, d=16)
    cluster = network.homogeneous_lan(n=4)
    with pytest.raises(ValueError):
        schedule_equal_number(g, cluster, device_subset=[])


# ---------------------------------------------------- joint co-planning ----
def _geo_workload(n_layers=16, d=128, batch=16, n=8, seed=7):
    g, shapes, _, _ = mlp_chain(n_layers=n_layers, d=d, batch=batch)
    prof = g.annotate(shapes)
    cluster = network.geo_random(n=n, n_sites=3, seed=seed)
    return g, prof, cluster


def test_joint_never_worse_than_sequential_pipeline():
    """The co-planner evaluates the sequential schedule-then-compress
    candidate in round 0, so under the shared Eq. 3 pace metric it can only
    tie or beat it — at any ratio."""
    g, prof, cluster = _geo_workload()
    dense = EdgeCostModel(g, prof, cluster)
    seq_sched = schedule_opfence(g, prof, cluster)
    for ratio in (10.0, 100.0, 1000.0):
        seq_plan = plan_adatopk(g, prof, cluster, seq_sched.placement, ratio)
        seq_pace = dense.with_plan(seq_plan).stage_pace(seq_sched)
        jp = schedule_joint(g, prof, cluster, ratio=ratio)
        assert jp.predicted_pace <= seq_pace * (1 + 1e-12), ratio
        assert jp.schedule.predicted_pace == pytest.approx(jp.predicted_pace)
        # the returned plan is consistent with the returned schedule
        placement = jp.schedule.placement
        for (a, n) in jp.plan.edge_ratio:
            assert placement[a] != placement[n]


def test_joint_recut_strictly_beats_sequential_on_gpt2xl_testbed1():
    """Acceptance: on the paper's GPT2-XL/testbed-1 workload compression
    changes the bottleneck-optimal cut, and the fixed point finds it (the
    blind schedule-then-compress pipeline cannot)."""
    from repro.configs import resolve
    from repro.models.opgraph_models import profile_opgraph
    cfg = resolve("gpt2-xl").full
    batch, seq = 3, 1024      # paper Table 6
    g = profile_opgraph(cfg, batch, seq)
    prof = g.annotate({"tokens": (batch, seq), "labels": (batch, seq)})
    cluster = network.paper_testbed(1, seed=0)
    dense = EdgeCostModel(g, prof, cluster)
    seq_sched = schedule_opfence(g, prof, cluster)
    improved = False
    for ratio in (100.0, 300.0, 1000.0):
        seq_plan = plan_adatopk(g, prof, cluster, seq_sched.placement, ratio)
        seq_pace = dense.with_plan(seq_plan).stage_pace(seq_sched)
        jp = schedule_joint(g, prof, cluster, ratio=ratio)
        assert jp.predicted_pace <= seq_pace * (1 + 1e-12)
        improved |= jp.predicted_pace < seq_pace * (1 - 1e-6)
    assert improved


def test_joint_registered_in_schedulers():
    g, prof, cluster = _geo_workload(n_layers=8, d=32, batch=4, n=4)
    sch = SCHEDULERS["joint"](g, prof, cluster, ratio=100.0)
    placed = sorted(op for seg in sch.assignment for op in seg)
    assert placed == sorted(g.nodes)
    sch.pipeline_subdags(g)    # Table-3 edge sets build cleanly
