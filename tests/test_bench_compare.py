"""CI perf-regression gate (benchmarks/compare.py): the pure comparison
logic, the committed baseline's schema, and the CLI exit codes — including
the acceptance requirement that an injected 20% pace regression fails the
gate."""
import copy
import json
import os

import pytest

from benchmarks.compare import (append_history, compare, history_gate,
                                history_path_for, load_result, main,
                                tracked_only)

BASELINE = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "baselines", "BENCH_baseline_joint.json")


def _base():
    return {
        "opfence": {"pace": 0.030, "phi": 16.0, "iter_s": 0.1},
        "joint": {"pace": 0.025, "phi": 18.0, "iter_s": 0.09},
    }


def test_identical_results_pass():
    assert compare(_base(), _base()) == []


def test_injected_pace_regression_fails():
    """Acceptance: the gate demonstrably fails on a 20% pace regression."""
    new = copy.deepcopy(_base())
    new["joint"]["pace"] *= 1.20
    violations = compare(new, _base(), max_regress=0.10)
    assert len(violations) == 1
    assert "joint.pace" in violations[0]


def test_injected_throughput_regression_fails():
    new = copy.deepcopy(_base())
    new["opfence"]["phi"] *= 0.80
    violations = compare(new, _base(), max_regress=0.10)
    assert len(violations) == 1 and "opfence.phi" in violations[0]


def test_regressions_inside_budget_pass():
    new = copy.deepcopy(_base())
    new["joint"]["pace"] *= 1.09
    new["opfence"]["phi"] *= 0.91
    assert compare(new, _base(), max_regress=0.10) == []


def test_improvements_never_fail():
    new = copy.deepcopy(_base())
    new["joint"]["pace"] *= 0.5
    new["opfence"]["phi"] *= 2.0
    assert compare(new, _base()) == []


def test_missing_system_fails_and_new_system_passes():
    new = copy.deepcopy(_base())
    del new["opfence"]
    new["experimental"] = {"pace": 99.0, "phi": 0.001}   # no bar yet
    violations = compare(new, _base())
    assert len(violations) == 1 and "opfence" in violations[0]


def test_untracked_metrics_ignored():
    base, new = _base(), copy.deepcopy(_base())
    new["joint"]["iter_s"] *= 100          # iter_s is informational only
    base["wall_seconds"] = 12.0            # scalar annotation: not a system
    new["wall_seconds"] = 9000.0
    assert compare(new, base) == []


def test_committed_baseline_gates_itself():
    """Schema drift guard: the committed baseline must contain tracked
    metrics and pass the gate against itself."""
    base = load_result(BASELINE)
    assert any(isinstance(v, dict) and "pace" in v and "phi" in v
               for v in base.values()), base
    assert compare(base, base) == []


def test_cli_exit_codes(tmp_path):
    base_p = tmp_path / "base.json"
    base_p.write_text(json.dumps({"result": _base()}))
    ok_p = tmp_path / "ok.json"
    ok_p.write_text(json.dumps({"result": _base()}))
    assert main([str(ok_p), str(base_p)]) == 0
    bad = copy.deepcopy(_base())
    bad["joint"]["pace"] *= 1.20           # the injected regression
    bad_p = tmp_path / "bad.json"
    bad_p.write_text(json.dumps({"result": bad}))
    assert main([str(bad_p), str(base_p)]) == 1
    # a tighter budget flips the verdict on a small regression
    small = copy.deepcopy(_base())
    small["joint"]["pace"] *= 1.06
    small_p = tmp_path / "small.json"
    small_p.write_text(json.dumps({"result": small}))
    assert main([str(small_p), str(base_p)]) == 0
    assert main([str(small_p), str(base_p), "--max-regress", "0.05"]) == 1


def test_write_baseline_round_trip(tmp_path):
    """--write-baseline refreshes the committed file from a fresh artifact:
    the rewritten baseline gates the producing run cleanly and drops scalar
    annotations that are not per-system metric maps."""
    new_p = tmp_path / "new.json"
    payload = dict(_base())
    payload["wall_seconds"] = 12.0          # harness annotation, not a system
    new_p.write_text(json.dumps({"result": payload}))
    base_p = tmp_path / "base.json"
    assert main([str(new_p), str(base_p), "--write-baseline"]) == 0
    refreshed = load_result(str(base_p))
    assert "wall_seconds" not in refreshed
    assert compare(_base(), refreshed) == []
    assert main([str(new_p), str(base_p)]) == 0


def _entries(paces):
    return [{"source": f"run{i}",
             "result": {"joint": {"pace": p, "phi": 1.0 / p}}}
            for i, p in enumerate(paces)]


def test_history_gate_monotone_degradation_fails():
    """Acceptance: three consecutive runs each strictly worse trip the
    trend gate even when every single step is inside the 10% margin."""
    violations = history_gate(_entries([0.025, 0.026, 0.027]))
    # pace rising AND phi falling monotonically -> both flagged
    assert len(violations) == 2
    assert any("joint.pace" in v and "rising" in v for v in violations)
    assert any("joint.phi" in v and "falling" in v for v in violations)


def test_history_gate_non_monotone_passes():
    assert history_gate(_entries([0.025, 0.027, 0.026])) == []
    assert history_gate(_entries([0.027, 0.026, 0.025])) == []   # improving


def test_history_gate_needs_full_window():
    assert history_gate(_entries([0.025, 0.026])) == []
    # only the trailing window counts: an old spike then flat is clean
    assert history_gate(_entries([0.030, 0.025, 0.025, 0.025])) == []


def test_history_gate_ignores_missing_series():
    entries = _entries([0.025, 0.026, 0.027])
    del entries[0]["result"]["joint"]["phi"]
    violations = history_gate(entries)
    assert len(violations) == 1 and "joint.pace" in violations[0]


def test_append_history_round_trip(tmp_path):
    hist = str(tmp_path / "HISTORY_joint_planning.jsonl")
    for i, pace in enumerate((0.025, 0.026)):
        result = {"joint": {"pace": pace, "phi": 1.0 / pace,
                            "iter_s": 0.1},    # untracked: stripped
                  "wall_seconds": 9.0}
        entries = append_history(result, hist, source=f"run{i}")
    assert len(entries) == 2
    with open(hist) as f:
        lines = [json.loads(line) for line in f if line.strip()]
    assert [e["source"] for e in lines] == ["run0", "run1"]
    assert lines[0]["result"] == {"joint": {"pace": 0.025, "phi": 40.0}}


def test_tracked_only_strips_annotations():
    out = tracked_only({"joint": {"pace": 0.02, "phi": 50.0, "iter_s": 1.0},
                        "wall_seconds": 9.0, "empty": {"iter_s": 2.0}})
    assert out == {"joint": {"pace": 0.02, "phi": 50.0}}


def test_history_path_naming():
    assert history_path_for("BENCH_joint_planning.json", "benchmarks/baselines") \
        == os.path.join("benchmarks", "baselines",
                        "HISTORY_joint_planning.jsonl")
    assert history_path_for("/x/y/other.json", "d") \
        == os.path.join("d", "HISTORY_other.jsonl")


def test_cli_history_gate(tmp_path):
    """--history appends and fails only once the monotone window fills."""
    hist_dir = str(tmp_path / "baselines")
    new_p = tmp_path / "BENCH_trend.json"
    for pace, want in ((0.025, 0), (0.026, 0), (0.027, 1)):
        result = copy.deepcopy(_base())
        result["joint"]["pace"] = pace
        new_p.write_text(json.dumps({"result": result}))
        assert main([str(new_p), "--history", "--history-dir",
                     hist_dir]) == want
    # baseline-less invocation without --history is a usage error
    with pytest.raises(SystemExit):
        main([str(new_p)])


def test_committed_baseline_separates_joint_from_opfence():
    """The refreshed baseline is pinned on a profile where co-planning
    actually matters: the blind pipeline's pace is strictly worse."""
    base = load_result(BASELINE)
    assert base["opfence"]["pace"] > 1.5 * base["joint"]["pace"], base
    assert base["joint"]["phi"] > base["opfence"]["phi"], base
