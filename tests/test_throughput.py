"""Eq. 2–4 throughput model + discrete-event simulator invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (NodeLoad, estimate_iteration, latency_pipelined,
                        latency_single_pass, network, plan_adatopk,
                        plan_uniform, schedule_equal_compute,
                        simulate_iteration, throughput)
from helpers import mlp_chain


loads_st = st.lists(
    st.tuples(st.floats(1e-6, 10.0), st.floats(0.0, 10.0)).map(
        lambda t: NodeLoad(comp=t[0], recv=t[1])),
    min_size=1, max_size=8)


@given(loads_st)
def test_eq3_reduces_to_eq2_at_one_microbatch(loads):
    assert latency_pipelined(loads, 1) == pytest.approx(
        latency_single_pass(loads))


@given(loads_st, st.integers(1, 16))
def test_eq3_monotone_in_microbatches(loads, nb):
    assert latency_pipelined(loads, nb + 1) >= latency_pipelined(loads, nb)


@given(loads_st, st.integers(1, 16))
def test_eq3_linear_extrapolation(loads, nb):
    """T(n_b) = T(1) + (n_b-1)·max_p max(C_p,R_p) exactly."""
    pace = max(l.bottleneck for l in loads)
    assert latency_pipelined(loads, nb) == pytest.approx(
        latency_single_pass(loads) + (nb - 1) * pace)


@given(loads_st, st.integers(1, 8), st.integers(1, 512))
def test_throughput_eq4(loads, nb, bs):
    phi = throughput(loads, nb, bs)
    assert phi == pytest.approx(bs / latency_pipelined(loads, nb))


class TestSimulator:
    def setup_method(self):
        g, shapes, params, inputs = mlp_chain(n_layers=12, d=128, batch=16)
        self.g, self.prof = g, g.annotate(shapes)
        self.cluster = network.paper_testbed(1, seed=0)
        self.sch = schedule_equal_compute(self.g, self.prof, self.cluster)

    def test_sim_time_monotone_in_microbatches(self):
        t = [simulate_iteration(self.g, self.prof, self.sch, self.cluster,
                                n_micro=n).iteration_time for n in (1, 2, 4)]
        assert t[0] <= t[1] <= t[2]

    def test_pipelining_overlaps(self):
        """4 micro-batches cost < 4x one micro-batch (overlap exists)."""
        t1 = simulate_iteration(self.g, self.prof, self.sch, self.cluster,
                                n_micro=1).iteration_time
        t4 = simulate_iteration(self.g, self.prof, self.sch, self.cluster,
                                n_micro=4).iteration_time
        assert t4 < 4 * t1

    def test_compression_reduces_time_and_bytes(self):
        dense = simulate_iteration(self.g, self.prof, self.sch, self.cluster,
                                   n_micro=4)
        plan = plan_uniform(self.g, self.sch.placement, ratio=100)
        comp = simulate_iteration(self.g, self.prof, self.sch, self.cluster,
                                  plan, n_micro=4)
        assert comp.comm_bytes < dense.comm_bytes
        assert comp.iteration_time <= dense.iteration_time

    def test_adatopk_comparable_to_uniform_and_beats_dense(self):
        """Paper Fig. 10: both compressors beat dense; uniform and adaptive
        land close (uniform compresses every link at r, adaptive hits only
        the slow links but at 3r — either can edge out the other depending
        on where the pipeline bottleneck sits)."""
        plan_u = plan_uniform(self.g, self.sch.placement, ratio=100)
        plan_a = plan_adatopk(self.g, self.prof, self.cluster,
                              self.sch.placement, ratio=100)
        t_d = simulate_iteration(self.g, self.prof, self.sch, self.cluster,
                                 n_micro=4).iteration_time
        t_u = simulate_iteration(self.g, self.prof, self.sch, self.cluster,
                                 plan_u, n_micro=4).iteration_time
        t_a = simulate_iteration(self.g, self.prof, self.sch, self.cluster,
                                 plan_a, n_micro=4).iteration_time
        assert t_u < t_d and t_a < t_d
        assert abs(t_u - t_a) < 0.15 * t_d

    def test_estimator_consistent_with_simulator(self):
        """Eq. 3 closed form and the event simulator agree within 2x (the
        estimator ignores per-link queuing the simulator models)."""
        est = estimate_iteration(self.g, self.prof, self.cluster,
                                 self.sch.placement, n_micro=4, batch_size=16)
        sim = simulate_iteration(self.g, self.prof, self.sch, self.cluster,
                                 n_micro=4)
        ratio = est.iteration_time / sim.iteration_time
        assert 0.3 < ratio < 3.0
