"""Static plan-verifier (repro.check): mutation-kill coverage.

Every mutation class the ISSUE names — cycle, orphan op,
double-assignment, capacity blow-out, past-break-even ratio,
non-conserving move-set — must be rejected with a typed, op-naming
error; every artifact the repo actually commits (configs, baselines,
executor traces) must pass clean.  Property tests (hypothesis) are
skipped individually when hypothesis is absent, per repo convention."""
import copy
import dataclasses
import json
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:              # tier-1 image has no hypothesis: property
    def given(*args, **kwargs):  # tests skip, everything else still runs
        def deco(fn):
            return pytest.mark.skip(reason="needs hypothesis")(fn)
        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    class _NullStrategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _NullStrategies()

from repro.check import (BaselineCheckError, CompressionCheckError,
                         GraphCheckError, ScheduleCheckError,
                         TraceOrderError, check_bench_result, check_graph,
                         check_moves, check_schedule, check_trace_order,
                         verify_plan, verify_replan, verify_schedule,
                         verify_trace)
from repro.check.__main__ import check_config
from repro.check.costs import check_cost_model
from repro.check.lint import lint_source
from repro.configs import ARCH_IDS
from repro.core import network
from repro.core.compression import encoding_break_even, plan_adatopk
from repro.core.costmodel import EdgeCostModel
from repro.core.estimator import ClusterSpec
from repro.core.executor import simulate_iteration
from repro.core.opgraph import OpGraph, OpNode, OpType, build_subdags
from repro.core.scheduler import schedule_opfence
from repro.elastic.replan import replan
from repro.obs.trace import TraceRecorder
from helpers import mlp_chain


def _toy(n_layers=8, n_dev=6):
    g, shapes, _, _ = mlp_chain(n_layers=n_layers, d=16)
    prof = g.annotate(shapes)
    cluster = network.geo_random(n_dev, n_sites=2, seed=0)
    sched = schedule_opfence(g, prof, cluster)
    return g, shapes, prof, cluster, sched


# --------------------------------------------------- typed IR construction --
def test_opgraph_add_names_duplicate_op():
    g = OpGraph()
    g.add(OpNode("a", OpType.PLACEHOLDER))
    with pytest.raises(GraphCheckError) as ei:
        g.add(OpNode("a", OpType.PLACEHOLDER))
    assert "duplicate-op" in ei.value.codes
    assert ei.value.findings[0].where == "a"
    assert isinstance(ei.value, ValueError)   # legacy catch sites unbroken


def test_opgraph_add_names_dangling_dep():
    g = OpGraph()
    with pytest.raises(GraphCheckError) as ei:
        g.add(OpNode("b", OpType.NON_PARAMETRIC, args=("ghost",)))
    assert "dangling-dep" in ei.value.codes
    assert ei.value.findings[0].where == "b"
    assert "ghost" in str(ei.value)


def test_build_subdags_typed_coverage_errors():
    g, _, _, _, _ = _toy()
    names = list(g.nodes)
    with pytest.raises(GraphCheckError) as ei:
        build_subdags(g, [names, names[:1]])      # l-th op assigned twice
    assert "double-assignment" in ei.value.codes
    with pytest.raises(GraphCheckError) as ei:
        build_subdags(g, [names[:-1]])            # one op dropped
    assert "unassigned-op" in ei.value.codes
    assert ei.value.findings[0].where == names[-1]


def test_subdag_rejects_duplicate_node_names():
    from repro.core.opgraph import SubDag
    with pytest.raises(GraphCheckError) as ei:
        SubDag(index=3, node_names=["x", "y", "x"])
    assert "duplicate-op" in ei.value.codes and \
        ei.value.findings[0].where == "x"


# ------------------------------------------------------------ graph checks --
def test_check_graph_names_cycle_members():
    g, _, _, _, _ = _toy(n_layers=4)
    g.nodes["l0"].args = ("x", "l2")     # back edge: l0 <- l2 <- l1 <- l0
    findings = check_graph(g)
    codes = {f.code for f in findings}
    assert "cycle" in codes
    cyc = next(f for f in findings if f.code == "cycle")
    assert "l0" in cyc.message and "l2" in cyc.message


def test_check_graph_flags_op_unreachable_from_loss():
    g, shapes, _, _, _ = _toy(n_layers=4)
    g.add(OpNode("orphan", OpType.PARAMETRIC, args=("l3",),
                 out_shape_fn=lambda s: s))     # trains nothing: no loss path
    findings = check_graph(g, shapes)
    bad = [f for f in findings if f.code == "unreachable-from-loss"]
    assert [f.where for f in bad] == ["orphan"]
    assert all(f.severity == "error" for f in bad)


def test_check_graph_clean_on_valid_model():
    g, shapes, prof, _, _ = _toy()
    assert check_graph(g, shapes) == []
    from repro.check import check_profiles
    assert check_profiles(g, prof, shapes) == []


# --------------------------------------------------------- schedule checks --
def test_schedule_mutation_dropped_op_is_caught():
    g, _, prof, cluster, sched = _toy()
    mut = copy.deepcopy(sched)
    d = mut.stage_devices()[0]
    dropped = mut.assignment[d].pop()
    with pytest.raises(ScheduleCheckError) as ei:
        verify_schedule(g, mut, profiles=prof, cluster=cluster)
    assert "unassigned-op" in ei.value.codes
    assert any(f.where == dropped for f in ei.value.findings)


def test_schedule_mutation_double_assignment_is_caught():
    g, _, prof, cluster, sched = _toy()
    mut = copy.deepcopy(sched)
    devs = mut.stage_devices()
    dup = mut.assignment[devs[0]][0]
    mut.assignment[devs[-1]].append(dup)
    with pytest.raises(ScheduleCheckError) as ei:
        verify_schedule(g, mut, profiles=prof, cluster=cluster)
    assert "double-assignment" in ei.value.codes
    assert any(f.where == dup for f in ei.value.findings)


def test_schedule_mutation_swapped_stages_is_caught():
    g, _, prof, cluster, sched = _toy()
    mut = copy.deepcopy(sched)
    devs = mut.stage_devices()
    a, b = devs[0], devs[-1]
    mut.assignment[a], mut.assignment[b] = \
        mut.assignment[b], mut.assignment[a]   # stage order now violates chain
    findings = check_schedule(g, mut, profiles=prof, cluster=cluster)
    assert any(f.code in ("stage-order", "non-contiguous-stage")
               for f in findings)


def test_schedule_capacity_blow_out_names_biggest_op():
    g, _, prof, cluster, sched = _toy()
    tiny = ClusterSpec(
        [dataclasses.replace(d, mem_bytes=16.0) for d in cluster.devices],
        cluster._links)
    with pytest.raises(ScheduleCheckError) as ei:
        verify_schedule(g, sched, profiles=prof, cluster=tiny)
    assert "capacity" in ei.value.codes
    cap = next(f for f in ei.value.findings if f.code == "capacity")
    assert cap.where in g.nodes          # the dominating op is named


def test_planner_output_passes_and_verify_flag_works():
    g, _, prof, cluster, _ = _toy()
    sched = schedule_opfence(g, prof, cluster, verify=True)
    assert check_schedule(g, sched, profiles=prof, cluster=cluster) == []


# ------------------------------------------- compression/cost-model checks --
def test_adatopk_plan_passes_then_inflated_ratio_is_caught():
    g, _, prof, cluster, sched = _toy()
    plan = plan_adatopk(g, prof, cluster, sched.placement, 100.0)
    verify_plan(g, prof, plan, placement=sched.placement)
    assert plan.edge_ratio, "toy model must have at least one cross edge"
    edge = next(iter(plan.edge_ratio))
    be = encoding_break_even("paper", 4)
    mut = dataclasses.replace(
        plan, edge_ratio={**plan.edge_ratio, edge: be * 0.9})
    with pytest.raises(CompressionCheckError) as ei:
        verify_plan(g, prof, mut, placement=sched.placement)
    assert "ratio-below-break-even" in ei.value.codes
    assert any(f.where == f"{edge[0]}->{edge[1]}" for f in ei.value.findings)


def test_compression_invalid_ratio_and_unknown_op():
    g, _, prof, cluster, sched = _toy()
    plan = plan_adatopk(g, prof, cluster, sched.placement, 100.0)
    edge = next(iter(plan.edge_ratio))
    bad = dataclasses.replace(plan, edge_ratio={edge: float("nan"),
                                                ("ghost", "l1"): 8.0})
    with pytest.raises(CompressionCheckError) as ei:
        verify_plan(g, prof, bad)
    assert {"ratio-invalid", "unknown-op"} <= set(ei.value.codes)


def test_cost_model_parity_holds_and_clamp_violation_is_caught():
    g, _, prof, cluster, sched = _toy()
    plan = plan_adatopk(g, prof, cluster, sched.placement, 100.0)
    model = EdgeCostModel(g, prof, cluster, plan)
    assert check_cost_model(model, sched.placement) == []
    rigged = EdgeCostModel(g, prof, cluster, plan,
                           link_corrections={(0, 1): 80.0})
    findings = check_cost_model(rigged, sched.placement)
    assert any(f.code == "correction-out-of-clamp" for f in findings)


# ------------------------------------------------------------ elastic checks --
def _replan_scenario():
    g, _, prof, cluster, sched = _toy(n_layers=10, n_dev=6)
    dead = [sched.stage_devices()[0]]
    alive = [d for d in range(len(cluster)) if d not in dead]
    rp = replan(g, prof, cluster, sched, alive=alive, dead=dead)
    return g, prof, cluster, sched, rp


def test_replan_winner_passes_verification():
    g, prof, cluster, sched, rp = _replan_scenario()
    verify_replan(g, prof, rp, sched, cluster=cluster)


def test_nonconserving_move_set_is_caught():
    from repro.check import ElasticCheckError
    g, prof, cluster, sched, rp = _replan_scenario()
    moves = list(rp.migration.moves)
    assert moves, "killing the first stage must move state"
    # mutation 1: drop a move — parameters silently vanish
    lost = moves[0]
    findings = check_moves(sched, rp.schedule, prof, moves[1:],
                           dead=rp.dead)
    assert any(f.code == "missing-move" and f.where == lost.op
               for f in findings)
    # mutation 2: inflate the byte account — state no longer conserved
    inflated = [dataclasses.replace(moves[0], nbytes=moves[0].nbytes + 1)] \
        + moves[1:]
    findings = check_moves(sched, rp.schedule, prof, inflated, dead=rp.dead)
    assert any(f.code == "state-bytes-mismatch" and f.where == lost.op
               for f in findings)
    # mutation 3: reroute to the wrong destination
    rerouted = [dataclasses.replace(moves[0], dst=moves[0].dst + 1)] \
        + moves[1:]
    findings = check_moves(sched, rp.schedule, prof, rerouted, dead=rp.dead)
    assert any(f.code in ("wrong-destination", "phantom-move")
               for f in findings)
    # and the raising wrapper carries the typed error
    mut = dataclasses.replace(rp, migration=dataclasses.replace(
        rp.migration, moves=moves[1:]))
    with pytest.raises(ElasticCheckError) as ei:
        verify_replan(g, prof, mut, sched, cluster=cluster)
    assert "missing-move" in ei.value.codes


def test_score_table_winner_mismatch_is_caught():
    g, prof, cluster, sched, rp = _replan_scenario()
    mut = dataclasses.replace(rp, mode="keep" if rp.mode != "keep"
                              else "full")
    from repro.check import check_replan
    found = check_replan(g, prof, mut, sched, cluster=cluster)
    assert any(f.code == "score-winner-mismatch" for f in found)


# --------------------------------------------------------- trace ordering --
def test_simulated_iteration_trace_passes_happens_before():
    g, _, prof, cluster, sched = _toy()
    plan = plan_adatopk(g, prof, cluster, sched.placement, 100.0)
    rec = TraceRecorder()
    simulate_iteration(g, prof, sched, cluster, plan, n_micro=3, trace=rec)
    findings = check_trace_order(rec.events())
    assert [f for f in findings if f.severity == "error"] == []


def test_trace_order_catches_overlapping_sends_on_one_link():
    rec = TraceRecorder()
    rec.span("link.transfer", "Fxfer.mb0", "link 0->1", 0.0, 2.0)
    rec.span("link.transfer", "Fxfer.mb1", "link 0->1", 1.0, 3.0)  # overlap
    rec.span("stage.fwd", "F1.mb0", "dev1", 2.0, 4.0)
    rec.span("stage.fwd", "F1.mb1", "dev1", 4.0, 6.0)
    findings = check_trace_order(rec.events())
    assert any(f.code == "overlap" and f.where == "link 0->1"
               for f in findings)


def test_trace_order_catches_compute_before_inbound_transfer():
    rec = TraceRecorder()
    rec.span("link.transfer", "Fxfer.mb0", "link 0->1", 0.0, 5.0)
    rec.span("stage.fwd", "F1.mb0", "dev1", 3.0, 6.0)   # starts mid-transfer
    with pytest.raises(TraceOrderError) as ei:
        verify_trace(rec.events())
    assert "compute-before-transfer" in ei.value.codes
    assert any("dev1" in f.where for f in ei.value.findings)


def test_trace_order_catches_nonmonotonic_device_track():
    rec = TraceRecorder()
    rec.span("stage.fwd", "F0.mb1", "dev0", 5.0, 6.0)
    rec.span("stage.fwd", "F0.mb0", "dev0", 0.0, 1.0)   # recorded later,
    findings = check_trace_order(rec.events())          # starts earlier
    assert any(f.code == "nonmonotonic-track" and f.where == "dev0"
               for f in findings)


def test_trace_order_jsonl_roundtrip(tmp_path):
    from repro.obs.export import write_jsonl
    g, _, prof, cluster, sched = _toy()
    rec = TraceRecorder()
    simulate_iteration(g, prof, sched, cluster, n_micro=2, trace=rec)
    p = tmp_path / "t.jsonl"
    write_jsonl(rec, str(p))
    assert [f for f in verify_trace(str(p)) if f.severity == "error"] == []


# -------------------------------------------------------- bench baselines --
BASELINE = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "baselines", "BENCH_baseline_joint.json")


def test_committed_baseline_passes_schema():
    with open(BASELINE) as f:
        payload = json.load(f)
    assert check_bench_result(payload, source=BASELINE) == []


def test_truncated_or_poisoned_baseline_fails_loudly(tmp_path):
    from benchmarks.compare import load_result
    empty = tmp_path / "empty.json"
    empty.write_text(json.dumps({"result": {}}))
    with pytest.raises(BaselineCheckError):
        load_result(str(empty))
    nan = tmp_path / "nan.json"
    nan.write_text('{"result": {"joint": {"pace": NaN, "phi": 1.0}}}')
    with pytest.raises(BaselineCheckError) as ei:
        load_result(str(nan))
    assert "non-finite-metric" in ei.value.codes
    zero = tmp_path / "zero.json"
    zero.write_text(json.dumps({"result": {"joint": {"pace": 0.0}}}))
    with pytest.raises(BaselineCheckError) as ei:
        load_result(str(zero))
    assert "bad-tracked-metric" in ei.value.codes


# ------------------------------------------------------------- custom lint --
def test_lint_flags_raw_byte_math_and_wallclock():
    findings = lint_source(
        "def f(link, numel, x):\n"
        "    import time\n"
        "    t0 = time.time()\n"
        "    return numel * x.itemsize + link.beta * numel\n",
        "core/rogue.py")
    codes = [f.code for f in findings]
    assert codes.count("raw-byte-math") == 2
    assert "wallclock-in-sim" in codes


def test_lint_allows_sanctioned_modules_and_main_prints():
    assert lint_source("k = numel * itemsize\n",
                       "core/compression.py") == []
    assert lint_source("def main():\n    print('ok')\n", "obs/x.py") == []
    assert lint_source("print('no')\n", "obs/x.py") != []


def test_live_tree_is_lint_clean():
    from repro.check.lint import lint_tree
    assert [str(f) for f in lint_tree()] == []


# ------------------------------------------------------- committed configs --
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_committed_config_passes_full_sweep(arch):
    findings = check_config(arch)
    assert [str(f) for f in findings
            if f.severity == "error"] == [], arch


# ---------------------------------------------------------- property tests --
@given(st.floats(min_value=1.001, max_value=2.999))
@settings(max_examples=20, deadline=None)
def test_property_any_subbreakeven_ratio_is_rejected(ratio):
    g, _, prof, cluster, sched = _toy()
    plan = plan_adatopk(g, prof, cluster, sched.placement, 100.0)
    edge = next(iter(plan.edge_ratio))
    mut = dataclasses.replace(plan,
                              edge_ratio={**plan.edge_ratio, edge: ratio})
    with pytest.raises(CompressionCheckError):
        verify_plan(g, prof, mut, placement=sched.placement)


@given(st.integers(min_value=0, max_value=7))
@settings(max_examples=8, deadline=None)
def test_property_dropping_any_op_is_caught(idx):
    g, _, prof, cluster, sched = _toy(n_layers=8)
    mut = copy.deepcopy(sched)
    chain_ops = [op for d in mut.stage_devices() for op in mut.assignment[d]]
    victim = chain_ops[idx % len(chain_ops)]
    for d in mut.stage_devices():
        if victim in mut.assignment[d]:
            mut.assignment[d].remove(victim)
    findings = check_schedule(g, mut)
    assert any(f.code == "unassigned-op" and f.where == victim
               for f in findings)


@given(st.integers(min_value=1, max_value=10 ** 6))
@settings(max_examples=20, deadline=None)
def test_property_any_byte_skew_breaks_conservation(skew):
    g, prof, cluster, sched, rp = _replan_scenario()
    moves = list(rp.migration.moves)
    mut = [dataclasses.replace(moves[0], nbytes=moves[0].nbytes + skew)] \
        + moves[1:]
    findings = check_moves(sched, rp.schedule, prof, mut, dead=rp.dead)
    assert any(f.code == "state-bytes-mismatch" for f in findings)
