"""Closed-loop epoch planning: per-link telemetry emission, windowed/MAD
link aggregation, calibration that converges instead of compounding, the
controller's auto-fit + hysteresis + pace-divergence re-plan trigger, and
the joint planner driving epoch plans end to end."""
import numpy as np
import pytest

from repro.core import EdgeCostModel, fit_link_corrections, network
from repro.core.compression import plan_adatopk
from repro.core.estimator import predict_step_times
from repro.core.executor import LinkTiming, TelemetrySink, simulate_iteration
from repro.core.scheduler import schedule_joint, schedule_opfence
from repro.elastic import (ChurnEvent, ChurnTrace, ElasticController,
                           MembershipView, TelemetryLog, replan)
from helpers import mlp_chain


def _setup(n_layers=12, d=512, batch=8, seed=0):
    """β-dominated regime: 16KB boundaries over fat-pipe links, where a
    bandwidth drop shifts observed transfer seconds ≈ proportionally (on
    α-dominated links a congested wire is invisible to the fit)."""
    g, shapes, params, inputs = mlp_chain(n_layers=n_layers, d=d, batch=batch)
    prof = g.annotate(shapes)
    cluster = network.fat_pipe_sites(n=8, n_sites=2, seed=seed)
    return g, prof, cluster


# ------------------------------------------------------- link telemetry ----
def test_simulator_emits_link_samples_matching_model():
    """Every cross-stage transfer surfaces as one LinkTiming whose bytes and
    seconds are exactly the unified model's — the raw calibration input is
    bias-free by construction."""
    g, prof, cluster = _setup()
    sch = schedule_opfence(g, prof, cluster)
    sink = TelemetrySink()
    n_micro = 2
    simulate_iteration(g, prof, sch, cluster, n_micro=n_micro, telemetry=sink)
    assert sink.link_samples
    model = EdgeCostModel(g, prof, cluster)
    placement = sch.placement
    per_link = {}
    for s in sink.link_samples:
        per_link[(s.src, s.dst)] = per_link.get((s.src, s.dst), 0.0) \
            + s.seconds
        # each sample's seconds is the α–β time of its own bytes
        assert s.seconds == pytest.approx(
            cluster.comm_time(s.src, s.dst, s.nbytes), rel=1e-12)
    expect = {}
    for (a, n) in model.cross_edges(placement):
        src, dst = placement[a], placement[n]
        t = model.edge_seconds(a, n, src, dst)
        # FP rides (src, dst), BP rides (dst, src); n_micro each
        expect[(src, dst)] = expect.get((src, dst), 0.0) + n_micro * t
        expect[(dst, src)] = expect.get((dst, src), 0.0) + n_micro * t
    for k, v in per_link.items():
        assert v == pytest.approx(expect[k], rel=1e-9), k


def test_link_window_mad_rejects_spike_and_withholds_sparse():
    log = TelemetryLog(window=5, mad_k=3.5)
    for step in range(5):
        sec = 1.0 if step != 2 else 9.0          # one congested step
        log.record_link(LinkTiming(src=0, dst=1, nbytes=1e6, seconds=sec,
                                   step=step))
    log.record_link(LinkTiming(src=2, dst=3, nbytes=1e6, seconds=1.0, step=0))
    samples = log.link_samples(min_steps=3)
    assert (2, 3) not in samples             # 1 step < min_steps: withheld
    pairs = samples[(0, 1)]
    assert len(pairs) == 4                   # the spiked step is rejected
    assert all(s == pytest.approx(1.0) for _, s in pairs)


def test_link_step_folding_is_alpha_exact():
    """K transfers in one step fold to the per-step MEAN pair, so a healthy
    link fits to exactly 1.0 — the raw per-step total would carry K α's
    against the model's one and bias every correction upward."""
    cluster = network.homogeneous_lan(n=2, bandwidth_Bps=1e8, alpha=5e-2)
    log = TelemetryLog(window=5)
    for step in range(4):
        for _ in range(3):                   # 3 transfers per step
            log.record_link(LinkTiming(
                src=0, dst=1, nbytes=2e6,
                seconds=cluster.comm_time(0, 1, 2e6), step=step))
    corr = fit_link_corrections(log.link_samples(min_steps=3), cluster)
    assert corr[(0, 1)] == pytest.approx(1.0, rel=1e-12)


# ---------------------------------------------------- calibration bugfix ---
def test_refits_converge_and_do_not_compound():
    """Regression (satellite bugfix): repeated re-fit/install cycles under
    stationary telemetry must converge on the measured ratio.  Fitting each
    window against the previously *corrected* predictions instead of the
    base spec compounds through the clamp (1.7, 2.89, 4.0, 4.0·4.0-clamped…)
    and the strawman below demonstrates exactly that drift."""
    rng = np.random.default_rng(0)
    cluster = network.homogeneous_lan(n=2, bandwidth_Bps=1e9, alpha=1e-3)
    sizes = [1e6, 4e6, 16e6]
    model = EdgeCostModel.__new__(EdgeCostModel)  # placeholder, built below
    installed = {}
    history = []
    for _ in range(8):
        measured = {(0, 1): [
            (s, 1.7 * cluster.comm_time(0, 1, s)
             * float(rng.uniform(0.95, 1.05))) for s in sizes]}
        # the API under test: the fit goes against the uncorrected base even
        # when handed a corrections-bearing model
        g, shapes, _, _ = mlp_chain(n_layers=2, d=8, batch=2)
        prof = g.annotate(shapes)
        model = EdgeCostModel(g, prof, cluster,
                              link_corrections=installed)
        fitted = fit_link_corrections(measured, model)
        installed = dict(fitted)
        history.append(fitted[(0, 1)])
    assert all(abs(c - 1.7) < 0.15 for c in history), history

    # strawman: multiplying each window's (absolute) fit into the installed
    # correction — "re-fits compound with previously installed corrections"
    # — drifts geometrically under the SAME stationary telemetry, because
    # every window re-measures the full 1.7 against the base spec
    compounding = 1.0
    for _ in range(8):
        obs = 1.7 * cluster.comm_time(0, 1, 4e6)
        fitted_vs_base = float(np.clip(
            obs / cluster.comm_time(0, 1, 4e6), 0.25, 4.0))
        compounding *= fitted_vs_base       # compose instead of replace
    assert compounding > 4.0 * 1.7          # drifted far past the truth


# --------------------------------------------------- controller closed loop -
def _fat_pipe_victim(probe, cluster):
    """A stage device whose pipeline-adjacent links are all intra-site (see
    benchmarks/churn.py: degrading a WAN-adjacent node degrades the
    max-compressed WAN edge, which Eq. 7 cannot relieve)."""
    devs = probe.schedule.stage_devices()
    wan_bw = min(cluster.link(a, b).bandwidth for a, b in zip(devs, devs[1:]))
    adjacent = {d: [] for d in devs}
    for a, b in zip(devs, devs[1:]):
        adjacent[a].append((a, b))
        adjacent[b].append((a, b))
    eligible = [d for d in devs
                if adjacent[d] and all(
                    cluster.link(*p).bandwidth > 10.0 * wan_bw
                    for p in adjacent[d])]
    model = EdgeCostModel(probe.graph, probe.profiles, cluster, probe.plan)
    placement = probe.schedule.placement
    weight = {d: 0.0 for d in devs}
    for (a, n) in model.cross_edges(placement):
        pair = (placement[a], placement[n])
        for d in pair:
            if pair in adjacent.get(d, []):
                weight[d] += model.edge_seconds(a, n, *pair)
    return max(eligible, key=lambda d: weight[d])


def test_closed_loop_calibration_converges_and_replan_beats_static():
    """Acceptance-shaped unit: a link secretly at 0.5× spec bandwidth.  The
    calibrated controller's per-link correction converges to the simulated
    truth (≈2×) within a few windows, its repriced detector predictions
    match the telemetry (severity ≈ 1: no phantom straggler), the pace
    divergence triggers a ``calibration`` re-plan, and the re-planned run
    beats the static-cost-model controller's post-degradation throughput."""
    g, prof, cluster = _setup()
    common = dict(n_micro=2, planner="joint", joint_ratio=64.0,
                  detector_threshold=20.0, calibrate_min_samples=3,
                  replan_pace_margin=0.2)
    probe = ElasticController(g, prof, cluster, ChurnTrace(()),
                              calibrate_interval=0, **common)
    t1 = probe.run(steps=1).steps[0].step_seconds
    victim = _fat_pipe_victim(probe, cluster)
    t_deg = 4.0 * t1
    trace = ChurnTrace((ChurnEvent(time=t_deg, kind="slowlink", node=victim,
                                   factor=0.5),))
    runs = {}
    for name, interval in (("cal", 3), ("static", 0)):
        ctrl = ElasticController(g, prof, cluster, trace,
                                 calibrate_interval=interval, **common)
        runs[name] = (ctrl, ctrl.run(steps=30))
    ctrl, res = runs["cal"]
    # corrections converged to the simulated truth on the degraded links
    assert ctrl.link_corrections, "no correction fitted"
    for (i, j), c in ctrl.link_corrections.items():
        assert victim in (i, j)
        assert c == pytest.approx(2.0, rel=0.15)
    # calibrated prediction matches simulated truth: no node looks degraded
    # once the link belief is correct (the detector was repriced in place)
    obs = ctrl.telemetry.node_step_times()
    pred = predict_step_times(g, prof, ctrl.believed_cluster(),
                              ctrl.schedule.placement,
                              cost_model=ctrl.believed_model())
    for d in obs:
        assert obs[d] == pytest.approx(pred[d], rel=0.15), d
    assert "calibration" in [e.cause for e in res.epochs]
    # the triggered re-plan beats the uncalibrated schedule post-degradation
    def post_phi(r):
        useful = sum(1 for s in r.steps if not s.lost and s.clock > t_deg)
        return useful / (r.total_seconds - t_deg)
    assert post_phi(res) > post_phi(runs["static"][1])
    stat_ctrl, stat_res = runs["static"]
    assert stat_ctrl.link_corrections == {}
    assert [e.cause for e in stat_res.epochs] == ["initial"]


def test_hysteresis_noisy_unbiased_telemetry_zero_replans():
    """Noisy but unbiased link telemetry must produce zero calibration
    re-plans: the MAD window + relative hysteresis band absorb jitter that
    averages to the spec."""
    g, prof, cluster = _setup()
    ctrl = ElasticController(g, prof, cluster, ChurnTrace(()), n_micro=2,
                             calibrate_interval=3, calibrate_min_samples=3)
    ctrl.run(steps=12)                      # clean run: nothing to correct
    assert ctrl.calibration_count == 0
    assert ctrl.link_corrections == {}
    assert [e.cause for e in ctrl.epoch_records] == ["initial"]
    # now feed synthetic ±10% unbiased jitter for many windows
    rng = np.random.default_rng(7)
    devs = ctrl.schedule.stage_devices()
    pairs = list(zip(devs, devs[1:]))
    fired = 0
    for step in range(12, 60):
        for (a, b) in pairs:
            base = cluster.comm_time(a, b, 1e5)
            ctrl.telemetry.record_link(LinkTiming(
                src=a, dst=b, nbytes=1e5,
                seconds=base * float(rng.uniform(0.9, 1.1)), step=step))
        if step % 3 == 0:
            fired += bool(ctrl._calibrate())
    assert fired == 0
    assert ctrl.calibration_count == 0
    assert ctrl.link_corrections == {}


# --------------------------------------------------------- joint planning --
def test_controller_joint_planner_drives_epoch_plans():
    """planner='joint': the controller's initial schedule is the co-planner's
    and the installed plan is its AdaTopK fixed-point companion — co-planning
    actually runs the epochs, it is not just a registry entry."""
    g, prof, cluster = _setup()
    ratio = 32.0
    ctrl = ElasticController(g, prof, cluster, ChurnTrace(()), n_micro=2,
                             planner="joint", joint_ratio=ratio)
    jp = schedule_joint(g, prof, cluster, ratio=ratio)
    assert ctrl.schedule.assignment == jp.schedule.assignment
    expect_plan = plan_adatopk(g, prof, cluster, jp.schedule.placement, ratio)
    assert ctrl.plan.edge_ratio == expect_plan.edge_ratio
    assert ctrl.plan.edge_ratio            # something actually compressed
    with pytest.raises(ValueError):
        ElasticController(g, prof, cluster, ChurnTrace(()), planner="bogus")


def test_replan_joint_full_candidate_and_keep():
    g, prof, cluster = _setup()
    old = schedule_opfence(g, prof, cluster)
    alive = list(range(len(cluster)))
    victim = old.stage_devices()[1]
    surv = [d for d in alive if d != victim]
    rp = replan(g, prof, cluster, old, alive=surv, dead=[victim],
                mode="full", planner="joint", joint_ratio=32.0)
    direct = schedule_joint(g, prof, cluster, ratio=32.0, device_subset=surv)
    assert rp.schedule.assignment == direct.schedule.assignment
    # keep candidate: with every stage host alive and moves priced at
    # astronomic state sizes, staying put wins outright
    rp2 = replan(g, prof, cluster, old, alive=alive,
                 opt_state_mult=1e6,
                 cost_model=EdgeCostModel(g, prof, cluster))
    assert rp2.mode in ("keep", "anchored")
    assert rp2.migration.moves == []
    # a dead stage host disqualifies keep
    rp3 = replan(g, prof, cluster, old, alive=surv, dead=[victim])
    assert rp3.mode != "keep"
    with pytest.raises(ValueError):
        replan(g, prof, cluster, old, alive=alive, planner="bogus")


def test_pin_boundaries_defaults_by_migration_mode():
    g, prof, cluster = _setup(n_layers=8)
    trace = ChurnTrace(())
    assert ElasticController(g, prof, cluster, trace).pin_boundaries is False
    assert ElasticController(g, prof, cluster, trace,
                             migration_mode="overlap").pin_boundaries is True
    assert ElasticController(g, prof, cluster, trace,
                             migration_mode="overlap",
                             pin_boundaries=False).pin_boundaries is False


# ----------------------------------------------------------- membership ----
def test_slowlink_event_roundtrip_and_ground_truth():
    trace = ChurnTrace.build([
        {"t": 2.0, "kind": "slowlink", "node": 1, "factor": 0.5},
        {"t": 6.0, "kind": "recover", "node": 1},
    ])
    back = ChurnTrace.from_json(trace.to_json())
    assert back == trace
    view = MembershipView(4, trace, lease_s=1.0)
    view.poll(3.0)
    assert view.link_factor == {1: 0.5}
    assert view.epoch == 0                 # ground truth, not a membership op
    view.poll(7.0)
    assert view.link_factor == {}
    with pytest.raises(ValueError):
        ChurnEvent(time=0.0, kind="slowlink", node=0, factor=1.5)
