"""OP-DAG IR: structure, shapes, Table-2/3 semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.opgraph import (OpGraph, OpNode, OpType, build_subdags, chain)


def paper_fig3_graph():
    """The exact example DAG of paper Fig. 3 / Tables 2–3:
    Input->Conv->Add<-ReLU<-TensorA; Add->Linear->CE<-Label."""
    g = OpGraph("fig3")
    g.add(OpNode("Input", OpType.PLACEHOLDER))
    g.add(OpNode("Conv", OpType.PARAMETRIC, args=("Input",),
                 init_fn=lambda r, s: {"w": jnp.ones((4, 4))},
                 apply_fn=lambda p, x: x @ p["w"],
                 out_shape_fn=lambda s: (s[0], 4),
                 flops_fn=lambda s: 2 * s[0] * 4 * 4))
    g.add(OpNode("TensorA", OpType.VARIABLE, meta={"shape": (2, 4)}))
    g.add(OpNode("ReLu", OpType.NON_PARAMETRIC, args=("TensorA",),
                 apply_fn=lambda p, x: jax.nn.relu(x)))
    g.add(OpNode("Add", OpType.NON_PARAMETRIC, args=("ReLu", "Conv"),
                 apply_fn=lambda p, a, b: a + b,
                 out_shape_fn=lambda a, b: a))
    g.add(OpNode("Linear", OpType.PARAMETRIC, args=("Add",),
                 init_fn=lambda r, s: {"w": jnp.ones((4, 3))},
                 apply_fn=lambda p, x: x @ p["w"],
                 out_shape_fn=lambda s: (s[0], 3)))
    g.add(OpNode("Label", OpType.PLACEHOLDER))
    g.add(OpNode("CE", OpType.LOSS, args=("Linear", "Label"),
                 apply_fn=lambda p, x, y: jnp.mean((x - y) ** 2),
                 out_shape_fn=lambda a, b: ()))
    return g


def test_topo_order_and_users():
    g = paper_fig3_graph()
    order = g.topo_order()
    assert order.index("Conv") < order.index("Add") < order.index("CE")
    assert g.users["Conv"] == ["Add"]
    assert set(g.users["Add"]) == {"Linear"}


def test_cycle_detection():
    g = OpGraph()
    g.add(OpNode("a", OpType.PLACEHOLDER))
    g.add(OpNode("b", OpType.NON_PARAMETRIC, args=("a",)))
    g.nodes["a"].__dict__["args"] = ("b",)  # forge a cycle
    with pytest.raises(ValueError, match="cycle"):
        g.topo_order()


def test_shape_inference_and_profiles():
    g = paper_fig3_graph()
    shapes = g.infer_shapes({"Input": (2, 4), "Label": (2, 3)})
    assert shapes["Conv"] == (2, 4)
    assert shapes["Linear"] == (2, 3)
    prof = g.annotate({"Input": (2, 4), "Label": (2, 3)})
    assert prof["Conv"].fwd_flops == 2 * 2 * 4 * 4
    assert prof["Linear"].out_bytes == 2 * 3 * 4


def test_subdags_match_paper_table3():
    """Paper Table 3: CompNode1={Input,Conv}, 2={TensorA,ReLu},
    3={Label,Add,Linear,CE}."""
    g = paper_fig3_graph()
    sds = build_subdags(g, [["Input", "Conv"], ["TensorA", "ReLu"],
                            ["Label", "Add", "Linear", "CE"]])
    assert sds[0].send_acti == ["Conv"] and sds[0].required_acti == []
    assert sds[0].required_grad == [("Conv", "Add")]
    assert sds[1].send_acti == ["ReLu"]
    assert sds[1].required_grad == [("ReLu", "Add")]
    assert set(sds[2].required_acti) == {"Conv", "ReLu"}
    assert set(sds[2].send_grad) == {("Conv", "Add"), ("ReLu", "Add")}
    assert sds[2].send_acti == []


def test_apply_executes_full_graph():
    g = paper_fig3_graph()
    params = g.init(jax.random.PRNGKey(0),
                    {"Input": (2, 4), "Label": (2, 3)})
    vals = g.apply(params, {"Input": jnp.ones((2, 4)),
                            "Label": jnp.zeros((2, 3))},
                   variables={"TensorA": jnp.ones((2, 4))})
    assert vals["CE"].shape == ()
    assert np.isfinite(float(vals["CE"]))


def test_max_degree_small_for_chain():
    g = paper_fig3_graph()
    assert g.max_degree() <= 2  # paper Observation 1
