"""Error-feedback RAD (beyond-paper): EF on gradient edges must (a) keep
the dense semantics when compression is off-path, and (b) transmit the full
gradient signal over time — the cure for the compressed-training divergence
measured in EXPERIMENTS.md §Convergence.  Also covers the runtime dispatch:
``CompressionPlan.error_feedback=True`` must actually route
``DecentralizedRuntime.train_step`` through the EF path (regression — the
flag used to be silently ignored)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DecentralizedRuntime, PipelineProgram, init_ef_state,
                        network, pipeline_loss_and_grad,
                        pipeline_loss_and_grad_ef, plan_uniform,
                        schedule_opfence, single_device_loss_and_grad)
from helpers import mlp_chain


def _setup():
    g, shapes, params, inputs = mlp_chain(n_layers=6, d=16)
    prof = g.annotate(shapes)
    cluster = network.paper_testbed(1, seed=0)
    sch = schedule_opfence(g, prof, cluster)
    prog = PipelineProgram.build(g, sch.pipeline_subdags(g))
    return g, params, inputs, sch, prog


def test_ef_matches_plain_on_first_step_with_zero_residual():
    g, params, inputs, sch, prog = _setup()
    plan = plan_uniform(g, sch.placement, ratio=4)
    ef0 = init_ef_state(prog, params, inputs)
    loss_a, grads_a = pipeline_loss_and_grad(prog, params, inputs, plan)
    loss_b, grads_b, ef1 = pipeline_loss_and_grad_ef(prog, params, inputs,
                                                     plan, ef0)
    assert np.allclose(float(loss_a), float(loss_b), rtol=1e-6)
    # forward transport identical; backward: plain compresses g, EF
    # compresses g + 0 -> same on step one
    for op in grads_a:
        np.testing.assert_allclose(np.asarray(grads_a[op]["w"]),
                                   np.asarray(grads_b[op]["w"]), atol=1e-6)
    # residuals now hold the dropped mass
    assert any(float(jnp.sum(jnp.abs(v))) > 0 for v in ef1.values())


def test_ef_accumulated_grads_approach_reference():
    """EF telescoping: averaged over steps at a fixed point, EF-compressed
    gradients converge to the exact gradient OF THE FORWARD-COMPRESSED MODEL
    (EF heals the gradient transport; the forward sparsification is part of
    the model being differentiated).  Plain per-step compression stays
    biased."""
    from repro.core.rad import pipeline_backward, pipeline_forward

    g, params, inputs, sch, prog = _setup()
    plan = plan_uniform(g, sch.placement, ratio=8)
    # reference: fwd compressed, bwd transport exact
    _, vjps, received = pipeline_forward(prog, params, inputs, plan,
                                         compress_bwd=False)
    ref = pipeline_backward(prog, vjps, received, plan=None)

    def flat(gr):
        return np.concatenate([np.ravel(gr[o]["w"]) for o in sorted(gr)])

    dvec = flat(ref)
    ef = init_ef_state(prog, params, inputs)
    acc_ef = np.zeros_like(dvec)
    acc_plain = np.zeros_like(dvec)
    T = 24
    for _ in range(T):
        _, g_ef, ef = pipeline_loss_and_grad_ef(prog, params, inputs, plan,
                                                ef)
        acc_ef += flat(g_ef) / T
        _, g_pl = pipeline_loss_and_grad(prog, params, inputs, plan)
        acc_plain += flat(g_pl) / T

    def cos(a, b):
        return float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-12))

    assert cos(acc_ef, dvec) > cos(acc_plain, dvec) + 0.05
    assert cos(acc_ef, dvec) > 0.8


def test_runtime_dispatches_error_feedback_flag():
    """Regression (dead flag): the runtime must honour
    ``plan.error_feedback=True`` — carry residual state across steps, produce
    different grads from plain Top-K past step one, and track the
    forward-compressed model's exact gradient *better* than plain Top-K."""
    from repro.core.rad import pipeline_backward, pipeline_forward

    g, params, inputs, sch, prog = _setup()
    plan_plain = plan_uniform(g, sch.placement, ratio=8)
    plan_ef = plan_uniform(g, sch.placement, ratio=8, error_feedback=True)
    assert plan_ef.error_feedback and not plan_plain.error_feedback

    rt_plain = DecentralizedRuntime(g, sch, plan_plain)
    rt_ef = DecentralizedRuntime(g, sch, plan_ef)

    # reference: fwd compressed, bwd transport exact (what EF converges to)
    _, vjps, received = pipeline_forward(prog, params, inputs, plan_plain,
                                         compress_bwd=False)
    ref = pipeline_backward(prog, vjps, received, plan=None)

    def flat(gr):
        return np.concatenate([np.ravel(gr[o]["w"]) for o in sorted(gr)])

    dvec = flat(ref)
    T = 12
    acc_plain = np.zeros_like(dvec)
    acc_ef = np.zeros_like(dvec)
    for t in range(T):
        _, g_pl = rt_plain.train_step(params, [inputs])
        _, g_ef = rt_ef.train_step(params, [inputs])
        acc_plain += flat(g_pl) / T
        acc_ef += flat(g_ef) / T
        if t == 0:
            # zero residual: EF's first step equals plain Top-K transport
            np.testing.assert_allclose(flat(g_pl), flat(g_ef), atol=1e-6)
    # residual memory survives across steps on the runtime ...
    assert rt_ef.ef_state is not None
    assert any(float(jnp.sum(jnp.abs(v))) > 0 for v in rt_ef.ef_state.values())
    assert rt_plain.ef_state is None
    # ... so later steps transport corrected gradients (flag changes output)
    assert not np.allclose(acc_plain, acc_ef)

    def err(a):
        return float(np.linalg.norm(a - dvec) / (np.linalg.norm(dvec) + 1e-12))

    # and the EF path lands measurably closer to the exact gradient
    assert err(acc_ef) < err(acc_plain) - 0.02
