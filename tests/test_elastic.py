"""Elastic runtime: membership determinism, re-plan validity, bit-exact
migration, and loss continuity across a mid-training fail-over."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import network
from repro.core.opgraph import chain
from repro.core.estimator import predict_step_times
from repro.core.executor import simulate_migration
from repro.core.scheduler import schedule_opfence
from repro.elastic import (ChurnEvent, ChurnTrace, ElasticController,
                           MembershipView, StragglerDetector, apply_moves,
                           cross_cluster_bytes, diff_schedules,
                           interim_schedule, replan, single_failure_trace,
                           trees_bitexact)
from repro.optim.optimizers import adamw, sgd
from helpers import mlp_chain


# ------------------------------------------------------------- membership --
def test_trace_json_roundtrip_and_ordering():
    trace = ChurnTrace.build([
        {"t": 9.0, "kind": "leave", "node": 2},
        {"t": 1.0, "kind": "slowdown", "node": 0, "factor": 0.25},
        {"t": 4.0, "kind": "join", "node": 5},
    ])
    assert [e.time for e in trace.events] == [1.0, 4.0, 9.0]  # sorted
    back = ChurnTrace.from_json(trace.to_json())
    assert back == trace
    assert back.between(1.0, 9.0) == list(trace.events[1:])


def test_membership_lease_delays_leave_detection():
    trace = ChurnTrace.build([{"t": 5.0, "kind": "leave", "node": 1}])
    view = MembershipView(4, trace, lease_s=3.0)
    assert view.poll(6.0) == []            # departed but lease still valid
    assert view.alive == [0, 1, 2, 3] and view.epoch == 0
    deltas = view.poll(8.5)                # lease expired at t=8
    assert len(deltas) == 1 and deltas[0].detected_at == 8.0
    assert view.alive == [0, 2, 3] and view.epoch == 1


def test_membership_slowdown_is_ground_truth_not_epoch():
    trace = ChurnTrace.build(
        [{"t": 2.0, "kind": "slowdown", "node": 0, "factor": 0.5},
         {"t": 6.0, "kind": "recover", "node": 0}])
    view = MembershipView(2, trace, lease_s=1.0)
    view.poll(3.0)
    assert view.slow_factor == {0: 0.5} and view.epoch == 0
    view.poll(7.0)
    assert view.slow_factor == {} and view.epoch == 0


def test_membership_trace_determinism():
    trace = ChurnTrace.build([
        {"t": 1.0, "kind": "slowdown", "node": 2, "factor": 0.3},
        {"t": 2.0, "kind": "leave", "node": 4},
        {"t": 3.0, "kind": "join", "node": 7},
        {"t": 5.0, "kind": "leave", "node": 0},
    ])
    times = [0.5, 1.5, 2.1, 3.3, 4.4, 6.6, 9.9]
    snaps = []
    for _ in range(2):
        v = MembershipView(8, trace, lease_s=1.5)
        snaps.append([v.poll(t) and v.snapshot() or v.snapshot()
                      for t in times])
    assert snaps[0] == snaps[1]


# --------------------------------------------------------------- detector --
def test_detector_flags_only_drifted_stage():
    det = StragglerDetector({0: 1.0, 1: 2.0}, alpha=0.5, threshold=1.8,
                            min_observations=3)
    for _ in range(5):
        det.observe({0: 1.05, 1: 8.0})     # node 1 runs 4x its prediction
    assert det.flagged() == [1]
    assert det.severity(0) == pytest.approx(1.05)
    assert det.believed_factors()[1] == pytest.approx(1.0 / det.severity(1))


def test_detector_warmup_delays_flag():
    det = StragglerDetector({0: 1.0}, alpha=1.0, min_observations=3)
    det.observe({0: 10.0})
    det.observe({0: 10.0})
    assert det.flagged() == []             # still warming up
    det.observe({0: 10.0})
    assert det.flagged() == [0]


# ----------------------------------------------------------------- replan --
def _mlp_setup(n_layers=10, n_dev=6, seed=3):
    g, shapes, params, inputs = mlp_chain(n_layers=n_layers, d=16, batch=4)
    prof = g.annotate(shapes)
    cluster = network.geo_random(n=n_dev, n_sites=2, seed=seed)
    return g, prof, cluster, params, inputs


def test_replan_after_node_loss_is_valid_and_connected():
    g, prof, cluster, _, _ = _mlp_setup()
    old = schedule_opfence(g, prof, cluster)
    victim = old.stage_devices()[1]
    alive = [d for d in range(len(cluster)) if d != victim]
    rp = replan(g, prof, cluster, old, alive=alive, dead=[victim])
    new = rp.schedule
    # dead CompNode holds nothing; every op assigned exactly once
    assert new.assignment[victim] == []
    placed = [op for seg in new.assignment for op in seg]
    assert sorted(placed) == sorted(g.nodes)
    # each stage is a contiguous run of the chain => connected sub-DAG
    order = {op: i for i, op in enumerate(chain(g))}
    for seg in new.assignment:
        idx = sorted(order[op] for op in seg if op in order)
        assert idx == list(range(idx[0], idx[0] + len(idx))) if idx else True
    new.pipeline_subdags(g)                # Table-3 edge sets build cleanly
    # ops stranded on the dead node stream from the checkpoint store
    dead_moves = [m for m in rp.migration.moves if m.from_checkpoint]
    assert dead_moves and all(m.dst != victim for m in rp.migration.moves)
    assert rp.migration.seconds > 0.0


def test_replan_auto_prefers_stability_when_pace_is_close():
    """After a node loss the anchored candidate (old stage order, re-cut DP
    split) must move far less state than a from-scratch OP-Fence pass; auto
    mode picks it unless the full re-plan's pace pays for its migration."""
    g, prof, cluster, _, _ = _mlp_setup(n_layers=16, n_dev=8)
    old = schedule_opfence(g, prof, cluster)
    victim = old.stage_devices()[2]
    alive = [d for d in range(len(cluster)) if d != victim]
    full = replan(g, prof, cluster, old, alive=alive, dead=[victim],
                  mode="full")
    anchored = replan(g, prof, cluster, old, alive=alive, dead=[victim],
                      mode="anchored")
    auto = replan(g, prof, cluster, old, alive=alive, dead=[victim])
    assert anchored.migration.total_bytes <= full.migration.total_bytes
    # anchored keeps the surviving relative stage order
    surv = [d for d in old.stage_devices() if d != victim]
    assert anchored.schedule.stage_devices() == surv
    best = min([anchored, full],          # anchored wins cost ties
               key=lambda r: r.migration.seconds
               + 100.0 * r.schedule.predicted_pace)
    assert auto.mode == best.mode


def test_pinned_replan_moves_zero_bytes_across_wan():
    """Acceptance (boundary-pinned re-cut): on the paper's two-cluster
    testbed the plain anchored candidate shifts a segment boundary across
    the inter-cluster WAN link after a failure — exactly the migration
    traffic overlapping cannot hide — while ``pin_boundaries=True`` freezes
    the WAN cuts and re-cuts each bandwidth cluster independently: zero
    cross-WAN migration bytes by construction, at no loss of validity."""
    from repro.elastic.replan import _communities_for
    g, shapes, _, _ = mlp_chain(n_layers=16, d=64, batch=8)
    prof = g.annotate(shapes)
    cluster = network.paper_testbed(1, seed=0)
    old = schedule_opfence(g, prof, cluster)
    victim = old.stage_devices()[2]
    alive = [d for d in range(len(cluster)) if d != victim]
    comms = _communities_for(cluster, old)
    unpinned = replan(g, prof, cluster, old, alive=alive, dead=[victim],
                      mode="anchored")
    pinned = replan(g, prof, cluster, old, alive=alive, dead=[victim],
                    mode="anchored", pin_boundaries=True)
    # the unpinned re-cut really does drag state over the WAN here
    assert cross_cluster_bytes(unpinned.migration.moves, comms) > 0
    assert cross_cluster_bytes(pinned.migration.moves, comms) == 0.0
    # pinned schedule is a valid pipeline: all ops placed once, contiguous
    # chain segments, Table-3 edge sets build, dead node holds nothing
    new = pinned.schedule
    assert new.assignment[victim] == []
    placed = sorted(op for seg in new.assignment for op in seg)
    assert placed == sorted(g.nodes)
    order = {op: i for i, op in enumerate(chain(g))}
    for seg in new.assignment:
        idx = sorted(order[op] for op in seg if op in order)
        assert idx == list(range(idx[0], idx[0] + len(idx))) if idx else True
    new.pipeline_subdags(g)
    assert new.predicted_pace is not None and new.predicted_pace > 0
    # pinning constrains the DP, so its pace can only be >= the free re-cut
    assert pinned.schedule.predicted_pace >= \
        unpinned.schedule.predicted_pace * (1 - 1e-12)


def test_pinned_replan_defers_unknown_community_joiner():
    """A joiner whose bandwidth community the old schedule never recorded
    (the schedule was cut on a survivor subset) must NOT be placed by the
    pinned candidate — feeding it state would cross the fence — while a
    joiner from a recorded community slots into its own community's slice
    with zero cross-community traffic."""
    from repro.elastic.replan import _communities_for
    g, shapes, _, _ = mlp_chain(n_layers=16, d=64, batch=8)
    prof = g.annotate(shapes)
    cluster = network.paper_testbed(1, seed=0)
    subset = [d for d in range(len(cluster)) if d not in (8, 9, 10, 11)]
    old = schedule_opfence(g, prof, cluster, device_subset=subset)
    comms = _communities_for(cluster, old)
    known = {d for c in comms for d in c}
    assert 8 not in known                   # its whole machine was excluded
    rp = replan(g, prof, cluster, old, alive=list(range(len(cluster))),
                joined=[8], mode="anchored", pin_boundaries=True)
    assert rp.schedule.assignment[8] == []  # deferred to the next full plan
    assert cross_cluster_bytes(rp.migration.moves, comms) == 0.0
    placed = sorted(op for seg in rp.schedule.assignment for op in seg)
    assert placed == sorted(g.nodes)
    # a joiner from a *recorded* community slots into that community's
    # slice: the full schedule's Louvain pass recorded all 24 devices, so an
    # idle device from a community that owns pipeline stages can join, and
    # any state it receives stays inside the fence
    full = schedule_opfence(g, prof, cluster)
    comms_full = _communities_for(cluster, full)
    idle = [d for d in range(len(cluster))
            if d not in set(full.stage_devices())]
    joiner = next(d for d in idle
                  if any(set(c) & set(full.stage_devices())
                         and d in c for c in comms_full))
    rp2 = replan(g, prof, cluster, full, alive=list(range(len(cluster))),
                 joined=[joiner], mode="anchored", pin_boundaries=True)
    assert cross_cluster_bytes(rp2.migration.moves, comms_full) == 0.0
    placed2 = sorted(op for seg in rp2.schedule.assignment for op in seg)
    assert placed2 == sorted(g.nodes)


def test_pinned_auto_falls_back_to_full_when_no_stage_host_survives():
    """When every old stage host dies, no pinned candidate exists — auto
    mode must recover via the full re-plan rather than raise.  The fence is
    vacuous there: every shard streams from the checkpoint store (src=None),
    so the fallback cannot move bytes across the WAN."""
    g, shapes, _, _ = mlp_chain(n_layers=16, d=64, batch=8)
    prof = g.annotate(shapes)
    cluster = network.paper_testbed(1, seed=0)
    old = schedule_opfence(g, prof, cluster)
    devs = old.stage_devices()
    spares = [d for d in range(len(cluster)) if d not in set(devs)]
    assert spares
    rp = replan(g, prof, cluster, old, alive=spares, dead=list(devs),
                mode="auto", pin_boundaries=True)
    assert rp.mode == "full"
    assert all(m.src is None for m in rp.migration.moves)
    placed = sorted(op for seg in rp.schedule.assignment for op in seg)
    assert placed == sorted(g.nodes)


def test_pinned_replan_maps_partial_site_joiner_into_its_community():
    """A joiner absent from the recorded clusters but whose site overlaps a
    recorded community (the old cut excluded only part of its machine) is
    mapped into that community and fed state without crossing the fence."""
    from repro.elastic.replan import _communities_for, _extend_communities
    g, shapes, _, _ = mlp_chain(n_layers=24, d=64, batch=8)
    prof = g.annotate(shapes)
    cluster = network.paper_testbed(1, seed=0)
    subset = [d for d in range(len(cluster)) if d not in (8, 9)]
    old = schedule_opfence(g, prof, cluster, device_subset=subset)
    comms = _communities_for(cluster, old)
    assert 8 not in {d for c in comms for d in c}
    ext = _extend_communities(cluster, comms, [8])
    host = next(c for c in ext if 8 in c)
    assert len(set(host) - {8}) > 0         # mapped into a recorded site
    rp = replan(g, prof, cluster, old, alive=subset + [8], joined=[8],
                mode="anchored", pin_boundaries=True)
    assert rp.schedule.assignment[8]        # the joiner actually hosts ops
    assert cross_cluster_bytes(rp.migration.moves, ext) == 0.0


def test_pinned_controller_failover_stays_intra_cluster():
    """End to end: a controller with pin_boundaries=True recovers from a
    failure without any survivor-to-survivor transfer crossing the WAN."""
    from repro.elastic.replan import _communities_for
    g, shapes, _, _ = mlp_chain(n_layers=16, d=32, batch=4)
    prof = g.annotate(shapes)
    cluster = network.paper_testbed(1, seed=0)
    probe = ElasticController(g, prof, cluster, ChurnTrace(()), n_micro=2)
    t1 = probe.run(steps=1).steps[0].step_seconds
    victim = probe.schedule.stage_devices()[2]
    comms = _communities_for(cluster, probe.schedule)
    ctrl = ElasticController(g, prof, cluster,
                             single_failure_trace(victim, at=2.5 * t1),
                             n_micro=2, lease_s=t1, replan_mode="anchored",
                             pin_boundaries=True)
    res = ctrl.run(steps=10)
    assert any(e.cause == "failure" for e in res.epochs)
    assert ctrl.schedule.assignment[victim] == []
    # reconstruct the failure epoch's moves via a fresh diff: every
    # survivor-to-survivor transfer stays inside its bandwidth cluster
    comm_of = {d: ci for ci, c in enumerate(comms) for d in c}
    rp = replan(g, prof, cluster, probe.schedule,
                alive=[d for d in range(len(cluster)) if d != victim],
                dead=[victim], mode="anchored", pin_boundaries=True)
    for m in rp.migration.moves:
        if m.src is not None:
            assert comm_of.get(m.src) == comm_of.get(m.dst)


def test_replan_noop_when_nothing_changed():
    g, prof, cluster, _, _ = _mlp_setup()
    old = schedule_opfence(g, prof, cluster)
    rp = replan(g, prof, cluster, old, alive=list(range(len(cluster))))
    assert rp.migration.moves == [] and rp.migration.seconds == 0.0


def test_simulate_migration_serializes_shared_endpoints():
    cluster = network.homogeneous_lan(n=4, bandwidth_Bps=1e9, alpha=0.0)
    one = simulate_migration({(0, 1): 1e9}, cluster).seconds
    # same source fanning out: serial on the uplink
    fan = simulate_migration({(0, 1): 1e9, (0, 2): 1e9}, cluster).seconds
    assert fan == pytest.approx(2 * one, rel=1e-6)
    # disjoint endpoints: fully parallel
    par = simulate_migration({(0, 1): 1e9, (2, 3): 1e9}, cluster).seconds
    assert par == pytest.approx(one, rel=1e-6)


# -------------------------------------------------------------- migration --
@pytest.mark.parametrize("make_opt", [lambda: adamw(lr=1e-3),
                                      lambda: sgd(lr=1e-2, momentum=0.9)])
def test_migration_roundtrip_is_bitexact(make_opt):
    g, prof, cluster, params, inputs = _mlp_setup()
    opt = make_opt()
    opt_state = opt.init(params)
    # put some non-trivial values into the moments
    grads = jax.tree_util.tree_map(jnp.ones_like, params)
    params, opt_state = opt.update(grads, opt_state, params)
    old = schedule_opfence(g, prof, cluster)
    victim = old.stage_devices()[0]
    alive = [d for d in range(len(cluster)) if d != victim]
    new = schedule_opfence(g, prof, cluster, device_subset=alive)
    moves = diff_schedules(old, new, prof)
    assert moves
    out = apply_moves(params, opt_state, moves)
    assert out.wire_bytes > 0
    assert trees_bitexact(params, out.params)
    assert trees_bitexact(opt_state, out.opt_state)


# ------------------------------------------------------------- controller --
def test_controller_sim_determinism():
    g, prof, cluster, _, _ = _mlp_setup()
    probe = ElasticController(g, prof, cluster, ChurnTrace(()), n_micro=2)
    t1 = probe.run(steps=1).steps[0].step_seconds
    dev = probe.schedule.stage_devices()
    trace = ChurnTrace((
        ChurnEvent(time=1.2 * t1, kind="slowdown", node=dev[0], factor=0.2),
        ChurnEvent(time=6.0 * t1, kind="leave", node=dev[1]),
    ))
    runs = []
    for _ in range(2):
        ctrl = ElasticController(g, prof, cluster, trace, n_micro=2,
                                 lease_s=t1)
        runs.append(ctrl.run(steps=25))
    a, b = runs
    assert [(e.cause, e.at_step, e.alive, e.stage_devices, e.clock)
            for e in a.epochs] == \
           [(e.cause, e.at_step, e.alive, e.stage_devices, e.clock)
            for e in b.epochs]
    assert [(s.step, s.clock, s.lost) for s in a.steps] == \
           [(s.step, s.clock, s.lost) for s in b.steps]
    assert len(a.epochs) >= 3              # initial + straggler + failure


def test_controller_charges_churn_costs():
    g, prof, cluster, _, _ = _mlp_setup()
    probe = ElasticController(g, prof, cluster, ChurnTrace(()), n_micro=2)
    t1 = probe.run(steps=1).steps[0].step_seconds
    victim = probe.schedule.stage_devices()[1]
    ctrl = ElasticController(g, prof, cluster,
                             single_failure_trace(victim, at=2.5 * t1),
                             n_micro=2, lease_s=t1)
    res = ctrl.run(steps=10)
    fail = [e for e in res.epochs if e.cause == "failure"]
    assert len(fail) == 1
    e = fail[0]
    assert e.migrate_seconds > 0 and e.refill_seconds > 0
    assert e.detect_seconds >= t1          # lease delay is wall-clock
    assert e.rollback_steps >= 1           # detection latency loses steps
    useful = sum(s.step_seconds for s in res.steps if not s.lost)
    assert res.total_seconds > useful      # churn overhead is charged


def _tiny_gpt():
    from repro.configs.base import ModelCfg
    from repro.models.opgraph_models import gpt_opgraph
    cfg = ModelCfg(name="gpt-tiny", family="dense", n_layers=4, d_model=32,
                   n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                   rope_fraction=0.0, max_seq=32, norm="layernorm",
                   act="gelu")
    batch, seq = 4, 16
    g = gpt_opgraph(cfg, batch, seq)
    shapes = {"tokens": (batch, seq), "labels": (batch, seq)}
    prof = g.annotate(shapes)
    params = g.init(jax.random.PRNGKey(0), shapes)
    return g, prof, params, batch, seq


def _gpt_data_fn(batch, seq, n_micro=2):
    from repro.data.synthetic import SyntheticLM
    ds = SyntheticLM(vocab=64, seq_len=seq, seed=0, order=1)

    def data_fn(step):
        b = ds.batch(batch, step)
        mb = batch // n_micro
        return [{"tokens": jnp.asarray(b["tokens"][i * mb:(i + 1) * mb]),
                 "labels": jnp.asarray(b["labels"][i * mb:(i + 1) * mb])}
                for i in range(n_micro)]
    return data_fn


@pytest.mark.slow
def test_failover_keeps_loss_continuous_on_paper_testbed():
    """Acceptance: 1 node failure mid-training on the paper's Cluster-A/B
    topology; ElasticController detects, re-plans, migrates bit-exactly, and
    the loss curve is IDENTICAL to an uninterrupted run."""
    g, prof, params, batch, seq = _tiny_gpt()
    cluster = network.paper_testbed(1, seed=0)
    data_fn = _gpt_data_fn(batch, seq)
    steps = 8

    probe = ElasticController(g, prof, cluster, ChurnTrace(()), n_micro=2)
    t1 = probe.run(steps=1).steps[0].step_seconds
    victim = probe.schedule.stage_devices()[2]

    base = ElasticController(g, prof, cluster, ChurnTrace(()),
                             optimizer=adamw(lr=1e-3), n_micro=2)
    res_base = base.run(steps=steps, data_fn=data_fn, params=params)

    ctrl = ElasticController(g, prof, cluster,
                             single_failure_trace(victim, at=2.5 * t1),
                             optimizer=adamw(lr=1e-3), n_micro=2,
                             lease_s=t1)
    res = ctrl.run(steps=steps, data_fn=data_fn, params=params)

    assert any(e.cause == "failure" for e in res.epochs)
    assert ctrl.schedule.assignment[victim] == []
    lb, lc = dict(res_base.losses), dict(res.losses)
    assert set(lb) == set(lc)
    for s in lb:
        assert lc[s] == pytest.approx(lb[s], rel=1e-6, abs=1e-7)
    # decreasing loss across the fail-over boundary (continuity, no spike)
    losses = [l for _, l in sorted(lc.items())]
    assert losses[-1] < losses[0]
    # end state bit-exact vs the uninterrupted run: same data, same numerics
    assert trees_bitexact(res.params, res_base.params)


def test_straggler_flag_and_rehabilitation_cycle():
    """Scripted slowdown -> detector flags -> re-plan with degraded belief;
    scripted recover -> severity drops to the believed factor -> belief
    cleared and the node re-planned at full speed."""
    g, prof, cluster, _, _ = _mlp_setup(n_layers=8)
    probe = ElasticController(g, prof, cluster, ChurnTrace(()), n_micro=2)
    t1 = probe.run(steps=1).steps[0].step_seconds
    victim = probe.schedule.stage_devices()[0]
    trace = ChurnTrace((
        ChurnEvent(time=1.5 * t1, kind="slowdown", node=victim, factor=0.4),
        ChurnEvent(time=25 * t1, kind="recover", node=victim),
    ))
    ctrl = ElasticController(g, prof, cluster, trace, n_micro=2)
    res = ctrl.run(steps=60)
    causes = [e.cause for e in res.epochs]
    assert "straggler" in causes and "recovery" in causes
    straggler = res.epochs[causes.index("straggler")]
    recovery = res.epochs[causes.index("recovery")]
    assert straggler.at_step < recovery.at_step
    assert ctrl.believed_factors == {}     # belief cleared after recovery


def test_join_triggers_replan_and_uses_new_node():
    g, prof, cluster, _, _ = _mlp_setup(n_layers=12)
    alive0 = [0, 1, 2, 3]
    probe = ElasticController(g, prof, cluster, ChurnTrace(()), n_micro=2,
                              initial_alive=alive0)
    t1 = probe.run(steps=1).steps[0].step_seconds
    trace = ChurnTrace((ChurnEvent(time=2.5 * t1, kind="join", node=4),))
    ctrl = ElasticController(g, prof, cluster, trace, n_micro=2,
                             initial_alive=alive0)
    res = ctrl.run(steps=8)
    joins = [e for e in res.epochs if e.cause == "join"]
    assert len(joins) == 1 and 4 in joins[0].alive
    assert joins[0].rollback_steps == 0    # joins never lose work


def test_controller_detector_consumes_telemetry_only():
    """The detector's observation path is executor telemetry end to end:
    samples flow, and the flagged severity equals the telemetry aggregate
    over prediction — not a fresh estimator sweep."""
    g, prof, cluster, _, _ = _mlp_setup()
    ctrl = ElasticController(g, prof, cluster, ChurnTrace(()), n_micro=2)
    ctrl.run(steps=4)
    assert ctrl.telemetry.n_samples > 0
    agg = ctrl.telemetry.node_step_times()
    for d, st in ctrl.detector.stats.items():
        if st.ewma is not None and d in agg:
            assert st.ewma == pytest.approx(agg[d], rel=1e-9, abs=1e-15)


# ------------------------------------------------------ overlapped recovery --
def test_interim_schedule_merges_dead_segment_into_neighbor():
    g, prof, cluster, _, _ = _mlp_setup(n_layers=12, n_dev=6)
    old = schedule_opfence(g, prof, cluster)
    devs = old.stage_devices()
    victim = devs[2]
    interim = interim_schedule(g, old, [victim], len(cluster))
    assert interim.assignment[victim] == []
    # every op still assigned exactly once; dead ops land on the predecessor
    placed = [op for seg in interim.assignment for op in seg]
    assert sorted(placed) == sorted(g.nodes)
    assert interim.stage_devices() == [d for d in devs if d != victim]
    for op in old.assignment[victim]:
        assert interim.placement[op] == devs[1]
    # survivors keep their own ops (nothing else moved)
    for d in devs:
        if d in (victim, devs[1]):
            continue
        assert interim.assignment[d] == old.assignment[d]
    # stages stay contiguous chain runs => valid pipeline sub-DAGs
    order = {op: i for i, op in enumerate(chain(g))}
    for seg in interim.assignment:
        idx = sorted(order[op] for op in seg if op in order)
        assert idx == list(range(idx[0], idx[0] + len(idx))) if idx else True
    interim.pipeline_subdags(g)
    # leading-stage death folds into the first survivor instead
    interim0 = interim_schedule(g, old, [devs[0]], len(cluster))
    for op in old.assignment[devs[0]]:
        assert interim0.placement[op] == devs[1]
    assert interim_schedule(g, old, list(devs), len(cluster)) is None


def _overlap_setup(n_layers=10, d=64, n_dev=6, seed=3):
    g, shapes, params, inputs = mlp_chain(n_layers=n_layers, d=d, batch=4)
    prof = g.annotate(shapes)
    cluster = network.geo_random(n=n_dev, n_sites=2, seed=seed)
    return g, prof, cluster, params, inputs


def _compute_bound_lan(n_layers=12, d=512, lam=1e-6):
    """Slow devices on a fast LAN: the merged interim stage is the pipeline
    bottleneck, so the re-planned target is clearly faster and the cost
    model streams the survivor bulk — the regime the background-stream
    machinery exists for."""
    g, shapes, _, _ = mlp_chain(n_layers=n_layers, d=d, batch=4)
    prof = g.annotate(shapes)
    cluster = network.with_slowdowns(
        network.homogeneous_lan(n=6, bandwidth_Bps=12.5e6, alpha=1e-4),
        {i: lam for i in range(6)})
    return g, prof, cluster


def test_overlap_mode_charges_only_blocking_migration():
    """Overlap accounting: the failure epoch charges only the dead shard's
    checkpoint stream + interim refill; the survivor bulk lands on the
    cutover epoch as background bytes, with no second cold fill (hot
    hand-off)."""
    g, prof, cluster = _compute_bound_lan()
    probe = ElasticController(g, prof, cluster, ChurnTrace(()), n_micro=2)
    t1 = probe.run(steps=1).steps[0].step_seconds
    victim = probe.schedule.stage_devices()[1]
    trace = single_failure_trace(victim, at=2.5 * t1)
    ctrl = ElasticController(g, prof, cluster, trace, n_micro=2, lease_s=t1,
                             migration_mode="overlap")
    res = ctrl.run(steps=30)
    causes = [e.cause for e in res.epochs]
    assert "failure" in causes
    fail = res.epochs[causes.index("failure")]
    assert fail.replan_mode == "interim"     # cost model chose to stream
    assert fail.migrate_seconds > 0          # checkpoint stream blocks
    assert fail.refill_seconds > 0           # interim pipeline starts cold
    assert fail.rollback_steps >= 1
    assert "cutover" in causes               # stream finished within the run
    cut = res.epochs[causes.index("cutover")]
    assert cut.background_bytes > 0
    assert cut.overlap_seconds > 0
    assert cut.refill_seconds == 0.0         # hot hand-off, no cold fill
    assert cut.replan_mode in ("full", "anchored")
    # steps executed while the background stream drained are marked
    assert any(s.overlapping for s in res.steps)


def test_overlap_keeps_interim_when_stream_cannot_pay_off():
    """Fair-share conservation: on the comm-dominated geo toy the re-planned
    schedule is no faster than the interim, so streaming the survivor bulk
    buys nothing — the cost model keeps the interim schedule outright."""
    g, prof, cluster, _, _ = _overlap_setup()
    probe = ElasticController(g, prof, cluster, ChurnTrace(()), n_micro=2)
    t1 = probe.run(steps=1).steps[0].step_seconds
    victim = probe.schedule.stage_devices()[1]
    ctrl = ElasticController(g, prof, cluster,
                             single_failure_trace(victim, at=2.5 * t1),
                             n_micro=2, lease_s=t1, migration_mode="overlap")
    res = ctrl.run(steps=20)
    causes = [e.cause for e in res.epochs]
    fail = res.epochs[causes.index("failure")]
    assert fail.replan_mode == "interim-final"
    assert "cutover" not in causes
    assert not any(s.overlapping for s in res.steps)
    assert ctrl.schedule.assignment[victim] == []


def test_overlap_determinism():
    g, prof, cluster, _, _ = _overlap_setup()
    probe = ElasticController(g, prof, cluster, ChurnTrace(()), n_micro=2)
    t1 = probe.run(steps=1).steps[0].step_seconds
    dev = probe.schedule.stage_devices()
    trace = ChurnTrace((
        ChurnEvent(time=1.2 * t1, kind="slowdown", node=dev[0], factor=0.2),
        ChurnEvent(time=6.0 * t1, kind="leave", node=dev[1]),
    ))
    runs = []
    for _ in range(2):
        ctrl = ElasticController(g, prof, cluster, trace, n_micro=2,
                                 lease_s=t1, migration_mode="overlap")
        runs.append(ctrl.run(steps=25))
    a, b = runs
    assert [(e.cause, e.at_step, e.alive, e.stage_devices, e.clock,
             e.background_bytes) for e in a.epochs] == \
           [(e.cause, e.at_step, e.alive, e.stage_devices, e.clock,
             e.background_bytes) for e in b.epochs]
    assert [(s.step, s.clock, s.lost, s.overlapping) for s in a.steps] == \
           [(s.step, s.clock, s.lost, s.overlapping) for s in b.steps]


def test_overlap_beats_stop_the_world_after_failure():
    """The point of overlapping: post-failure throughput strictly improves
    because survivor state streams while training continues instead of
    stalling the whole swarm."""
    g, shapes, _, _ = mlp_chain(n_layers=12, d=128, batch=4)
    prof = g.annotate(shapes)
    # bandwidth-constrained LAN + heavy optimizer state: relocating a shard
    # costs many step times, the regime overlapping exists for (on the toy
    # geo topology migration is α-cheap and refill dominates — there the
    # stop-the-world plan is already near-optimal)
    cluster = network.homogeneous_lan(n=6, bandwidth_Bps=12.5e6, alpha=1e-4)
    probe = ElasticController(g, prof, cluster, ChurnTrace(()), n_micro=2)
    t1 = probe.run(steps=1).steps[0].step_seconds
    victim = probe.schedule.stage_devices()[1]
    res = {}
    for mode in ("stop", "overlap"):
        ctrl = ElasticController(g, prof, cluster,
                                 single_failure_trace(victim, at=2.5 * t1),
                                 n_micro=2, lease_s=t1, migration_mode=mode,
                                 opt_state_mult=20.0)
        res[mode] = ctrl.run(steps=30)
    assert res["overlap"].useful_steps == res["stop"].useful_steps
    phi_stop = res["stop"].post_failure_throughput(1)
    phi_overlap = res["overlap"].post_failure_throughput(1)
    assert phi_overlap > phi_stop
    assert res["overlap"].total_seconds < res["stop"].total_seconds


def test_overlap_straggler_rehabilitation_survives_stream():
    """Recover announcements must not be lost while a background stream is
    polling membership: the straggler/recover cycle ends with the belief
    cleared in overlap mode exactly as in stop mode (regression — mid-stream
    polls used to consume and drop 'recover' deltas)."""
    g, prof, cluster, _, _ = _mlp_setup(n_layers=8)
    probe = ElasticController(g, prof, cluster, ChurnTrace(()), n_micro=2)
    t1 = probe.run(steps=1).steps[0].step_seconds
    victim = probe.schedule.stage_devices()[0]
    trace = ChurnTrace((
        ChurnEvent(time=1.5 * t1, kind="slowdown", node=victim, factor=0.4),
        ChurnEvent(time=25 * t1, kind="recover", node=victim),
    ))
    ctrl = ElasticController(g, prof, cluster, trace, n_micro=2,
                             migration_mode="overlap")
    res = ctrl.run(steps=60)
    causes = [e.cause for e in res.epochs]
    assert "straggler" in causes and "recovery" in causes
    assert ctrl.believed_factors == {}


def test_overlap_training_loss_identical_to_uninterrupted():
    """Numerics are mode-independent: overlap-mode training through a
    failure produces the same per-step losses and bit-exact final state as
    an uninterrupted run (migration stays bit-exact through interim +
    cutover)."""
    g, prof, cluster, params, inputs = _overlap_setup(n_layers=8)
    steps = 8

    def data_fn(step):
        return [inputs, inputs]

    probe = ElasticController(g, prof, cluster, ChurnTrace(()), n_micro=2)
    t1 = probe.run(steps=1).steps[0].step_seconds
    victim = probe.schedule.stage_devices()[1]

    base = ElasticController(g, prof, cluster, ChurnTrace(()),
                             optimizer=adamw(lr=1e-3), n_micro=2)
    res_base = base.run(steps=steps, data_fn=data_fn, params=params)
    ctrl = ElasticController(g, prof, cluster,
                             single_failure_trace(victim, at=2.5 * t1),
                             optimizer=adamw(lr=1e-3), n_micro=2,
                             lease_s=t1, migration_mode="overlap")
    res = ctrl.run(steps=steps, data_fn=data_fn, params=params)
    assert any(e.cause == "failure" for e in res.epochs)
    lb, lc = dict(res_base.losses), dict(res.losses)
    assert set(lb) == set(lc)
    for s in lb:
        assert lc[s] == pytest.approx(lb[s], rel=1e-6, abs=1e-7)
    assert trees_bitexact(res.params, res_base.params)


def test_predict_step_times_scale_with_slowdown():
    g, prof, cluster, _, _ = _mlp_setup()
    sched = schedule_opfence(g, prof, cluster)
    base = predict_step_times(g, prof, cluster, sched.placement)
    slow = predict_step_times(g, prof,
                              network.with_slowdowns(cluster, {0: 0.25}),
                              sched.placement)
    for d in base:
        if d == 0:
            assert slow[0] > base[0]       # 4x compute, recv unchanged
        else:
            assert slow[d] == pytest.approx(base[d])
