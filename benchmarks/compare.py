"""CI perf-regression gate over ``benchmarks/run.py --json`` artifacts.

    PYTHONPATH=src python -m benchmarks.compare NEW.json BASELINE.json \
        [--max-regress 0.10] [--write-baseline]

Diffs the ``result`` payload of a fresh ``BENCH_<name>.json`` against a
committed baseline (``benchmarks/baselines/``) and exits non-zero when any
tracked metric regresses beyond ``--max-regress`` (default 10%):

* ``pace``  — the planner's predicted Eq. 3 steady-state pace, lower is
              better: new > base · (1 + margin) fails;
* ``phi``   — simulated throughput (samples/s), higher is better:
              new < base · (1 − margin) fails.

Both are *deterministic* functions of (workload, topology, seed) — the
discrete-event simulator measures no wall-clock — so the gate is stable
across CI runners and the margin only absorbs float/library drift, not
machine noise.  A scheduler present in the baseline but missing from the new
run is itself a failure (a silently dropped system is the worst regression);
new schedulers absent from the baseline pass through (they have no bar yet —
refresh the baseline to start tracking them).

The comparison logic is a pure function (:func:`compare`) so the gate is
unit-testable: injecting a 20% pace regression must fail it (tested in
``tests/test_bench_compare.py``).

``--write-baseline`` refreshes the baseline instead of gating: the new
artifact's ``result`` payload is normalized (tracked metrics only, sorted
keys) and written over BASELINE.json.  Intentional perf shifts land as
one reviewable baseline diff::

    PYTHONPATH=src python -m benchmarks.run joint --joint-profile hetero \
        --json
    PYTHONPATH=src python -m benchmarks.compare BENCH_joint_planning.json \
        benchmarks/baselines/BENCH_baseline_joint.json --write-baseline
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Mapping

# metric -> direction: +1 higher-is-better, -1 lower-is-better
TRACKED = {"pace": -1, "phi": +1}


def load_result(path: str, validate: bool = True) -> Dict:
    """Read a BENCH json; accepts the harness envelope ({"result": ...}) or
    a bare result mapping.

    ``validate=True`` (default) runs the payload through the
    ``repro.check`` bench schema first — a hand-edited or truncated
    baseline raises :class:`repro.check.BaselineCheckError` instead of
    silently making the perf gate vacuous."""
    with open(path) as f:
        payload = json.load(f)
    if validate:
        from repro.check.bench import verify_bench_result
        verify_bench_result(payload, tracked=tuple(TRACKED), source=path)
    return payload.get("result", payload) if isinstance(payload, dict) \
        else payload


def compare(new: Mapping, base: Mapping,
            max_regress: float = 0.10) -> List[str]:
    """Violation messages for every tracked metric that regressed beyond
    ``max_regress`` (empty list = gate passes)."""
    violations: List[str] = []
    for system, base_metrics in sorted(base.items()):
        if not isinstance(base_metrics, Mapping):
            continue   # scalar annotations (wall time etc.) are not gated
        new_metrics = new.get(system)
        if new_metrics is None:
            violations.append(f"{system}: present in baseline but missing "
                              f"from the new run")
            continue
        for metric, sign in TRACKED.items():
            if metric not in base_metrics or metric not in new_metrics:
                continue
            b = float(base_metrics[metric])
            n = float(new_metrics[metric])
            if b <= 0.0:
                continue
            if sign < 0 and n > b * (1.0 + max_regress):
                violations.append(
                    f"{system}.{metric}: {n:.6g} vs baseline {b:.6g} "
                    f"(+{(n / b - 1.0) * 100:.1f}%, lower is better)")
            elif sign > 0 and n < b * (1.0 - max_regress):
                violations.append(
                    f"{system}.{metric}: {n:.6g} vs baseline {b:.6g} "
                    f"(-{(1.0 - n / b) * 100:.1f}%, higher is better)")
    return violations


def format_table(new: Mapping, base: Mapping) -> str:
    rows = [f"{'system':<16} {'metric':<6} {'baseline':>12} {'new':>12} "
            f"{'delta':>8}"]
    for system, base_metrics in sorted(base.items()):
        if not isinstance(base_metrics, Mapping):
            continue
        for metric in TRACKED:
            if metric not in base_metrics:
                continue
            b = float(base_metrics[metric])
            n = new.get(system, {}).get(metric)
            if n is None:
                rows.append(f"{system:<16} {metric:<6} {b:>12.6g} "
                            f"{'MISSING':>12} {'':>8}")
                continue
            n = float(n)
            delta = (n / b - 1.0) * 100 if b > 0 else float("nan")
            rows.append(f"{system:<16} {metric:<6} {b:>12.6g} {n:>12.6g} "
                        f"{delta:>+7.1f}%")
    return "\n".join(rows)


def write_baseline(new: Mapping, path: str, source: str = "") -> None:
    """Normalize a fresh result into a committed baseline: keep only the
    per-system mappings (and of those, every metric — extra context like
    ``iter_s`` is harmless and aids review), stamp the producing artifact."""
    result = {system: dict(metrics) for system, metrics in sorted(new.items())
              if isinstance(metrics, Mapping)}
    payload = {"baseline_of": source or "benchmarks.compare --write-baseline",
               "result": result}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True, default=str)
        f.write("\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("new", help="freshly produced BENCH_<name>.json")
    ap.add_argument("baseline", help="committed baseline json")
    ap.add_argument("--max-regress", type=float, default=0.10,
                    help="relative regression budget per metric (0.10 = 10%%)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="refresh BASELINE from NEW instead of gating")
    args = ap.parse_args(argv)
    if args.write_baseline:
        write_baseline(load_result(args.new), args.baseline, source=args.new)
        print(f"baseline refreshed: {args.baseline} <- {args.new}")
        return 0
    new, base = load_result(args.new), load_result(args.baseline)
    print(format_table(new, base))
    violations = compare(new, base, args.max_regress)
    if violations:
        print("\nPERF GATE FAILED "
              f"(budget {args.max_regress * 100:.0f}%):", file=sys.stderr)
        for v in violations:
            print(f"  - {v}", file=sys.stderr)
        return 1
    print(f"\nperf gate OK (budget {args.max_regress * 100:.0f}%)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
