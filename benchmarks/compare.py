"""CI perf-regression gate over ``benchmarks/run.py --json`` artifacts.

    PYTHONPATH=src python -m benchmarks.compare NEW.json BASELINE.json \
        [--max-regress 0.10] [--write-baseline]

Diffs the ``result`` payload of a fresh ``BENCH_<name>.json`` against a
committed baseline (``benchmarks/baselines/``) and exits non-zero when any
tracked metric regresses beyond ``--max-regress`` (default 10%):

* ``pace``  — the planner's predicted Eq. 3 steady-state pace, lower is
              better: new > base · (1 + margin) fails;
* ``phi``   — simulated throughput (samples/s), higher is better:
              new < base · (1 − margin) fails.

Both are *deterministic* functions of (workload, topology, seed) — the
discrete-event simulator measures no wall-clock — so the gate is stable
across CI runners and the margin only absorbs float/library drift, not
machine noise.  A scheduler present in the baseline but missing from the new
run is itself a failure (a silently dropped system is the worst regression);
new schedulers absent from the baseline pass through (they have no bar yet —
refresh the baseline to start tracking them).

The comparison logic is a pure function (:func:`compare`) so the gate is
unit-testable: injecting a 20% pace regression must fail it (tested in
``tests/test_bench_compare.py``).

``--write-baseline`` refreshes the baseline instead of gating: the new
artifact's ``result`` payload is normalized (tracked metrics only, sorted
keys) and written over BASELINE.json.  Intentional perf shifts land as
one reviewable baseline diff::

    PYTHONPATH=src python -m benchmarks.run joint --joint-profile hetero \
        --json
    PYTHONPATH=src python -m benchmarks.compare BENCH_joint_planning.json \
        benchmarks/baselines/BENCH_baseline_joint.json --write-baseline

``--history`` appends the new artifact's tracked metrics to
``benchmarks/baselines/HISTORY_<name>.jsonl`` (one JSON line per CI run)
and fails on a *monotone 3-run degradation* of any tracked metric — three
consecutive runs each strictly worse than the one before.  That catches
the slow-boil regression the single-baseline gate's 10% margin lets
through one slice at a time, and starts accumulating the bench trajectory
the baselines directory was always meant to hold.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Mapping, Sequence

# metric -> direction: +1 higher-is-better, -1 lower-is-better
TRACKED = {"pace": -1, "phi": +1}

# consecutive strictly-worsening runs (including the new one) that fail
# the --history gate
HISTORY_RUNS = 3


def load_result(path: str, validate: bool = True) -> Dict:
    """Read a BENCH json; accepts the harness envelope ({"result": ...}) or
    a bare result mapping.

    ``validate=True`` (default) runs the payload through the
    ``repro.check`` bench schema first — a hand-edited or truncated
    baseline raises :class:`repro.check.BaselineCheckError` instead of
    silently making the perf gate vacuous."""
    with open(path) as f:
        payload = json.load(f)
    if validate:
        from repro.check.bench import verify_bench_result
        verify_bench_result(payload, tracked=tuple(TRACKED), source=path)
    return payload.get("result", payload) if isinstance(payload, dict) \
        else payload


def compare(new: Mapping, base: Mapping,
            max_regress: float = 0.10) -> List[str]:
    """Violation messages for every tracked metric that regressed beyond
    ``max_regress`` (empty list = gate passes)."""
    violations: List[str] = []
    for system, base_metrics in sorted(base.items()):
        if not isinstance(base_metrics, Mapping):
            continue   # scalar annotations (wall time etc.) are not gated
        new_metrics = new.get(system)
        if new_metrics is None:
            violations.append(f"{system}: present in baseline but missing "
                              f"from the new run")
            continue
        for metric, sign in TRACKED.items():
            if metric not in base_metrics or metric not in new_metrics:
                continue
            b = float(base_metrics[metric])
            n = float(new_metrics[metric])
            if b <= 0.0:
                continue
            if sign < 0 and n > b * (1.0 + max_regress):
                violations.append(
                    f"{system}.{metric}: {n:.6g} vs baseline {b:.6g} "
                    f"(+{(n / b - 1.0) * 100:.1f}%, lower is better)")
            elif sign > 0 and n < b * (1.0 - max_regress):
                violations.append(
                    f"{system}.{metric}: {n:.6g} vs baseline {b:.6g} "
                    f"(-{(1.0 - n / b) * 100:.1f}%, higher is better)")
    return violations


def format_table(new: Mapping, base: Mapping) -> str:
    rows = [f"{'system':<16} {'metric':<6} {'baseline':>12} {'new':>12} "
            f"{'delta':>8}"]
    for system, base_metrics in sorted(base.items()):
        if not isinstance(base_metrics, Mapping):
            continue
        for metric in TRACKED:
            if metric not in base_metrics:
                continue
            b = float(base_metrics[metric])
            n = new.get(system, {}).get(metric)
            if n is None:
                rows.append(f"{system:<16} {metric:<6} {b:>12.6g} "
                            f"{'MISSING':>12} {'':>8}")
                continue
            n = float(n)
            delta = (n / b - 1.0) * 100 if b > 0 else float("nan")
            rows.append(f"{system:<16} {metric:<6} {b:>12.6g} {n:>12.6g} "
                        f"{delta:>+7.1f}%")
    return "\n".join(rows)


def write_baseline(new: Mapping, path: str, source: str = "") -> None:
    """Normalize a fresh result into a committed baseline: keep only the
    per-system mappings (and of those, every metric — extra context like
    ``iter_s`` is harmless and aids review), stamp the producing artifact."""
    result = {system: dict(metrics) for system, metrics in sorted(new.items())
              if isinstance(metrics, Mapping)}
    payload = {"baseline_of": source or "benchmarks.compare --write-baseline",
               "result": result}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True, default=str)
        f.write("\n")


def tracked_only(result: Mapping) -> Dict[str, Dict[str, float]]:
    """The gate-relevant slice of a result: per-system tracked metrics."""
    out: Dict[str, Dict[str, float]] = {}
    for system, metrics in sorted(result.items()):
        if not isinstance(metrics, Mapping):
            continue
        row = {m: float(metrics[m]) for m in TRACKED if m in metrics}
        if row:
            out[system] = row
    return out


def history_gate(entries: Sequence[Mapping],
                 runs: int = HISTORY_RUNS) -> List[str]:
    """Violation messages when the last ``runs`` history entries show a
    *monotone* degradation of a tracked metric — each run strictly worse
    than the one before.  Pure (list of history entries in, strings out)
    so the trend rule is unit-testable."""
    if len(entries) < runs:
        return []
    tail = [e.get("result", {}) for e in entries[-runs:]]
    violations: List[str] = []
    last = tail[-1]
    for system, metrics in sorted(last.items()):
        if not isinstance(metrics, Mapping):
            continue
        for metric, sign in TRACKED.items():
            try:
                series = [float(t[system][metric]) for t in tail]
            except (KeyError, TypeError):
                continue
            worsening = all(
                (b > a) if sign < 0 else (b < a)
                for a, b in zip(series, series[1:]))
            if worsening:
                arrow = " -> ".join(f"{v:.6g}" for v in series)
                direction = "rising" if sign < 0 else "falling"
                violations.append(
                    f"{system}.{metric}: monotone {direction} over the last "
                    f"{runs} runs ({arrow}, "
                    f"{'lower' if sign < 0 else 'higher'} is better)")
    return violations


def append_history(result: Mapping, history_path: str,
                   source: str = "") -> List[Mapping]:
    """Append the tracked slice of ``result`` to the history JSONL and
    return all entries (oldest first, the new one last)."""
    entries: List[Mapping] = []
    if os.path.exists(history_path):
        with open(history_path) as f:
            for line in f:
                line = line.strip()
                if line:
                    entries.append(json.loads(line))
    entry = {"source": source, "result": tracked_only(result)}
    entries.append(entry)
    os.makedirs(os.path.dirname(history_path) or ".", exist_ok=True)
    with open(history_path, "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")
    return entries


def history_path_for(new_path: str, history_dir: str) -> str:
    """``BENCH_<name>.json`` -> ``<history_dir>/HISTORY_<name>.jsonl``."""
    stem = os.path.splitext(os.path.basename(new_path))[0]
    if stem.startswith("BENCH_"):
        stem = stem[len("BENCH_"):]
    return os.path.join(history_dir, f"HISTORY_{stem}.jsonl")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("new", help="freshly produced BENCH_<name>.json")
    ap.add_argument("baseline", nargs="?", default=None,
                    help="committed baseline json (optional with --history)")
    ap.add_argument("--max-regress", type=float, default=0.10,
                    help="relative regression budget per metric (0.10 = 10%%)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="refresh BASELINE from NEW instead of gating")
    ap.add_argument("--history", action="store_true",
                    help="append NEW's tracked metrics to "
                         "HISTORY_<name>.jsonl and fail on a monotone "
                         f"{HISTORY_RUNS}-run degradation")
    ap.add_argument("--history-dir", default="benchmarks/baselines",
                    help="directory holding HISTORY_<name>.jsonl files")
    args = ap.parse_args(argv)
    if args.write_baseline:
        if args.baseline is None:
            ap.error("--write-baseline needs a BASELINE path")
        write_baseline(load_result(args.new), args.baseline, source=args.new)
        print(f"baseline refreshed: {args.baseline} <- {args.new}")
        return 0
    if args.baseline is None and not args.history:
        ap.error("need a BASELINE to gate against (or --history)")
    new = load_result(args.new)
    violations: List[str] = []
    if args.baseline is not None:
        base = load_result(args.baseline)
        print(format_table(new, base))
        violations += compare(new, base, args.max_regress)
    if args.history:
        hist_path = history_path_for(args.new, args.history_dir)
        entries = append_history(new, hist_path, source=args.new)
        print(f"history: {hist_path} now {len(entries)} run(s)")
        violations += history_gate(entries)
    if violations:
        print("\nPERF GATE FAILED "
              f"(budget {args.max_regress * 100:.0f}%):", file=sys.stderr)
        for v in violations:
            print(f"  - {v}", file=sys.stderr)
        return 1
    print(f"\nperf gate OK (budget {args.max_regress * 100:.0f}%)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
