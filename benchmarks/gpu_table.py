"""Paper Table 1: GPU-days and #GPUs to pre-train GPT-3 (175B).

Pure arithmetic over the device sheets — included so every paper table has a
benchmark; reproduces the paper's headline 'H100 needs 13.17 years'."""
from __future__ import annotations

GPT3_FLOPS = 3.14e23          # paper's cited total training FLOPs
GPT3_PARAM_BYTES = 175e9 * 4  # fp32 weights (reproduces the paper's H100=9)

PRICES = {"H100": 37_799, "A100": 6_780, "RTX4090": 1_699,
          "RTX4080": 989, "RTX3080": 679}


def rows():
    from repro.core.estimator import DEVICE_SHEETS
    out = []
    for name, price in PRICES.items():
        peak, mem = DEVICE_SHEETS[name]
        days = GPT3_FLOPS / peak / 86_400
        n_gpus = -(-GPT3_PARAM_BYTES // mem)
        out.append({"gpu": name, "price_usd": price,
                    "tflops": peak / 1e12, "gpu_days": round(days),
                    "gpu_years": round(days / 365.25, 2),
                    "n_to_load_gpt3": int(n_gpus),
                    "days_per_dollar": days / price})
    return out


def run(csv_writer):
    for r in rows():
        csv_writer("table1_gpu_days", r["gpu_days"] * 86400 * 1e6,
                   f"{r['gpu']}:years={r['gpu_years']},load={r['n_to_load_gpt3']}")
    # paper's claims: H100 ~13.17y, 4090 ~60.28y (at the paper's FLOPs/peaks)
    h100 = next(r for r in rows() if r["gpu"] == "H100")
    assert 12 < h100["gpu_years"] < 14, h100
