"""Closed-loop swarm-serving benchmark: tokens/s and per-token latency
percentiles with and without a mid-session stage-replica failure.

Two legs over the *same* simulated Poisson offered load (heavy-traffic
arrival process, per-request generation lengths), stage-sharded across a
simulated cluster:

* ``no_churn``     — the steady-state baseline;
* ``one_failure``  — a scripted stage-replica death, derived from the
  baseline leg's own token timeline (:func:`derive_midsession_failure`)
  so it is guaranteed to land while sessions are mid-decode.  The router
  re-routes every evicted session onto a surviving replica and the
  runtime replays each session's KV prefix there.

Reported per leg: ``tokens_per_s`` (tracked), ``p50_ms`` / ``p99_ms``
per-token latency, session/reroute counts, simulated makespan.  The bench
*asserts* (not just reports) the recovery story: under the failure every
admitted session still completes, at least one session was re-routed
mid-flight, and greedy output tokens are bit-identical to the no-churn
leg — the KV replay reproduced the prefix exactly.

``profile="tiny"`` is the CI smoke (tiny 4-layer decoder, 6-device LAN,
seconds); ``profile="geo"`` runs the llama3-8b smoke config over
geo-distributed sites.  ``trace=True`` writes ``TRACE_serving_swarm.*``
and ``FLIGHT_serving_swarm.jsonl`` artifacts from the failure leg and
prints the run report (serving timeline + routing decision log).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax

from repro.configs import resolve
from repro.configs.base import ModelCfg
from repro.core.network import geo_random, homogeneous_lan
from repro.elastic.membership import ChurnTrace, MembershipView
from repro.models import causal_lm
from repro.obs import FlightRecorder, TraceRecorder, write_jsonl
from repro.serving import (ServingCostModel, ServingRuntime,
                           churn_trace_for, derive_midsession_failure,
                           plan_serving, poisson_trace)

LEASE_S = 1e-5


def _tiny_cfg() -> ModelCfg:
    return ModelCfg(name="serve-tiny", family="dense", n_layers=4,
                    d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=97)


def _workload(profile: str):
    """(cfg, cluster, n_stages, cache_len, max_batch, requests)."""
    if profile == "tiny":
        cfg = _tiny_cfg()
        cluster = homogeneous_lan(6)
        reqs = poisson_trace(5, rate=200.0, vocab=cfg.vocab,
                             gen_len=(30, 40), seed=3)
        return cfg, cluster, 2, 64, 3, reqs
    if profile == "geo":
        cfg = resolve("llama3-8b").smoke
        cluster = geo_random(8, seed=0)
        reqs = poisson_trace(10, rate=100.0, vocab=cfg.vocab,
                             prompt_len=(4, 12), gen_len=(16, 32), seed=0)
        return cfg, cluster, 2, 64, 4, reqs
    raise ValueError(f"unknown serving profile {profile!r}")


def _leg_metrics(report) -> Dict[str, float]:
    return {
        "tokens_per_s": report.tokens_per_s,
        "p50_ms": report.p50_ms,
        "p99_ms": report.p99_ms,
        "sim_seconds": report.sim_seconds,
        "n_sessions": report.n_sessions,
        "n_completed": report.n_completed,
        "n_reroutes": report.n_reroutes,
        "all_completed": int(report.all_completed),
    }


def run(csv_writer, profile: str = "geo", trace: bool = False
        ) -> Dict[str, Dict[str, float]]:
    cfg, cluster, n_stages, cache_len, max_batch, requests = \
        _workload(profile)
    n_dev = len(cluster)
    params = causal_lm.init(cfg, jax.random.PRNGKey(0))
    costs = ServingCostModel(cfg, cluster)
    plan = plan_serving(cfg, costs, list(range(n_dev)), n_stages=n_stages,
                        cache_len=cache_len, max_batch=max_batch)

    # ---- leg 1: no churn (doubles as the failure-derivation dry run) ----
    victim, at, base_report, base_tokens = derive_midsession_failure(
        cfg, params, plan, requests, n_dev, lease_s=LEASE_S)

    # ---- leg 2: same offered load, scripted mid-session failure --------
    view = MembershipView(n_dev, churn_trace_for(victim, at),
                          lease_s=LEASE_S)
    tr = TraceRecorder(enabled=trace)
    fl = FlightRecorder()
    churn_tokens: Dict[str, List[int]] = {}
    runtime = ServingRuntime(
        cfg, params, plan, view, trace=tr, flight=fl,
        on_token=lambda rid, tok, now:
            churn_tokens.setdefault(rid, []).append(tok))
    churn_report = runtime.run(list(requests))

    # the recovery story is the acceptance bar, not a soft metric
    assert churn_report.all_completed, \
        "one_failure leg dropped admitted sessions — re-route failed"
    assert churn_report.n_reroutes >= 1, \
        "scripted failure did not interrupt any session"
    assert churn_tokens == base_tokens, \
        "greedy output diverged under churn — KV replay is not bit-exact"

    for name, rep in (("no_churn", base_report),
                      ("one_failure", churn_report)):
        csv_writer(f"serving_{profile}_{name}",
                   rep.p50_ms * 1e3,     # per-token p50 in us
                   f"tok/s={rep.tokens_per_s:.1f} "
                   f"p99={rep.p99_ms:.3f}ms "
                   f"reroutes={rep.n_reroutes} "
                   f"completed={rep.n_completed}/{rep.n_sessions}")

    if trace:
        from repro.obs.export import write_chrome_trace
        from repro.obs.report import build_report
        write_jsonl(tr.events(), "TRACE_serving_swarm.jsonl")
        write_chrome_trace(tr, "TRACE_serving_swarm.json")
        fl.to_jsonl("FLIGHT_serving_swarm.jsonl")
        print(build_report(tr.events(), fl.to_dicts(), width=100))

    return {"no_churn": _leg_metrics(base_report),
            "one_failure": _leg_metrics(churn_report),
            "scripted_failure": {"victim": victim, "at_s": at}}
