"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [BENCH] [--steps N] [--json]
    PYTHONPATH=src python benchmarks/run.py churn --churn-profile tiny --trace

Prints ``name,us_per_call,derived`` CSV lines.  ``BENCH`` selects benches by
name prefix (``churn`` runs ``churn_elastic``; ``--only`` remains the exact
form).  ``--json`` additionally writes one ``BENCH_<name>.json`` perf
artifact per bench from whatever the bench's ``run()`` returned (throughput
+ predicted pace per scheduler for ``joint_planning``) — CI uploads these so
the perf trajectory is tracked per commit instead of scrolling away in logs.

``--trace`` attaches the observability layer to the benches that support it
(currently the churn bench, including its closed-loop calibration demo):
each instrumented run writes ``TRACE_<name>.json`` (open in Perfetto),
``TRACE_<name>.jsonl`` and ``FLIGHT_<name>.jsonl`` artifacts and prints the
run report — per-stage timeline, comm/compute overlap fraction, straggler
heatmap, and the broker's decision log.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

if __package__ in (None, ""):           # `python benchmarks/run.py ...`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    __package__ = "benchmarks"          # noqa: A001 — relative imports below


def csv_writer(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def write_json_artifact(name: str, result, wall_s: float) -> None:
    path = f"BENCH_{name}.json"
    payload = {"bench": name, "wall_seconds": wall_s, "result": result}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True, default=str)
    print(f"# wrote {path}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("bench", nargs="?", default=None,
                    help="run only benches whose name starts with this "
                         "prefix (e.g. 'churn', 'joint')")
    ap.add_argument("--only", default=None,
                    help="run exactly this bench (exact-name form of BENCH)")
    ap.add_argument("--steps", type=int, default=80,
                    help="convergence steps (Fig. 8)")
    ap.add_argument("--churn-profile", default="gpt2-xl",
                    choices=["gpt2-xl", "tiny"],
                    help="churn bench workload (tiny = CI smoke)")
    ap.add_argument("--churn-migration-mode", default=None,
                    choices=["stop", "overlap"],
                    help="force every elastic churn system onto one "
                         "migration mode (CI smokes the overlap defaults)")
    ap.add_argument("--joint-profile", default="gpt2-xl",
                    choices=["gpt2-xl", "tiny", "hetero"],
                    help="joint planning bench workload (tiny = CI smoke, "
                         "hetero = the mixed-width chain the perf baseline "
                         "is pinned on)")
    ap.add_argument("--serving-profile", default="geo",
                    choices=["geo", "tiny"],
                    help="swarm serving bench workload (tiny = CI smoke)")
    ap.add_argument("--trace", action="store_true",
                    help="record span traces + the broker flight recorder "
                         "on supporting benches; writes TRACE_*/FLIGHT_* "
                         "artifacts and prints the run report")
    ap.add_argument("--json", action="store_true",
                    help="write a BENCH_<name>.json artifact per bench")
    args = ap.parse_args()

    from . import (ablation_microbatch, churn, convergence, gpu_table,
                   joint_planning, kernel_bench, latency, ratio_sweep,
                   roofline_table, serving, speedup_table)

    benches = {
        "churn_elastic": lambda: churn.run(
            csv_writer, profile=args.churn_profile,
            migration_mode=args.churn_migration_mode, trace=args.trace),
        "joint_planning": lambda: joint_planning.run(
            csv_writer, profile=args.joint_profile),
        "table1_gpu": lambda: gpu_table.run(csv_writer),
        "fig8_convergence": lambda: convergence.run(csv_writer,
                                                    steps=args.steps),
        "fig10_latency": lambda: latency.run(csv_writer),
        "fig11_ratio": lambda: ratio_sweep.run(csv_writer),
        "speedup_headline": lambda: speedup_table.run(csv_writer),
        "kernel_topk": lambda: kernel_bench.run(csv_writer),
        "serving_swarm": lambda: serving.run(
            csv_writer, profile=args.serving_profile, trace=args.trace),
        "ablation_nmicro": lambda: ablation_microbatch.run(csv_writer),
        "roofline": lambda: roofline_table.run(csv_writer),
    }
    if args.bench and not any(n.startswith(args.bench) for n in benches):
        print(f"# no bench matches prefix {args.bench!r}; "
              f"available: {sorted(benches)}", file=sys.stderr)
        raise SystemExit(2)
    failed = []
    for name, fn in benches.items():
        if args.only and args.only != name:
            continue
        if args.bench and not name.startswith(args.bench):
            continue
        t0 = time.time()
        try:
            result = fn()
            wall = time.time() - t0
            csv_writer(f"{name}__wall", wall * 1e6, "ok")
            if args.json:
                write_json_artifact(name, result, wall)
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
            csv_writer(f"{name}__wall", (time.time() - t0) * 1e6,
                       f"FAILED:{type(e).__name__}")
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
