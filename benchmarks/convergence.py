"""Paper Fig. 8: training-loss convergence under dense / uniform TopK /
AdaTopK compression (ratio 100), for an LM (GPT-2 family) and a CV model
(CNN stand-in for ResNet), trained with the real decentralized runtime
(OP-Fence schedule + RAD executor) on synthetic-but-learnable data."""
from __future__ import annotations

import functools
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import resolve
from repro.core import (PipelineProgram, network, pipeline_loss_and_grad,
                        plan_adatopk, plan_none, plan_uniform,
                        schedule_opfence)
from repro.data import SyntheticImages, SyntheticLM
from repro.models.opgraph_models import convnet_opgraph, gpt_opgraph
from repro.optim import adamw

# The paper uses ratio 100 on GPT2-XL (d=1600: ~16 surviving dims/token).
# At this benchmark's CPU-scale model (d=128) ratio 100 keeps ~1 dim/token
# and stalls; ratio 20 matches the paper's per-token survivor count, so the
# relative comparison (dense vs uniform vs adaptive) is scale-fair.
RATIO = 20.0


def _train(graph, shapes, data_fn, steps, plan, lr=1e-3, seed=0,
           grad_clip=1.0):
    """AdamW + global-norm clipping.  Clipping matters: sparsified boundary
    gradients are heavy-tailed and unclipped runs DIVERGE at this scale
    (measured — see EXPERIMENTS.md §Convergence)."""
    from repro.optim import clip_by_global_norm

    params = graph.init(jax.random.PRNGKey(seed), shapes)
    opt = adamw(lr, weight_decay=0.0)
    state = opt.init(params)
    prof = graph.annotate(shapes)
    cluster = network.paper_testbed(1, seed=0)
    sch = schedule_opfence(graph, prof, cluster)
    prog = PipelineProgram.build(graph, sch.pipeline_subdags(graph))

    @jax.jit
    def step(params, state, inputs):
        loss, grads = pipeline_loss_and_grad(prog, params, inputs, plan)
        grads, _ = clip_by_global_norm(grads, grad_clip)
        new_params, new_state = opt.update(grads, state, params)
        return new_params, new_state, loss

    losses = []
    for i in range(steps):
        inputs = data_fn(i)
        params, state, loss = step(params, state, inputs)
        losses.append(float(loss))
    return losses


def lm_setup(steps_batch=16, seq=64):
    cfg = resolve("gpt2-xl").smoke.replace(max_seq=seq, vocab=64,
                                           vocab_pad_to=1)
    graph = gpt_opgraph(cfg, steps_batch, seq)
    shapes = {"tokens": (steps_batch, seq), "labels": (steps_batch, seq)}
    # order-1 Markov: learnable to near the noise floor within ~100 steps
    ds = SyntheticLM(vocab=cfg.vocab, seq_len=seq, seed=0, order=1)

    def data(i):
        b = ds.batch(steps_batch, i)
        return {"tokens": jnp.asarray(b["tokens"]),
                "labels": jnp.asarray(b["labels"])}
    return graph, shapes, data


def cv_setup(batch=32, hw=16):
    graph = convnet_opgraph(hw=hw)
    shapes = {"images": (batch, hw, hw, 3), "labels": (batch,)}
    ds = SyntheticImages(hw=hw, seed=0, noise=0.4)

    def data(i):
        b = ds.batch(batch, i)
        return {"images": jnp.asarray(b["images"]),
                "labels": jnp.asarray(b["labels"])}
    return graph, shapes, data


def run(csv_writer, steps=80):
    results: Dict[str, Dict[str, List[float]]] = {}
    for model_name, setup in [("gpt2", lm_setup), ("convnet", cv_setup)]:
        graph, shapes, data = setup()
        prof = graph.annotate(shapes)
        cluster = network.paper_testbed(1, seed=0)
        sch = schedule_opfence(graph, prof, cluster)
        plans = {
            "dense": plan_none(graph, sch.placement),
            "uniform_topk": plan_uniform(graph, sch.placement, RATIO),
            "adatopk": plan_adatopk(graph, prof, cluster, sch.placement,
                                    RATIO),
        }
        results[model_name] = {}
        for plan_name, plan in plans.items():
            t0 = time.time()
            losses = _train(graph, shapes, data, steps, plan)
            dt = (time.time() - t0) / steps
            results[model_name][plan_name] = losses
            tail = float(np.mean(losses[-10:]))
            csv_writer(f"fig8_convergence_{model_name}_{plan_name}",
                       dt * 1e6,
                       f"loss0={losses[0]:.3f},tail={tail:.3f}")
    # Fig. 8 claims, checked in relative terms: every variant is stable and
    # descending; dense converges fastest at this scale (the paper's
    # "little gap" for AdaTopK holds at GPT2-XL widths, not at d=128 —
    # quantified in EXPERIMENTS.md §Convergence).
    for model_name in results:
        r = results[model_name]
        start = r["dense"][0]
        for variant, losses in r.items():
            tail = np.mean(losses[-10:])
            assert tail < start * 1.02, (model_name, variant, tail, start)
        assert np.mean(r["dense"][-10:]) <= np.mean(r["uniform_topk"][-10:]) \
            + 0.05
    return results
