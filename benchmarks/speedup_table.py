"""Paper abstract/§7 headline: 1.45–9.39× speedup of the full system
(OP-Fence + AdaTopK) over baseline configurations, across testbeds.

Baseline = equal-number scheduling without compression (the paper's basic
baseline); system = OP-Fence + AdaTopK(100)."""
from __future__ import annotations

from repro.configs import resolve
from repro.core import (network, plan_adatopk, plan_none,
                        schedule_equal_number, schedule_opfence,
                        simulate_iteration)
from repro.models.opgraph_models import profile_opgraph
from .latency import BATCH, N_MICRO, SEQ


def run(csv_writer):
    cfg = resolve("gpt2-xl").full
    graph = profile_opgraph(cfg, BATCH, SEQ)
    prof = graph.annotate({"tokens": (BATCH, SEQ), "labels": (BATCH, SEQ)})
    speedups = {}
    for testbed in (1, 2):
        cluster = network.paper_testbed(testbed, seed=0)
        base_sch = schedule_equal_number(graph, cluster)
        t_base = simulate_iteration(
            graph, prof, base_sch, cluster,
            plan_none(graph, base_sch.placement),
            n_micro=N_MICRO).iteration_time
        sys_sch = schedule_opfence(graph, prof, cluster)
        plan = plan_adatopk(graph, prof, cluster, sys_sch.placement, 100.0)
        t_sys = simulate_iteration(graph, prof, sys_sch, cluster, plan,
                                   n_micro=N_MICRO).iteration_time
        speedups[testbed] = t_base / t_sys
        csv_writer(f"speedup_testbed{testbed}", t_sys * 1e6,
                   f"speedup={speedups[testbed]:.2f}x")
    # the paper reports 1.45–9.39x; our simulated testbeds must land inside
    # a generous envelope of that range
    assert all(1.2 < s < 20 for s in speedups.values()), speedups
    return speedups
