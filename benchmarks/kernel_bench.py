"""Kernel micro-benchmark (paper §6: 'TopK faster than framework TopK').

On CPU/interpret the Pallas wall-time is meaningless; we measure the XLA
path vs the reference top_k formulation (both jitted) and report the
kernel's structural stats (VMEM block bytes, passes) — the TPU-relevant
numbers."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import topk_mask
from repro.kernels import ref as kref
from repro.kernels import topk_compress as tk


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run(csv_writer):
    n = 1 << 20
    x = jnp.asarray(np.random.default_rng(0).standard_normal(n), jnp.float32)
    k = n // 100

    global_topk = jax.jit(lambda v: topk_mask(v, k))
    block_ref = jax.jit(lambda v: kref.blockwise_topk_mask_ref(
        v, k // (n // 4096), 4096))
    t_g = _time(global_topk, x)
    t_b = _time(block_ref, x)
    csv_writer("kernel_global_topk_xla", t_g * 1e6, f"n={n},k={k}")
    csv_writer("kernel_blockwise_topk_xla", t_b * 1e6,
               f"n={n},k_per_block={k // (n // 4096)}")
    # structural stats of the Pallas kernel
    block = tk.DEFAULT_BLOCK
    vmem_bytes = block * 4 * 2          # in + out tiles
    csv_writer("kernel_pallas_structure", 0.0,
               f"block={block},vmem_bytes={vmem_bytes},"
               f"search_iters={tk._SEARCH_BITS},grid={n // block}")
