"""Kernel micro-benchmark (paper §6: compression must outrun the wire).

Three measurements, all on whatever backend is present:

* the legacy unfused wire path the fused kernels replace — global
  ``topk_select`` (full-tensor top-k + gather), then a separate scatter
  into a dense keep-mask, a separate bitmap pack, each its own XLA op;
* the fused blockwise encode (``xla_encode_topk`` — the ``"auto"``
  policy's CPU fallback, identical tie-capped selection semantics to the
  Pallas kernel) and its EF variant;
* interpret-mode Pallas parity against the XLA oracle on a small tensor
  (structural correctness — interpret wall time itself is meaningless),
  plus the compiled kernel's structural stats (VMEM tile bytes, grid,
  threshold-search passes): the TPU-relevant numbers.  Re-pin on real
  hardware by flipping ``repro.kernels.ops.INTERPRET`` to False and
  re-running this bench there (README "Kernels").

The returned result dict carries ``speedup`` (unfused / fused seconds) as
the tracked metric for the BENCH artifact.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import topk_select
from repro.kernels import ops as kops
from repro.kernels import topk_compress as tk


def _time(fn, *args, reps=7):
    jax.block_until_ready(fn(*args))       # one warm-up, whole result tree
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))         # robust to GC / scheduler noise


def _unfused_encode(n: int, nb: int, block: int, k_total: int):
    """The replaced hot path, jitted: global select, then mask scatter and
    bitmap pack as separate ops over the full tensor."""
    shifts = jnp.arange(32, dtype=jnp.uint32)

    @jax.jit
    def encode(v):
        flat = v.reshape(-1)
        values, idx = topk_select(flat, k_total)
        keep = jnp.zeros((n,), jnp.bool_).at[idx].set(True)
        words = keep.reshape(-1, 32).astype(jnp.uint32)
        bitmap = jnp.sum(words << shifts[None, :], axis=1,
                         dtype=jnp.uint32).reshape(nb, block // 32)
        return values, bitmap

    return encode


def run(csv_writer):
    n = 1 << 20
    block = tk.DEFAULT_BLOCK
    nb = n // block
    x = jnp.asarray(np.random.default_rng(0).standard_normal(n), jnp.float32)
    k = n // 100
    kpb = kops.per_block_k(n, k, block)
    k_total = nb * kpb                      # equal wire payload both paths

    unfused = _unfused_encode(n, nb, block, k_total)
    fused = jax.jit(lambda v: kops.xla_encode_topk(v, kpb, block))
    r0 = jnp.zeros_like(x)
    fused_ef = jax.jit(
        lambda v, r: kops.xla_ef_encode_topk(v, r, kpb, block))

    t_unfused = _time(unfused, x)
    t_fused = _time(fused, x)
    t_fused_ef = _time(fused_ef, x, r0)
    speedup = t_unfused / max(t_fused, 1e-12)
    csv_writer("kernel_unfused_select_encode", t_unfused * 1e6,
               f"n={n},k={k_total},global topk_select + scatter + pack")
    csv_writer("kernel_fused_encode_xla", t_fused * 1e6,
               f"n={n},k_per_block={kpb},speedup={speedup:.2f}x")
    csv_writer("kernel_fused_ef_encode_xla", t_fused_ef * 1e6,
               f"n={n},k_per_block={kpb},residual update fused")

    # interpret-mode Pallas parity vs the XLA oracle (small tensor: the
    # interpreter is slow, and parity is independent of size)
    ns = 1 << 14
    xs = jnp.asarray(np.random.default_rng(1).standard_normal(ns),
                     jnp.float32)
    ks = kops.per_block_k(ns, ns // 100, block)
    v_i, m_i = kops.encode_topk(xs, ks, block, interpret=True)
    v_x, m_x = kops.xla_encode_topk(xs, ks, block)
    parity = bool(jnp.array_equal(v_i, v_x) and jnp.array_equal(m_i, m_x))
    rt = kops.decode_topk(v_i, m_i, xs.shape, interpret=True)
    rt_ok = bool(jnp.array_equal(rt, kops.xla_decode_topk(v_x, m_x,
                                                          xs.shape)))
    csv_writer("kernel_interpret_parity", 0.0,
               f"encode={'ok' if parity else 'MISMATCH'},"
               f"roundtrip={'ok' if rt_ok else 'MISMATCH'}")

    # structural stats of the compiled Pallas encode kernel (TPU numbers)
    kp = tk._lane_pad(kpb)
    vmem_bytes = block * 4 + kp * 4 + (block // 32) * 4
    csv_writer("kernel_pallas_structure", 0.0,
               f"block={block},vmem_bytes={vmem_bytes},"
               f"search_iters={tk._SEARCH_BITS},grid={nb},"
               f"values_lanes={kp}")
    return {"kernel": {
        "t_unfused_us": t_unfused * 1e6,
        "t_fused_us": t_fused * 1e6,
        "t_fused_ef_us": t_fused_ef * 1e6,
        "speedup": speedup,
        "parity": float(parity and rt_ok),
        "vmem_bytes": float(vmem_bytes),
        "grid": float(nb),
    }}
