"""Paper Fig. 10: averaged one-iteration training latency on the paper's
testbeds, for {equal-number, equal-compute, OP-Fence} × {dense, uniform
TopK, AdaTopK}, on the GPT2-XL profile with the paper's Table-6 settings
(batch 3, 2 micro-batches).

Wall-time over the Internet cannot be measured in this container; the
discrete-event simulator (repro.core.executor) replays the same GPipe
schedule over the same α–β link model the paper's estimator uses — its
agreement with the closed-form Eq. 3 is covered by tests."""
from __future__ import annotations

from typing import Dict

from repro.configs import resolve
from repro.core import (network, plan_adatopk, plan_none, plan_uniform,
                        simulate_iteration, SCHEDULERS)
from repro.models.opgraph_models import profile_opgraph

RATIO = 100.0
BATCH, SEQ, N_MICRO = 3, 1024, 2   # paper Table 6 for GPT2-XL


def run_one_testbed(testbed: int) -> Dict[str, Dict[str, float]]:
    cfg = resolve("gpt2-xl").full
    graph = profile_opgraph(cfg, BATCH, SEQ)
    shapes = {"tokens": (BATCH, SEQ), "labels": (BATCH, SEQ)}
    prof = graph.annotate(shapes)
    cluster = network.paper_testbed(testbed, seed=0)

    out: Dict[str, Dict[str, float]] = {}
    for sname, sfn in SCHEDULERS.items():
        if sname == "joint":
            continue   # Fig. 10 is the paper's 3 schedulers; the joint
        sch = sfn(graph, prof, cluster)   # co-planner has its own bench
                                          # (joint_planning / ratio_sweep)
        plans = {
            "dense": plan_none(graph, sch.placement),
            "uniform_topk": plan_uniform(graph, sch.placement, RATIO),
            "adatopk": plan_adatopk(graph, prof, cluster, sch.placement,
                                    RATIO),
        }
        out[sname] = {}
        for pname, plan in plans.items():
            sim = simulate_iteration(graph, prof, sch, cluster, plan,
                                     n_micro=N_MICRO)
            out[sname][pname] = sim.iteration_time
    return out


def run(csv_writer):
    for testbed in (1, 2):
        res = run_one_testbed(testbed)
        for sname, plans in res.items():
            for pname, t in plans.items():
                csv_writer(f"fig10_latency_tb{testbed}_{sname}_{pname}",
                           t * 1e6, f"iter_s={t:.3f}")
        # paper's ordering claims on every testbed:
        for sname in res:
            assert res[sname]["uniform_topk"] < res[sname]["dense"], sname
            assert res[sname]["adatopk"] < res[sname]["dense"], sname
        # OP-Fence ≤ the naive baselines under dense transport
        assert res["opfence"]["dense"] <= res["equal_number"]["dense"] * 1.01
    return res
