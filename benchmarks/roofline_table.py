"""§Roofline table (deliverable g): aggregates experiments/dryrun/*.json into
the per-(arch × shape × mesh) roofline rows — three terms, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs ratio — and emits CSV."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_records(pattern: str = "*.json") -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def baseline_records() -> List[Dict]:
    return [r for r in load_records()
            if r.get("status") == "ok" and not r.get("unroll")
            and "__" not in os.path.basename(str(r.get("hlo_path", "")))
            and "overrides" not in json.dumps(r.get("note", ""))]


def run(csv_writer):
    recs = [r for r in load_records() if r.get("status") == "ok"]
    if not recs:
        csv_writer("roofline_table", 0.0, "no dryrun records: run "
                   "`python -m repro.launch.dryrun --all` first")
        return []
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        rf = r["roofline"]
        bound = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        ratio = rf.get("useful_ratio")
        csv_writer(
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
            bound * 1e6,
            f"dom={rf['dominant']},c={rf['compute_s']:.2e},"
            f"m={rf['memory_s']:.2e},coll={rf['collective_s']:.2e},"
            f"useful={ratio if ratio is None else round(ratio, 3)},"
            f"mem_GiB={r['mem']['peak_per_device'] / 2**30:.1f}")
    return recs
