"""§Roofline table (deliverable g): aggregates experiments/dryrun/*.json into
the per-(arch × shape × mesh) roofline rows — three terms, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs ratio — and emits CSV.

Also carries the compression-pricing A/B (§6: the codec is a roofline term
too): the same workload co-planned with the codec priced free (legacy)
versus priced by calibrated :class:`KernelCostModel` entries — the sim's
``compress_busy``, the overlap-discounted wall-clock delta, and how the
planner's chosen ratios change once encode compute enters the cost model."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def compression_ab(csv_writer, arch: str = "gpt2-xl", batch: int = 2,
                   seq: int = 128, ratio: float = 64.0) -> Dict[str, Dict]:
    """schedule_joint + simulate_iteration with the codec priced free vs
    priced by a calibrated per-device KernelCostModel.

    Three CSV rows: the free-codec baseline, the priced run (nonzero
    ``compress_busy``, overlap-discounted delta = how much of the codec the
    pipeline could NOT hide behind next-micro-batch compute), and a
    slow-codec run where the profitability guard prunes the plan — the
    chosen ratios visibly react to ``compress_seconds``."""
    from repro.configs import resolve
    from repro.core import EdgeCostModel, simulate_iteration
    from repro.core.costmodel import KernelCostModel
    from repro.core.network import paper_testbed
    from repro.core.scheduler import schedule_joint
    from repro.models.opgraph_models import profile_opgraph

    cfg = resolve(arch).smoke
    graph = profile_opgraph(cfg, batch, seq)
    shapes = {"tokens": (batch, seq), "labels": (batch, seq)}
    profiles = graph.annotate(shapes)
    cluster = paper_testbed(1, seed=0)
    n_micro = 4

    # ~10 GB/s codec: roughly the CPU fused-encode pace kernel_bench
    # measures (re-pin from BENCH_kernel_topk on real hardware); "slow"
    # is wire-speed-comparable, where compressing stops paying for itself.
    devices = range(len(cluster.devices))
    kc = {d: KernelCostModel(bytes_per_second=1e10) for d in devices}
    kc_slow = {d: KernelCostModel(bytes_per_second=2e6) for d in devices}

    out: Dict[str, Dict] = {}
    for name, costs in (("free", None), ("priced", kc), ("slow", kc_slow)):
        seed_model = EdgeCostModel(graph, profiles, cluster,
                                   kernel_costs=costs or {})
        jp = schedule_joint(graph, profiles, cluster, ratio=ratio, seed=0,
                            cost_model=seed_model, verify=False)
        sim_model = jp.cost_model.with_plan(jp.plan)
        sim = simulate_iteration(graph, profiles, jp.schedule, cluster,
                                 jp.plan, n_micro=n_micro,
                                 cost_model=sim_model)
        ratios = sorted(jp.plan.edge_ratio.values()) if jp.plan else []
        out[name] = {
            "iteration_s": sim.iteration_time,
            "compress_busy_s": sim.compress_busy,
            "pace_s": jp.predicted_pace,
            "n_compressed_edges": float(len(ratios)),
            "mean_ratio": float(sum(ratios) / len(ratios)) if ratios else 0.0,
        }
    base, priced = out["free"], out["priced"]
    # overlap discount: codec seconds the pipeline hid behind compute
    delta = priced["iteration_s"] - base["iteration_s"]
    hidden = priced["compress_busy_s"] - delta
    priced["overlap_hidden_s"] = hidden
    priced["wall_delta_s"] = delta
    for name, r in out.items():
        csv_writer(
            f"roofline_compress_ab_{name}", r["iteration_s"] * 1e6,
            f"arch={arch},compress_busy_us={r['compress_busy_s'] * 1e6:.1f},"
            f"edges={int(r['n_compressed_edges'])},"
            f"mean_ratio={r['mean_ratio']:.1f}"
            + (f",overlap_hidden_us={hidden * 1e6:.1f}"
               if name == "priced" else ""))
    return out


def load_records(pattern: str = "*.json") -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def baseline_records() -> List[Dict]:
    return [r for r in load_records()
            if r.get("status") == "ok" and not r.get("unroll")
            and "__" not in os.path.basename(str(r.get("hlo_path", "")))
            and "overrides" not in json.dumps(r.get("note", ""))]


def run(csv_writer):
    ab = compression_ab(csv_writer)
    recs = [r for r in load_records() if r.get("status") == "ok"]
    if not recs:
        csv_writer("roofline_table", 0.0, "no dryrun records: run "
                   "`python -m repro.launch.dryrun --all` first")
        return {"compression_ab": ab, "rows": []}
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        rf = r["roofline"]
        bound = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        ratio = rf.get("useful_ratio")
        csv_writer(
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
            bound * 1e6,
            f"dom={rf['dominant']},c={rf['compute_s']:.2e},"
            f"m={rf['memory_s']:.2e},coll={rf['collective_s']:.2e},"
            f"useful={ratio if ratio is None else round(ratio, 3)},"
            f"mem_GiB={r['mem']['peak_per_device'] / 2**30:.1f}")
    return {"compression_ab": ab, "rows": recs}
