"""Elastic runtime under churn: simulated throughput vs. churn rate.

Three systems on the paper's testbed-1 topology (Cluster A/B), GPT2-XL
profile workload, scripted node-failure traces:

* ``elastic``          — ElasticController: lease-based detection, OP-Fence
                         re-plan on the survivors, minimal state migration,
                         pipeline refill; overheads charged to the clock.
* ``elastic_adatopk``  — same, composed with AdaTopK(100) on the activation/
                         gradient edges (migration payloads stay dense —
                         bit-exactness is non-negotiable).
* ``static``           — the seed system: one schedule for the whole job.  A
                         failure of any scheduled CompNode wedges the
                         pipeline; throughput over the same wall-clock window
                         is whatever finished before the hit.

Effective throughput = useful samples / simulated wall-clock.
"""
from __future__ import annotations

from typing import List

from repro.configs import resolve
from repro.core import network, plan_adatopk, simulate_iteration
from repro.elastic import ChurnEvent, ChurnTrace, ElasticController
from repro.models.opgraph_models import profile_opgraph

BATCH, SEQ, N_MICRO = 3, 1024, 2       # paper Table 6 for GPT2-XL
HORIZON = 40                           # useful steps each system must deliver


def _failure_trace(victims: List[int], t_iter: float, horizon: int
                   ) -> ChurnTrace:
    """k failures spread evenly across the horizon."""
    k = len(victims)
    events = [ChurnEvent(time=(i + 1) * horizon * t_iter / (k + 1),
                         kind="leave", node=v)
              for i, v in enumerate(victims)]
    return ChurnTrace(tuple(events))


def run(csv_writer, horizon: int = HORIZON):
    cfg = resolve("gpt2-xl").full
    graph = profile_opgraph(cfg, BATCH, SEQ)
    prof = graph.annotate({"tokens": (BATCH, SEQ), "labels": (BATCH, SEQ)})
    cluster = network.paper_testbed(1, seed=0)

    probe = ElasticController(graph, prof, cluster, ChurnTrace(()),
                              n_micro=N_MICRO)
    sched0 = probe.schedule
    stage_devs = sched0.stage_devices()
    # victims spread across pipeline positions, no repeats
    pool = stage_devs[1::max(1, len(stage_devs) // 5)]

    def adatopk_factory(g, p, cl, placement):
        return plan_adatopk(g, p, cl, placement, 100.0)

    systems = (("elastic", None), ("elastic_adatopk", adatopk_factory))
    # per-system churn-free iteration time: churn is wall-clock, so a trace
    # with "k failures mid-run" must be scaled to each system's own pace or
    # the faster system just finishes before the first failure lands
    t_iter = {}
    for name, factory in systems:
        plan = factory(graph, prof, cluster, sched0.placement) if factory \
            else None
        t_iter[name] = simulate_iteration(graph, prof, sched0, cluster, plan,
                                          n_micro=N_MICRO).iteration_time

    results = {}
    for n_fail in (0, 1, 2, 3):
        phi = {}
        for name, factory in systems:
            trace = _failure_trace(pool[:n_fail], t_iter[name], horizon)
            ctrl = ElasticController(graph, prof, cluster, trace,
                                     plan_factory=factory, n_micro=N_MICRO,
                                     lease_s=2.0 * t_iter[name],
                                     checkpoint_interval=2)
            res = ctrl.run(steps=horizon)
            phi[name] = res.samples_per_second(BATCH)
            if name == "elastic":
                window = res.total_seconds
                n_epochs = len(res.epochs)
                moved_gb = sum(e.moved_bytes for e in res.epochs) / 1e9
        # static baseline: completes steps at its churn-free pace until a
        # scheduled CompNode dies, then the pipeline is wedged for the rest
        # of its planned horizon
        trace = _failure_trace(pool[:n_fail], t_iter["elastic"], horizon)
        hits = [e.time for e in trace.events if e.node in stage_devs]
        static_steps = horizon if not hits \
            else min(horizon, int(min(hits) / t_iter["elastic"]))
        phi["static"] = static_steps * BATCH / (horizon * t_iter["elastic"])
        speed = phi["elastic"] / phi["static"] if phi["static"] > 0 \
            else float("inf")
        results[n_fail] = phi
        csv_writer(f"churn{n_fail}_elastic", window / horizon * 1e6,
                   f"phi={phi['elastic']:.3f}smp/s_epochs={n_epochs}"
                   f"_moved={moved_gb:.1f}GB")
        csv_writer(f"churn{n_fail}_elastic_adatopk", 0.0,
                   f"phi={phi['elastic_adatopk']:.3f}smp/s")
        csv_writer(f"churn{n_fail}_static", 0.0,
                   f"phi={phi['static']:.3f}smp/s_speedup={speed:.2f}x")

    # sanity: elastic survives churn the static plan cannot
    assert results[0]["elastic"] > 0
    for n_fail in (1, 2, 3):
        assert results[n_fail]["elastic"] > results[n_fail]["static"], results
        # graceful degradation: anchored re-plans keep migration near the
        # dead node's own shard, so churn costs stay bounded
        assert results[n_fail]["elastic"] > 0.4 * results[0]["elastic"], \
            results
    return results
