"""Elastic runtime under churn: simulated throughput vs. churn rate.

Four systems on the paper's testbed-1 topology (Cluster A/B), GPT2-XL
profile workload, scripted node-failure traces:

* ``elastic``          — ElasticController (PR 1): lease-based detection,
                         OP-Fence re-plan on the survivors, stop-the-world
                         state migration, pipeline refill; the straggler
                         detector consumes only executor telemetry
                         (TelemetryLog aggregates the simulator's StepTiming
                         samples — never the estimator).
* ``elastic_overlap``  — same detection, overlapped migration: after the
                         failure only the dead shard's checkpoint stream
                         blocks; training resumes on the interim schedule
                         while survivor state drains in the background over
                         bandwidth-shared links, then cut-over charges the
                         residual + one refill.  Boundary pinning is on (the
                         overlap-mode default): no re-cut moves state across
                         the WAN.
* ``elastic_joint``    — stop-the-world with the OP-Fence × AdaTopK
                         co-planner *driving epoch plans end to end*
                         (``planner="joint"``, ratio 100): schedule_joint
                         produces the initial and full-re-plan candidates,
                         and AdaTopK plans follow every re-cut (migration
                         payloads stay dense — bit-exactness is
                         non-negotiable).
* ``static``           — the seed system: one schedule for the whole job.  A
                         failure of any scheduled CompNode wedges the
                         pipeline; throughput over the same wall-clock window
                         is whatever finished before the hit.

Effective throughput = useful samples / simulated wall-clock.  The headline
metric for overlapping is *post-failure* throughput (useful samples per
second from failure detection to the end of the run): the acceptance bar is
``elastic_overlap ≥ 1.2× elastic`` there.

A second scenario exercises the **closed planning loop**: no node fails, but
one intra-site link silently congests to 0.5× its spec bandwidth
(``slowlink`` churn event) on a β-dominated long-fat-network topology.  The
calibrated controller (periodic `fit_link_corrections` from link telemetry +
joint re-plan on the corrected costs) must recover ≥
``CLOSED_LOOP_SPEEDUP``× the post-degradation throughput of an identical
controller with calibration off (the static-cost-model broker) — the
acceptance bar of the closed-loop PR.

``profile="tiny"`` runs the same pipeline on a 4-layer GPT so CI can smoke
the elastic path in seconds (asserts relaxed to sanity checks);
``migration_mode="overlap"`` forces every elastic system onto the overlapped
path so CI exercises the new overlap defaults end to end.

``trace=True`` (harness flag ``--trace``) attaches the observability layer
(:mod:`repro.obs`) to two representative runs — the 1-failure ``elastic``
system and the closed-loop ``calibrated`` controller — and writes
``TRACE_<name>.json`` (Perfetto), ``TRACE_<name>.jsonl`` (loss-free event
log), ``FLIGHT_<name>.jsonl`` (the broker's decision log, including any
watchdog trips), ``METRICS_<name>.json`` (metrics snapshot with the sim's
busy totals) and ``CRITPATH_<name>.json`` (the critical-path blame table)
next to the BENCH artifacts, then prints the run report (timeline,
comm/compute overlap, straggler heatmap, critical path, top interventions,
decision log).  Tracing is observation-only: the traced runs' simulated
metrics are bit-identical to untraced ones (tested).
"""
from __future__ import annotations

from typing import List, Optional

from repro.configs import resolve
from repro.core import EdgeCostModel, network, plan_adatopk, simulate_iteration
from repro.elastic import ChurnEvent, ChurnTrace, ElasticController
from repro.models.opgraph_models import profile_opgraph

BATCH, SEQ, N_MICRO = 3, 1024, 2       # paper Table 6 for GPT2-XL
HORIZON = 40                           # useful steps each system must deliver
POST_FAILURE_SPEEDUP = 1.2             # overlap acceptance bar (gpt2-xl)
CLOSED_LOOP_SPEEDUP = 1.2              # calibration acceptance bar
CLOSED_LOOP_RATIO = 16.0               # AdaTopK ratio for the fat-pipe demo


def _failure_trace(victims: List[int], t_iter: float, horizon: int
                   ) -> ChurnTrace:
    """k failures spread evenly across the horizon."""
    k = len(victims)
    events = [ChurnEvent(time=(i + 1) * horizon * t_iter / (k + 1),
                         kind="leave", node=v)
              for i, v in enumerate(victims)]
    return ChurnTrace(tuple(events))


def _workload(profile: str):
    """(graph, profiles, cluster, batch) for a named churn profile.  Both
    profiles use the metadata-only opgraph — this benchmark is sim-only."""
    if profile == "gpt2-xl":
        cfg = resolve("gpt2-xl").full
        batch, seq = BATCH, SEQ
        cluster = network.paper_testbed(1, seed=0)
    elif profile == "tiny":
        from repro.configs.base import ModelCfg
        cfg = ModelCfg(name="gpt-churn-tiny", family="dense", n_layers=4,
                       d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                       vocab=128, rope_fraction=0.0, max_seq=64,
                       norm="layernorm", act="gelu")
        batch, seq = 2, 64
        cluster = network.geo_random(n=8, n_sites=2, seed=0)
    else:
        raise ValueError(f"unknown churn profile {profile!r}")
    graph = profile_opgraph(cfg, batch, seq)
    prof = graph.annotate({"tokens": (batch, seq), "labels": (batch, seq)})
    return graph, prof, cluster, batch


def _obs_kit():
    """A fresh (tracer, flight, metrics, watchdog) bundle for one traced
    run.  The watchdog subscribes to the controller's telemetry bus and
    writes its trips into the same flight recorder, so the decision log
    shows symptom (watchdog) and cure (re-plan) on one timeline."""
    from repro.obs import FlightRecorder, MetricsRegistry, TraceRecorder, Watchdog
    return dict(tracer=TraceRecorder(), flight=FlightRecorder(),
                metrics=MetricsRegistry(), watchdog=Watchdog())


def _write_obs(name: str, kit) -> None:
    """Emit the trace/flight/metrics/attribution artifacts for one
    instrumented run and print its report.  The Perfetto export is
    schema-checked before it is written — a malformed trace fails the
    bench, not the viewer.  The metrics snapshot carries the simulator's
    ``sim_*_busy_seconds`` totals, which CI gates the critpath attribution
    against (``--expect-busy``, 1% budget)."""
    import json

    from repro.obs import critpath as obs_critpath
    from repro.obs import export as obs_export
    from repro.obs import report as obs_report
    bad = obs_export.validate_trace_events(
        obs_export.to_trace_events(kit["tracer"]))
    assert not bad, bad
    chrome, jsonl = f"TRACE_{name}.json", f"TRACE_{name}.jsonl"
    flight = f"FLIGHT_{name}.jsonl"
    metrics_path = f"METRICS_{name}.json"
    crit_path = f"CRITPATH_{name}.json"
    obs_export.write_chrome_trace(kit["tracer"], chrome,
                                  metrics=kit["metrics"])
    obs_export.write_jsonl(kit["tracer"], jsonl, metrics=kit["metrics"])
    kit["flight"].to_jsonl(flight)
    with open(metrics_path, "w") as f:
        json.dump(kit["metrics"].snapshot(), f, indent=2, sort_keys=True)
        f.write("\n")
    events = kit["tracer"].events()
    decomps = obs_critpath.analyze(events)
    rows = obs_critpath.blame(decomps)
    busy = obs_critpath.busy_accounting(events)
    with open(crit_path, "w") as f:
        json.dump(obs_critpath.to_artifact(decomps, rows, busy, source=jsonl),
                  f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {chrome} {jsonl} {flight} {metrics_path} {crit_path}",
          flush=True)
    print(obs_report.build_report(events, kit["flight"].to_dicts()),
          flush=True)


def run(csv_writer, horizon: int = HORIZON, profile: str = "gpt2-xl",
        migration_mode: Optional[str] = None, trace: bool = False):
    if profile == "tiny":
        horizon = min(horizon, 12)
    graph, prof, cluster, batch = _workload(profile)

    probe = ElasticController(graph, prof, cluster, ChurnTrace(()),
                              n_micro=N_MICRO)
    sched0 = probe.schedule
    stage_devs = sched0.stage_devices()
    # victims spread across pipeline positions, no repeats
    pool = stage_devs[1::max(1, len(stage_devs) // 5)]

    def adatopk_factory(g, p, cl, placement):
        return plan_adatopk(g, p, cl, placement, 100.0)

    systems = (("elastic", "stop", None, {}),
               ("elastic_overlap", "overlap", None, {}),
               ("elastic_joint", "stop", None,
                {"planner": "joint", "joint_ratio": 100.0}))
    # per-system churn-free iteration time: churn is wall-clock, so a trace
    # with "k failures mid-run" must be scaled to each system's own pace or
    # the faster system just finishes before the first failure lands
    t_iter = {}
    for name, _, factory, extra in systems:
        plan = adatopk_factory(graph, prof, cluster, sched0.placement) \
            if extra.get("planner") == "joint" else \
            (factory(graph, prof, cluster, sched0.placement) if factory
             else None)
        t_iter[name] = simulate_iteration(graph, prof, sched0, cluster, plan,
                                          n_micro=N_MICRO).iteration_time

    results = {}
    for n_fail in (0, 1, 2, 3):
        phi = {}
        phi_post = {}
        for name, mode, factory, extra in systems:
            churn_trace = _failure_trace(pool[:n_fail], t_iter[name], horizon)
            kit = _obs_kit() if trace and name == "elastic" and n_fail == 1 \
                else None
            ctrl = ElasticController(graph, prof, cluster, churn_trace,
                                     plan_factory=factory, n_micro=N_MICRO,
                                     lease_s=2.0 * t_iter[name],
                                     checkpoint_interval=2,
                                     migration_mode=migration_mode or mode,
                                     **(kit or {}), **extra)
            res = ctrl.run(steps=horizon)
            if kit is not None:
                _write_obs("churn_elastic", kit)
            # detection is telemetry-fed end to end (never the estimator)
            assert ctrl.telemetry.n_samples > 0
            phi[name] = res.samples_per_second(batch)
            phi_post[name] = res.post_failure_throughput(batch)
            if name == "elastic":
                window = res.total_seconds
                n_epochs = len(res.epochs)
                moved_gb = sum(e.moved_bytes for e in res.epochs) / 1e9
            elif name == "elastic_overlap":
                bg_gb = sum(e.background_bytes for e in res.epochs) / 1e9
        # static baseline: completes steps at its churn-free pace until a
        # scheduled CompNode dies, then the pipeline is wedged for the rest
        # of its planned horizon
        churn_trace = _failure_trace(pool[:n_fail], t_iter["elastic"], horizon)
        hits = [e.time for e in churn_trace.events if e.node in stage_devs]
        static_steps = horizon if not hits \
            else min(horizon, int(min(hits) / t_iter["elastic"]))
        phi["static"] = static_steps * batch / (horizon * t_iter["elastic"])
        speed = phi["elastic"] / phi["static"] if phi["static"] > 0 \
            else float("inf")
        post_speed = phi_post["elastic_overlap"] / phi_post["elastic"] \
            if 0 < phi_post["elastic"] < float("inf") else float("inf")
        results[n_fail] = dict(phi, post=dict(phi_post))
        csv_writer(f"churn{n_fail}_elastic", window / horizon * 1e6,
                   f"phi={phi['elastic']:.3f}smp/s_epochs={n_epochs}"
                   f"_moved={moved_gb:.1f}GB")
        csv_writer(f"churn{n_fail}_elastic_overlap", 0.0,
                   f"phi={phi['elastic_overlap']:.3f}smp/s"
                   f"_bg={bg_gb:.1f}GB_postx={post_speed:.2f}")
        csv_writer(f"churn{n_fail}_elastic_joint", 0.0,
                   f"phi={phi['elastic_joint']:.3f}smp/s")
        csv_writer(f"churn{n_fail}_static", 0.0,
                   f"phi={phi['static']:.3f}smp/s_speedup={speed:.2f}x")

    # sanity: elastic survives churn the static plan cannot
    assert results[0]["elastic"] > 0
    for n_fail in (1, 2, 3):
        assert results[n_fail]["elastic"] > results[n_fail]["static"], results
        if profile != "gpt2-xl" or migration_mode is not None:
            continue
        # graceful degradation: anchored re-plans keep migration near the
        # dead node's own shard, so churn costs stay bounded
        assert results[n_fail]["elastic"] > 0.4 * results[0]["elastic"], \
            results
        # acceptance: overlapping recovers ≥1.2× faster than stop-the-world
        post = results[n_fail]["post"]
        assert post["elastic_overlap"] >= \
            POST_FAILURE_SPEEDUP * post["elastic"], (n_fail, post)
    results["closed_loop"] = closed_loop(csv_writer, profile, trace=trace)
    return results


def closed_loop(csv_writer, profile: str, steps: int = 30,
                trace: bool = False):
    """Closed-loop calibration demo (the PR's acceptance scenario).

    No node fails.  One *intra-site* link — the consumer side of the
    heaviest intra-site pipeline boundary — silently congests to 0.5× its
    spec bandwidth on a β-dominated long-fat-network topology
    (:func:`repro.core.network.fat_pipe_sites`).  The spec-planned AdaTopK
    allocation equalizes every compressed edge near ``R_max/r``, so the
    degraded edge becomes the new pace bound and *only* a re-fit of the cost
    model can relieve it: the WAN bottleneck is already max-compressed, and
    re-allocating against spec costs reproduces the same plan.  Two
    otherwise identical joint-planned controllers run the same trace:

    * ``calibrated`` — periodic ``fit_link_corrections`` over the telemetry
      window; the fitted ≈2× correction re-prices the degraded edge, the
      pace-divergence trigger fires, and the joint re-plan re-compresses it.
    * ``static_model`` — ``calibrate_interval=0``: the PR 3 broker, which
      keeps believing the spec sheets and never re-plans.

    The straggler detector is parked at a high threshold for *both* systems:
    a slow inbound link inflates the consumer's observed step time, and the
    compute-slowdown path would otherwise kick in and blur which subsystem
    earned the recovery.  Acceptance: calibrated post-degradation throughput
    ≥ ``CLOSED_LOOP_SPEEDUP`` × static.

    The scenario runs one fixed workload regardless of churn profile: the
    4-layer GPT on the fat-pipe topology is the *recoverable* regime (one
    congested link among several is a large pace fraction, and its AdaTopK
    allocation has headroom).  GPT2-XL at the paper's WAN bandwidths is
    α/pipeline-fill-dominated: a single link at 0.5× moves end-to-end
    throughput by only a few percent, so no broker — however well
    calibrated — has 1.2× to recover there; measured ≈1.08× for the
    calibrated controller, which is real but not a subsystem acceptance bar.
    """
    del profile   # one fixed workload: the demo is about the control loop
    from repro.configs.base import ModelCfg
    cfg = ModelCfg(name="gpt-churn-tiny", family="dense", n_layers=4,
                   d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                   vocab=128, rope_fraction=0.0, max_seq=64,
                   norm="layernorm", act="gelu")
    batch, seq = 2, 64
    cluster = network.fat_pipe_sites(n=8, n_sites=2, seed=0)
    graph = profile_opgraph(cfg, batch, seq)
    prof = graph.annotate({"tokens": (batch, seq), "labels": (batch, seq)})

    # deep micro-batching: steady-state pace (what the degraded edge bounds,
    # and what calibration recovers) dominates the one-off pipeline fill —
    # at n_micro=2 the fill term dilutes a single link's degradation to a
    # few percent of the iteration regardless of how well the broker plans
    common = dict(n_micro=8, planner="joint",
                  joint_ratio=CLOSED_LOOP_RATIO, detector_threshold=20.0,
                  calibrate_min_samples=3, replan_pace_margin=0.2)
    probe = ElasticController(graph, prof, cluster, ChurnTrace(()),
                              calibrate_interval=0, **common)
    t1 = probe.run(steps=1).steps[0].step_seconds

    # victim: the device with the heaviest intra-site boundary among devices
    # whose pipeline-adjacent links are ALL intra-site.  ``slowlink``
    # degrades every link touching the node, so a WAN-adjacent victim would
    # degrade the max-compressed WAN edge too — which Eq. 7 cannot relieve
    # (it is already at full allocation; re-planning against the new Rmax
    # just decompresses everyone else).  The demo isolates the recoverable
    # regime: a congested link with re-allocation headroom.
    devs = probe.schedule.stage_devices()
    model = EdgeCostModel(graph, prof, cluster, probe.plan)
    placement = probe.schedule.placement
    boundary_s = {}
    for (a, n) in model.cross_edges(placement):
        key = (placement[a], placement[n])
        boundary_s[key] = boundary_s.get(key, 0.0) + \
            model.edge_seconds(a, n, *key)
    wan_bw = min(cluster.link(a, b).bandwidth
                 for a, b in zip(devs, devs[1:]))

    def is_intra(i, j):
        return cluster.link(i, j).bandwidth > 10.0 * wan_bw

    adjacent = {d: [] for d in devs}
    for a, b in zip(devs, devs[1:]):
        adjacent[a].append((a, b))
        adjacent[b].append((a, b))
    eligible = [d for d in devs
                if all(is_intra(*pair) for pair in adjacent[d])]
    assert eligible, "no device with purely intra-site pipeline boundaries"
    victim = max(eligible,
                 key=lambda d: sum(boundary_s.get(pair, 0.0)
                                   for pair in adjacent[d]))

    t_deg = 4.0 * t1
    churn_trace = ChurnTrace((ChurnEvent(time=t_deg, kind="slowlink",
                                         node=victim, factor=0.5),))
    out = {}
    for name, interval in (("calibrated", 3), ("static_model", 0)):
        kit = _obs_kit() if trace and name == "calibrated" else None
        ctrl = ElasticController(graph, prof, cluster, churn_trace,
                                 calibrate_interval=interval,
                                 **(kit or {}), **common)
        res = ctrl.run(steps=steps)
        if kit is not None:
            _write_obs("closed_loop", kit)
        useful = sum(1 for s in res.steps if not s.lost and s.clock > t_deg)
        window = res.total_seconds - t_deg
        out[name] = dict(
            phi_post=useful * batch / window,
            phi=res.samples_per_second(batch),
            epochs=[e.cause for e in res.epochs],
            corrections={f"{i}->{j}": round(c, 3) for (i, j), c
                         in sorted(ctrl.link_corrections.items())})
        csv_writer(f"closedloop_{name}", 0.0,
                   f"phi_post={out[name]['phi_post']:.3f}smp/s"
                   f"_epochs={len(out[name]['epochs'])}")
    speedup = out["calibrated"]["phi_post"] / out["static_model"]["phi_post"]
    out["speedup"] = speedup
    csv_writer("closedloop_speedup", 0.0, f"x={speedup:.3f}")
    # the loop actually closed: corrections fitted, a calibration epoch ran
    assert "calibration" in out["calibrated"]["epochs"], out
    assert out["calibrated"]["corrections"], out
    assert "calibration" not in out["static_model"]["epochs"], out
    # acceptance: auto-calibration + joint re-plan recovers ≥1.2×
    assert speedup >= CLOSED_LOOP_SPEEDUP, out
    return out
