"""Elastic runtime under churn: simulated throughput vs. churn rate.

Four systems on the paper's testbed-1 topology (Cluster A/B), GPT2-XL
profile workload, scripted node-failure traces:

* ``elastic``          — ElasticController (PR 1): lease-based detection,
                         OP-Fence re-plan on the survivors, stop-the-world
                         state migration, pipeline refill; the straggler
                         detector consumes only executor telemetry
                         (TelemetryLog aggregates the simulator's StepTiming
                         samples — never the estimator).
* ``elastic_overlap``  — same detection, overlapped migration: after the
                         failure only the dead shard's checkpoint stream
                         blocks; training resumes on the interim schedule
                         while survivor state drains in the background over
                         bandwidth-shared links, then cut-over charges the
                         residual + one refill.
* ``elastic_adatopk``  — stop-the-world, composed with AdaTopK(100) on the
                         activation/gradient edges (migration payloads stay
                         dense — bit-exactness is non-negotiable).
* ``static``           — the seed system: one schedule for the whole job.  A
                         failure of any scheduled CompNode wedges the
                         pipeline; throughput over the same wall-clock window
                         is whatever finished before the hit.

Effective throughput = useful samples / simulated wall-clock.  The headline
metric for overlapping is *post-failure* throughput (useful samples per
second from failure detection to the end of the run): the acceptance bar is
``elastic_overlap ≥ 1.2× elastic`` there.

``profile="tiny"`` runs the same pipeline on a 4-layer GPT so CI can smoke
the elastic path in seconds (asserts relaxed to sanity checks).
"""
from __future__ import annotations

from typing import List

from repro.configs import resolve
from repro.core import network, plan_adatopk, simulate_iteration
from repro.elastic import ChurnEvent, ChurnTrace, ElasticController
from repro.models.opgraph_models import profile_opgraph

BATCH, SEQ, N_MICRO = 3, 1024, 2       # paper Table 6 for GPT2-XL
HORIZON = 40                           # useful steps each system must deliver
POST_FAILURE_SPEEDUP = 1.2             # overlap acceptance bar (gpt2-xl)


def _failure_trace(victims: List[int], t_iter: float, horizon: int
                   ) -> ChurnTrace:
    """k failures spread evenly across the horizon."""
    k = len(victims)
    events = [ChurnEvent(time=(i + 1) * horizon * t_iter / (k + 1),
                         kind="leave", node=v)
              for i, v in enumerate(victims)]
    return ChurnTrace(tuple(events))


def _workload(profile: str):
    """(graph, profiles, cluster, batch) for a named churn profile.  Both
    profiles use the metadata-only opgraph — this benchmark is sim-only."""
    if profile == "gpt2-xl":
        cfg = resolve("gpt2-xl").full
        batch, seq = BATCH, SEQ
        cluster = network.paper_testbed(1, seed=0)
    elif profile == "tiny":
        from repro.configs.base import ModelCfg
        cfg = ModelCfg(name="gpt-churn-tiny", family="dense", n_layers=4,
                       d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                       vocab=128, rope_fraction=0.0, max_seq=64,
                       norm="layernorm", act="gelu")
        batch, seq = 2, 64
        cluster = network.geo_random(n=8, n_sites=2, seed=0)
    else:
        raise ValueError(f"unknown churn profile {profile!r}")
    graph = profile_opgraph(cfg, batch, seq)
    prof = graph.annotate({"tokens": (batch, seq), "labels": (batch, seq)})
    return graph, prof, cluster, batch


def run(csv_writer, horizon: int = HORIZON, profile: str = "gpt2-xl"):
    if profile == "tiny":
        horizon = min(horizon, 12)
    graph, prof, cluster, batch = _workload(profile)

    probe = ElasticController(graph, prof, cluster, ChurnTrace(()),
                              n_micro=N_MICRO)
    sched0 = probe.schedule
    stage_devs = sched0.stage_devices()
    # victims spread across pipeline positions, no repeats
    pool = stage_devs[1::max(1, len(stage_devs) // 5)]

    def adatopk_factory(g, p, cl, placement):
        return plan_adatopk(g, p, cl, placement, 100.0)

    systems = (("elastic", "stop", None),
               ("elastic_overlap", "overlap", None),
               ("elastic_adatopk", "stop", adatopk_factory))
    # per-system churn-free iteration time: churn is wall-clock, so a trace
    # with "k failures mid-run" must be scaled to each system's own pace or
    # the faster system just finishes before the first failure lands
    t_iter = {}
    for name, _, factory in systems:
        plan = factory(graph, prof, cluster, sched0.placement) if factory \
            else None
        t_iter[name] = simulate_iteration(graph, prof, sched0, cluster, plan,
                                          n_micro=N_MICRO).iteration_time

    results = {}
    for n_fail in (0, 1, 2, 3):
        phi = {}
        phi_post = {}
        for name, mode, factory in systems:
            trace = _failure_trace(pool[:n_fail], t_iter[name], horizon)
            ctrl = ElasticController(graph, prof, cluster, trace,
                                     plan_factory=factory, n_micro=N_MICRO,
                                     lease_s=2.0 * t_iter[name],
                                     checkpoint_interval=2,
                                     migration_mode=mode)
            res = ctrl.run(steps=horizon)
            # detection is telemetry-fed end to end (never the estimator)
            assert ctrl.telemetry.n_samples > 0
            phi[name] = res.samples_per_second(batch)
            phi_post[name] = res.post_failure_throughput(batch)
            if name == "elastic":
                window = res.total_seconds
                n_epochs = len(res.epochs)
                moved_gb = sum(e.moved_bytes for e in res.epochs) / 1e9
            elif name == "elastic_overlap":
                bg_gb = sum(e.background_bytes for e in res.epochs) / 1e9
        # static baseline: completes steps at its churn-free pace until a
        # scheduled CompNode dies, then the pipeline is wedged for the rest
        # of its planned horizon
        trace = _failure_trace(pool[:n_fail], t_iter["elastic"], horizon)
        hits = [e.time for e in trace.events if e.node in stage_devs]
        static_steps = horizon if not hits \
            else min(horizon, int(min(hits) / t_iter["elastic"]))
        phi["static"] = static_steps * batch / (horizon * t_iter["elastic"])
        speed = phi["elastic"] / phi["static"] if phi["static"] > 0 \
            else float("inf")
        post_speed = phi_post["elastic_overlap"] / phi_post["elastic"] \
            if 0 < phi_post["elastic"] < float("inf") else float("inf")
        results[n_fail] = dict(phi, post=dict(phi_post))
        csv_writer(f"churn{n_fail}_elastic", window / horizon * 1e6,
                   f"phi={phi['elastic']:.3f}smp/s_epochs={n_epochs}"
                   f"_moved={moved_gb:.1f}GB")
        csv_writer(f"churn{n_fail}_elastic_overlap", 0.0,
                   f"phi={phi['elastic_overlap']:.3f}smp/s"
                   f"_bg={bg_gb:.1f}GB_postx={post_speed:.2f}")
        csv_writer(f"churn{n_fail}_elastic_adatopk", 0.0,
                   f"phi={phi['elastic_adatopk']:.3f}smp/s")
        csv_writer(f"churn{n_fail}_static", 0.0,
                   f"phi={phi['static']:.3f}smp/s_speedup={speed:.2f}x")

    # sanity: elastic survives churn the static plan cannot
    assert results[0]["elastic"] > 0
    for n_fail in (1, 2, 3):
        assert results[n_fail]["elastic"] > results[n_fail]["static"], results
        if profile != "gpt2-xl":
            continue
        # graceful degradation: anchored re-plans keep migration near the
        # dead node's own shard, so churn costs stay bounded
        assert results[n_fail]["elastic"] > 0.4 * results[0]["elastic"], \
            results
        # acceptance: overlapping recovers ≥1.2× faster than stop-the-world
        post = results[n_fail]["post"]
        assert post["elastic_overlap"] >= \
            POST_FAILURE_SPEEDUP * post["elastic"], (n_fail, post)
    return results
