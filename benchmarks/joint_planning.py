"""Joint OP-Fence/AdaTopK co-planning: throughput + predicted pace per
scheduler — the perf artifact the CI trajectory tracks.

Each scheduler in the registry (equal_number, equal_compute, opfence, joint)
is paired with its AdaTopK plan (joint uses the plan its fixed point
converged on) and measured two ways on the same workload/topology:

* ``pace``    — the unified EdgeCostModel's Eq. 3 steady-state pace, the
                planner's own objective;
* ``phi``     — samples/second from the discrete-event simulator, the
                ground-truth the pace is supposed to track.

``profile="tiny"`` shrinks the workload so CI can smoke the whole joint
path in seconds; ``--json`` on the harness dumps the returned dict into
``BENCH_joint_planning.json``.
"""
from __future__ import annotations

from typing import Dict

from repro.configs import resolve
from repro.core import (EdgeCostModel, SCHEDULERS, network, plan_adatopk,
                        schedule_joint, simulate_iteration)
from repro.models.opgraph_models import profile_opgraph

RATIO = 100.0


def _workload(profile: str):
    if profile == "gpt2-xl":
        cfg = resolve("gpt2-xl").full
        batch, seq = 3, 1024               # paper Table 6
        cluster = network.paper_testbed(1, seed=0)
    elif profile == "tiny":
        from repro.configs.base import ModelCfg
        cfg = ModelCfg(name="gpt-joint-tiny", family="dense", n_layers=4,
                       d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                       vocab=128, rope_fraction=0.0, max_seq=64,
                       norm="layernorm", act="gelu")
        batch, seq = 2, 64
        cluster = network.geo_random(n=8, n_sites=2, seed=0)
    else:
        raise ValueError(f"unknown joint profile {profile!r}")
    graph = profile_opgraph(cfg, batch, seq)
    prof = graph.annotate({"tokens": (batch, seq), "labels": (batch, seq)})
    return graph, prof, cluster, batch


def run(csv_writer, profile: str = "gpt2-xl", n_micro: int = 2
        ) -> Dict[str, Dict[str, float]]:
    graph, prof, cluster, batch = _workload(profile)
    dense = EdgeCostModel(graph, prof, cluster)
    out: Dict[str, Dict[str, float]] = {}
    for name, sfn in SCHEDULERS.items():
        if name == "joint":
            jp = schedule_joint(graph, prof, cluster, ratio=RATIO)
            sch, plan = jp.schedule, jp.plan
            pace = jp.predicted_pace
        else:
            sch = sfn(graph, prof, cluster)
            plan = plan_adatopk(graph, prof, cluster, sch.placement, RATIO)
            pace = dense.with_plan(plan).stage_pace(sch)
        t = simulate_iteration(graph, prof, sch, cluster, plan,
                               n_micro=n_micro).iteration_time
        phi = batch / t
        out[name] = dict(pace=pace, iter_s=t, phi=phi)
        csv_writer(f"joint_{profile}_{name}", t * 1e6,
                   f"phi={phi:.3f}smp/s_pace={pace:.4f}")
    # the co-planner's pace may never exceed the blind pipeline's
    assert out["joint"]["pace"] <= out["opfence"]["pace"] * (1 + 1e-12), out
    return out
