"""Joint OP-Fence/AdaTopK co-planning: throughput + predicted pace per
scheduler — the perf artifact the CI trajectory tracks.

Each scheduler in the registry (equal_number, equal_compute, opfence, joint)
is paired with its AdaTopK plan (joint uses the plan its fixed point
converged on) and measured two ways on the same workload/topology:

* ``pace``    — the unified EdgeCostModel's Eq. 3 steady-state pace, the
                planner's own objective;
* ``phi``     — samples/second from the discrete-event simulator, the
                ground-truth the pace is supposed to track.

``profile="tiny"`` shrinks the workload so CI can smoke the whole joint
path in seconds; ``--json`` on the harness dumps the returned dict into
``BENCH_joint_planning.json``.

``profile="hetero"`` is the regime the co-planner exists for — and the one
the committed perf baseline (``benchmarks/baselines/``) is pinned on.  On a
uniform-width transformer chain AdaTopK compresses every boundary by the
same factor, so compression never changes which cut is optimal and joint
degenerates to schedule-then-compress (the tiny/gpt2-xl rows show exactly
pace ratio 1.0).  A mixed-width chain breaks that symmetry: Eq. 7 allocates
compression ∝ dense receive time, so wide boundaries shrink ~R× while
narrow ones stay dense, and the DP cut that avoided wide boundaries at
dense costs loses to a compute-balanced cut through them once they are
compressed.  On this profile the blind pipeline's predicted pace is ≈2.5×
the co-planner's (simulated iteration ≈1.7× — asserted below, and gated in
CI against the committed baseline).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.configs import resolve
from repro.core import (EdgeCostModel, SCHEDULERS, network, plan_adatopk,
                        schedule_joint, simulate_iteration)
from repro.core.opgraph import OpGraph, OpNode, OpType
from repro.models.opgraph_models import profile_opgraph

RATIO = 100.0

# profile="hetero": boundary widths of the mixed chain (wide=4096 boundaries
# take ~1000× the narrow=128 ones dense — and ~R× less compressed)
HETERO_WIDTHS = (128, 4096, 128, 128, 4096, 4096, 4096, 4096, 128, 4096,
                 4096, 4096, 128, 128, 128, 4096, 4096, 128, 4096, 128,
                 128, 4096, 4096, 128, 4096)
HETERO_SEPARATION = 1.5    # pace(opfence) ≥ 1.5 × pace(joint), pinned


def _hetero_chain(widths, batch: int) -> OpGraph:
    """Metadata-only mixed-width linear chain (cf. profile_opgraph: no
    apply fns, the simulator only reads shapes/flops/params)."""
    g = OpGraph("hetero-chain")
    g.add(OpNode("x", OpType.PLACEHOLDER))
    prev = "x"
    for i, (din, dout) in enumerate(zip(widths, widths[1:])):
        g.add(OpNode(f"l{i}", OpType.PARAMETRIC, args=(prev,),
                     out_shape_fn=lambda s, dout=dout: (s[0], dout),
                     flops_fn=lambda s, din=din, dout=dout:
                         2.0 * s[0] * din * dout,
                     n_params_fn=lambda s, din=din, dout=dout:
                         din * dout + dout))
        prev = f"l{i}"
    g.add(OpNode("y", OpType.PLACEHOLDER))
    g.add(OpNode("loss", OpType.LOSS, args=(prev, "y"),
                 out_shape_fn=lambda *s: (),
                 flops_fn=lambda *s: float(np.prod(s[0]))))
    return g


def _workload(profile: str):
    if profile == "gpt2-xl":
        cfg = resolve("gpt2-xl").full
        batch, seq = 3, 1024               # paper Table 6
        cluster = network.paper_testbed(1, seed=0)
    elif profile == "tiny":
        from repro.configs.base import ModelCfg
        cfg = ModelCfg(name="gpt-joint-tiny", family="dense", n_layers=4,
                       d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                       vocab=128, rope_fraction=0.0, max_seq=64,
                       norm="layernorm", act="gelu")
        batch, seq = 2, 64
        cluster = network.geo_random(n=8, n_sites=2, seed=0)
    elif profile == "hetero":
        batch = 8
        graph = _hetero_chain(HETERO_WIDTHS, batch)
        prof = graph.annotate({"x": (batch, HETERO_WIDTHS[0]),
                               "y": (batch, HETERO_WIDTHS[-1])})
        return graph, prof, network.fat_pipe_sites(n=4, n_sites=2, seed=2), \
            batch
    else:
        raise ValueError(f"unknown joint profile {profile!r}")
    graph = profile_opgraph(cfg, batch, seq)
    prof = graph.annotate({"tokens": (batch, seq), "labels": (batch, seq)})
    return graph, prof, cluster, batch


def run(csv_writer, profile: str = "gpt2-xl", n_micro: int = 2
        ) -> Dict[str, Dict[str, float]]:
    graph, prof, cluster, batch = _workload(profile)
    dense = EdgeCostModel(graph, prof, cluster)
    out: Dict[str, Dict[str, float]] = {}
    for name, sfn in SCHEDULERS.items():
        if name == "joint":
            jp = schedule_joint(graph, prof, cluster, ratio=RATIO)
            sch, plan = jp.schedule, jp.plan
            pace = jp.predicted_pace
        else:
            sch = sfn(graph, prof, cluster)
            plan = plan_adatopk(graph, prof, cluster, sch.placement, RATIO)
            pace = dense.with_plan(plan).stage_pace(sch)
        t = simulate_iteration(graph, prof, sch, cluster, plan,
                               n_micro=n_micro).iteration_time
        phi = batch / t
        out[name] = dict(pace=pace, iter_s=t, phi=phi)
        csv_writer(f"joint_{profile}_{name}", t * 1e6,
                   f"phi={phi:.3f}smp/s_pace={pace:.4f}")
    # the co-planner's pace may never exceed the blind pipeline's
    assert out["joint"]["pace"] <= out["opfence"]["pace"] * (1 + 1e-12), out
    if profile == "hetero":
        # the regime the baseline gates: joint strictly separates here
        assert out["opfence"]["pace"] >= \
            HETERO_SEPARATION * out["joint"]["pace"], out
    return out
