"""Ablation (Eq. 3): micro-batch pipelining amortizes the fill/drain cost —
T(n_b)/n_b falls toward the bottleneck pace.  Not a paper figure; validates
the throughput model the paper's scheduler optimizes."""
from __future__ import annotations

from repro.configs import resolve
from repro.core import network, plan_adatopk, schedule_opfence, \
    simulate_iteration
from repro.models.opgraph_models import profile_opgraph
from .latency import BATCH, SEQ


def run(csv_writer):
    cfg = resolve("gpt2-xl").full
    graph = profile_opgraph(cfg, BATCH, SEQ)
    prof = graph.annotate({"tokens": (BATCH, SEQ), "labels": (BATCH, SEQ)})
    cluster = network.paper_testbed(1, seed=0)
    sch = schedule_opfence(graph, prof, cluster)
    plan = plan_adatopk(graph, prof, cluster, sch.placement, 100.0)
    per_mb = {}
    for nb in (1, 2, 4, 8, 16):
        t = simulate_iteration(graph, prof, sch, cluster, plan,
                               n_micro=nb).iteration_time
        per_mb[nb] = t / nb
        csv_writer(f"ablation_nmicro_{nb}", t * 1e6,
                   f"per_microbatch_s={t / nb:.3f}")
    # Eq. 3: amortized cost strictly improves with pipelining depth
    assert per_mb[16] < per_mb[1]
    return per_mb
