"""Paper Fig. 11 + joint co-planning sweep.

Fig. 11: compression ratio 100 vs 1000 — the 10× larger ratio does NOT buy
10× lower latency because per-message latency (α) and the compute floor take
over.

Joint sweep (beyond-paper): at each ratio, compare the sequential pipeline
(OP-Fence on dense bytes, then AdaTopK) against ``schedule_joint``'s
OP-Fence × AdaTopK fixed point, under the shared EdgeCostModel pace metric
and the discrete-event simulator.  Acceptance: joint is never worse, and
strictly better on at least one ratio — compression changes which cut is
bottleneck-optimal, and only the co-planner can exploit that.
"""
from __future__ import annotations

from repro.configs import resolve
from repro.core import (EdgeCostModel, network, plan_adatopk, plan_uniform,
                        schedule_joint, schedule_opfence, simulate_iteration)
from repro.models.opgraph_models import profile_opgraph
from .latency import BATCH, N_MICRO, SEQ

JOINT_RATIOS = (10.0, 100.0, 300.0, 1000.0)


def run(csv_writer):
    cfg = resolve("gpt2-xl").full
    graph = profile_opgraph(cfg, BATCH, SEQ)
    prof = graph.annotate({"tokens": (BATCH, SEQ), "labels": (BATCH, SEQ)})
    cluster = network.paper_testbed(1, seed=0)
    sch = schedule_opfence(graph, prof, cluster)
    times = {}
    for ratio in (1, 100, 1000):
        plan = plan_uniform(graph, sch.placement, ratio) if ratio > 1 \
            else None
        t = simulate_iteration(graph, prof, sch, cluster, plan,
                               n_micro=N_MICRO).iteration_time
        times[ratio] = t
        csv_writer(f"fig11_ratio_{ratio}", t * 1e6, f"iter_s={t:.3f}")
    # Fig. 11's finding: 1000 is NOT ~10x better than 100
    speedup_100_to_1000 = times[100] / times[1000]
    assert speedup_100_to_1000 < 5.0, times
    assert times[100] < times[1], times

    # ---------------------------------------- joint vs sequential sweep ----
    dense = EdgeCostModel(graph, prof, cluster)
    joint = {}
    strictly_better = False
    for ratio in JOINT_RATIOS:
        seq_plan = plan_adatopk(graph, prof, cluster, sch.placement, ratio)
        seq_pace = dense.with_plan(seq_plan).stage_pace(sch)
        seq_iter = simulate_iteration(graph, prof, sch, cluster, seq_plan,
                                      n_micro=N_MICRO).iteration_time
        jp = schedule_joint(graph, prof, cluster, ratio=ratio)
        joint_iter = simulate_iteration(graph, prof, jp.schedule, cluster,
                                        jp.plan,
                                        n_micro=N_MICRO).iteration_time
        assert jp.predicted_pace <= seq_pace * (1 + 1e-12), ratio
        strictly_better |= jp.predicted_pace < seq_pace * (1 - 1e-6)
        joint[ratio] = dict(seq_pace=seq_pace, joint_pace=jp.predicted_pace,
                            seq_iter_s=seq_iter, joint_iter_s=joint_iter,
                            rounds=jp.iterations)
        csv_writer(f"joint_r{ratio:g}", joint_iter * 1e6,
                   f"pace={jp.predicted_pace:.4f}_seq={seq_pace:.4f}"
                   f"_speedup={seq_pace / jp.predicted_pace:.2f}x")
    assert strictly_better, joint
    return {"fig11": times, "joint": joint}
