"""Paper Fig. 11: compression ratio 100 vs 1000 — the 10× larger ratio does
NOT buy 10× lower latency because per-message latency (α) and the compute
floor take over."""
from __future__ import annotations

from repro.configs import resolve
from repro.core import network, plan_uniform, schedule_opfence, \
    simulate_iteration
from repro.models.opgraph_models import profile_opgraph
from .latency import BATCH, N_MICRO, SEQ


def run(csv_writer):
    cfg = resolve("gpt2-xl").full
    graph = profile_opgraph(cfg, BATCH, SEQ)
    prof = graph.annotate({"tokens": (BATCH, SEQ), "labels": (BATCH, SEQ)})
    cluster = network.paper_testbed(1, seed=0)
    sch = schedule_opfence(graph, prof, cluster)
    times = {}
    for ratio in (1, 100, 1000):
        plan = plan_uniform(graph, sch.placement, ratio) if ratio > 1 \
            else None
        t = simulate_iteration(graph, prof, sch, cluster, plan,
                               n_micro=N_MICRO).iteration_time
        times[ratio] = t
        csv_writer(f"fig11_ratio_{ratio}", t * 1e6, f"iter_s={t:.3f}")
    # Fig. 11's finding: 1000 is NOT ~10x better than 100
    speedup_100_to_1000 = times[100] / times[1000]
    assert speedup_100_to_1000 < 5.0, times
    assert times[100] < times[1], times
    return times
