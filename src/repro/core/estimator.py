"""Workload / hardware estimation (FusionLLM §3.5).

The decentralized computing system is a bidirectional graph of CompNodes with
heterogeneous GPU memory ``D^p``, compute speed ``S(p)`` and pairwise link
parameters.  Three models from the paper:

* actual compute speed  S(p) = λ_p · S*(p)   (λ fitted by warm-up profiling)
* link cost             T_comm^{ij}(M) = α^{ij} + β^{ij} · M
* per-op time           T(f,p) = R(Pa(f)) + C(f,p) + W(f,p),   Eq. (1)
  with C(f,p) = FLOPs(f)/S(p); R is a link transfer when f and Pa(f) live on
  different CompNodes and ~0 otherwise; W (local write) is ignored as in the
  paper.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .opgraph import OpGraph, OpProfile


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """One CompNode's hardware sheet (paper Table 1 rows + λ_p)."""

    name: str
    peak_flops: float          # S*(p), FLOP/s
    mem_bytes: float           # D^p_gpu
    lam: float = 1.0           # λ_p scaling-down factor (warm-up profiled)

    @property
    def speed(self) -> float:  # S(p)
        return self.lam * self.peak_flops


# Representative consumer/datacenter sheets (paper Table 1, fp16 tensor FLOPS).
DEVICE_SHEETS: Dict[str, Tuple[float, float]] = {
    "H100":     (756e12, 80e9),
    "A100":     (311.84e12, 80e9),
    "RTX4090":  (165.16e12, 24e9),
    "RTX4080":  (97.5e12, 16e9),
    "RTX3080":  (59.5e12, 10e9),
    "RTX2080":  (40.0e12, 8e9),
    "TPUv5e":   (197e12, 16e9),
}


def make_device(name: str, sheet: str, lam: float = 1.0) -> DeviceSpec:
    peak, mem = DEVICE_SHEETS[sheet]
    return DeviceSpec(name=name, peak_flops=peak, mem_bytes=mem, lam=lam)


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """α–β model for one directed link."""

    alpha: float               # latency, seconds
    beta: float                # seconds per byte (1/bandwidth)

    def time(self, nbytes: float) -> float:
        return self.alpha + self.beta * float(nbytes)

    @property
    def bandwidth(self) -> float:
        return 1.0 / self.beta if self.beta > 0 else float("inf")


LOCAL_LINK = LinkSpec(alpha=0.0, beta=0.0)


class ClusterSpec:
    """CompNode group P = <{p_i}, {p_i,p_j}> with pairwise α–β links."""

    def __init__(self, devices: Sequence[DeviceSpec],
                 links: Mapping[Tuple[int, int], LinkSpec]):
        self.devices = list(devices)
        self._links = dict(links)
        n = len(self.devices)
        for (i, j) in self._links:
            if not (0 <= i < n and 0 <= j < n):
                raise ValueError(f"link ({i},{j}) out of range for {n} devices")

    def __len__(self) -> int:
        return len(self.devices)

    def link(self, i: int, j: int) -> LinkSpec:
        if i == j:
            return LOCAL_LINK
        if (i, j) in self._links:
            return self._links[(i, j)]
        if (j, i) in self._links:
            return self._links[(j, i)]
        raise KeyError(f"no link between CompNodes {i} and {j}")

    def comm_time(self, i: int, j: int, nbytes: float) -> float:
        return self.link(i, j).time(nbytes)

    def bandwidth_matrix(self) -> np.ndarray:
        n = len(self.devices)
        bw = np.zeros((n, n))
        for i in range(n):
            for j in range(n):
                if i != j:
                    bw[i, j] = self.link(i, j).bandwidth
        return bw

    def compute_time(self, flops: float, p: int) -> float:
        """C(f,p) = FLOPs(f) / S(p)."""
        return flops / self.devices[p].speed

    def links(self) -> Dict[Tuple[int, int], LinkSpec]:
        """Copy of the directed link table (topology transforms use this)."""
        return dict(self._links)

    def with_devices(self, devices: Sequence[DeviceSpec]) -> "ClusterSpec":
        """Same topology, replaced device sheets (elastic runtime: degraded
        λ_p for stragglers, restored λ_p on recovery)."""
        if len(devices) != len(self.devices):
            raise ValueError("device count must match the topology")
        return ClusterSpec(devices, self._links)


def fit_lambda(measured_flops_per_s: float, peak_flops: float) -> float:
    """Regression-based scaling-down factor λ_p = S(p)/S*(p) (paper cites
    Paleo).  With a single warm-up measurement this is a ratio; with several,
    the least-squares slope of achieved-vs-peak."""
    return float(measured_flops_per_s) / float(peak_flops)


def fit_lambda_regression(flops: Sequence[float], seconds: Sequence[float],
                          peak_flops: float) -> float:
    """λ from multiple warm-up profiles: least-squares slope through origin of
    time = FLOPs / (λ·S*)."""
    f = np.asarray(flops, dtype=np.float64)
    t = np.asarray(seconds, dtype=np.float64)
    # time = f / (lam*peak)  =>  lam = sum(f^2) / (peak * sum(f*t))  (LS)
    denom = peak_flops * float(np.dot(f, t))
    if denom <= 0:
        raise ValueError("degenerate warm-up profile")
    return float(np.dot(f, f)) / denom


def fit_alpha_beta(sizes: Sequence[float], seconds: Sequence[float]) -> LinkSpec:
    """Least-squares α–β fit from ping-pong style measurements."""
    M = np.stack([np.ones(len(sizes)), np.asarray(sizes, dtype=np.float64)], axis=1)
    sol, *_ = np.linalg.lstsq(M, np.asarray(seconds, dtype=np.float64), rcond=None)
    alpha, beta = float(max(sol[0], 0.0)), float(max(sol[1], 0.0))
    return LinkSpec(alpha=alpha, beta=beta)


@dataclasses.dataclass(frozen=True)
class OpCost:
    """Estimated cost of one op on its assigned CompNode (Eq. 1 terms)."""

    name: str
    comp_time: float       # C(f,p)
    recv_time: float       # R(Pa(f)) — only cross-CompNode parents
    recv_bytes: int
    send_bytes: int

    @property
    def total(self) -> float:
        return self.comp_time + self.recv_time


def estimate_op_costs(graph: OpGraph,
                      profiles: Mapping[str, OpProfile],
                      cluster: ClusterSpec,
                      placement: Mapping[str, int],
                      cost_model=None,
                      backward: bool = False) -> Dict[str, OpCost]:
    """Per-op Eq.(1) costs under a placement {op -> CompNode index}.

    All transported-byte accounting flows through the unified
    :class:`repro.core.costmodel.EdgeCostModel`: a cross-node edge's payload
    is the model's exact integer wire encoding under its compression plan
    (dense when the model carries no plan).  ``cost_model`` defaults to a
    dense model over ``(graph, profiles, cluster)``; pass
    ``EdgeCostModel(..., plan=plan)`` to estimate under compression — this
    replaces the removed ad-hoc ``compress_ratio`` mapping, whose smooth
    ``3/r`` approximation disagreed with the executor's exact wire bytes.
    """
    if cost_model is None:
        from .costmodel import EdgeCostModel   # late: costmodel imports us
        cost_model = EdgeCostModel(graph, profiles, cluster)
    costs: Dict[str, OpCost] = {}
    for n, node in graph.nodes.items():
        p = placement[n]
        prof = profiles[n]
        flops = prof.bwd_flops if backward else prof.fwd_flops
        comp = cluster.compute_time(flops, p)
        recv = 0.0
        recv_bytes = 0
        for a in node.args:
            q = placement[a]
            if q == p:
                continue
            nbytes = cost_model.edge_wire_bytes(a, n)
            recv += cost_model.link_seconds(q, p, nbytes)
            recv_bytes += int(nbytes)
        send_bytes = 0
        for u in graph.users[n]:
            if placement[u] != p:
                send_bytes += int(cost_model.edge_wire_bytes(n, u))
        costs[n] = OpCost(name=n, comp_time=comp, recv_time=recv,
                          recv_bytes=recv_bytes, send_bytes=send_bytes)
    return costs


def predict_step_time_components(graph: OpGraph,
                                 profiles: Mapping[str, OpProfile],
                                 cluster: ClusterSpec,
                                 placement: Mapping[str, int],
                                 cost_model=None,
                                 ) -> Dict[int, Tuple[float, float]]:
    """Per-CompNode (compute, recv) predicted FP+BP seconds, one micro-batch.

    Both directions of every cross-node edge are charged to the CompNode
    owning the *consumer* op — the attribution the executor's telemetry
    samples reproduce, so predictions and observations decompose identically.
    ``cost_model`` (see :func:`estimate_op_costs`) carries the compression
    plan and any telemetry-calibrated link corrections.
    """
    fwd = estimate_op_costs(graph, profiles, cluster, placement,
                            cost_model, backward=False)
    bwd = estimate_op_costs(graph, profiles, cluster, placement,
                            cost_model, backward=True)
    out: Dict[int, Tuple[float, float]] = {}
    for n in graph.nodes:
        p = placement[n]
        comp, recv = out.get(p, (0.0, 0.0))
        out[p] = (comp + fwd[n].comp_time + bwd[n].comp_time,
                  recv + fwd[n].recv_time + bwd[n].recv_time)
    return out


def predict_step_times(graph: OpGraph,
                       profiles: Mapping[str, OpProfile],
                       cluster: ClusterSpec,
                       placement: Mapping[str, int],
                       cost_model=None,
                       ) -> Dict[int, float]:
    """Per-CompNode predicted FP+BP seconds for one micro-batch.

    Sums Eq. (1) over each CompNode's assigned ops, forward and backward.
    This is the *reference prediction* the elastic straggler detector
    compares against — never the observation source: observations come from
    executor telemetry (:class:`repro.elastic.telemetry.TelemetryLog`), so a
    node is judged by its measured pace, not by re-running the model that
    scheduled it.

    Under closed-loop calibration the controller re-evaluates this with a
    corrections-bearing ``cost_model`` after every accepted link fit and
    *re-prices* the detector in place
    (:meth:`repro.elastic.detector.StragglerDetector.reprice`) — the
    prediction tracks the links as measured, so a slow-but-known wire stops
    looking like a slow node.
    """
    out: Dict[int, float] = {}
    for p, (comp, recv) in predict_step_time_components(
            graph, profiles, cluster, placement, cost_model).items():
        out[p] = comp + recv
    return out
