"""Throughput model (FusionLLM §3.6, Eq. 2–4; §5.2, Eq. 8).

Per-CompNode totals under an assignment A:
    C_p = Σ_{k∈A_p} Σ_{f∈S_k} C(f,p)
    R_p = Σ_{k∈A_p} Σ_{f∈S_k, P(f)≠P(Pa(f))} R(Pa(f))

single-pass latency       T_lat   = Σ_p (C_p + R_p)                     (Eq. 2)
pipelined (n_b batches)   T_pipe  = Σ_p (C_p + R_p) + (n_b-1)·max_p max(C_p,R_p)  (Eq. 3)
throughput                φ       = N_s / T_pipe                         (Eq. 4)
adaptive compression      ~T_pipe = Σ_p (C_p + 3·R_p/r_i) + 3(n_b-1)·max_p(C_p,R_p)/r   (Eq. 8)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from .estimator import ClusterSpec, OpCost, estimate_op_costs
from .opgraph import OpGraph, OpProfile


@dataclasses.dataclass(frozen=True)
class NodeLoad:
    """Per-CompNode (C_p, R_p) pair."""

    comp: float      # C_p
    recv: float      # R_p

    @property
    def total(self) -> float:
        return self.comp + self.recv

    @property
    def bottleneck(self) -> float:
        """max(C_p, R_p) — with compute/communication overlap a CompNode's
        steady-state stage time is whichever dominates (paper Eq. 3)."""
        return max(self.comp, self.recv)


def node_loads(op_costs: Mapping[str, OpCost],
               placement: Mapping[str, int],
               n_nodes: int) -> List[NodeLoad]:
    comp = [0.0] * n_nodes
    recv = [0.0] * n_nodes
    for name, cost in op_costs.items():
        p = placement[name]
        comp[p] += cost.comp_time
        recv[p] += cost.recv_time
    return [NodeLoad(comp=c, recv=r) for c, r in zip(comp, recv)]


def latency_single_pass(loads: Sequence[NodeLoad]) -> float:
    """Eq. 2 — one forward pass of the whole graph, sequential stages."""
    return sum(l.total for l in loads)


def latency_pipelined(loads: Sequence[NodeLoad], n_micro: int) -> float:
    """Eq. 3 — GPipe-style: fill/drain once, then the slowest stage paces
    the remaining (n_b - 1) micro-batches."""
    if n_micro < 1:
        raise ValueError("n_micro >= 1")
    fill = sum(l.total for l in loads)
    pace = max((l.bottleneck for l in loads), default=0.0)
    return fill + (n_micro - 1) * pace


def throughput(loads: Sequence[NodeLoad], n_micro: int, batch_size: int) -> float:
    """Eq. 4 — samples/second."""
    t = latency_pipelined(loads, n_micro)
    return batch_size / t if t > 0 else float("inf")


@dataclasses.dataclass(frozen=True)
class IterationEstimate:
    """Full FP+BP iteration estimate for a placement."""

    fwd_loads: Tuple[NodeLoad, ...]
    bwd_loads: Tuple[NodeLoad, ...]
    n_micro: int
    batch_size: int

    @property
    def fwd_time(self) -> float:
        return latency_pipelined(self.fwd_loads, self.n_micro)

    @property
    def bwd_time(self) -> float:
        return latency_pipelined(self.bwd_loads, self.n_micro)

    @property
    def iteration_time(self) -> float:
        return self.fwd_time + self.bwd_time

    @property
    def samples_per_sec(self) -> float:
        return self.batch_size / self.iteration_time


def estimate_iteration(graph: OpGraph,
                       profiles: Mapping[str, OpProfile],
                       cluster: ClusterSpec,
                       placement: Mapping[str, int],
                       n_micro: int,
                       batch_size: int,
                       cost_model=None) -> IterationEstimate:
    """End-to-end Eq. 2–4 (and, with a plan-bearing ``cost_model``, Eq. 8)
    estimate.

    BP communication mirrors FP (boundary gradients have the same size as the
    forward activations they correspond to) and BP compute uses the standard
    2× forward approximation — both per the paper's symmetric DAG treatment.
    Compression enters through the unified
    :class:`repro.core.costmodel.EdgeCostModel` (exact wire encoding), which
    replaced the removed smooth ``compress_ratio`` approximation.
    """
    fwd = estimate_op_costs(graph, profiles, cluster, placement,
                            cost_model, backward=False)
    bwd = estimate_op_costs(graph, profiles, cluster, placement,
                            cost_model, backward=True)
    n = len(cluster)
    return IterationEstimate(
        fwd_loads=tuple(node_loads(fwd, placement, n)),
        bwd_loads=tuple(node_loads(bwd, placement, n)),
        n_micro=n_micro, batch_size=batch_size)


def peak_activation_bytes(graph: OpGraph, profiles: Mapping[str, OpProfile],
                          placement: Mapping[str, int], n_nodes: int,
                          n_micro: int) -> List[int]:
    """Per-CompNode activation footprint: every op's output is held for BP,
    for every in-flight micro-batch (GPipe holds all n_b)."""
    acc = [0] * n_nodes
    for name, prof in profiles.items():
        acc[placement[name]] += prof.out_bytes
    return [a * n_micro for a in acc]


def memory_feasible(graph: OpGraph, profiles: Mapping[str, OpProfile],
                    cluster: ClusterSpec, placement: Mapping[str, int],
                    n_micro: int, optimizer_state_mult: float = 2.0) -> bool:
    """Constraint (6): params + optimizer state + activations fit D^p_gpu."""
    n = len(cluster)
    param_b = [0.0] * n
    for name, prof in profiles.items():
        param_b[placement[name]] += prof.param_bytes
    act_b = peak_activation_bytes(graph, profiles, placement, n, n_micro)
    for p in range(n):
        need = param_b[p] * (1.0 + 1.0 + optimizer_state_mult) + act_b[p]
        if need > cluster.devices[p].mem_bytes:
            return False
    return True
