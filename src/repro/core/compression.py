"""Top-K / AdaTopK communication compression (FusionLLM §5).

Top-K sparsification keeps the k largest-magnitude entries of a boundary
tensor (activation in FP, boundary gradient in BP); the receiver decodes by
scattering into zeros (paper Fig. 6).  Wire size for the paper's encoding is
``k·32 (values) + k·64 (indexes)`` bits = ``3·k·4`` bytes, i.e. with ratio
``r = d/k`` the payload shrinks to ``3/r`` of the original — the coefficient
3 in Eq. 7/8.

AdaTopK (Eq. 7) assigns *per-link* ratios so only the slowest links compress
hard::

    r_i = max(1, 3 r · R_i / max_p R_p)

**Break-even clamp** (bugfix over the paper's formula): the encoding has a
fixed per-kept-element overhead, so a ratio in ``(1, break_even]`` *inflates*
wire traffic instead of shrinking it — for the paper encoding
``k·(itemsize+8)`` bytes beat the dense ``d·itemsize`` only when
``r = d/k > (itemsize+8)/itemsize`` (3.0 at fp32, 5.0 at bf16 — the int64
index overhead amortizes over fewer payload bytes); for the mask encoding
``d/8 + k·itemsize ≤ d·itemsize`` requires ``r > itemsize/(itemsize−1/8)``.
:func:`adaptive_ratios` clamps any ratio at or below the encoding's
break-even to 1.0 (send dense), and :func:`plan_adatopk` additionally
verifies each planned edge with the exact integer :func:`wire_bytes` at the
producer's profile-derived itemsize (ceil(d/r) can tip a ratio just above
break-even back over the dense size, and a bf16 edge inflates where an fp32
edge would not), so no planned edge ever carries more bytes than the
uncompressed tensor.

Beyond-paper extras (both off by default, flagged where used):
* mask+values encoding — 1 bit/elem bitmap instead of int64 indexes
  (overhead ``(d/8 + 4k)/(4d)`` instead of ``3k/d``) — TPU-friendly since the
  decoded form stays dense;
* error-feedback memory (residual accumulation) for the gradient direction.

The hot inner op (`topk_mask`) dispatches through the kernel policy in
:mod:`repro.kernels.ops` (``resolve_policy``): ``use_kernel`` accepts
``False``/``"off"`` (legacy global top-k XLA — the default, bit-compatible
with :mod:`repro.kernels.ref`), ``"auto"`` (fused Pallas encode→decode on
TPU, fused blockwise XLA fallback on CPU — same selection semantics either
way), and ``True``/``"force"`` (Pallas even on CPU, interpret mode).  When a
kernel mode is active the sparsified tensor is the decode of the fused wire
encode — the consumer sees exactly what the "mask" encoding carried.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

KernelPolicy = Union[bool, str, None]


# ------------------------------------------------------------- primitives --
def topk_select(x: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Flat Top-K by magnitude: returns (values, int32 indices), the paper's
    wire format.

    One ``top_k`` over the magnitude key and one gather for the signed
    payload — the magnitudes ``top_k`` materializes are ``|x|``, not ``x``,
    so they cannot serve as wire values and the single gather is
    irreducible (no second magnitude pass, no ``flat[idx]`` advanced-index
    re-gather).

    Wire-format note: indices are emitted as **int32** (boundary numel is
    far below 2^31), while ``wire_bytes(encoding="paper")`` still charges
    **8 bytes per index** to stay faithful to Eq. 7's int64 accounting —
    the byte model is deliberately conservative relative to this payload.
    """
    flat = x.reshape(-1)
    k = int(min(max(k, 1), flat.shape[0]))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    idx = idx.astype(jnp.int32)
    return jnp.take(flat, idx, axis=0), idx


def topk_decode(values: jax.Array, idx: jax.Array, shape: Tuple[int, ...],
                dtype=None) -> jax.Array:
    """Scatter values back into zeros (paper Fig. 6 'Decoded Vector').

    ``dtype`` defaults to ``values.dtype`` so a bf16 boundary round-trips as
    bf16 — decoding must not silently upcast the wire payload."""
    if dtype is None:
        dtype = values.dtype
    flat = jnp.zeros((int(np.prod(shape)),), dtype=dtype)
    flat = flat.at[idx].set(values.astype(dtype))
    return flat.reshape(shape)


def topk_mask(x: jax.Array, k: int,
              use_kernel: KernelPolicy = False) -> jax.Array:
    """Dense sparsified tensor: x with everything below the k-th magnitude
    zeroed.  Semantically identical to select→decode, but stays dense (no
    scatter) — the TPU-native formulation used inside jitted steps.

    ``use_kernel`` is the kernel dispatch policy (module docstring): any
    non-"global" mode routes through the fused wire codec
    (:func:`repro.kernels.ops.codec_topk_mask`) — blockwise, tie-capped,
    wire-faithful."""
    from repro.kernels import ops as _kops
    mode = _kops.resolve_policy(use_kernel)
    if mode != "global":
        return _kops.codec_topk_mask(x, k, mode=mode)
    flat = x.reshape(-1)
    k = int(min(max(k, 1), flat.shape[0]))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    thresh = vals[-1]
    keep = jnp.abs(flat) >= thresh
    # Tie-break: if duplicates of the threshold magnitude would keep > k
    # entries, that is acceptable for convergence (superset of Top-K) and is
    # what a thresholding decoder observes; tests treat it as the oracle does.
    return jnp.where(keep, flat, 0.0).reshape(x.shape)


def ratio_to_k(numel: int, ratio: float) -> int:
    """ratio r = d/k (paper: 'compression ratio 100' keeps 1%)."""
    if ratio <= 1.0:
        return int(numel)
    return max(1, int(np.ceil(numel / ratio)))


# ------------------------------------------------------------ wire models --
def wire_bytes(numel: int, ratio: float, encoding: str = "paper",
               itemsize: int = 4) -> float:
    """Bytes on the wire for one tensor under a ratio.

    ``itemsize`` is the boundary tensor's dtype width — the wire carries
    values at that width (:func:`topk_decode` preserves the wire dtype), so a
    bf16 edge pays 2 bytes per kept value, not a hard-coded 4.

    encoding='paper' : k·(itemsize values + 8 index) bytes  (Eq. 7 @ fp32)
    encoding='mask'  : k·itemsize + numel/8 bytes           (bitmap)
    encoding='none'  : numel·itemsize
    """
    if ratio <= 1.0 or encoding == "none":
        return float(numel * itemsize)
    k = ratio_to_k(numel, ratio)
    if encoding == "paper":
        return float(k * (itemsize + 8))
    if encoding == "mask":
        return float(k * itemsize + numel / 8.0)
    raise ValueError(f"unknown encoding {encoding!r}")


def dense_payload_bytes(x: jax.Array) -> float:
    """Dense in-memory bytes of a boundary tensor.  This is the sanctioned
    home for the ``numel·itemsize`` product — callers outside the cost-model
    layer (e.g. rad.py's kernel-timing hook) must use this instead of inline
    itemsize arithmetic (the ``raw-byte-math`` lint rule enforces it)."""
    return float(int(np.prod(x.shape)) * x.dtype.itemsize)


# --------------------------------------------------------------- AdaTopK ---
def encoding_break_even(encoding: str, itemsize: int = 4) -> float:
    """Smallest ratio at which the encoding stops inflating wire traffic.

    paper : k·(itemsize+8) vs dense d·itemsize → r > (itemsize+8)/itemsize
            (3.0 @ fp32, 5.0 @ bf16 — narrower dtypes pay the int64 index
            overhead over fewer payload bytes, so they break even later)
    mask  : k·itemsize + d/8 vs dense d·itemsize
            → r > itemsize/(itemsize − 1/8)
    none  : never compresses → +inf.
    """
    if encoding == "paper":
        return (itemsize + 8.0) / itemsize
    if encoding == "mask":
        return itemsize / (itemsize - 0.125)
    if encoding == "none":
        return float("inf")
    raise ValueError(f"unknown encoding {encoding!r}")


def adaptive_ratios(recv_times: Sequence[float], r: float,
                    index_overhead=3.0,
                    break_even=None) -> list:
    """Eq. 7 with a break-even clamp: per-CompNode ratio from estimated
    original communication times.

    r_i = overhead · r · R_i / max_p R_p.  CompNodes on fast links get
    r_i → 1 (no compression); the slowest link gets the full overhead·r.
    The paper's coefficient 3 is the fp32 paper-encoding overhead
    ``(itemsize+8)/itemsize``; both ``index_overhead`` and ``break_even``
    also accept a per-edge sequence so narrow dtypes (bf16: overhead 5) hit
    the requested wire-byte target instead of under-compressing at the fp32
    coefficient.  Any r_i at or below its ``break_even`` (default:
    ``index_overhead``, the encoding's per-element overhead factor) is
    clamped to 1.0 — the paper's ``max(1, ·)`` floor still pays the
    overhead per kept element, so ratios in ``(1, break_even]`` would
    *inflate* the wire payload.
    """
    if break_even is None:
        break_even = index_overhead
    R = np.asarray(list(recv_times), dtype=np.float64)
    oh = np.broadcast_to(np.asarray(index_overhead, dtype=np.float64),
                         R.shape)
    be = np.broadcast_to(np.asarray(break_even, dtype=np.float64), R.shape)
    mx = float(R.max()) if R.size else 0.0
    if mx <= 0.0:
        return [1.0 for _ in recv_times]
    raw = oh * r * R / mx
    return [float(ri) if ri > be_i else 1.0
            for ri, be_i in zip(raw, be)]


@dataclasses.dataclass
class CompressionPlan:
    """Broker-side plan: per cross-node edge (producer_op, consumer_op) the
    ratio to use, plus the encoding.  Built by :func:`plan_uniform` /
    :func:`plan_adatopk`; consumed by the executor, rad.py, and the
    throughput model (compress_cfg of OpData, §3.4)."""

    edge_ratio: Dict[Tuple[str, str], float]
    encoding: str = "paper"
    base_ratio: float = 1.0
    error_feedback: bool = False

    def ratio(self, producer: str, consumer: str) -> float:
        return self.edge_ratio.get((producer, consumer), 1.0)

    def as_mapping(self) -> Mapping[Tuple[str, str], float]:
        return self.edge_ratio


def _cross_edges(graph, placement: Mapping[str, int]):
    for n, node in graph.nodes.items():
        for a in node.args:
            if placement[a] != placement[n]:
                yield (a, n)


def plan_none(graph, placement) -> CompressionPlan:
    return CompressionPlan(edge_ratio={}, base_ratio=1.0, encoding="none")


def plan_uniform(graph, placement: Mapping[str, int], ratio: float,
                 encoding: str = "paper",
                 error_feedback: bool = False) -> CompressionPlan:
    """Uniform Top-K baseline: every cross-node edge compresses at r."""
    edges = {e: float(ratio) for e in _cross_edges(graph, placement)}
    return CompressionPlan(edge_ratio=edges, base_ratio=ratio,
                          encoding=encoding, error_feedback=error_feedback)


def plan_adatopk(graph, profiles, cluster, placement: Mapping[str, int],
                 ratio: float, encoding: str = "paper",
                 index_overhead: Optional[float] = None,
                 error_feedback: bool = False,
                 cost_model=None) -> CompressionPlan:
    """AdaTopK: Eq. 7 driven by the per-edge *dense* receive times — a thin
    policy over :class:`repro.core.costmodel.EdgeCostModel`.

    ``index_overhead=None`` (default) uses each edge's own encoding overhead
    factor ``(itemsize+8)/itemsize`` as Eq. 7's coefficient — exactly the
    paper's 3 for fp32 paper encoding, 5 for bf16 — so narrow dtypes hit the
    requested wire-byte target instead of under-compressing at the fp32
    coefficient.  Pass a number to force one uniform coefficient (the
    pre-dtype-aware knob).

    Ratios at or below their edge's dtype-exact break-even are clamped to
    1.0 (see module docstring), and every surviving edge is verified against
    the exact integer :func:`wire_bytes` at the producer's dtype —
    ``ceil(d/r)`` rounding can push a ratio just above break-even back over
    the dense payload.  The guarantee is hard: no planned edge carries more
    wire bytes than its dense tensor.

    If the cost model carries calibrated per-device kernel costs
    (``kernel_costs``), each surviving edge must also be *profitable*: the
    fused-encode compute seconds on the producer's codec stream must be
    strictly less than the link seconds the ratio saves, else the edge
    stays dense (FusionLLM §6's premise — compression must outrun the
    bandwidth it buys back).

    ``cost_model`` supplies the byte/seconds arithmetic (its own compression
    plan is ignored — AdaTopK rates links by their *uncompressed* transport
    time); by default a dense model over ``(graph, profiles, cluster)`` is
    built.
    """
    from .costmodel import EdgeCostModel   # late import: costmodel composes
    model = (cost_model or                 # this module's wire encodings
             EdgeCostModel(graph, profiles, cluster)).with_plan(None)
    edges = list(model.cross_edges(placement))
    if not edges:
        return CompressionPlan(edge_ratio={}, base_ratio=ratio,
                               encoding=encoding,
                               error_feedback=error_feedback)
    times = [model.link_seconds(placement[a], placement[n],
                                model.dense_bytes(a)) for (a, n) in edges]
    be_edge = [encoding_break_even(encoding, model.itemsize(a))
               for (a, n) in edges]
    overheads = be_edge if index_overhead is None \
        else [float(index_overhead)] * len(edges)
    ratios = adaptive_ratios(times, ratio, index_overhead=overheads,
                             break_even=be_edge)
    kernel_costs = getattr(model, "kernel_costs", None) or {}
    edge_ratio: Dict[Tuple[str, str], float] = {}
    for (a, n), r_i in zip(edges, ratios):
        if r_i <= 1.0:
            continue
        wire = wire_bytes(model.numel(a), r_i, encoding,
                          itemsize=model.itemsize(a))
        if wire >= model.dense_bytes(a):
            continue         # integer rounding re-inflated this edge
        kc = kernel_costs.get(placement[a])
        if kc is not None:
            # Profitability: the fused encode runs on the producer's codec
            # stream; if its compute time exceeds the wire seconds the
            # ratio saves on this link, compressing slows the step down.
            src, dst = placement[a], placement[n]
            dense = model.dense_bytes(a)
            saved = (model.link_seconds(src, dst, dense)
                     - model.link_seconds(src, dst, wire))
            if kc.seconds(dense) >= saved:
                continue
        edge_ratio[(a, n)] = r_i
    return CompressionPlan(edge_ratio=edge_ratio, base_ratio=ratio,
                           encoding=encoding, error_feedback=error_feedback)


# ------------------------------------------------- differentiable boundary --
@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def boundary_compress(x: jax.Array, k_fwd: int, k_bwd: int,
                      use_kernel: KernelPolicy = False) -> jax.Array:
    """Lossy stage boundary: FP transports Top-k_fwd(x); BP transports
    Top-k_bwd(grad).  Matches the paper's RAD transport exactly — the
    receiving stage trains on the sparsified activation, the sending stage
    receives the sparsified boundary gradient.  0 < k ≥ numel disables.
    ``use_kernel`` is the kernel dispatch policy (a hashable scalar — safe
    as a ``custom_vjp`` nondiff arg)."""
    return topk_mask(x, k_fwd, use_kernel=use_kernel)


def _bc_fwd(x, k_fwd, k_bwd, use_kernel):
    return topk_mask(x, k_fwd, use_kernel=use_kernel), None


def _bc_bwd(k_fwd, k_bwd, use_kernel, res, g):
    del res
    return (topk_mask(g, k_bwd, use_kernel=use_kernel),)


boundary_compress.defvjp(_bc_fwd, _bc_bwd)


def compress_for_edge(x: jax.Array, ratio: float,
                      use_kernel: KernelPolicy = False,
                      compress_bwd: bool = True) -> jax.Array:
    """Apply the plan's ratio to a concrete boundary tensor inside a jitted
    step (static k derived from the trace-time shape).  ``compress_bwd``
    False leaves the cotangent dense (used by the error-feedback path,
    which compresses gradients itself, statefully)."""
    if ratio <= 1.0:
        return x
    numel = int(np.prod(x.shape))
    k = ratio_to_k(numel, ratio)
    return boundary_compress(x, k, k if compress_bwd else numel, use_kernel)


# ----------------------------------------------------------- error feedback --
@dataclasses.dataclass
class ErrorFeedbackState:
    """Residual memory per edge (beyond-paper; standard EF-SGD trick)."""

    residual: Any  # pytree matching the boundary tensor

    @staticmethod
    def init(example: jax.Array) -> "ErrorFeedbackState":
        return ErrorFeedbackState(residual=jnp.zeros_like(example))


def ef_compress(x: jax.Array, state: ErrorFeedbackState, k: int,
                use_kernel: KernelPolicy = False
                ) -> Tuple[jax.Array, ErrorFeedbackState]:
    """Compress (x + residual); remember what was dropped.

    Under a kernel dispatch mode the residual update is fused into the
    encode kernel itself (:func:`repro.kernels.ops.codec_ef_topk`) — one
    pallas_call emits (values, bitmap, new_residual)."""
    from repro.kernels import ops as _kops
    mode = _kops.resolve_policy(use_kernel)
    if mode != "global":
        sent, newr = _kops.codec_ef_topk(x, state.residual, k, mode=mode)
        return sent, ErrorFeedbackState(residual=newr)
    corrected = x + state.residual
    sent = topk_mask(corrected, k, use_kernel=False)
    return sent, ErrorFeedbackState(residual=corrected - sent)
