"""FusionLLM core: OP-DAG IR, RAD, estimator, unified edge-cost model,
OP-Fence scheduler + joint co-planner, AdaTopK."""
from .opgraph import (OpData, OpGraph, OpNode, OpProfile, OpType, SubDag,
                      build_subdags)
from .estimator import (ClusterSpec, DeviceSpec, LinkSpec, make_device,
                        fit_alpha_beta, fit_lambda, estimate_op_costs,
                        predict_step_times)
from .costmodel import EdgeCost, EdgeCostModel, fit_link_corrections
from .throughput import (IterationEstimate, NodeLoad, estimate_iteration,
                         latency_pipelined, latency_single_pass, node_loads,
                         throughput)
from .partition import (min_bottleneck_chain, partition_equal_compute,
                        partition_equal_number, partition_min_bottleneck)
from .scheduler import (JointPlan, Schedule, SCHEDULERS, louvain_communities,
                        schedule_equal_compute, schedule_equal_number,
                        schedule_joint, schedule_opfence)
from .compression import (CompressionPlan, adaptive_ratios, boundary_compress,
                          compress_for_edge, ef_compress, plan_adatopk,
                          plan_none, plan_uniform, ratio_to_k, topk_decode,
                          topk_mask, topk_select, wire_bytes)
from .rad import (PipelineProgram, init_ef_state, pipeline_loss_and_grad,
                  pipeline_loss_and_grad_ef, pipeline_train_step,
                  single_device_loss_and_grad)
from .executor import (DecentralizedRuntime, LinkTiming, MigrationSim,
                       SimResult, StepTiming, TelemetrySink,
                       pipeline_fill_seconds, simulate_iteration,
                       simulate_migration)
from . import network
