"""OP-DAG partitioning (FusionLLM §4 + baselines from §7.2).

Three chain partitioners (Observation 1: DNN DAGs are near-chains, so we
linearize topologically and split into contiguous segments — contiguity also
guarantees each sub-DAG is a connected sub-graph, which OP-Fence requires):

* ``partition_equal_number``  — baseline 1: same #ops per CompNode.
* ``partition_equal_compute`` — baseline 2: balance Σ FLOPs per CompNode.
* ``partition_min_bottleneck``— DP-optimal contiguous split minimizing the
  pipelined bottleneck max_p max(C_p, R_p) of Eq. 3 (used inside OP-Fence).
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .estimator import ClusterSpec
from .opgraph import OpGraph, OpProfile, chain


def _segments_to_assignment(order: Sequence[str], cuts: Sequence[int]) -> List[List[str]]:
    """cuts = segment end indices (exclusive), ascending, last == len(order)."""
    out: List[List[str]] = []
    start = 0
    for c in cuts:
        out.append(list(order[start:c]))
        start = c
    return out


def attach_sources(graph: OpGraph, assignment: List[List[str]]) -> List[List[str]]:
    """Place each placeholder/variable with its first consumer's segment (the
    paper puts Input with CompNode 1, Label with the loss's CompNode)."""
    owner: Dict[str, int] = {}
    for k, seg in enumerate(assignment):
        for n in seg:
            owner[n] = k
    users = graph.users
    for n, node in graph.nodes.items():
        if n in owner:
            continue
        cons = [owner[u] for u in users[n] if u in owner]
        k = min(cons) if cons else 0
        assignment[k].insert(0, n)
        owner[n] = k
    return assignment


def partition_equal_number(graph: OpGraph, n_parts: int) -> List[List[str]]:
    """Baseline: equal number of (compute) ops per part."""
    order = chain(graph)
    n = len(order)
    if n_parts > n:
        raise ValueError(f"cannot split {n} ops into {n_parts} parts")
    cuts = [round((i + 1) * n / n_parts) for i in range(n_parts)]
    cuts[-1] = n
    # De-duplicate rounding collisions while keeping each segment non-empty.
    for i in range(1, n_parts):
        if cuts[i] <= cuts[i - 1]:
            cuts[i] = cuts[i - 1] + 1
    if cuts[-1] != n:
        raise ValueError("rounding produced an invalid split")
    return attach_sources(graph, _segments_to_assignment(order, cuts))


def partition_equal_compute(graph: OpGraph, profiles: Mapping[str, OpProfile],
                            n_parts: int,
                            weights: Optional[Mapping[str, float]] = None) -> List[List[str]]:
    """Baseline: balance cumulative FLOPs — greedy prefix walk toward the
    ideal total/ n_parts per segment."""
    order = chain(graph)
    w = np.array([(weights or {}).get(n, profiles[n].fwd_flops) for n in order],
                 dtype=np.float64)
    w = np.maximum(w, 1e-9)
    target = w.sum() / n_parts
    cuts: List[int] = []
    acc = 0.0
    for i, wi in enumerate(w):
        acc += wi
        remaining_ops = len(order) - (i + 1)
        remaining_parts = n_parts - len(cuts) - 1
        if len(cuts) < n_parts - 1 and (acc >= target or remaining_ops == remaining_parts):
            cuts.append(i + 1)
            acc = 0.0
    cuts.append(len(order))
    return attach_sources(graph, _segments_to_assignment(order, cuts))


def min_bottleneck_chain(ops: Sequence[str],
                         profiles: Mapping[str, OpProfile],
                         cluster: ClusterSpec,
                         device_order: Sequence[int],
                         cost_model,
                         inbound: Optional[Tuple[str, int]] = None,
                         ) -> Tuple[List[List[str]], float]:
    """DP over contiguous splits of ``ops`` (a chain slice, in chain order)
    onto ``device_order``, minimizing Eq. 3's steady-state pace
    ``max_k max(C_k, R_k)``.  Returns raw segments (no source attachment).

    R_k is the time stage k spends receiving its boundary activation from
    stage k-1 over the (device_order[k-1] -> device_order[k]) link; the
    boundary edge is the op pair straddling the cut, and its bytes/seconds
    come from the unified ``cost_model`` — so a compression-plan-bearing
    model re-cuts under *compressed* costs, which replaced the old
    stage-indexed ``edge_bytes_scale`` hack.

    ``inbound = (producer_op, src_device)`` charges stage 0 for receiving
    ``producer_op``'s boundary from ``src_device`` — used by the
    boundary-pinned elastic re-cut, where a sub-chain's first stage still
    pays for the (frozen) cross-cluster edge feeding it.

    DP state: best[i][k] = minimal pace for placing first i ops on first k+1
    devices.  O(n² · d) — fine for n ≤ a few thousand ops.
    """
    order = list(ops)
    n = len(order)
    d = len(device_order)
    if d > n:
        raise ValueError(f"{d} stages > {n} ops")
    flops = np.array([profiles[m].fwd_flops for m in order], dtype=np.float64)
    pre = np.concatenate([[0.0], np.cumsum(flops)])
    # boundary edge at cut position i: producer order[i-1] -> consumer
    # order[i]; transport seconds for every stage pair, precomputed once
    recv_cache: Dict[Tuple[int, int], float] = {}

    def comp_time(i: int, j: int, k: int) -> float:  # ops [i,j) on stage k
        return (pre[j] - pre[i]) / cluster.devices[device_order[k]].speed

    def recv_time(i: int, k: int) -> float:  # boundary into stage k at op i
        if k == 0:
            if inbound is None or i != 0:
                return 0.0
            prod, src = inbound
            return cost_model.edge_seconds(prod, order[0], src,
                                           device_order[0])
        if i == 0:
            return 0.0
        key = (i, k)
        if key not in recv_cache:
            recv_cache[key] = cost_model.edge_seconds(
                order[i - 1], order[i],
                device_order[k - 1], device_order[k])
        return recv_cache[key]

    INF = float("inf")
    best = np.full((n + 1, d), INF)
    back = np.full((n + 1, d), -1, dtype=np.int64)
    for j in range(1, n - d + 2):
        best[j][0] = max(comp_time(0, j, 0), recv_time(0, 0))
    for k in range(1, d):
        for j in range(k + 1, n - (d - 1 - k) + 1):
            for i in range(k, j):
                if best[i][k - 1] == INF:
                    continue
                pace = max(best[i][k - 1],
                           comp_time(i, j, k),
                           recv_time(i, k))
                if pace < best[j][k]:
                    best[j][k] = pace
                    back[j][k] = i
    if best[n][d - 1] == INF:
        raise RuntimeError("DP found no feasible split")
    cuts: List[int] = [n]
    j, k = n, d - 1
    while k > 0:
        j = int(back[j][k])
        cuts.append(j)
        k -= 1
    cuts = sorted(cuts)
    return _segments_to_assignment(order, cuts), float(best[n][d - 1])


def partition_min_bottleneck(graph: OpGraph, profiles: Mapping[str, OpProfile],
                             cluster: ClusterSpec,
                             device_order: Sequence[int],
                             cost_model=None,
                             ) -> Tuple[List[List[str]], float]:
    """Min-bottleneck DP over the whole op chain (see
    :func:`min_bottleneck_chain`), with placeholders/variables attached to
    their consumers' segments.  ``cost_model`` defaults to dense transport;
    pass a plan-bearing :class:`repro.core.costmodel.EdgeCostModel` to cut
    under compressed byte costs (the OP-Fence/AdaTopK co-planner does)."""
    if cost_model is None:
        from .costmodel import EdgeCostModel   # late: costmodel imports core
        cost_model = EdgeCostModel(graph, profiles, cluster)
    segs, pace = min_bottleneck_chain(chain(graph), profiles, cluster,
                                      device_order, cost_model)
    return attach_sources(graph, segs), pace
