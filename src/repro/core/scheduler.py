"""OP-Fence scheduler (FusionLLM §4).

Observation 2 (network locality): bandwidth clusters exist.  OP-Fence
1. detects high-bandwidth clusters of CompNodes with the Louvain algorithm
   over the bandwidth graph,
2. orders clusters into a pipeline path that keeps consecutive stages on
   well-connected clusters,
3. splits the op chain across clusters proportionally to aggregate compute,
4. within each cluster, solves the DP min-bottleneck split (partition.py),
so every cluster holds a *connected* sub-graph and only cluster-boundary
(slow) edges carry inter-cluster traffic — the "fence".

Baselines (paper §7.2): ``schedule_equal_number`` / ``schedule_equal_compute``
ignore network structure and allocate segments to CompNodes in index order.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .compression import CompressionPlan, plan_adatopk
from .costmodel import EdgeCostModel
from .estimator import ClusterSpec
from .opgraph import OpGraph, OpProfile, build_subdags, SubDag
from .partition import (partition_equal_compute, partition_equal_number,
                        partition_min_bottleneck, attach_sources,
                        _segments_to_assignment)
from .opgraph import chain as op_chain


# --------------------------------------------------------------- Louvain ---
def louvain_communities(weights: np.ndarray, seed: int = 0,
                        max_passes: int = 16) -> List[List[int]]:
    """Weighted-graph Louvain (Blondel et al. 2008), self-contained.

    ``weights`` is a symmetric non-negative matrix (bandwidth as edge weight;
    0 = no edge).  Returns communities as lists of original node indices.
    """
    w = np.asarray(weights, dtype=np.float64)
    if w.ndim != 2 or w.shape[0] != w.shape[1]:
        raise ValueError("weights must be square")
    w = (w + w.T) / 2.0
    np.fill_diagonal(w, 0.0)  # no self-loops in the input graph
    n0 = w.shape[0]
    members: List[List[int]] = [[i] for i in range(n0)]
    rng = np.random.default_rng(seed)

    while True:
        n = w.shape[0]
        m2 = w.sum()  # = 2m (self-loops carry intra-community weight upward)
        if m2 <= 0:
            break
        k = w.sum(axis=1)              # weighted degree (self-loop included)
        comm = np.arange(n)            # community of each super-node
        # Σ_tot per community; Σ_in not needed for the move gain formula below.
        tot = k.copy()

        improved_any = False
        for _pass in range(max_passes):
            improved = False
            order = rng.permutation(n)
            for i in order:
                ci = comm[i]
                # links from i to each community (self-loop excluded — it is
                # community-invariant and cancels in the gain)
                nb = {}
                for j in np.nonzero(w[i])[0]:
                    if j != i:
                        nb[comm[j]] = nb.get(comm[j], 0.0) + w[i, j]
                # remove i from its community
                tot[ci] -= k[i]
                best_c, best_gain = ci, 0.0
                base = nb.get(ci, 0.0) - tot[ci] * k[i] / m2
                for c, w_ic in nb.items():
                    gain = (w_ic - tot[c] * k[i] / m2) - base
                    if gain > best_gain + 1e-15:
                        best_gain, best_c = gain, c
                tot[best_c] += k[i]
                if best_c != ci:
                    comm[i] = best_c
                    improved = improved_any = True
            if not improved:
                break
        if not improved_any:
            break
        # aggregate
        labels = {c: idx for idx, c in enumerate(sorted(set(comm.tolist())))}
        nn = len(labels)
        if nn == n:
            break
        new_members: List[List[int]] = [[] for _ in range(nn)]
        for i in range(n):
            new_members[labels[comm[i]]].extend(members[i])
        neww = np.zeros((nn, nn))
        for i in range(n):
            for j in range(n):
                neww[labels[comm[i]], labels[comm[j]]] += w[i, j]
        # keep the diagonal: intra-community weight must survive aggregation
        # or upper levels see only inter-community edges and merge everything.
        w, members = neww, new_members
    return [sorted(m) for m in members]


# ------------------------------------------------------------- schedules ---
@dataclasses.dataclass
class Schedule:
    """Result of scheduling: ops per CompNode + derived sub-DAG edge sets.

    ``assignment[p]`` is the op list on CompNode p (may be empty); ``stages``
    is the pipeline order of the non-empty CompNodes.
    """

    assignment: List[List[str]]
    stages: List[int]
    clusters: Optional[List[List[int]]] = None
    predicted_pace: Optional[float] = None

    @property
    def placement(self) -> Dict[str, int]:
        return {n: p for p, seg in enumerate(self.assignment) for n in seg}

    def subdags(self, graph: OpGraph) -> List[SubDag]:
        return build_subdags(graph, self.assignment)

    def pipeline_subdags(self, graph: OpGraph) -> List[SubDag]:
        """Non-empty sub-DAGs in *pipeline stage order* (what the RAD
        executor needs — required activations always come from an earlier
        stage).  ``subdags()[i].index`` is the CompNode; here index is the
        stage position."""
        segments = [self.assignment[d] for d in self.stages
                    if self.assignment[d]]
        covered = sum(len(s) for s in segments)
        total = sum(len(s) for s in self.assignment)
        if covered != total:
            raise ValueError("stages do not cover all assigned ops")
        return build_subdags(graph, segments)

    def stage_devices(self) -> List[int]:
        return [d for d in self.stages if self.assignment[d]]


def _to_full_assignment(segments: List[List[str]], stage_devices: Sequence[int],
                        n_devices: int) -> Tuple[List[List[str]], List[int]]:
    assignment: List[List[str]] = [[] for _ in range(n_devices)]
    stages: List[int] = []
    for seg, dev in zip(segments, stage_devices):
        assignment[dev] = seg
        stages.append(dev)
    return assignment, stages


def _resolve_subset(cluster: ClusterSpec,
                    device_subset: Optional[Sequence[int]]) -> List[int]:
    """Validated CompNode subset, ascending (full cluster when None)."""
    if device_subset is None:
        return list(range(len(cluster)))
    subset = sorted(set(int(d) for d in device_subset))
    if not subset:
        raise ValueError("device_subset must name at least one CompNode")
    if subset[0] < 0 or subset[-1] >= len(cluster):
        raise ValueError("device_subset out of range")
    return subset


def schedule_equal_number(graph: OpGraph, cluster: ClusterSpec,
                          device_subset: Optional[Sequence[int]] = None,
                          ) -> Schedule:
    """Baseline 1.  ``device_subset`` restricts placement to the listed
    CompNodes (index order) — baselines must not silently schedule onto dead
    nodes in churn experiments."""
    devs = _resolve_subset(cluster, device_subset)
    n = max(1, min(len(devs), len(op_chain(graph))))
    segs = partition_equal_number(graph, n)
    a, s = _to_full_assignment(segs, devs[:n], len(cluster))
    return Schedule(assignment=a, stages=s)


def schedule_equal_compute(graph: OpGraph, profiles: Mapping[str, OpProfile],
                           cluster: ClusterSpec,
                           device_subset: Optional[Sequence[int]] = None,
                           ) -> Schedule:
    """Baseline 2; ``device_subset`` as in :func:`schedule_equal_number`."""
    devs = _resolve_subset(cluster, device_subset)
    n = max(1, min(len(devs), len(op_chain(graph))))
    segs = partition_equal_compute(graph, profiles, n)
    a, s = _to_full_assignment(segs, devs[:n], len(cluster))
    return Schedule(assignment=a, stages=s)


def _order_clusters(clusters: List[List[int]], bw: np.ndarray) -> List[int]:
    """Pipeline order over clusters: greedy max-bandwidth path (nearest
    neighbour on mean inter-cluster bandwidth), exhaustive when ≤ 6 clusters."""
    nc = len(clusters)
    if nc == 1:
        return [0]
    inter = np.zeros((nc, nc))
    for a in range(nc):
        for b in range(nc):
            if a != b:
                vals = [bw[i, j] for i in clusters[a] for j in clusters[b]]
                inter[a, b] = float(np.mean(vals)) if vals else 0.0

    def path_cost(path: Sequence[int]) -> float:
        # maximize the weakest consecutive link, then the sum
        links = [inter[path[i], path[i + 1]] for i in range(len(path) - 1)]
        return min(links) * 1e6 + sum(links)

    if nc <= 6:
        return list(max(itertools.permutations(range(nc)), key=path_cost))
    # greedy from the strongest edge
    a, b = np.unravel_index(np.argmax(inter), inter.shape)
    path = [int(a), int(b)]
    rest = set(range(nc)) - set(path)
    while rest:
        head, tail = path[0], path[-1]
        cand = max(rest, key=lambda c: max(inter[c, head], inter[tail, c]))
        if inter[cand, head] > inter[tail, cand]:
            path.insert(0, cand)
        else:
            path.append(cand)
        rest.remove(cand)
    return path


def schedule_opfence(graph: OpGraph, profiles: Mapping[str, OpProfile],
                     cluster: ClusterSpec, seed: int = 0,
                     cost_model: Optional[EdgeCostModel] = None,
                     device_subset: Optional[Sequence[int]] = None,
                     verify: bool = True,
                     ) -> Schedule:
    """The OP-Fence scheduler.

    ``cost_model`` is the unified byte/seconds source the DP split reads; a
    plan-bearing :class:`repro.core.costmodel.EdgeCostModel` re-schedules
    under that compression plan (AdaTopK shrinks the slowest edges, which can
    change the optimal split — the :func:`schedule_joint` co-planner iterates
    exactly this loop).  Defaults to dense transport.

    ``device_subset`` restricts placement to the listed CompNodes (the elastic
    runtime re-plans on the survivors after churn); the returned Schedule
    still spans the full device index space, with excluded CompNodes empty.

    ``verify=True`` (default) runs the emitted schedule through the
    :mod:`repro.check` static verifier (coverage, contiguity, subset
    membership) and raises :class:`repro.check.ScheduleCheckError` on any
    violation — a planner bug must surface here, not as a silently wrong
    pace downstream.  ``verify=False`` opts out (hot inner loops).
    """
    bw = cluster.bandwidth_matrix()
    subset = _resolve_subset(cluster, device_subset)
    if cost_model is None:
        cost_model = EdgeCostModel(graph, profiles, cluster)
    # Louvain on the surviving sub-graph, communities mapped back to the
    # original CompNode indices so link lookups stay in the full topology.
    sub_bw = bw[np.ix_(subset, subset)]
    clusters = [[subset[i] for i in c]
                for c in louvain_communities(sub_bw, seed=seed)]
    order = _order_clusters(clusters, bw)
    # Device pipeline order: clusters in path order; inside a cluster, fastest
    # devices first (they will absorb the bigger DP segments).
    device_order: List[int] = []
    for c in order:
        device_order.extend(sorted(clusters[c],
                                   key=lambda i: -cluster.devices[i].speed))
    n_ops = len(op_chain(graph))
    device_order = device_order[:max(1, min(len(device_order), n_ops))]
    segs, pace = partition_min_bottleneck(graph, profiles, cluster,
                                          device_order,
                                          cost_model=cost_model)
    a, s = _to_full_assignment(segs, device_order, len(cluster))
    sched = Schedule(assignment=a, stages=s,
                     clusters=[clusters[c] for c in order],
                     predicted_pace=pace)
    if verify:
        from repro.check.schedule import verify_schedule
        verify_schedule(graph, sched, profiles=profiles, cluster=cluster,
                        alive=subset, check_capacity=False)
    return sched


# ---------------------------------------------------- joint co-planning ----
@dataclasses.dataclass
class JointPlan:
    """Converged output of :func:`schedule_joint`: the schedule, the AdaTopK
    plan it was cut under, the plan-bearing cost model (single source of
    truth for every downstream byte account), and how the fixed point ran."""

    schedule: Schedule
    plan: CompressionPlan
    cost_model: EdgeCostModel
    predicted_pace: float
    iterations: int
    converged: bool


def schedule_joint(graph: OpGraph, profiles: Mapping[str, OpProfile],
                   cluster: ClusterSpec, ratio: float = 100.0,
                   encoding: str = "paper", seed: int = 0,
                   device_subset: Optional[Sequence[int]] = None,
                   max_rounds: int = 4,
                   cost_model: Optional[EdgeCostModel] = None,
                   verify: bool = True) -> JointPlan:
    """OP-Fence × AdaTopK fixed-point co-planner.

    The blind pipeline (schedule on dense bytes, then compress) is
    sub-optimal whenever compression changes which cut is
    bottleneck-limiting: AdaTopK shrinks the slowest edges by up to the
    encoding factor, so a cut that avoided a WAN boundary at dense costs may
    afford it compressed — and vice versa.  This iterates

        schedule (under current edge costs) → plan_adatopk → re-cost

    to convergence (identical assignment) or ``max_rounds``, and returns the
    best (schedule, plan) pair seen, scored by the unified model's Eq. 3
    steady-state pace.  Round 0 *is* the sequential schedule-then-compress
    baseline, so the result is never worse than it under the shared metric.

    ``cost_model`` seeds the iteration's base (dense) model — pass one
    carrying telemetry-calibrated link corrections so the closed planning
    loop co-plans against the links as *measured*, not as spec'd.  Its plan
    (if any) is stripped and it is rebased onto ``cluster``.

    ``verify=True`` (default) statically verifies the *winning*
    (schedule, plan) pair through :mod:`repro.check` — schedule coverage/
    contiguity plus the AdaTopK break-even bounds and, when the model
    carries calibrated kernel costs, encode profitability (no chosen ratio
    may cost more codec time than the wire time it saves); intermediate
    fixed-point rounds are never verified (they are search states, not
    plans).
    """
    dense_model = (cost_model.with_cluster(cluster).with_plan(None)
                   if cost_model is not None
                   else EdgeCostModel(graph, profiles, cluster))
    sched = schedule_opfence(graph, profiles, cluster, seed=seed,
                             cost_model=dense_model,
                             device_subset=device_subset, verify=False)
    best: Optional[JointPlan] = None
    seen_assignments = []
    converged = False
    for it in range(max_rounds):
        plan = plan_adatopk(graph, profiles, cluster, sched.placement, ratio,
                            encoding=encoding, cost_model=dense_model)
        model = dense_model.with_plan(plan)
        pace = model.stage_pace(sched)
        if best is None or pace < best.predicted_pace:
            best = JointPlan(schedule=sched, plan=plan, cost_model=model,
                             predicted_pace=pace, iterations=it + 1,
                             converged=False)
        if sched.assignment in seen_assignments:
            converged = True       # fixed point (or 2-cycle) reached
            break
        seen_assignments.append(sched.assignment)
        if it == max_rounds - 1:
            break                  # a re-cut now would never be scored
        sched = schedule_opfence(graph, profiles, cluster, seed=seed,
                                 cost_model=model,
                                 device_subset=device_subset, verify=False)
    best.converged = converged
    best.schedule = dataclasses.replace(
        best.schedule, predicted_pace=best.predicted_pace)
    if verify:
        from repro.check.costs import verify_plan
        from repro.check.schedule import verify_schedule
        verify_schedule(graph, best.schedule, profiles=profiles,
                        cluster=cluster,
                        alive=_resolve_subset(cluster, device_subset),
                        check_capacity=False)
        verify_plan(graph, profiles, best.plan,
                    placement=best.schedule.placement,
                    cost_model=best.cost_model)
    return best


SCHEDULERS = {
    "equal_number":
        lambda g, prof, cl, **kw: schedule_equal_number(g, cl, **kw),
    "equal_compute":
        lambda g, prof, cl, **kw: schedule_equal_compute(g, prof, cl, **kw),
    "opfence": lambda g, prof, cl, **kw: schedule_opfence(g, prof, cl, **kw),
    "joint": lambda g, prof, cl, **kw: schedule_joint(g, prof, cl,
                                                      **kw).schedule,
}
