"""Remote automatic differentiation (FusionLLM §3.3).

No ML framework differentiates across machine boundaries; FusionLLM's answer
is stage-local autodiff plus boundary exchange: every CompNode runs FP/BP on
its own sub-DAG and only boundary activations (FP) and boundary gradients
(BP, keyed ``producer->user``) travel between CompNodes.

JAX mapping: each sub-DAG becomes a pure function
``f_k(params_k, ext_acts, inputs) -> (sends, loss_k)``; the forward sweep
chains them in stage order and *records* ``jax.vjp`` closures; the backward
sweep calls them in reverse, routing each cotangent back over the edge it
belongs to.  Compression (AdaTopK) is applied to the transported tensor on
both directions of every cross-node edge — outside any stage's autodiff,
exactly like the real transport (the consumer trains on the sparsified
activation; the producer backpropagates the sparsified gradient).

``pipeline_train_step`` with no compression is bit-identical to single-device
``jax.grad`` over :meth:`OpGraph.apply` (tested), which is the correctness
contract of RAD.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .compression import (CompressionPlan, KernelPolicy, compress_for_edge,
                          dense_payload_bytes, plan_none)
from .opgraph import OpGraph, OpType, SubDag
from ..obs.trace import CAT_ENCODE


Params = Mapping[str, Any]

# Measured-wall-clock hook: (stage_index, backward, seconds) per stage call.
# The DecentralizedRuntime wraps this into StepTiming telemetry samples.
TimingCb = Callable[[int, bool, float], None]

# Measured-wall-clock codec hook: (stage_index, backward, seconds,
# dense_bytes) per compressed boundary edge.  The DecentralizedRuntime wraps
# this into KernelTiming telemetry samples — the raw material of
# fit_kernel_costs calibration.
KernelCb = Callable[[int, bool, float, float], None]


def _traced_compress(trace, name: str, track: str, backward: bool,
                     ratio: float, fn, kernel_cb: Optional[KernelCb] = None,
                     stage: int = 0, dense_bytes: float = 0.0):
    """Run one boundary compression, recording a wall-clock encode span when
    tracing and a ``kernel_cb`` timing sample when instrumented.  The decode
    half is fused into the same op (a kernel-dispatched topk_mask is
    encode→decode of the wire format), so both cover the whole codec;
    ``ratio<=1`` edges transport dense and record nothing."""
    traced = trace is not None and getattr(trace, "enabled", False)
    if ratio <= 1.0 or (not traced and kernel_cb is None):
        return fn()
    t0 = time.perf_counter() if kernel_cb is not None else 0.0
    if traced:
        with trace.region(CAT_ENCODE, name, track,
                          args={"ratio": ratio, "backward": backward}):
            out = fn()
            jax.block_until_ready(out)
    else:
        out = fn()
        jax.block_until_ready(out)
    if kernel_cb is not None:
        kernel_cb(stage, backward, time.perf_counter() - t0, dense_bytes)
    return out


def make_stage_fn(graph: OpGraph, subdag: SubDag
                  ) -> Callable[[Params, Mapping[str, jax.Array], Mapping[str, jax.Array]],
                                Tuple[Dict[str, jax.Array], jax.Array]]:
    """Build the pure function executed by one CompNode.

    Args: ``params`` for this sub-DAG's parametric ops; ``ext_acts`` —
    activations received from other CompNodes (keys = producer op names,
    i.e. ``subdag.required_acti``); ``inputs`` — placeholder/variable values
    owned by this sub-DAG.  Returns (sends, loss) where ``sends`` maps each
    ``send_acti`` op name to its output and ``loss`` sums this sub-DAG's loss
    nodes (0.0 if none).
    """
    topo = [n for n in graph.topo_order() if n in subdag.node_set]

    def stage_fn(params: Params, ext_acts: Mapping[str, jax.Array],
                 inputs: Mapping[str, jax.Array]
                 ) -> Tuple[Dict[str, jax.Array], jax.Array]:
        vals: Dict[str, jax.Array] = dict(ext_acts)
        loss = jnp.asarray(0.0, dtype=jnp.float32)
        for n in topo:
            node = graph.nodes[n]
            if node.op_type in (OpType.PLACEHOLDER, OpType.VARIABLE):
                vals[n] = inputs[n]
                continue
            args = [vals[a] for a in node.args]
            out = node.apply_fn(params.get(n), *args) if node.apply_fn else args[0]
            vals[n] = out
            if node.op_type is OpType.LOSS:
                loss = loss + jnp.sum(out).astype(jnp.float32)
        sends = {n: vals[n] for n in subdag.send_acti}
        return sends, loss

    return stage_fn


@dataclasses.dataclass
class PipelineProgram:
    """Compiled stage plan: stage functions in pipeline order plus routing
    tables (which stage consumes which producer's output)."""

    graph: OpGraph
    subdags: List[SubDag]
    stage_fns: List[Callable]
    # consumer routing: producer op -> list of (consumer_stage_idx)
    consumers: Dict[str, List[int]]
    owner_stage: Dict[str, int]

    @staticmethod
    def build(graph: OpGraph, subdags: Sequence[SubDag]) -> "PipelineProgram":
        subdags = list(subdags)
        owner: Dict[str, int] = {}
        for si, sd in enumerate(subdags):
            for n in sd.node_names:
                owner[n] = si
        consumers: Dict[str, List[int]] = {}
        for si, sd in enumerate(subdags):
            for a in sd.required_acti:
                consumers.setdefault(a, []).append(si)
        return PipelineProgram(
            graph=graph, subdags=subdags,
            stage_fns=[make_stage_fn(graph, sd) for sd in subdags],
            consumers=consumers, owner_stage=owner)

    def split_params(self, params: Params) -> List[Dict[str, Any]]:
        out: List[Dict[str, Any]] = [{} for _ in self.subdags]
        for name, p in params.items():
            out[self.owner_stage[name]][name] = p
        return out

    def split_inputs(self, inputs: Mapping[str, jax.Array],
                     variables: Optional[Mapping[str, jax.Array]] = None
                     ) -> List[Dict[str, jax.Array]]:
        merged = dict(inputs)
        merged.update(variables or {})
        out: List[Dict[str, jax.Array]] = [{} for _ in self.subdags]
        for si, sd in enumerate(self.subdags):
            for n in sd.node_names:
                node = self.graph.nodes[n]
                if node.op_type in (OpType.PLACEHOLDER, OpType.VARIABLE):
                    out[si][n] = merged[n]
        return out


def pipeline_forward(prog: PipelineProgram, params: Params,
                     inputs: Mapping[str, jax.Array],
                     plan: Optional[CompressionPlan] = None,
                     use_kernel: KernelPolicy = False,
                     compress_bwd: bool = True,
                     timing_cb: Optional[TimingCb] = None,
                     trace: Optional[Any] = None,
                     kernel_cb: Optional[KernelCb] = None
                     ) -> Tuple[jax.Array, List[Any], List[Dict[str, jax.Array]]]:
    """Forward sweep.  Returns (total_loss, vjp closures per stage, the
    per-stage received ext_acts — needed to key backward cotangents).
    ``timing_cb(stage, backward=False, seconds)`` receives each stage's
    measured host wall-clock (telemetry hook; None = no instrumentation);
    ``trace`` additionally records wall-clock ``compress.encode`` spans per
    compressed boundary edge; ``kernel_cb(stage, backward, seconds,
    dense_bytes)`` receives each compressed edge's measured codec time."""
    plan = plan or plan_none(prog.graph, prog.owner_stage)
    stage_params = prog.split_params(params)
    stage_inputs = prog.split_inputs(inputs)
    mailbox: Dict[Tuple[str, int], jax.Array] = {}  # (producer, consumer_stage)
    vjps: List[Any] = []
    received: List[Dict[str, jax.Array]] = []
    total_loss = jnp.asarray(0.0, dtype=jnp.float32)

    for si, (fn, sd) in enumerate(zip(prog.stage_fns, prog.subdags)):
        ext = {a: mailbox[(a, si)] for a in sd.required_acti}
        received.append(ext)
        t0 = time.perf_counter() if timing_cb else 0.0
        (sends, loss), vjp_fn = jax.vjp(
            lambda p, e: fn(p, e, stage_inputs[si]), stage_params[si], ext)
        if timing_cb:
            # async dispatch returns before the compute runs — force it so
            # the sample measures stage execution, not dispatch overhead
            jax.block_until_ready((sends, loss))
            timing_cb(si, False, time.perf_counter() - t0)
        vjps.append(vjp_fn)
        total_loss = total_loss + loss
        # transport: compress per edge (producer -> each consumer stage link)
        for a, out in sends.items():
            for cj in prog.consumers.get(a, []):
                consumer_ops = [n for n in prog.subdags[cj].node_names
                                if a in prog.graph.nodes[n].args]
                # one physical message per (producer, consumer CompNode); the
                # plan is keyed per (producer op, consumer op) — same ratio
                # for all consumers on one CompNode by construction.
                ratio = max([plan.ratio(a, c) for c in consumer_ops] or [1.0])
                mailbox[(a, cj)] = _traced_compress(
                    trace, f"enc {a}->s{cj}", f"stage{si}", False, ratio,
                    lambda out=out, ratio=ratio: compress_for_edge(
                        out, ratio, use_kernel, compress_bwd),
                    kernel_cb=kernel_cb, stage=si,
                    dense_bytes=dense_payload_bytes(out))
    return total_loss, vjps, received


def pipeline_backward(prog: PipelineProgram, vjps: List[Any],
                      received: List[Dict[str, jax.Array]],
                      plan: Optional[CompressionPlan] = None,
                      use_kernel: KernelPolicy = False,
                      timing_cb: Optional[TimingCb] = None,
                      trace: Optional[Any] = None,
                      kernel_cb: Optional[KernelCb] = None) -> Dict[str, Any]:
    """Backward sweep in reverse stage order; boundary gradients are
    compressed on the same links as their forward activations."""
    plan = plan or plan_none(prog.graph, prog.owner_stage)
    n_stages = len(prog.subdags)
    # cotangents awaiting each stage's sends: (producer, producer_stage) -> g
    grad_mail: Dict[str, jax.Array] = {}
    grads: Dict[str, Any] = {}

    for si in range(n_stages - 1, -1, -1):
        sd = prog.subdags[si]
        sends_cot = {}
        for a in sd.send_acti:
            g = grad_mail.get(a)
            if g is None:
                # consumer never contributed (e.g. consumer had no grad path)
                shape_src = received_shape = None
                raise RuntimeError(f"missing boundary gradient for {a!r}")
            sends_cot[a] = g
        loss_cot = jnp.asarray(1.0, dtype=jnp.float32)
        t0 = time.perf_counter() if timing_cb else 0.0
        p_cot, ext_cot = vjps[si]((sends_cot, loss_cot))
        if timing_cb:
            jax.block_until_ready((p_cot, ext_cot))
            timing_cb(si, True, time.perf_counter() - t0)
        grads.update(p_cot)
        # route ext cotangents back to producers, compressed per link
        for a, g in ext_cot.items():
            producer_ops_here = [n for n in sd.node_names
                                 if a in prog.graph.nodes[n].args]
            ratio = max([plan.ratio(a, c) for c in producer_ops_here] or [1.0])
            g = _traced_compress(
                trace, f"enc grad({a})", f"stage{si}", True, ratio,
                lambda g=g, ratio=ratio: compress_for_edge(g, ratio,
                                                           use_kernel),
                kernel_cb=kernel_cb, stage=si,
                dense_bytes=dense_payload_bytes(g))
            grad_mail[a] = grad_mail[a] + g if a in grad_mail else g
    return grads


def pipeline_loss_and_grad(prog: PipelineProgram, params: Params,
                           inputs: Mapping[str, jax.Array],
                           plan: Optional[CompressionPlan] = None,
                           use_kernel: KernelPolicy = False,
                           timing_cb: Optional[TimingCb] = None,
                           trace: Optional[Any] = None,
                           kernel_cb: Optional[KernelCb] = None
                           ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One RAD iteration (all stages, one micro-batch)."""
    loss, vjps, received = pipeline_forward(prog, params, inputs, plan,
                                            use_kernel, timing_cb=timing_cb,
                                            trace=trace, kernel_cb=kernel_cb)
    grads = pipeline_backward(prog, vjps, received, plan, use_kernel,
                              timing_cb=timing_cb, trace=trace,
                              kernel_cb=kernel_cb)
    return loss, grads


def pipeline_train_step(prog: PipelineProgram, params: Params,
                        micro_batches: Sequence[Mapping[str, jax.Array]],
                        plan: Optional[CompressionPlan] = None,
                        use_kernel: KernelPolicy = False
                        ) -> Tuple[jax.Array, Dict[str, Any]]:
    """GPipe-style accumulation over micro-batches (paper Eq. 3 schedule;
    numerically the order does not matter, the executor models the timing)."""
    total_loss = jnp.asarray(0.0, dtype=jnp.float32)
    acc: Optional[Dict[str, Any]] = None
    for mb in micro_batches:
        loss, grads = pipeline_loss_and_grad(prog, params, mb, plan, use_kernel)
        total_loss = total_loss + loss
        if acc is None:
            acc = grads
        else:
            acc = jax.tree_util.tree_map(jnp.add, acc, grads)
    n = float(len(micro_batches))
    acc = jax.tree_util.tree_map(lambda g: g / n, acc)
    return total_loss / n, acc


def init_ef_state(prog: PipelineProgram, params: Params,
                  inputs: Mapping[str, jax.Array]) -> Dict[str, jax.Array]:
    """Zero error-feedback residuals, one per backward (gradient) edge —
    keyed by producer op.  Shapes come from a throwaway forward."""
    _, _, received = pipeline_forward(prog, params, inputs)
    shapes: Dict[str, jax.Array] = {}
    for ext in received:
        for a, v in ext.items():
            shapes[a] = jnp.zeros_like(v)
    return shapes


def pipeline_loss_and_grad_ef(prog: PipelineProgram, params: Params,
                              inputs: Mapping[str, jax.Array],
                              plan: CompressionPlan,
                              ef_state: Dict[str, jax.Array],
                              use_kernel: KernelPolicy = False,
                              timing_cb: Optional[TimingCb] = None,
                              trace: Optional[Any] = None,
                              kernel_cb: Optional[KernelCb] = None
                              ) -> Tuple[jax.Array, Dict[str, Any],
                                         Dict[str, jax.Array]]:
    """RAD iteration with error feedback on the BACKWARD (gradient) edges
    (beyond-paper: EF-SGD residual memory; motivated by the measured
    divergence of plain compressed training, EXPERIMENTS.md §Convergence).

    Forward activations compress exactly as the paper's transport; the
    gradient of each cross-node edge sends TopK(g + residual) and keeps
    what was dropped for the next step."""
    from .compression import ratio_to_k, topk_mask

    # forward-only transport compression here; the gradient direction is
    # compressed below, WITH the residual memory (otherwise the custom_vjp
    # would sparsify the cotangent before EF sees it — double compression).
    loss, vjps, received = pipeline_forward(prog, params, inputs, plan,
                                            use_kernel, compress_bwd=False,
                                            timing_cb=timing_cb, trace=trace,
                                            kernel_cb=kernel_cb)
    n_stages = len(prog.subdags)
    grad_mail: Dict[str, jax.Array] = {}
    grads: Dict[str, Any] = {}
    new_ef = dict(ef_state)

    for si in range(n_stages - 1, -1, -1):
        sd = prog.subdags[si]
        sends_cot = {a: grad_mail[a] for a in sd.send_acti}
        t0 = time.perf_counter() if timing_cb else 0.0
        p_cot, ext_cot = vjps[si]((sends_cot,
                                   jnp.asarray(1.0, jnp.float32)))
        if timing_cb:
            jax.block_until_ready((p_cot, ext_cot))
            timing_cb(si, True, time.perf_counter() - t0)
        grads.update(p_cot)
        for a, g in ext_cot.items():
            consumer_ops = [n for n in sd.node_names
                            if a in prog.graph.nodes[n].args]
            ratio = max([plan.ratio(a, c) for c in consumer_ops] or [1.0])
            if ratio > 1.0:
                corrected = g + ef_state[a].astype(g.dtype)
                k = ratio_to_k(int(np.prod(g.shape)), ratio)
                sent = _traced_compress(
                    trace, f"enc ef({a})", f"stage{si}", True, ratio,
                    lambda corrected=corrected, k=k: topk_mask(
                        corrected, k, use_kernel=use_kernel),
                    kernel_cb=kernel_cb, stage=si,
                    dense_bytes=dense_payload_bytes(g))
                new_ef[a] = corrected - sent
                g = sent
            grad_mail[a] = grad_mail[a] + g if a in grad_mail else g
    return loss, grads, new_ef


def single_device_loss_and_grad(graph: OpGraph, params: Params,
                                inputs: Mapping[str, jax.Array]
                                ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Reference semantics: whole graph on one device, plain ``jax.grad`` —
    the ground truth RAD must reproduce when compression is off."""

    def loss_fn(p):
        vals = graph.apply(p, inputs)
        return sum(jnp.sum(vals[ln]).astype(jnp.float32)
                   for ln in graph.loss_nodes())

    return jax.value_and_grad(loss_fn)(dict(params))
