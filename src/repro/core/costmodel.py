"""Unified edge-cost model — the single source of truth for transported bytes.

Before this layer existed the codebase carried **three inconsistent byte
models** that could not compose:

* ``partition.py`` scaled stage-boundary bytes with an ad-hoc stage-indexed
  ``edge_bytes_scale`` mapping,
* ``estimator.py`` approximated compression with a smooth per-edge
  ``compress_ratio`` (``bytes · 3/r``, no integer rounding, fp32 hard-coded),
* ``compression.py`` / ``executor.py`` used the exact integer
  :func:`repro.core.compression.wire_bytes` encoding.

The planner therefore scheduled on one arithmetic and simulated on another —
and AdaTopK, which *changes* which cut is bottleneck-optimal, could not feed
back into the DP at all.  :class:`EdgeCostModel` composes, per op-pair edge:

* the α–β link model of :class:`repro.core.estimator.ClusterSpec`,
* the exact integer wire encoding (dtype-aware itemsize derived from the
  producer's profile, index overhead, break-even clamp) under an optional
  :class:`repro.core.compression.CompressionPlan`,
* optional telemetry-calibrated per-link corrections (a measured/modeled
  seconds ratio fitted by :func:`fit_link_corrections`),
* optional telemetry-calibrated per-device **kernel costs** — the compute
  seconds the fused compression codec spends per edge
  (:class:`KernelCostModel`, fitted by :func:`fit_kernel_costs` from
  ``KernelTiming`` samples), so planners stop pricing compression at zero.

Every byte-accounting consumer — the min-bottleneck DP, OP-Fence, the Eq. 1
estimator, the discrete-event simulator, AdaTopK planning, and the elastic
re-planner — now reads this one model, so "schedule under compressed costs"
is just ``model.with_plan(plan)``.  The stage-boundary view the DP needs is
*derived* from op-pair costs (the boundary edge between consecutive chain
segments is itself an op pair), never duplicated.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Mapping, Optional, Sequence, Tuple

import numpy as np

from .compression import CompressionPlan, wire_bytes
from .estimator import ClusterSpec
from .opgraph import OpGraph, OpProfile


@dataclasses.dataclass(frozen=True)
class KernelCostModel:
    """Per-device compression-codec cost: ``seconds(d) = alpha + d/B`` for
    ``d`` dense payload bytes through the fused encode(+EF) kernel.

    ``alpha`` is the fixed launch/dispatch overhead; ``bytes_per_second``
    the codec's streaming throughput (``inf`` = free, the legacy
    assumption).  Fitted per device by :func:`fit_kernel_costs` from
    ``KernelTiming`` telemetry."""

    alpha: float = 0.0
    bytes_per_second: float = float("inf")

    def seconds(self, dense_bytes: float) -> float:
        t = float(self.alpha)
        if np.isfinite(self.bytes_per_second) and self.bytes_per_second > 0:
            t += float(dense_bytes) / float(self.bytes_per_second)
        return t


@dataclasses.dataclass(frozen=True)
class EdgeCost:
    """Fully resolved cost of one cross-CompNode edge."""

    producer: str
    consumer: str
    src: int
    dst: int
    dense_bytes: float         # uncompressed payload at the producer's dtype
    wire_bytes: float          # exact on-the-wire bytes under the plan
    seconds: float             # α + β·wire_bytes, link-corrected


class EdgeCostModel:
    """Per-edge transported bytes and seconds, keyed by (producer, consumer).

    Immutable by convention: derive variants with :meth:`with_plan` /
    :meth:`with_cluster` / :meth:`with_link_corrections` /
    :meth:`with_kernel_costs` instead of mutating.  ``plan=None`` means dense
    transport; ``link_corrections`` maps a directed CompNode pair ``(i, j)``
    to a multiplicative correction on the modeled link seconds (1.0 = trust
    the α–β fit); ``kernel_costs`` maps a device id to its
    :class:`KernelCostModel` (absent = codec priced free, the legacy
    behaviour, so unpinned baselines are unchanged).
    """

    def __init__(self, graph: OpGraph, profiles: Mapping[str, OpProfile],
                 cluster: ClusterSpec,
                 plan: Optional[CompressionPlan] = None,
                 link_corrections: Optional[Mapping[Tuple[int, int], float]] = None,
                 kernel_costs: Optional[Mapping[int, KernelCostModel]] = None):
        self.graph = graph
        self.profiles = profiles
        self.cluster = cluster
        self.plan = plan
        self.link_corrections = dict(link_corrections or {})
        self.kernel_costs = dict(kernel_costs or {})

    # ------------------------------------------------------------ variants --
    def with_plan(self, plan: Optional[CompressionPlan]) -> "EdgeCostModel":
        return EdgeCostModel(self.graph, self.profiles, self.cluster, plan,
                             self.link_corrections, self.kernel_costs)

    def with_cluster(self, cluster: ClusterSpec) -> "EdgeCostModel":
        return EdgeCostModel(self.graph, self.profiles, cluster, self.plan,
                             self.link_corrections, self.kernel_costs)

    def with_link_corrections(self, corrections: Mapping[Tuple[int, int], float]
                              ) -> "EdgeCostModel":
        return EdgeCostModel(self.graph, self.profiles, self.cluster,
                             self.plan, corrections, self.kernel_costs)

    def with_kernel_costs(self, kernel_costs: Mapping[int, KernelCostModel]
                          ) -> "EdgeCostModel":
        return EdgeCostModel(self.graph, self.profiles, self.cluster,
                             self.plan, self.link_corrections, kernel_costs)

    # -------------------------------------------------------------- per-op --
    def numel(self, op: str) -> int:
        return int(np.prod(self.profiles[op].out_shape)) \
            if self.profiles[op].out_shape else 1

    def itemsize(self, op: str) -> int:
        """Activation itemsize derived from the producer's profile (the
        profile's ``out_bytes`` already encodes the dtype the broker annotated
        the graph with — bf16 boundaries are 2 bytes/elem, not a hard-coded
        4)."""
        n = self.numel(op)
        if n <= 0:
            return 4
        return max(1, int(round(self.profiles[op].out_bytes / n)))

    def dense_bytes(self, op: str) -> float:
        """Uncompressed payload of one boundary tensor."""
        return float(self.profiles[op].out_bytes)

    # ------------------------------------------------------------ per-edge --
    def ratio(self, producer: str, consumer: str) -> float:
        if self.plan is None:
            return 1.0
        return self.plan.ratio(producer, consumer)

    @property
    def encoding(self) -> str:
        return self.plan.encoding if self.plan is not None else "none"

    def edge_wire_bytes(self, producer: str, consumer: str) -> float:
        """Exact integer-encoding bytes on the wire for one edge, under the
        plan's ratio (dense when unplanned) at the producer's dtype."""
        r = self.ratio(producer, consumer)
        if r <= 1.0 or self.encoding == "none":
            return self.dense_bytes(producer)   # exact, even for 0-byte ops
        return wire_bytes(self.numel(producer), r, self.encoding,
                          itemsize=self.itemsize(producer))

    def link_seconds(self, src: int, dst: int, nbytes: float) -> float:
        """α–β seconds for ``nbytes`` on the (src, dst) link, scaled by the
        telemetry-calibrated correction when one was fitted."""
        t = self.cluster.comm_time(src, dst, nbytes)
        return t * self.link_corrections.get((src, dst), 1.0)

    def edge_seconds(self, producer: str, consumer: str,
                     src: int, dst: int) -> float:
        """Transport seconds of one edge's payload over the (src, dst) link."""
        if src == dst:
            return 0.0
        return self.link_seconds(src, dst,
                                 self.edge_wire_bytes(producer, consumer))

    def compress_seconds(self, producer: str, consumer: str,
                         device: int) -> float:
        """Compute seconds the fused compression codec spends on one edge's
        payload, on ``device``'s codec stream (the encoder side — the
        transfer's source).  Zero when the edge is unplanned/dense or the
        device has no calibrated kernel cost (legacy: compression is free).
        The term covers the whole codec (encode + EF update; decode rides
        the same calibrated throughput)."""
        r = self.ratio(producer, consumer)
        if r <= 1.0 or self.encoding == "none":
            return 0.0
        kc = self.kernel_costs.get(device)
        if kc is None:
            return 0.0
        return kc.seconds(self.dense_bytes(producer))

    def edge_cost(self, producer: str, consumer: str,
                  src: int, dst: int) -> EdgeCost:
        wb = self.edge_wire_bytes(producer, consumer)
        return EdgeCost(producer=producer, consumer=consumer, src=src, dst=dst,
                        dense_bytes=self.dense_bytes(producer), wire_bytes=wb,
                        seconds=0.0 if src == dst
                        else self.link_seconds(src, dst, wb))

    # --------------------------------------------------------------- views --
    def cross_edges(self, placement: Mapping[str, int]
                    ) -> Iterator[Tuple[str, str]]:
        """(producer, consumer) pairs crossing CompNodes under a placement."""
        for n, node in self.graph.nodes.items():
            for a in node.args:
                if placement[a] != placement[n]:
                    yield (a, n)

    def stage_pace(self, schedule) -> float:
        """Eq. 3 steady-state pace ``max_k max(C_k, R_k, E_k)`` of a schedule
        under this model — the *derived* stage-boundary view.

        ``C_k`` uses forward FLOPs (the same objective the min-bottleneck DP
        optimizes) and ``R_k`` charges every cross-stage edge to the CompNode
        owning the consumer op, the shared attribution of estimator,
        simulator, and telemetry.  ``E_k`` is the codec stream: per-device
        fused-encode seconds summed over the edges *produced* there — the
        codec double-buffers against the next micro-batch's compute, so in
        steady state it bounds pace exactly like ``C`` and ``R`` do (zero
        unless kernel costs are calibrated).
        """
        placement = schedule.placement
        comp: Dict[int, float] = {}
        recv: Dict[int, float] = {}
        enc: Dict[int, float] = {}
        for d in schedule.stage_devices():
            comp[d] = sum(self.profiles[n].fwd_flops
                          for n in schedule.assignment[d]) \
                / self.cluster.devices[d].speed
            recv[d] = 0.0
            enc[d] = 0.0
        for (a, n) in self.cross_edges(placement):
            recv[placement[n]] = recv.get(placement[n], 0.0) + \
                self.edge_seconds(a, n, placement[a], placement[n])
            enc[placement[a]] = enc.get(placement[a], 0.0) + \
                self.compress_seconds(a, n, placement[a])
        return max((max(comp[d], recv[d], enc.get(d, 0.0)) for d in comp),
                   default=0.0)


def fit_link_corrections(measured: Mapping[Tuple[int, int],
                                           Sequence[Tuple[float, float]]],
                         cluster,
                         clamp: Tuple[float, float] = (0.25, 4.0)
                         ) -> Dict[Tuple[int, int], float]:
    """Telemetry-calibrated link corrections.

    ``measured[(i, j)]`` is a sequence of ``(nbytes, observed_seconds)``
    transfer samples on the directed (i, j) link.  The correction is the
    least-squares scale of observed vs α–β-modeled seconds (slope through the
    origin), clamped to ``clamp`` so one pathological sample cannot swing the
    planner by orders of magnitude.  Feed the result to
    :meth:`EdgeCostModel.with_link_corrections`.

    Corrections are **absolute** multipliers on the *uncorrected* α–β spec:
    re-fits replace what is installed, they never compose with it.  The clamp
    makes composing actively dangerous — each re-fit of a badly degraded link
    can contribute up to ``clamp[1]``, so corrections stacked across windows
    drift geometrically (``4, 16, 64, …``) under perfectly stationary
    telemetry instead of converging on the true ratio.  To make that mistake
    unrepresentable, ``cluster`` may be either a bare :class:`ClusterSpec` or
    an :class:`EdgeCostModel`; a model is reduced to its **base** cluster and
    any corrections it already carries are ignored, so the fit always
    measures observed seconds against the pristine spec.
    """
    if isinstance(cluster, EdgeCostModel):
        cluster = cluster.cluster   # the uncorrected α–β base, by definition
    lo, hi = clamp
    out: Dict[Tuple[int, int], float] = {}
    for (i, j), samples in measured.items():
        pred = np.array([cluster.comm_time(i, j, nb) for nb, _ in samples],
                        dtype=np.float64)
        obs = np.array([s for _, s in samples], dtype=np.float64)
        denom = float(np.dot(pred, pred))
        if denom <= 0.0:
            continue
        out[(i, j)] = float(np.clip(np.dot(pred, obs) / denom, lo, hi))
    return out


def fit_kernel_costs(measured: Mapping[int, Sequence[Tuple[float, float]]]
                     ) -> Dict[int, KernelCostModel]:
    """Telemetry-calibrated per-device codec costs.

    ``measured[device]`` is a sequence of ``(dense_bytes, seconds)``
    ``KernelTiming`` samples from that device's fused compression codec.
    Fit is the least-squares seconds-per-byte slope through the origin —
    the same estimator shape as :func:`fit_link_corrections`, so outliers
    already rejected by the telemetry MAD window cannot tilt it.  Devices
    with degenerate samples (no bytes, non-positive slope) are skipped:
    absence means "priced free", never "priced garbage"."""
    out: Dict[int, KernelCostModel] = {}
    for device, samples in measured.items():
        b = np.array([nb for nb, _ in samples], dtype=np.float64)
        s = np.array([sec for _, sec in samples], dtype=np.float64)
        denom = float(np.dot(b, b))
        if denom <= 0.0:
            continue
        slope = float(np.dot(b, s) / denom)   # seconds per dense byte
        if slope <= 0.0 or not np.isfinite(slope):
            continue
        out[int(device)] = KernelCostModel(alpha=0.0,
                                           bytes_per_second=1.0 / slope)
    return out
