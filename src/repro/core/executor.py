"""DAG runtime executor + discrete-event timing simulator (FusionLLM §3.2–3.3).

Two layers:

* :class:`DecentralizedRuntime` — the *functional* executor.  Every CompNode
  owns a sub-DAG, a mailbox, and its slice of the parameters; OpData
  envelopes (paper §3.4) carry boundary activations/gradients between
  CompNodes; FP/BP use the stage-local autodiff of :mod:`repro.core.rad`.
  Numerics are exact (single host process stands in for the swarm).

* :func:`simulate_iteration` — the *timing* simulator.  Discrete-event
  replay of the GPipe schedule (Eq. 3) at stage granularity with separate
  compute and link resources, heterogeneous α–β links and per-edge
  compression; this is what the paper's Fig. 10 latency numbers correspond
  to, since real wall-time over the Internet cannot be measured here.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .compression import CompressionPlan, plan_none, ratio_to_k
from .costmodel import EdgeCostModel
from .estimator import ClusterSpec, LinkSpec
from .opgraph import OpData, OpGraph, OpProfile, OpType
from .rad import (PipelineProgram, init_ef_state, pipeline_loss_and_grad,
                  pipeline_loss_and_grad_ef)
from .scheduler import Schedule
from ..obs.trace import CAT_BWD, CAT_ENCODE, CAT_FWD, CAT_TRANSFER


# ========================================================== telemetry hook ==
@dataclasses.dataclass(frozen=True)
class StepTiming:
    """One per-stage, per-micro-batch timing sample.

    Emitted by :func:`simulate_iteration` (simulated seconds) and by
    :class:`DecentralizedRuntime` (measured host wall-clock); consumed by the
    broker-side :class:`repro.elastic.telemetry.TelemetryLog`, which
    aggregates samples into the per-CompNode step times the straggler
    detector observes.  ``comm_seconds`` is charged to the stage owning the
    *consumer* op of each cross-stage edge in both passes — the same
    attribution :func:`repro.core.estimator.predict_step_times` uses, so
    telemetry observations and estimator predictions are directly comparable.
    """

    node: int                  # CompNode (device) index
    stage: int                 # pipeline stage position
    micro_batch: int
    backward: bool
    compute_seconds: float
    comm_seconds: float = 0.0
    step: int = 0              # training step the sample belongs to

    @property
    def seconds(self) -> float:
        return self.compute_seconds + self.comm_seconds


@dataclasses.dataclass(frozen=True)
class LinkTiming:
    """One per-link transfer observation: ``nbytes`` rode the directed
    (src, dst) CompNode link and took ``seconds`` on the wire.

    Emitted by :func:`simulate_iteration` alongside :class:`StepTiming` (one
    sample per cross-stage edge transfer, per micro-batch, per direction).
    This is the raw material of closed-loop link calibration: the broker's
    :class:`repro.elastic.telemetry.TelemetryLog` windows and MAD-filters
    these into the ``(nbytes, seconds)`` pairs that
    :func:`repro.core.costmodel.fit_link_corrections` turns into per-link
    corrections on the planner's α–β model.
    """

    src: int                   # producer-side CompNode (device) index
    dst: int                   # consumer-side CompNode (device) index
    nbytes: float              # exact wire bytes of the transfer
    seconds: float             # observed transport seconds on the link
    backward: bool = False
    step: int = 0


@dataclasses.dataclass(frozen=True)
class KernelTiming:
    """One compression-codec observation: the fused encode(+EF) kernel on
    CompNode ``node`` chewed through ``nbytes`` of *dense* payload in
    ``seconds`` of compute.

    Emitted by :func:`simulate_iteration` (one sample per compressed edge
    transfer, priced by the model's :class:`~repro.core.costmodel.
    KernelCostModel`) and by :class:`DecentralizedRuntime` (measured host
    wall-clock around the traced codec).  The broker's TelemetryLog windows
    and MAD-filters these into the ``(dense_bytes, seconds)`` pairs
    :func:`repro.core.costmodel.fit_kernel_costs` turns into per-device
    codec costs — closing the same loop link calibration closes for α–β.
    ``nbytes`` is dense payload, not wire bytes: codec time scales with
    what the kernel reads, not with what survives compression."""

    node: int                  # CompNode (device) index running the codec
    nbytes: float              # dense payload bytes through the kernel
    seconds: float             # codec compute seconds
    backward: bool = False
    step: int = 0


class TelemetrySink:
    """Anything with ``record(StepTiming)`` (and optionally
    ``record_link(LinkTiming)`` / ``record_kernel(KernelTiming)``); the
    trivial list-backed sink."""

    def __init__(self):
        self.samples: List[StepTiming] = []
        self.link_samples: List[LinkTiming] = []
        self.kernel_samples: List[KernelTiming] = []

    def record(self, sample: StepTiming) -> None:
        self.samples.append(sample)

    def record_link(self, sample: LinkTiming) -> None:
        self.link_samples.append(sample)

    def record_kernel(self, sample: KernelTiming) -> None:
        self.kernel_samples.append(sample)


# ===================================================== functional executor ==
class CompNodeRuntime:
    """One participant: holds its sub-DAG's params and a mailbox of OpData."""

    def __init__(self, device_index: int, stage_index: int):
        self.device_index = device_index
        self.stage_index = stage_index
        self.mailbox: List[OpData] = []
        self.sent_log: List[OpData] = []

    def deliver(self, msg: OpData) -> None:
        self.mailbox.append(msg)

    def pop_activations(self, needed: Sequence[str], micro_batch: int
                        ) -> Dict[str, jax.Array]:
        out: Dict[str, jax.Array] = {}
        for m in self.mailbox:
            if (not m.is_loss and m.actual_op_user is None
                    and m.name in needed and m.micro_batch == micro_batch):
                out[m.name] = m.payload
        missing = set(needed) - set(out)
        if missing:
            raise RuntimeError(f"CompNode {self.device_index} missing "
                               f"activations {sorted(missing)}")
        return out


class DecentralizedRuntime:
    """End-to-end FusionLLM runtime over a Schedule (broker's output).

    ``train_step`` runs n_micro micro-batches through FP+BP with per-edge
    compression and returns (mean loss, accumulated grads, OpData traffic
    log).  Gradient identity: messages with ``actual_op_user`` set are
    boundary gradients keyed producer->user (paper Table 3).

    ``plan.error_feedback=True`` dispatches to the EF-SGD gradient transport
    (:func:`repro.core.rad.pipeline_loss_and_grad_ef`); the residual memory
    lives on the runtime and carries across micro-batches and steps.

    ``telemetry`` (anything with ``record(StepTiming)``) receives one
    measured-wall-clock sample per (stage, micro-batch, direction) — the
    real-executor observation source for the broker's straggler detector.
    """

    def __init__(self, graph: OpGraph, schedule: Schedule,
                 plan: Optional[CompressionPlan] = None,
                 use_kernel: Any = False,
                 telemetry: Optional[Any] = None,
                 trace: Optional[Any] = None):
        self.graph = graph
        self.schedule = schedule
        self.plan = plan or plan_none(graph, schedule.placement)
        self.use_kernel = use_kernel
        self.prog = PipelineProgram.build(graph, schedule.pipeline_subdags(graph))
        self.comp_nodes = [CompNodeRuntime(dev, s)
                           for s, dev in enumerate(schedule.stage_devices())]
        self.traffic: List[OpData] = []
        self.telemetry = telemetry
        self.trace = trace
        self.ef_state: Optional[Dict[str, jax.Array]] = None
        self.step_index = 0

    def _log(self, msg: OpData) -> None:
        self.traffic.append(msg)

    def _timing_cb(self, mb_idx: int):
        trace = self.trace if getattr(self.trace, "enabled", False) else None
        if self.telemetry is None and trace is None:
            return None
        devs = self.schedule.stage_devices()

        def cb(stage: int, backward: bool, seconds: float) -> None:
            if self.telemetry is not None:
                self.telemetry.record(StepTiming(
                    node=devs[stage], stage=stage, micro_batch=mb_idx,
                    backward=backward, compute_seconds=seconds,
                    step=self.step_index))
            if trace is not None:
                trace.complete_wall(
                    CAT_BWD if backward else CAT_FWD,
                    f"{'B' if backward else 'F'}{stage}.mb{mb_idx}",
                    f"dev{devs[stage]}", seconds,
                    args={"stage": stage, "mb": mb_idx,
                          "step": self.step_index})
        return cb

    def _kernel_cb(self, mb_idx: int):
        """Measured codec-time hook -> KernelTiming samples, only when the
        sink can absorb them (forcing device sync for nobody is not free)."""
        if self.telemetry is None \
                or not hasattr(self.telemetry, "record_kernel"):
            return None
        devs = self.schedule.stage_devices()

        def cb(stage: int, backward: bool, seconds: float,
               dense_bytes: float) -> None:
            self.telemetry.record_kernel(KernelTiming(
                node=devs[stage], nbytes=dense_bytes, seconds=seconds,
                backward=backward, step=self.step_index))
        return cb

    def train_step(self, params: Mapping[str, Any],
                   micro_batches: Sequence[Mapping[str, jax.Array]]
                   ) -> Tuple[jax.Array, Dict[str, Any]]:
        total = jnp.asarray(0.0, jnp.float32)
        acc: Optional[Dict[str, Any]] = None
        for mb_idx, mb in enumerate(micro_batches):
            cb = self._timing_cb(mb_idx)
            kcb = self._kernel_cb(mb_idx)
            if self.plan.error_feedback:
                if self.ef_state is None:
                    self.ef_state = init_ef_state(self.prog, params, mb)
                loss, grads, self.ef_state = pipeline_loss_and_grad_ef(
                    self.prog, params, mb, self.plan, self.ef_state,
                    self.use_kernel, timing_cb=cb, trace=self.trace,
                    kernel_cb=kcb)
            else:
                loss, grads = pipeline_loss_and_grad(
                    self.prog, params, mb, self.plan, self.use_kernel,
                    timing_cb=cb, trace=self.trace, kernel_cb=kcb)
            # traffic accounting (envelope per cross-stage edge, FP + BP)
            for si, sd in enumerate(self.prog.subdags):
                for a in sd.required_acti:
                    self._log(OpData(name=a,
                                     op_users=tuple(self.graph.users[a]),
                                     micro_batch=mb_idx,
                                     compress_cfg={"ratio": self._edge_ratio(a, sd)}))
                for (prod, user) in sd.send_grad:
                    self._log(OpData(name=prod, op_users=(user,),
                                     actual_op_user=user, micro_batch=mb_idx,
                                     compress_cfg={"ratio": self.plan.ratio(prod, user)}))
            total = total + loss
            acc = grads if acc is None else jax.tree_util.tree_map(
                jnp.add, acc, grads)
        n = float(len(micro_batches))
        self.step_index += 1
        return total / n, jax.tree_util.tree_map(lambda g: g / n, acc)

    def _edge_ratio(self, producer: str, sd) -> float:
        cs = [n for n in sd.node_names if producer in self.graph.nodes[n].args]
        return max([self.plan.ratio(producer, c) for c in cs] or [1.0])


# ======================================================= timing simulator ==
@dataclasses.dataclass
class SimResult:
    iteration_time: float
    fwd_time: float
    bwd_time: float
    device_busy: List[float]
    link_busy: float
    comm_bytes: float
    events: List[Tuple[float, float, str]]  # (start, end, label)
    compress_busy: float = 0.0  # codec-stream seconds (0 unless the model
                                # carries calibrated kernel costs)

    @property
    def utilization(self) -> List[float]:
        t = max(self.iteration_time, 1e-12)
        return [b / t for b in self.device_busy]


def _stage_tables(graph: OpGraph, profiles: Mapping[str, OpProfile],
                  schedule: Schedule, cluster: ClusterSpec,
                  model: EdgeCostModel, backward: bool):
    """Per-stage compute seconds + boundary (bytes, link) into each stage.

    All transported bytes/seconds come from the unified ``model`` (the plan's
    exact wire encoding at the producer's dtype plus α–β link seconds), so
    simulated comm charges agree with the estimator's prediction exactly."""
    placement = schedule.placement
    stages = [d for d in schedule.stages if schedule.assignment[d]]
    comp = []
    for d in stages:
        flops = sum((profiles[n].bwd_flops if backward else profiles[n].fwd_flops)
                    for n in schedule.assignment[d])
        comp.append(flops / cluster.devices[d].speed)
    # boundary edges between consecutive stages (chain partition ⇒ boundary
    # traffic flows stage k -> k+1 in FP and back in BP); multi-user edges
    # (e.g. shared attention, cross-attention) may skip stages — each gets
    # its own link transfer.  ``charge`` is the stage owning the consumer op,
    # the stage whose telemetry sample absorbs the transfer time (matching
    # the estimator's recv attribution, see StepTiming).  ``t_enc`` is the
    # codec seconds on the transfer's *source* device (FP: the producer
    # encodes the activation; BP: the consumer encodes the boundary
    # gradient) — zero unless the model carries calibrated kernel costs.
    # (from, to, seconds, charge, wire_bytes, enc_seconds, dense_bytes)
    edges: List[Tuple[int, int, float, int, float, float, float]] = []
    stage_of = {d: i for i, d in enumerate(stages)}
    total_bytes = 0.0
    for n, node in graph.nodes.items():
        for a in node.args:
            if placement[a] == placement[n]:
                continue
            if graph.nodes[a].op_type in (OpType.PLACEHOLDER, OpType.VARIABLE):
                continue
            nbytes = model.edge_wire_bytes(a, n)
            src, dst = placement[a], placement[n]
            if backward:
                src, dst = dst, src
            t = model.link_seconds(src, dst, nbytes)
            t_enc = model.compress_seconds(a, n, src)
            edges.append((stage_of[src], stage_of[dst], t,
                          stage_of[placement[n]], nbytes, t_enc,
                          model.dense_bytes(a)))
            total_bytes += nbytes
    return stages, comp, edges, total_bytes


def simulate_iteration(graph: OpGraph, profiles: Mapping[str, OpProfile],
                       schedule: Schedule, cluster: ClusterSpec,
                       plan: Optional[CompressionPlan] = None,
                       n_micro: int = 1,
                       telemetry: Optional[Any] = None,
                       step: int = 0,
                       cost_model: Optional[EdgeCostModel] = None,
                       trace: Optional[Any] = None) -> SimResult:
    """Discrete-event GPipe replay: FP fills stage by stage per micro-batch,
    then BP drains in reverse.  Each device is a serial resource; each
    directed stage pair is a serial link; compute of micro-batch m+1 overlaps
    the transfer of micro-batch m (the overlap Eq. 3 assumes).

    ``telemetry`` (anything with ``record(StepTiming)``) receives one sample
    per (stage, micro-batch, direction), stamped with ``step`` — the
    simulated stand-in for real per-CompNode executor timings that the
    elastic broker's TelemetryLog aggregates for straggler detection.  A
    sink that additionally exposes ``record_link(LinkTiming)`` also gets one
    sample per cross-stage edge transfer (micro-batch × direction), the raw
    per-link observations closed-loop calibration fits corrections from;
    one that exposes ``record_kernel(KernelTiming)`` gets one sample per
    *compressed* edge transfer when the cost model carries calibrated
    kernel costs (the codec-stream spans, on trace track ``codec<dev>``).
    Compression compute is modeled as a per-boundary span on the source
    device's serial codec stream: it delays the transfer's availability but
    double-buffers against the device's next micro-batch compute
    (``StepTiming.compute_seconds`` excludes it by design — the detector's
    estimator parity is over stage compute + recv only).

    ``cost_model`` supplies the wire encoding (its plan, overriding the
    ``plan`` argument) and any telemetry-calibrated link corrections; by
    default one is built from ``plan``.  Either way the model is rebased
    onto ``cluster`` — compute charges read ``cluster.devices`` directly,
    so comm must price against the same topology or the SimResult would
    silently mix believed and true clusters.

    ``trace`` (a :class:`repro.obs.trace.TraceRecorder`) receives one
    sim-clock span per stage compute window (``stage.fwd``/``stage.bwd`` on
    track ``dev<i>``) and one per boundary transfer (``link.transfer`` on
    track ``link <src>-><dst>``, args carrying exact wire ``nbytes`` and the
    ``charge`` device — the same consumer-side attribution StepTiming uses).
    Tracing is observation only: timings are computed identically with it on
    or off (pinned in tests)."""
    if cost_model is not None:
        model = cost_model.with_cluster(cluster)
    else:
        model = EdgeCostModel(graph, profiles, cluster,
                              plan or plan_none(graph, schedule.placement))

    record_link = getattr(telemetry, "record_link", None)
    record_kernel = getattr(telemetry, "record_kernel", None)
    tracer = trace if getattr(trace, "enabled", False) else None

    def run_pass(backward: bool, t0: float, events, device_free, busy,
                 enc_free):
        stages, comp, edges, nbytes = _stage_tables(
            graph, profiles, schedule, cluster, model, backward)
        k = len(stages)
        order = list(range(k - 1, -1, -1)) if backward else list(range(k))
        in_edges: Dict[int, List[Tuple[int, float, int, float, float, float]]] = {}
        for (s, d2, t, charge, ebytes, t_enc, dbytes) in edges:
            in_edges.setdefault(d2, []).append((s, t, charge, ebytes,
                                                t_enc, dbytes))
        link_free: Dict[Tuple[int, int], float] = {}
        done = {}  # (stage, mb) -> finish time
        comm_total = 0.0
        enc_total = 0.0
        comm_charged: Dict[Tuple[int, int], float] = {}  # (stage, mb) -> s
        cat = CAT_BWD if backward else CAT_FWD
        tag = "B" if backward else "F"
        for mb in range(n_micro):
            for pos, st in enumerate(order):
                dev = stages[st]
                ready = t0
                for (src, tcomm, charge, ebytes, t_enc, dbytes) \
                        in in_edges.get(st, []):
                    dep = done.get((src, mb))
                    if dep is None:
                        continue
                    # Codec span: the fused encode runs on the source
                    # device's serial codec stream, *double-buffered*
                    # against that device's next micro-batch compute — it
                    # delays when the payload reaches the link, but never
                    # pushes device_free.
                    if t_enc > 0.0:
                        src_dev = stages[src]
                        e_start = max(dep, enc_free.get(src_dev, t0))
                        dep = e_start + t_enc
                        enc_free[src_dev] = dep
                        enc_total += t_enc
                        if record_kernel is not None:
                            record_kernel(KernelTiming(
                                node=src_dev, nbytes=dbytes, seconds=t_enc,
                                backward=backward, step=step))
                        if tracer is not None:
                            tracer.span(
                                CAT_ENCODE, f"{tag}enc.mb{mb}",
                                f"codec{src_dev}", e_start, dep,
                                args={"dense_bytes": dbytes, "mb": mb})
                    lk = (src, st)
                    start = max(dep, link_free.get(lk, t0))
                    link_free[lk] = start + tcomm
                    comm_total += tcomm
                    comm_charged[(charge, mb)] = \
                        comm_charged.get((charge, mb), 0.0) + tcomm
                    if record_link is not None:
                        record_link(LinkTiming(
                            src=stages[src], dst=stages[st], nbytes=ebytes,
                            seconds=tcomm, backward=backward, step=step))
                    if tracer is not None:
                        tracer.span(
                            CAT_TRANSFER, f"{tag}xfer.mb{mb}",
                            f"link {stages[src]}->{stages[st]}",
                            start, start + tcomm,
                            args={"nbytes": ebytes, "mb": mb,
                                  "charge": stages[charge]})
                    ready = max(ready, start + tcomm)
                start = max(ready, device_free.get(dev, t0))
                end = start + comp[st]
                device_free[dev] = end
                busy[dev] = busy.get(dev, 0.0) + comp[st]
                done[(st, mb)] = end
                if tracer is not None:
                    tracer.span(cat, f"{tag}{st}.mb{mb}", f"dev{dev}",
                                start, end, args={"stage": st, "mb": mb})
                events.append((start, end,
                               f"{'B' if backward else 'F'}{st}.mb{mb}"))
        if telemetry is not None:
            for st in range(k):
                for mb in range(n_micro):
                    telemetry.record(StepTiming(
                        node=stages[st], stage=st, micro_batch=mb,
                        backward=backward, compute_seconds=comp[st],
                        comm_seconds=comm_charged.get((st, mb), 0.0),
                        step=step))
        finish = max(done.values()) if done else t0
        return finish, comm_total, nbytes * n_micro, enc_total

    events: List[Tuple[float, float, str]] = []
    device_free: Dict[int, float] = {}
    busy: Dict[int, float] = {}
    enc_free: Dict[int, float] = {}
    t_fwd, comm_f, bytes_f, enc_f = run_pass(False, 0.0, events, device_free,
                                             busy, enc_free)
    t_end, comm_b, bytes_b, enc_b = run_pass(True, t_fwd, events, device_free,
                                             busy, enc_free)
    n_dev = len(cluster)
    return SimResult(
        iteration_time=t_end, fwd_time=t_fwd, bwd_time=t_end - t_fwd,
        device_busy=[busy.get(d, 0.0) for d in range(n_dev)],
        link_busy=comm_f + comm_b, comm_bytes=bytes_f + bytes_b,
        events=sorted(events), compress_busy=enc_f + enc_b)


# ================================================= churn-event simulation ==
# Default α–β for restoring state out of the broker's checkpoint store when
# the original owner is gone (a dead CompNode cannot send).  Roughly the
# intra-cluster tier of network.py — the broker sits inside one cluster.
CHECKPOINT_LINK = LinkSpec(alpha=1e-3, beta=8.0 / 1e9)   # 1 Gbps


@dataclasses.dataclass(frozen=True)
class MigrationSim:
    """Simulated wall-clock of one state migration (elastic re-plan)."""

    seconds: float
    total_bytes: float
    n_transfers: int
    events: Tuple[Tuple[float, float, str], ...] = ()


def simulate_migration(transfers: Mapping[Tuple[Optional[int], int], float],
                       cluster: ClusterSpec,
                       checkpoint_link: LinkSpec = CHECKPOINT_LINK,
                       bandwidth_fraction: float = 1.0) -> MigrationSim:
    """Discrete-event replay of a migration plan's bulk transfers.

    ``transfers`` maps (src CompNode, dst CompNode) -> bytes; ``src=None``
    means the original owner is dead and the payload streams from the
    broker's checkpoint store over ``checkpoint_link``.  Each node's uplink
    and downlink is a serial resource (so one node fanning state out to many
    peers serializes, as does a node receiving from many), and the broker's
    checkpoint store is one shared uplink; transfers on disjoint endpoints
    overlap.  Deterministic: transfers run in sorted key order.

    ``bandwidth_fraction`` < 1 models background migration sharing links with
    foreground boundary traffic (overlapped-migration mode): each transfer
    sees only that fraction of the link's bandwidth (α unchanged).
    """
    if not (0.0 < bandwidth_fraction <= 1.0):
        raise ValueError("bandwidth_fraction in (0, 1]")
    up_free: Dict[Any, float] = {}
    down_free: Dict[int, float] = {}
    events: List[Tuple[float, float, str]] = []
    total_bytes = 0.0
    finish = 0.0
    order = sorted(transfers.items(),
                   key=lambda kv: (kv[0][0] is None, kv[0]))
    for (src, dst), nbytes in order:
        if nbytes <= 0:
            continue
        if src is None:
            lk = checkpoint_link
            src_key: Any = "__ckpt__"
        else:
            lk = cluster.link(src, dst)
            src_key = src
        # throttled stream = same α–β link carrying the scaled-up payload
        t = lk.time(float(nbytes) / bandwidth_fraction)
        start = max(up_free.get(src_key, 0.0), down_free.get(dst, 0.0))
        end = start + t
        up_free[src_key] = end
        down_free[dst] = end
        finish = max(finish, end)
        total_bytes += nbytes
        events.append((start, end, f"mig:{src if src is not None else 'ckpt'}"
                                   f"->{dst}"))
    return MigrationSim(seconds=finish, total_bytes=total_bytes,
                        n_transfers=len(events), events=tuple(events))


def pipeline_fill_seconds(graph: OpGraph, profiles: Mapping[str, OpProfile],
                          schedule: Schedule, cluster: ClusterSpec,
                          plan: Optional[CompressionPlan] = None,
                          cost_model: Optional[EdgeCostModel] = None) -> float:
    """Fill cost of a cold pipeline: one micro-batch traversing every stage
    sequentially, FP + BP (the Σ_p (C_p + R_p) term of Eq. 3).  Charged by
    the elastic controller after every re-plan — a fresh schedule starts with
    an empty pipeline.  ``cost_model`` is rebased onto ``cluster`` exactly as
    in :func:`simulate_iteration`."""
    if cost_model is not None:
        model = cost_model.with_cluster(cluster)
    else:
        model = EdgeCostModel(graph, profiles, cluster,
                              plan or plan_none(graph, schedule.placement))
    total = 0.0
    for backward in (False, True):
        _, comp, edges, _ = _stage_tables(graph, profiles, schedule, cluster,
                                          model, backward)
        total += sum(comp) + sum(t + t_enc
                                 for (_, _, t, _, _, t_enc, _) in edges)
    return total
