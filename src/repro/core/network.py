"""Simulated network topologies (FusionLLM §7.1 testbeds + TPU pods).

The paper evaluates on two physical clusters joined over the Internet:

* Cluster A — 2 machines × 8 RTX 4090
* Cluster B — 8 machines × 4 RTX 2080

with GPU-to-GPU bandwidths spanning 8 Mbps – 10 Gbps (Fig. 9) and four
testbeds (Table 5).  This module reconstructs those topologies as
:class:`ClusterSpec` instances for the scheduler / throughput model /
discrete-event executor, and adds the TPU two-level hierarchy used by the
multi-pod dry-run adaptation (intra-pod ICI vs. inter-pod links).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .estimator import ClusterSpec, DeviceSpec, LinkSpec, make_device


def _bw(mbps: float) -> float:
    """Mbit/s -> bytes/s."""
    return mbps * 1e6 / 8.0


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """Bandwidth/latency for one locality tier."""

    bandwidth_Bps: float
    alpha: float

    def link(self) -> LinkSpec:
        return LinkSpec(alpha=self.alpha, beta=1.0 / self.bandwidth_Bps)


# Locality tiers roughly matching paper Fig. 9 (and its §7.1 note that
# intra-machine links deliberately avoid NCCL to mimic slow networks).
TIER_INTRA_MACHINE = TierSpec(bandwidth_Bps=_bw(10_000), alpha=1e-4)   # 10 Gbps
TIER_INTRA_CLUSTER = TierSpec(bandwidth_Bps=_bw(1_000), alpha=1e-3)    # 1 Gbps
TIER_INTER_CLUSTER = TierSpec(bandwidth_Bps=_bw(8), alpha=5e-2)        # 8 Mbps WAN


def paper_testbed(testbed: int = 2, seed: int = 0,
                  jitter: float = 0.15) -> ClusterSpec:
    """Paper Table 5 testbeds.

    testbed=1 : Cluster A 1×8 RTX4090 + Cluster B 4×4 RTX2080 (24 GPUs)
    testbed=2 : Cluster A 2×8 RTX4090 + Cluster B 8×4 RTX2080 (48 GPUs)
    ``jitter`` randomizes per-link bandwidth (log-uniform ±) to mirror the
    measured heterogeneity of Fig. 9.
    """
    if testbed == 1:
        a_machines, b_machines = 1, 4
    elif testbed == 2:
        a_machines, b_machines = 2, 8
    else:
        raise ValueError("testbed in {1, 2}")
    rng = np.random.default_rng(seed)

    devices: List[DeviceSpec] = []
    machine_of: List[int] = []
    cluster_of: List[int] = []
    mid = 0
    for _ in range(a_machines):
        for g in range(8):
            devices.append(make_device(f"A{mid}g{g}", "RTX4090",
                                       lam=float(rng.uniform(0.55, 0.75))))
            machine_of.append(mid)
            cluster_of.append(0)
        mid += 1
    for _ in range(b_machines):
        for g in range(4):
            devices.append(make_device(f"B{mid}g{g}", "RTX2080",
                                       lam=float(rng.uniform(0.5, 0.7))))
            machine_of.append(mid)
            cluster_of.append(1)
        mid += 1

    links: Dict[Tuple[int, int], LinkSpec] = {}
    n = len(devices)
    for i in range(n):
        for j in range(i + 1, n):
            if machine_of[i] == machine_of[j]:
                tier = TIER_INTRA_MACHINE
            elif cluster_of[i] == cluster_of[j]:
                tier = TIER_INTRA_CLUSTER
            else:
                tier = TIER_INTER_CLUSTER
            scale = float(np.exp(rng.uniform(-jitter, jitter)))
            links[(i, j)] = LinkSpec(alpha=tier.alpha,
                                     beta=1.0 / (tier.bandwidth_Bps * scale))
    return ClusterSpec(devices, links)


def homogeneous_lan(n: int = 8, sheet: str = "RTX4090",
                    bandwidth_Bps: float = _bw(10_000),
                    alpha: float = 1e-4) -> ClusterSpec:
    """Flat LAN — the degenerate case where OP-Fence must match
    equal-compute (one Louvain community)."""
    devices = [make_device(f"n{i}", sheet) for i in range(n)]
    link = LinkSpec(alpha=alpha, beta=1.0 / bandwidth_Bps)
    links = {(i, j): link for i in range(n) for j in range(i + 1, n)}
    return ClusterSpec(devices, links)


def geo_random(n: int = 16, n_sites: int = 4, seed: int = 0) -> ClusterSpec:
    """Random geo-distributed volunteers: n GPUs spread over n_sites regions;
    intra-site fast, inter-site slow with distance-dependent α."""
    rng = np.random.default_rng(seed)
    sheets = ["RTX4090", "RTX4080", "RTX3080", "RTX2080"]
    site = rng.integers(0, n_sites, size=n)
    pos = rng.uniform(0.0, 1.0, size=(n_sites, 2))
    devices = [make_device(f"v{i}", sheets[int(rng.integers(len(sheets)))],
                           lam=float(rng.uniform(0.4, 0.8))) for i in range(n)]
    links: Dict[Tuple[int, int], LinkSpec] = {}
    for i in range(n):
        for j in range(i + 1, n):
            if site[i] == site[j]:
                bw = _bw(rng.uniform(1_000, 10_000))
                alpha = 2e-4
            else:
                d = float(np.linalg.norm(pos[site[i]] - pos[site[j]]))
                bw = _bw(rng.uniform(8, 300))
                alpha = 5e-3 + 0.08 * d
            links[(i, j)] = LinkSpec(alpha=alpha, beta=1.0 / bw)
    return ClusterSpec(devices, links)


def fat_pipe_sites(n: int = 8, n_sites: int = 2, seed: int = 0,
                   intra_Bps: float = _bw(1_000), inter_Bps: float = _bw(25),
                   alpha: float = 2e-5, jitter: float = 0.1) -> ClusterSpec:
    """Long-fat-network geo topology: β-dominated links (negligible α on
    every tier), two bandwidth classes, heterogeneous consumer GPUs.

    This is the regime closed-loop link calibration exists for: transport
    seconds scale with payload, so a link silently congesting below its spec
    bandwidth shifts every transfer's observed seconds proportionally — a
    signal :func:`repro.core.costmodel.fit_link_corrections` can fit a clean
    multiplicative correction from (on α-dominated links a bandwidth drop
    barely moves small transfers and hides from the fit).
    """
    rng = np.random.default_rng(seed)
    sheets = ["RTX4090", "RTX4080", "RTX3080", "RTX2080"]
    devices = [make_device(f"f{i}", sheets[i % len(sheets)],
                           lam=float(rng.uniform(0.5, 0.8)))
               for i in range(n)]
    site = [i % n_sites for i in range(n)]
    links: Dict[Tuple[int, int], LinkSpec] = {}
    for i in range(n):
        for j in range(i + 1, n):
            bw = intra_Bps if site[i] == site[j] else inter_Bps
            scale = float(np.exp(rng.uniform(-jitter, jitter)))
            links[(i, j)] = LinkSpec(alpha=alpha, beta=1.0 / (bw * scale))
    return ClusterSpec(devices, links)


# ------------------------------------------------- churn-trace transforms --
def with_slowdowns(cluster: ClusterSpec,
                   factors: Dict[int, float]) -> ClusterSpec:
    """Degraded view of a topology: device i's effective speed is scaled by
    ``factors[i]`` (0 < f ≤ 1; thermal throttling, contention, preemption).

    The elastic runtime uses this twice: the *ground-truth* cluster that a
    scripted ``slowdown`` churn event produces, and the *believed* cluster
    the broker re-plans on once the straggler detector has flagged the node.
    """
    devices = []
    for i, d in enumerate(cluster.devices):
        f = float(factors.get(i, 1.0))
        if f <= 0.0:
            raise ValueError(f"slowdown factor for device {i} must be > 0")
        devices.append(dataclasses.replace(d, lam=d.lam * f))
    return cluster.with_devices(devices)


def with_link_slowdowns(cluster: ClusterSpec,
                        factors: Dict[int, float]) -> ClusterSpec:
    """Degraded links: every link touching device i gets its bandwidth scaled
    by ``factors[i]`` (congestion on the node's uplink).  α is unchanged."""
    links = {}
    for (i, j), lk in cluster.links().items():
        f = float(factors.get(i, 1.0)) * float(factors.get(j, 1.0))
        if f <= 0.0:
            raise ValueError("link slowdown factors must be > 0")
        links[(i, j)] = LinkSpec(alpha=lk.alpha, beta=lk.beta / f)
    return ClusterSpec(list(cluster.devices), links)


def with_shared_links(cluster: ClusterSpec,
                      busy_pairs: Iterable[Tuple[int, int]],
                      foreground_fraction: float = 0.5) -> ClusterSpec:
    """Foreground view of a topology while background bulk transfers run.

    Overlapped migration streams state in the background over specific links;
    each link carrying an active background transfer keeps only
    ``foreground_fraction`` of its bandwidth for foreground boundary traffic
    (fair-share: the transfer slows training, it does not block it).  α is
    unchanged — latency is not consumed by bulk flows.  Contention is
    **per link**, the native granularity of the pairwise α–β model: a bulk
    flow on a fast intra-cluster wire must not throttle the WAN edge the
    pipeline is actually bound by.
    """
    if not (0.0 < foreground_fraction <= 1.0):
        raise ValueError("foreground_fraction in (0, 1]")
    busy = {(int(i), int(j)) for (i, j) in busy_pairs}
    busy |= {(j, i) for (i, j) in busy}
    links = {}
    for (i, j), lk in cluster.links().items():
        if (i, j) in busy:
            lk = LinkSpec(alpha=lk.alpha, beta=lk.beta / foreground_fraction)
        links[(i, j)] = lk
    return ClusterSpec(list(cluster.devices), links)


def tpu_two_pods(chips_per_pod: int = 4, ici_GBps: float = 50.0,
                 dci_GBps: float = 5.0) -> ClusterSpec:
    """TPU adaptation of the geo hierarchy: two pod slices, fast ICI inside,
    ~10× slower inter-pod links — the 'slowest links' AdaTopK targets in the
    multi-pod mapping (DESIGN.md §2)."""
    n = 2 * chips_per_pod
    devices = [make_device(f"pod{i // chips_per_pod}c{i % chips_per_pod}",
                           "TPUv5e") for i in range(n)]
    links: Dict[Tuple[int, int], LinkSpec] = {}
    for i in range(n):
        for j in range(i + 1, n):
            same = (i // chips_per_pod) == (j // chips_per_pod)
            bw = (ici_GBps if same else dci_GBps) * 1e9
            links[(i, j)] = LinkSpec(alpha=1e-6 if same else 1e-4, beta=1.0 / bw)
    return ClusterSpec(devices, links)
