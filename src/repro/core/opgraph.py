"""OP-DAG intermediate representation (FusionLLM §3.3–3.4).

The model is a directed acyclic graph of operators.  Each node (``OpNode``)
is one operator (layer); each directed edge carries an ``OpData`` payload:
activations during forward propagation (FP) and boundary gradients during
backward propagation (BP).  The graph is partitioned into ``SubDag``s which
are deployed onto CompNodes (paper Table 2 / Table 3).

JAX mapping: every OpNode owns a pure ``init_fn(rng, *in_shapes) -> params``
and ``apply_fn(params, *inputs) -> output``.  The graph itself is
framework-agnostic metadata; execution happens in :mod:`repro.core.rad`
(stage-wise VJP chaining — the paper's remote automatic differentiation) and
:mod:`repro.core.executor` (the multi-CompNode event-driven runtime).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.check.errors import Finding, GraphCheckError

Shape = Tuple[int, ...]


class OpType(enum.Enum):
    """Operator classes from paper Table 2."""

    PLACEHOLDER = "placeholder"       # graph inputs (Input, Label, patch/frame embeds)
    VARIABLE = "variable"             # free tensors (paper's "Tensor A")
    PARAMETRIC = "parametric"         # has trainable params (Conv, Linear, Block, ...)
    NON_PARAMETRIC = "non_parametric" # pure function (ReLU, Add, reshape, ...)
    LOSS = "loss"                     # loss function (CE); BP root


@dataclasses.dataclass
class OpData:
    """Unified inter-operator message (paper §3.4).

    One instance is produced per (producer-op, micro-batch, iteration) and
    consumed by every OP user of that producer.  ``compress_cfg`` carries the
    compression meta-information negotiated by the broker for the link this
    message travels on.
    """

    name: str                         # originating OP node
    op_users: Tuple[str, ...]         # consumers of this output
    actual_op_user: Optional[str] = None  # for gradients: which user produced them
    is_loss: bool = False
    require_grad: bool = True
    local_iter: int = 0
    micro_batch: int = 0
    compress_cfg: Optional[Mapping[str, Any]] = None
    payload: Any = None               # the tensor (or compressed tuple)

    def nbytes(self) -> int:
        leaves = jax.tree_util.tree_leaves(self.payload)
        return int(sum(np.prod(l.shape) * np.dtype(l.dtype).itemsize for l in leaves))


@dataclasses.dataclass
class OpNode:
    """One operator in the OP-DAG.

    ``args`` lists the producer nodes whose outputs this op consumes, in
    positional order (paper Table 2 "Args").  ``init_fn``/``apply_fn`` are
    pure JAX functions; ``flops_fn`` returns the forward FLOP count given the
    input shapes (estimator C(f,p) numerator, paper §3.5); ``out_shape_fn``
    infers the output shape so the broker can size every edge *before*
    execution (needed for the α–β communication estimate and AdaTopK).
    """

    name: str
    op_type: OpType
    args: Tuple[str, ...] = ()
    init_fn: Optional[Callable[..., Any]] = None        # (rng, *in_shapes) -> params
    apply_fn: Optional[Callable[..., Any]] = None       # (params, *inputs) -> out
    out_shape_fn: Optional[Callable[..., Shape]] = None  # (*in_shapes) -> shape
    flops_fn: Optional[Callable[..., float]] = None      # (*in_shapes) -> flops
    out_dtype: Any = np.float32
    n_params_fn: Optional[Callable[..., int]] = None     # (*in_shapes) -> param count
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def is_parametric(self) -> bool:
        return self.op_type is OpType.PARAMETRIC

    def infer_out_shape(self, *in_shapes: Shape) -> Shape:
        if self.out_shape_fn is None:
            if len(in_shapes) == 1:
                return in_shapes[0]
            raise ValueError(f"op {self.name}: no out_shape_fn and {len(in_shapes)} inputs")
        return tuple(self.out_shape_fn(*in_shapes))

    def flops(self, *in_shapes: Shape) -> float:
        if self.flops_fn is None:
            return 0.0
        return float(self.flops_fn(*in_shapes))


class OpGraph:
    """The OP-DAG (paper Fig. 3).

    Nodes are held in insertion order; :meth:`topo_order` validates acyclicity.
    ``users`` is the reverse-edge map (paper Table 2 "OP users").
    """

    def __init__(self, name: str = "opgraph"):
        self.name = name
        self.nodes: Dict[str, OpNode] = {}

    # ------------------------------------------------------------- building
    def add(self, node: OpNode) -> OpNode:
        findings = []
        if node.name in self.nodes:
            findings.append(Finding(
                "duplicate-op", node.name,
                f"duplicate op name {node.name!r} in graph {self.name!r}"))
        for a in node.args:
            if a not in self.nodes:
                findings.append(Finding(
                    "dangling-dep", node.name,
                    f"op {node.name!r} arg {a!r} not yet defined "
                    "(add producers before consumers)"))
        if findings:
            raise GraphCheckError(
                f"cannot add op {node.name!r}", findings=findings)
        self.nodes[node.name] = node
        return node

    def __contains__(self, name: str) -> bool:
        return name in self.nodes

    def __getitem__(self, name: str) -> OpNode:
        return self.nodes[name]

    def __len__(self) -> int:
        return len(self.nodes)

    # ------------------------------------------------------------ structure
    @property
    def users(self) -> Dict[str, List[str]]:
        out: Dict[str, List[str]] = {n: [] for n in self.nodes}
        for node in self.nodes.values():
            for a in node.args:
                out[a].append(node.name)
        return out

    def topo_order(self) -> List[str]:
        """Kahn's algorithm; raises on cycles. Insertion order is the tiebreak
        so chains keep their natural layer order."""
        indeg = {n: len(self.nodes[n].args) for n in self.nodes}
        users = self.users
        ready = [n for n in self.nodes if indeg[n] == 0]
        order: List[str] = []
        while ready:
            n = ready.pop(0)
            order.append(n)
            for u in users[n]:
                indeg[u] -= 1
                if indeg[u] == 0:
                    ready.append(u)
        if len(order) != len(self.nodes):
            raise ValueError("OP-DAG contains a cycle")
        return order

    def placeholders(self) -> List[str]:
        return [n for n, v in self.nodes.items() if v.op_type is OpType.PLACEHOLDER]

    def loss_nodes(self) -> List[str]:
        return [n for n, v in self.nodes.items() if v.op_type is OpType.LOSS]

    def max_degree(self) -> int:
        """Paper Observation 1: deep-model DAG degree is usually small (<2)."""
        users = self.users
        return max([len(u) for u in users.values()] +
                   [len(v.args) for v in self.nodes.values()] + [0])

    # -------------------------------------------------------------- shapes
    def infer_shapes(self, input_shapes: Mapping[str, Shape]) -> Dict[str, Shape]:
        """Propagate shapes from placeholders through the DAG."""
        shapes: Dict[str, Shape] = {}
        for n in self.topo_order():
            node = self.nodes[n]
            if node.op_type is OpType.PLACEHOLDER:
                if n not in input_shapes:
                    raise ValueError(f"missing input shape for placeholder {n!r}")
                shapes[n] = tuple(input_shapes[n])
            elif node.op_type is OpType.VARIABLE:
                shapes[n] = tuple(node.meta["shape"])
            else:
                shapes[n] = node.infer_out_shape(*[shapes[a] for a in node.args])
        return shapes

    def annotate(self, input_shapes: Mapping[str, Shape],
                 activation_itemsize: int = 4) -> Dict[str, "OpProfile"]:
        """Per-op forward FLOPs + output bytes + param counts (broker-side
        profiling; feeds the workload estimator §3.5)."""
        shapes = self.infer_shapes(input_shapes)
        out: Dict[str, OpProfile] = {}
        for n in self.topo_order():
            node = self.nodes[n]
            in_shapes = [shapes[a] for a in node.args]
            flops = node.flops(*in_shapes)
            n_params = int(node.n_params_fn(*in_shapes)) if node.n_params_fn else 0
            out_bytes = int(np.prod(shapes[n])) * activation_itemsize if shapes[n] else 0
            out[n] = OpProfile(name=n, out_shape=shapes[n], fwd_flops=flops,
                               out_bytes=out_bytes, n_params=n_params)
        return out

    # ---------------------------------------------------------------- init
    def init(self, rng: jax.Array, input_shapes: Mapping[str, Shape]) -> Dict[str, Any]:
        """Initialize every parametric op; returns {op_name: params} pytree."""
        shapes = self.infer_shapes(input_shapes)
        params: Dict[str, Any] = {}
        for n in self.topo_order():
            node = self.nodes[n]
            if node.init_fn is None:
                continue
            rng, sub = jax.random.split(rng)
            params[n] = node.init_fn(sub, *[shapes[a] for a in node.args])
        return params

    # ------------------------------------------------------------- forward
    def apply(self, params: Mapping[str, Any], inputs: Mapping[str, Any],
              variables: Optional[Mapping[str, Any]] = None) -> Dict[str, Any]:
        """Run the full graph on one device; returns all op outputs.

        This is the single-node reference semantics; distributed execution
        (sub-DAGs + message passing) lives in rad.py / executor.py and must
        match this bit-for-bit (tested).
        """
        variables = variables or {}
        vals: Dict[str, Any] = {}
        for n in self.topo_order():
            node = self.nodes[n]
            if node.op_type is OpType.PLACEHOLDER:
                vals[n] = inputs[n]
            elif node.op_type is OpType.VARIABLE:
                vals[n] = variables[n]
            else:
                args = [vals[a] for a in node.args]
                p = params.get(n)
                vals[n] = node.apply_fn(p, *args) if node.apply_fn else args[0]
        return vals


@dataclasses.dataclass(frozen=True)
class OpProfile:
    name: str
    out_shape: Shape
    fwd_flops: float
    out_bytes: int
    n_params: int

    @property
    def bwd_flops(self) -> float:
        # Standard 2x-forward approximation for backprop (dL/dx and dL/dW).
        return 2.0 * self.fwd_flops

    @property
    def param_bytes(self) -> int:
        return self.n_params * 4


@dataclasses.dataclass
class SubDag:
    """A partition of the OP-DAG assigned to one CompNode (paper Table 3).

    The four derived edge sets drive message passing: during FP a CompNode
    waits for ``required_acti`` and pushes ``send_acti``; during BP it waits
    for ``required_grad`` (keyed ``producer->user`` since gradients must be
    identified by which OP generates them *and* which one needs them) and
    pushes ``send_grad``.
    """

    index: int
    node_names: List[str]
    required_acti: List[str] = dataclasses.field(default_factory=list)
    send_acti: List[str] = dataclasses.field(default_factory=list)
    required_grad: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    send_grad: List[Tuple[str, str]] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        self.node_set = set(self.node_names)
        if len(self.node_set) != len(self.node_names):
            seen: set = set()
            dup = next(n for n in self.node_names
                       if n in seen or seen.add(n))
            raise GraphCheckError(
                f"sub-DAG {self.index} is malformed",
                findings=[Finding(
                    "duplicate-op", dup,
                    f"op {dup!r} listed twice in sub-DAG {self.index}")])


def build_subdags(graph: OpGraph, assignment: Sequence[Sequence[str]]) -> List[SubDag]:
    """Derive Table-3 edge sets for a partition.

    ``assignment[k]`` is the list of op names on sub-DAG k.  Placeholders and
    loss nodes are ordinary ops here — the scheduler decides their placement
    (paper puts Input on CompNode 1 and Label/CE on the last one).
    """
    owner: Dict[str, int] = {}
    findings = []
    for k, names in enumerate(assignment):
        for n in names:
            if n in owner:
                findings.append(Finding(
                    "double-assignment", n,
                    f"op {n!r} assigned to sub-DAGs {owner[n]} and {k}"))
            elif n not in graph:
                findings.append(Finding(
                    "unknown-op", n,
                    f"op {n!r} on sub-DAG {k} is absent from the graph"))
            owner[n] = k
    for n in graph.nodes:
        if n not in owner:
            findings.append(Finding(
                "unassigned-op", n,
                f"op {n!r} is assigned to no sub-DAG"))
    if findings:
        raise GraphCheckError("partition does not cover the OP-DAG",
                              findings=findings)

    subdags = [SubDag(index=k, node_names=list(names))
               for k, names in enumerate(assignment)]
    for n, node in graph.nodes.items():
        for a in node.args:
            if owner[a] != owner[n]:
                producer_grad = graph.nodes[a].op_type not in (
                    OpType.PLACEHOLDER, OpType.VARIABLE)
                # FP: activation a -> n crosses CompNodes
                sd_p, sd_c = subdags[owner[a]], subdags[owner[n]]
                if a not in sd_p.send_acti:
                    sd_p.send_acti.append(a)
                if a not in sd_c.required_acti:
                    sd_c.required_acti.append(a)
                # BP: gradient (a,n) flows back n -> a, unless a is a leaf
                # that requires no gradient (Input / Label placeholders).
                if producer_grad:
                    sd_c.send_grad.append((a, n))
                    sd_p.required_grad.append((a, n))
    return subdags


def chain(graph: OpGraph) -> List[str]:
    """Return the topological order restricted to compute ops (the 'chain'
    view used by the chain partitioners; placeholders/variables excluded)."""
    return [n for n in graph.topo_order()
            if graph.nodes[n].op_type not in (OpType.PLACEHOLDER, OpType.VARIABLE)]
