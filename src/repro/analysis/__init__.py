from .hlo import collective_bytes, parse_hlo_computations, while_trip_counts
from .roofline import RooflineTerms, roofline_terms, HW
