"""Static HLO-text analysis: collective traffic accounting.

``cost_analysis()`` gives FLOPs/bytes but (a) omits collective traffic and
(b) counts a while-loop body once regardless of trip count (measured — see
EXPERIMENTS.md §Roofline methodology).  This module parses the compiled
module text:

* every computation's collective ops (all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute) with operand byte sizes;
* the computation call graph (while body/condition, fusion calls, to_apply);
* best-effort while trip counts (largest integer constant in the loop
  condition computation — exact for ``lax.scan``'s canonical counter);

and returns collective bytes with each computation weighted by the product
of trip counts on its call path.  The same multiplier machinery corrects
FLOPs/bytes from per-body cost analyses in the roofline harness.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(tok: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(tok):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Computation:
    name: str
    lines: List[str]
    collective_bytes: float = 0.0
    calls: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    # (callee_name, kind) kind in {"while_body", "while_cond", "call"}


_COLL_RE = re.compile(
    r"=\s*(?P<result>.*?)\s(?P<op>(?:%s)(?:-start|-done)?)\("
    % "|".join(_COLLECTIVES))


def _collective_line_bytes(line: str) -> Tuple[Optional[str], float]:
    """(kind, bytes) for a collective instruction line, else (None, 0).

    This HLO dialect prints operands without shapes, so we charge the
    RESULT shape — a consistent per-op traffic proxy (all-reduce moves ~2×
    this on a ring, all-gather ~(g-1)/g of it; constant factors documented
    in EXPERIMENTS.md §Roofline methodology).  Async ``-done`` halves are
    skipped to avoid double counting.
    """
    m = _COLL_RE.search(line)
    if not m:
        return None, 0.0
    op = m.group("op")
    if op.endswith("-done"):
        return None, 0.0
    kind = op.replace("-start", "")
    return kind, float(_shape_bytes(m.group("result")))


def parse_hlo_computations(text: str) -> Dict[str, Computation]:
    """Split module text into computations and extract collectives + calls."""
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.strip()
        if line.endswith("{") and "->" in line and "=" not in line.split(
                "(", 1)[0]:
            name = line.split("(", 1)[0].strip()
            if name.startswith("ENTRY"):
                name = name[len("ENTRY"):].strip()
            cur = Computation(name=name.lstrip("%"), lines=[])
            comps[cur.name] = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        cur.lines.append(line)
        _, nbytes = _collective_line_bytes(line)
        cur.collective_bytes += nbytes
        for wm in re.finditer(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)",
                              line):
            cur.calls.append((wm.group(1), "while_cond"))
            cur.calls.append((wm.group(2), "while_body"))
        for cm in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", line):
            cur.calls.append((cm.group(1), "call"))
    return comps


def while_trip_counts(comps: Dict[str, Computation]) -> Dict[str, int]:
    """body computation name -> inferred trip count (1 if unknown)."""
    trips: Dict[str, int] = {}
    for comp in comps.values():
        for line in comp.lines:
            wm = re.search(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)",
                           line)
            if not wm:
                continue
            cond, body = wm.group(1), wm.group(2)
            trip = 1
            cc = comps.get(cond)
            if cc:
                consts = [int(x) for l in cc.lines
                          for x in re.findall(r"constant\((\d+)\)", l)]
                if consts:
                    trip = max(consts)
            trips[body] = max(trips.get(body, 1), trip)
    return trips


def _multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    """Multiplicity of each computation = product of trip counts along the
    call chain from the entry."""
    trips = while_trip_counts(comps)
    # find entry: computation not called by anyone
    called = {callee for c in comps.values() for callee, _ in c.calls}
    entries = [c.name for c in comps.values() if c.name not in called]
    mult: Dict[str, float] = {}

    def visit(name: str, m: float):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        for callee, kind in comps[name].calls:
            child_m = m * trips.get(callee, 1) if kind == "while_body" else \
                (0.0 if kind == "while_cond" else m)
            if kind == "while_cond":
                child_m = m  # condition runs trip+1 times ~ trip; negligible
            visit(callee, child_m)

    for e in entries:
        visit(e, 1.0)
    return mult


def collective_bytes(text: str) -> float:
    """Total collective operand bytes, while-loop bodies weighted by trip
    count."""
    comps = parse_hlo_computations(text)
    mult = _multipliers(comps)
    return float(sum(c.collective_bytes * mult.get(c.name, 1.0)
                     for c in comps.values()))


def collective_breakdown(text: str) -> Dict[str, float]:
    """Per-collective-kind byte totals (trip-weighted)."""
    comps = parse_hlo_computations(text)
    mult = _multipliers(comps)
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for c in comps.values():
        m = mult.get(c.name, 1.0)
        for line in c.lines:
            kind, nbytes = _collective_line_bytes(line)
            if kind:
                out[kind] += nbytes * m
    return out
