"""Analytic MODEL_FLOPS per (arch × input shape).

The roofline table reports MODEL_FLOPS / HLO_FLOPs ("useful compute" ratio,
catches remat/redundancy waste).  MODEL_FLOPS counts only the mathematically
necessary work: matmul-type ops of the architecture itself, causal attention
at S·(S+1)/2 score pairs, MoE at active (top-k) expert FLOPs — 6·N·D-style
accounting generalized to every family.  Training = 3× forward (fwd + 2×bwd).
"""
from __future__ import annotations

from repro.configs.base import InputShape, ModelCfg
from repro.models import causal_lm
from repro.models.attention import attn_flops
from repro.models.layers import mlp_flops
from repro.models.moe import moe_flops
from repro.models.ssm import mamba_flops
from repro.models.xlstm import mlstm_flops, slstm_flops


def _attn(tokens: float, kv: float, cfg: ModelCfg, causal: bool) -> float:
    f = attn_flops(int(tokens), int(kv), cfg.d_model, cfg.n_heads,
                   cfg.n_kv_heads, cfg.head_dim)
    if causal:
        # remove half the score/value FLOPs (lower-triangular work only)
        scores = 2.0 * 2.0 * tokens * kv * cfg.n_heads * cfg.head_dim
        f -= scores / 2.0
    return f


def forward_flops(cfg: ModelCfg, batch: int, seq: int,
                  kv_len: float = None, decode: bool = False) -> float:
    """Whole-model forward FLOPs for ``batch`` sequences of ``seq`` new
    tokens (decode: seq=1, kv_len = cache depth)."""
    T = float(batch * seq)
    kv = float(kv_len if kv_len is not None else seq)
    eff_window = cfg.window or kv
    attn_kv = min(kv, eff_window)
    total = 2.0 * T * cfg.d_model * cfg.vocab_padded          # head

    if cfg.family == "encdec":
        S_src = kv_len if decode else max(seq // 8, 16)
        Tsrc = float(batch * S_src)
        per_enc = _attn(Tsrc, S_src, cfg, causal=False) \
            + mlp_flops(Tsrc, cfg.d_model, cfg.d_ff, cfg.act)
        per_dec = _attn(T, kv, cfg, causal=not decode) \
            + _attn(T, S_src, cfg, causal=False) \
            + mlp_flops(T, cfg.d_model, cfg.d_ff, cfg.act)
        enc = cfg.n_enc_layers * per_enc if not decode else 0.0
        return total + enc + cfg.n_dec_layers * per_dec

    if cfg.n_prefix and not decode:
        T = float(batch * (seq + cfg.n_prefix))
        kv = float(seq + cfg.n_prefix)
        total += 2.0 * batch * cfg.n_prefix * cfg.d_frontend * cfg.d_model

    for seg in causal_lm.segments(cfg):
        if seg.kind == "dense":
            per = _attn(T, attn_kv, cfg, causal=not decode) \
                + mlp_flops(T, cfg.d_model, cfg.d_ff, cfg.act)
            total += seg.count * per
        elif seg.kind == "moe":
            shared_ff = cfg.n_shared_experts * cfg.d_ff
            per = _attn(T, attn_kv, cfg, causal=not decode) \
                + moe_flops(T, cfg.d_model, cfg.d_ff, cfg.top_k, shared_ff)
            total += seg.count * per
        elif seg.kind in ("mamba",):
            total += seg.count * mamba_flops(T, causal_lm._mamba_cfg(cfg))
        elif seg.kind == "mlstm":
            total += seg.count * mlstm_flops(T, int(kv),
                                             causal_lm._xlstm_cfg(cfg))
        elif seg.kind == "zamba_group":
            mam = seg.inner * mamba_flops(T, causal_lm._mamba_cfg(cfg))
            sh_kv = kv if cfg.long_window is None else min(kv, cfg.long_window or kv)
            sh = _attn(T, kv if not decode else kv, cfg, causal=not decode) \
                + (mlp_flops(T, cfg.d_model, cfg.d_ff, cfg.act)
                   if cfg.d_ff else 0.0)
            total += seg.count * (mam + sh)
        elif seg.kind == "xlstm_group":
            xc = causal_lm._xlstm_cfg(cfg)
            total += seg.count * ((seg.inner - 1) * mlstm_flops(T, int(kv), xc)
                                  + slstm_flops(T, xc))
    return total


def model_flops(cfg: ModelCfg, shape: InputShape) -> float:
    """MODEL_FLOPS for one step of the shape's kind."""
    if shape.kind == "train":
        fwd = forward_flops(cfg, shape.global_batch, shape.seq_len)
        return 3.0 * fwd
    if shape.kind == "prefill":
        return forward_flops(cfg, shape.global_batch, shape.seq_len)
    # decode: one token, cache depth = seq_len
    from repro.distributed.steps import decode_window
    w = decode_window(cfg, shape)
    kv = min(shape.seq_len, w) if w else shape.seq_len
    return forward_flops(cfg, shape.global_batch, 1, kv_len=kv, decode=True)


def six_nd(cfg: ModelCfg, tokens: float) -> float:
    """Classic 6·N·D (N = matmul params; MoE uses active params)."""
    from repro.models import encdec as encdec_mod
    if cfg.family == "encdec":
        n = encdec_mod.count_params(cfg)
    else:
        n = causal_lm.count_params(cfg)
        n -= cfg.vocab_padded * cfg.d_model      # embed lookup isn't matmul
        if cfg.rope_fraction == 0.0:
            n -= cfg.max_seq * cfg.d_model
        if cfg.tie_embeddings:
            n += cfg.vocab_padded * cfg.d_model  # head matmul still happens
    if cfg.family == "moe":
        inactive = (cfg.n_experts - cfg.top_k) * cfg.d_model * cfg.d_ff * 3
        n -= cfg.n_layers * inactive
    return 6.0 * n * tokens
