"""Roofline terms from compiled-artifact statistics (deliverable g).

Hardware constants (TPU v5e, per system assignment):
    197 TFLOP/s bf16 per chip · 819 GB/s HBM · ~50 GB/s/link ICI.

    compute term    = HLO_FLOPs / (chips × peak)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()`` per-device (XLA reports
the per-partition program) — multiplied by chips to get totals, they cancel
back out in the terms; we therefore feed *per-device* numbers with chips=1
semantics and document it.  collective_bytes comes from the HLO text parse
(repro.analysis.hlo) and is per-device too.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class HWSpec:
    peak_flops: float = 197e12      # bf16 / chip
    hbm_bw: float = 819e9           # bytes/s
    link_bw: float = 50e9           # bytes/s/link ICI


HW = HWSpec()


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_accessed: float
    collective_bytes: float
    model_flops: Optional[float] = None

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        if self.model_flops is None or self.flops <= 0:
            return None
        return self.model_flops / self.flops

    def as_row(self) -> Dict[str, float]:
        return {"compute_s": self.compute_s, "memory_s": self.memory_s,
                "collective_s": self.collective_s, "dominant": self.dominant,
                "flops": self.flops, "bytes": self.bytes_accessed,
                "coll_bytes": self.collective_bytes,
                "model_flops": self.model_flops,
                "useful_ratio": self.useful_flops_ratio}


def roofline_terms(per_device_flops: float, per_device_bytes: float,
                   per_device_collective_bytes: float,
                   model_flops_total: Optional[float] = None,
                   chips: int = 1, hw: HWSpec = HW) -> RooflineTerms:
    """All inputs per-device (XLA's view of the partitioned program);
    ``model_flops_total`` is the whole-model 6ND figure and gets divided by
    ``chips`` for the useful-compute ratio."""
    return RooflineTerms(
        compute_s=per_device_flops / hw.peak_flops,
        memory_s=per_device_bytes / hw.hbm_bw,
        collective_s=per_device_collective_bytes / hw.link_bw,
        flops=per_device_flops,
        bytes_accessed=per_device_bytes,
        collective_bytes=per_device_collective_bytes,
        model_flops=(model_flops_total / chips
                     if model_flops_total is not None else None))
