from .synthetic import (SyntheticLM, SyntheticImages, SyntheticSeq2Seq,
                        make_batch_iterator)
from .loader import ShardedLoader
