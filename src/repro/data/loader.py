"""Shard-aware device feeding.

Host-side numpy batches -> device arrays laid out per the step's
in_shardings.  On a multi-host pod each host would feed its addressable
shard (``jax.make_array_from_process_local_data``); in this single-process
container that path degenerates to ``jax.device_put`` with the target
sharding, which is exactly what we do.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterator, Mapping, Optional

import jax
import numpy as np


class ShardedLoader:
    def __init__(self, batch_iter: Iterator[Dict[str, np.ndarray]],
                 shardings: Optional[Mapping[str, Any]] = None):
        self._it = batch_iter
        self._shardings = shardings or {}

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, jax.Array]:
        host = next(self._it)
        out = {}
        for k, v in host.items():
            sh = self._shardings.get(k)
            out[k] = jax.device_put(v, sh) if sh is not None else jax.device_put(v)
        return out
