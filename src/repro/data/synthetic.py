"""Synthetic-but-learnable datasets.

The paper trains on CIFAR-10 / TinyImageNet / WikiText-2; offline we need
datasets with real structure so convergence comparisons (dense vs uniform
TopK vs AdaTopK, paper Fig. 8) are meaningful, not noise:

* :class:`SyntheticLM` — order-2 Markov language: next token is a fixed
  random function of the two previous tokens plus noise.  A model must learn
  the transition table; loss floors well below log(vocab).
* :class:`SyntheticImages` — class templates + Gaussian noise; labels are
  recoverable by any conv/MLP classifier.
* :class:`SyntheticSeq2Seq` — "translation": target = source tokens mapped
  through a fixed permutation, reversed; source embeddings synthesized from
  the source tokens (stands in for the stubbed audio frontend).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    seed: int = 0
    noise: float = 0.1      # fraction of random tokens
    order: int = 2          # Markov order (1 = easier, learns in ~100 steps)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        if self.order == 1:
            self.table = rng.integers(0, self.vocab, size=(self.vocab,))
        else:
            self.table = rng.integers(0, self.vocab,
                                      size=(self.vocab, self.vocab))

    def batch(self, batch_size: int, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(self.seed + 7919 * step + 1)
        toks = np.empty((batch_size, self.seq_len + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=batch_size)
        toks[:, 1] = rng.integers(0, self.vocab, size=batch_size)
        for t in range(2, self.seq_len + 1):
            if self.order == 1:
                nxt = self.table[toks[:, t - 1]]
            else:
                nxt = self.table[toks[:, t - 2], toks[:, t - 1]]
            noise_mask = rng.random(batch_size) < self.noise
            nxt = np.where(noise_mask,
                           rng.integers(0, self.vocab, size=batch_size), nxt)
            toks[:, t] = nxt
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}


@dataclasses.dataclass
class SyntheticImages:
    n_classes: int = 10
    hw: int = 32
    channels: int = 3
    seed: int = 0
    noise: float = 0.5

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.templates = rng.normal(
            size=(self.n_classes, self.hw, self.hw, self.channels)).astype(
                np.float32)

    def batch(self, batch_size: int, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(self.seed + 104729 * step + 1)
        y = rng.integers(0, self.n_classes, size=batch_size)
        x = self.templates[y] + self.noise * rng.normal(
            size=(batch_size, self.hw, self.hw, self.channels)).astype(
                np.float32)
        return {"images": x.astype(np.float32), "labels": y.astype(np.int32)}


@dataclasses.dataclass
class SyntheticSeq2Seq:
    vocab: int
    src_len: int
    tgt_len: int
    d_frontend: int
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.perm = rng.permutation(self.vocab)
        self.frontend = rng.normal(
            size=(self.vocab, self.d_frontend)).astype(np.float32) * 0.5

    def batch(self, batch_size: int, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(self.seed + 611953 * step + 1)
        src = rng.integers(0, self.vocab, size=(batch_size, self.src_len))
        # target: permuted source, repeated/truncated to tgt_len, shifted
        mapped = self.perm[src][:, ::-1]
        reps = -(-(self.tgt_len + 1) // self.src_len)
        tgt = np.tile(mapped, (1, reps))[:, :self.tgt_len + 1]
        return {"src_embeds": self.frontend[src],
                "tokens": tgt[:, :-1].astype(np.int32),
                "labels": tgt[:, 1:].astype(np.int32)}


def make_batch_iterator(ds, batch_size: int, start_step: int = 0
                        ) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield ds.batch(batch_size, step)
        step += 1
