"""Broker-side timing telemetry (elastic runtime).

PR 1's straggler detector was fed ``predict_step_times`` evaluated on the
*ground-truth* cluster — i.e. the broker observed its own estimator, not the
system.  This module closes the loop on measured pace: the executors emit
per-stage, per-micro-batch :class:`repro.core.executor.StepTiming` samples
(`simulate_iteration` stamps simulated seconds; ``DecentralizedRuntime``
stamps measured host wall-clock), and the broker's :class:`TelemetryLog`
aggregates them into the per-CompNode step times that
:meth:`repro.elastic.detector.StragglerDetector.observe` consumes.

Aggregation is deliberately robust, because real volunteer timings are
noisy (GC pauses, page faults, transient congestion):

* per step, a node's samples are folded into one FP+BP seconds value per
  micro-batch (``Σ samples / n_micro`` — the unit ``predict_step_times``
  predicts);
* across the last ``window`` steps, outliers are rejected by the
  median-absolute-deviation rule (|x − median| > k·MAD) and the median of
  the survivors is reported.

A single spiked step therefore cannot flag a healthy node (tested), while a
genuine slowdown shifts the whole window and surfaces within ``window``
steps.  ``predict_step_times`` remains the detector's reference *prediction*
only — the observation path is telemetry, end to end.

Beyond node step times, the log also aggregates **per-link** transfer
observations (:class:`repro.core.executor.LinkTiming`): per step, each
directed CompNode pair's transfers fold into one ``(bytes, seconds)`` total,
and :meth:`TelemetryLog.link_samples` reports the MAD-filtered window of
those totals — the exact input
:func:`repro.core.costmodel.fit_link_corrections` needs to calibrate the
planner's α–β model against the wire the traffic actually rode.  That is the
observation half of the closed planning loop; the controller owns the fit,
hysteresis, and re-plan trigger.

The same machinery carries **per-device codec** observations
(:class:`repro.core.executor.KernelTiming`): per step, each device's encode
invocations fold into one ``(dense_bytes, seconds)`` total, and
:meth:`TelemetryLog.kernel_samples` reports the MAD-filtered window of
per-invocation means — the input
:func:`repro.core.costmodel.fit_kernel_costs` needs to price
``EdgeCostModel.compress_seconds`` from what the kernels actually cost on
this host, closing the planner's encode-vs-wire profitability loop.

Since the observability layer landed, ``TelemetryLog`` is one subscriber on
the controller's :class:`repro.obs.bus.TelemetryBus` rather than the sole
consumer of executor samples: the bus fans each ``StepTiming``/``LinkTiming``
out to every subscriber (this log, the metrics registry sink, …) with
per-sample semantics identical to feeding the log directly — bus-fed and
direct-fed logs agree bit for bit (tested).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.executor import KernelTiming, LinkTiming, StepTiming


def _robust_window_stat(values: Sequence[float], mad_k: float) -> float:
    """Median of the window after MAD outlier rejection.

    With < 3 samples there is nothing to reject against — return the plain
    median.  MAD of 0 (constant window) keeps only exact-median samples,
    which is the correct degenerate behaviour: one spike in an otherwise
    constant window is rejected outright.
    """
    x = np.asarray(values, dtype=np.float64)
    if x.size < 3:
        return float(np.median(x))
    med = float(np.median(x))
    mad = float(np.median(np.abs(x - med)))
    keep = np.abs(x - med) <= mad_k * mad
    if not np.any(keep):
        return med
    return float(np.median(x[keep]))


@dataclasses.dataclass
class _NodeSeries:
    """Per-node history: one aggregated seconds value per observed step."""

    steps: List[int] = dataclasses.field(default_factory=list)
    seconds: List[float] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _KernelSeries:
    """Per-device codec history: per observed step, the total dense payload
    bytes the device's encode kernels read, the total seconds they took, and
    the invocation count.  As with links, the calibration pair reported per
    step is the per-invocation *mean* ``(B/K, S/K)`` — exact under the
    affine ``α + dense_bytes/bw`` kernel cost model."""

    steps: List[int] = dataclasses.field(default_factory=list)
    nbytes: List[float] = dataclasses.field(default_factory=list)
    seconds: List[float] = dataclasses.field(default_factory=list)
    counts: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _LinkSeries:
    """Per-directed-link history: per observed step, the total wire bytes the
    link carried, the total transport seconds they took, and the number of
    transfers folded in.  The count matters: K transfers pay K α's, so the
    calibration pair reported per step is the *mean* transfer ``(B/K, S/K)``
    — exact under the affine α–β model (``Σ(α+β·bₖ)/K = α + β·(Σbₖ/K)``),
    whereas the raw total would inflate every healthy link by (K−1)·α."""

    steps: List[int] = dataclasses.field(default_factory=list)
    nbytes: List[float] = dataclasses.field(default_factory=list)
    seconds: List[float] = dataclasses.field(default_factory=list)
    counts: List[int] = dataclasses.field(default_factory=list)


class TelemetryLog:
    """Sliding-window aggregator from raw StepTiming samples to the
    per-CompNode step times the straggler detector observes.

    ``record`` accepts samples in any order within a step; ``node_step_times``
    reports, per node, the robust (median-of-window, MAD outlier-rejected)
    per-micro-batch FP+BP seconds over the last ``window`` distinct steps.
    ``record_step`` bulk-records a list of samples re-stamped to one step —
    the controller's path for cached simulator samples.
    """

    def __init__(self, window: int = 5, mad_k: float = 3.5,
                 history_steps: int = 64):
        if window < 1:
            raise ValueError("window must be >= 1")
        self.window = int(window)
        self.mad_k = float(mad_k)
        self.history_steps = max(int(history_steps), self.window)
        # (node, step) -> [total seconds, set of micro-batch indices]
        self._acc: Dict[Tuple[int, int], List] = {}
        self._series: Dict[int, _NodeSeries] = {}
        self._links: Dict[Tuple[int, int], _LinkSeries] = {}
        self._kernels: Dict[int, _KernelSeries] = {}
        self.n_samples = 0
        self.n_link_samples = 0
        self.n_kernel_samples = 0

    # ------------------------------------------------------------ recording
    def record(self, sample: StepTiming) -> None:
        key = (int(sample.node), int(sample.step))
        slot = self._acc.get(key)
        if slot is None:
            slot = self._acc[key] = [0.0, set()]
        slot[0] += float(sample.seconds)
        slot[1].add((int(sample.micro_batch)))
        self.n_samples += 1
        self._fold(key, slot)

    def record_step(self, samples: Iterable[StepTiming], step: int) -> None:
        for s in samples:
            self.record(dataclasses.replace(s, step=step))

    def record_link(self, sample: LinkTiming) -> None:
        """Fold one per-transfer link observation into the (src, dst) link's
        per-step (bytes, seconds) totals."""
        key = (int(sample.src), int(sample.dst))
        step = int(sample.step)
        series = self._links.setdefault(key, _LinkSeries())
        if series.steps and series.steps[-1] == step:
            series.nbytes[-1] += float(sample.nbytes)
            series.seconds[-1] += float(sample.seconds)
            series.counts[-1] += 1
        else:
            series.steps.append(step)
            series.nbytes.append(float(sample.nbytes))
            series.seconds.append(float(sample.seconds))
            series.counts.append(1)
            if len(series.steps) > self.history_steps:
                del series.steps[:-self.history_steps]
                del series.nbytes[:-self.history_steps]
                del series.seconds[:-self.history_steps]
                del series.counts[:-self.history_steps]
        self.n_link_samples += 1

    def record_link_step(self, samples: Iterable[LinkTiming],
                         step: int) -> None:
        for s in samples:
            self.record_link(dataclasses.replace(s, step=step))

    def record_kernel(self, sample: KernelTiming) -> None:
        """Fold one per-invocation codec observation into the device's
        per-step (dense bytes, seconds) totals."""
        key = int(sample.node)
        step = int(sample.step)
        series = self._kernels.setdefault(key, _KernelSeries())
        if series.steps and series.steps[-1] == step:
            series.nbytes[-1] += float(sample.nbytes)
            series.seconds[-1] += float(sample.seconds)
            series.counts[-1] += 1
        else:
            series.steps.append(step)
            series.nbytes.append(float(sample.nbytes))
            series.seconds.append(float(sample.seconds))
            series.counts.append(1)
            if len(series.steps) > self.history_steps:
                del series.steps[:-self.history_steps]
                del series.nbytes[:-self.history_steps]
                del series.seconds[:-self.history_steps]
                del series.counts[:-self.history_steps]
        self.n_kernel_samples += 1

    def record_kernel_step(self, samples: Iterable[KernelTiming],
                           step: int) -> None:
        for s in samples:
            self.record_kernel(dataclasses.replace(s, step=step))

    def _fold(self, key: Tuple[int, int], slot: List) -> None:
        """Fold the (node, step) accumulator into the node's series: total
        seconds normalized per micro-batch (the estimator's prediction unit).
        Idempotent per step — later samples for the same step update the
        entry in place."""
        node, step = key
        per_mb = slot[0] / max(1, len(slot[1]))
        series = self._series.setdefault(node, _NodeSeries())
        if series.steps and series.steps[-1] == step:
            series.seconds[-1] = per_mb
        else:
            series.steps.append(step)
            series.seconds.append(per_mb)
            if len(series.steps) > self.history_steps:
                del series.steps[:-self.history_steps]
                del series.seconds[:-self.history_steps]
        # accumulators for steps that scrolled out of history are dropped
        if len(self._acc) > 4 * self.history_steps * max(1, len(self._series)):
            horizon = step - self.history_steps
            self._acc = {k: v for k, v in self._acc.items()
                         if k[1] >= horizon}

    # ----------------------------------------------------------- aggregates
    def nodes(self) -> List[int]:
        return sorted(self._series)

    def node_step_times(self) -> Dict[int, float]:
        """Per-node robust step seconds over the aggregation window — the
        mapping ``StragglerDetector.observe`` consumes."""
        out: Dict[int, float] = {}
        for node, series in self._series.items():
            if not series.seconds:
                continue
            out[node] = _robust_window_stat(series.seconds[-self.window:],
                                            self.mad_k)
        return out

    def link_samples(self, min_steps: int = 3
                     ) -> Dict[Tuple[int, int], List[Tuple[float, float]]]:
        """MAD-filtered ``(nbytes, seconds)`` transfer samples per directed
        link over the aggregation window — the calibration input of
        :func:`repro.core.costmodel.fit_link_corrections`.

        Outlier rejection mirrors :func:`_robust_window_stat`, applied to the
        per-byte pace (seconds per byte) so windows mixing payload sizes are
        judged on the link's rate, not on payload-driven duration swings.
        Links with fewer than ``min_steps`` window entries are withheld: a
        correction fitted from one or two steps is exactly the noisy single
        window hysteresis exists to reject.
        """
        out: Dict[Tuple[int, int], List[Tuple[float, float]]] = {}
        for key, series in self._links.items():
            nb = series.nbytes[-self.window:]
            sec = series.seconds[-self.window:]
            cnt = series.counts[-self.window:]
            if len(nb) < max(1, int(min_steps)):
                continue
            pairs = [(b / k, s / k) for b, s, k in zip(nb, sec, cnt)]
            if len(pairs) >= 3:
                pace = np.array([s / max(b, 1.0) for b, s in pairs])
                med = float(np.median(pace))
                mad = float(np.median(np.abs(pace - med)))
                keep = np.abs(pace - med) <= self.mad_k * mad
                if np.any(keep):
                    pairs = [p for p, k in zip(pairs, keep) if k]
            out[key] = pairs
        return out

    def kernel_samples(self, min_steps: int = 3
                       ) -> Dict[int, List[Tuple[float, float]]]:
        """MAD-filtered ``(dense_bytes, seconds)`` codec samples per device
        over the aggregation window — the calibration input of
        :func:`repro.core.costmodel.fit_kernel_costs`.

        Mirrors :meth:`link_samples` exactly: outliers are rejected on the
        per-byte pace, and devices with fewer than ``min_steps`` window
        entries are withheld so a one-step spike never becomes a fitted cost.
        """
        out: Dict[int, List[Tuple[float, float]]] = {}
        for key, series in self._kernels.items():
            nb = series.nbytes[-self.window:]
            sec = series.seconds[-self.window:]
            cnt = series.counts[-self.window:]
            if len(nb) < max(1, int(min_steps)):
                continue
            pairs = [(b / k, s / k) for b, s, k in zip(nb, sec, cnt)]
            if len(pairs) >= 3:
                pace = np.array([s / max(b, 1.0) for b, s in pairs])
                med = float(np.median(pace))
                mad = float(np.median(np.abs(pace - med)))
                keep = np.abs(pace - med) <= self.mad_k * mad
                if np.any(keep):
                    pairs = [p for p, k in zip(pairs, keep) if k]
            out[key] = pairs
        return out

    def latest_step(self) -> Optional[int]:
        steps = [s.steps[-1] for s in self._series.values() if s.steps]
        return max(steps) if steps else None

    def clear(self) -> None:
        """Drop all history — called at every re-plan: a new schedule changes
        every stage's expected time, so old samples must not carry over.
        Link samples are dropped too (the new schedule routes different
        payloads over different wires); installed corrections live on the
        controller and survive."""
        self._acc.clear()
        self._series.clear()
        self._links.clear()
        self._kernels.clear()
        self.n_samples = 0
        self.n_link_samples = 0
        self.n_kernel_samples = 0
