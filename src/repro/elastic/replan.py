"""Incremental re-scheduling + migration planning (elastic runtime).

On a membership epoch change the broker re-runs OP-Fence on the surviving /
updated topology (``schedule_opfence(..., device_subset=alive)``), diffs the
old and new stage assignments, and emits the *minimal* migration plan: only
ops whose owner changed move, each carrying its parameters plus optimizer
state.  Transfer cost is estimated over the real α–β link specs by the
discrete-event :func:`repro.core.executor.simulate_migration`; ops stranded
on a dead CompNode stream from the broker's checkpoint store instead (a dead
node cannot send).

Migration payloads are never lossy-compressed: AdaTopK is for per-step
boundary tensors where error feedback and training itself absorb the loss;
migrated parameters/optimizer state must land bit-exact or the loss curve
jumps (see migrate.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.estimator import ClusterSpec, LinkSpec
from repro.core.executor import (CHECKPOINT_LINK, MigrationSim,
                                 simulate_migration)
from repro.core.opgraph import OpGraph, OpProfile
from repro.core.opgraph import chain as op_chain
from repro.core.partition import partition_min_bottleneck
from repro.core.scheduler import (Schedule, _to_full_assignment,
                                  schedule_opfence)


@dataclasses.dataclass(frozen=True)
class OpMove:
    """One op segment changing owner.  ``src=None`` — original owner dead,
    state comes from the broker's checkpoint store."""

    op: str
    src: Optional[int]
    dst: int
    nbytes: int          # params + optimizer state on the wire

    @property
    def from_checkpoint(self) -> bool:
        return self.src is None


@dataclasses.dataclass
class MigrationPlan:
    """Diff between two schedules, grouped into per-link bulk transfers."""

    moves: List[OpMove]
    sim: MigrationSim

    @property
    def total_bytes(self) -> float:
        return float(sum(m.nbytes for m in self.moves))

    @property
    def seconds(self) -> float:
        return self.sim.seconds

    def transfers(self) -> Dict[Tuple[Optional[int], int], float]:
        return _group_transfers(self.moves)


@dataclasses.dataclass
class ReplanResult:
    schedule: Schedule
    migration: MigrationPlan
    alive: List[int]
    dead: List[int]
    mode: str = "full"           # which candidate won: full | anchored


def state_bytes(profile: OpProfile, opt_state_mult: float = 2.0,
                param_itemsize: int = 4) -> int:
    """Wire bytes to relocate one op: params + optimizer state (AdamW keeps
    two fp32 moments per parameter -> mult 2.0; SGD momentum 1.0)."""
    return int(profile.n_params * param_itemsize * (1.0 + opt_state_mult))


def diff_schedules(old: Schedule, new: Schedule,
                   profiles: Mapping[str, OpProfile],
                   dead: Sequence[int] = (),
                   opt_state_mult: float = 2.0) -> List[OpMove]:
    """Ops whose owner changed, in graph order.  Ops with no trainable state
    (placeholders, activations-only ops) still move but cost zero bytes —
    re-binding ownership is a control-plane action."""
    dead_set = set(int(d) for d in dead)
    old_place, new_place = old.placement, new.placement
    moves: List[OpMove] = []
    for op, src in old_place.items():
        dst = new_place.get(op)
        if dst is None or dst == src:
            continue
        nbytes = state_bytes(profiles[op], opt_state_mult) \
            if op in profiles else 0
        moves.append(OpMove(op=op, src=None if src in dead_set else src,
                            dst=dst, nbytes=nbytes))
    return moves


def _group_transfers(moves: Sequence[OpMove]
                     ) -> Dict[Tuple[Optional[int], int], float]:
    out: Dict[Tuple[Optional[int], int], float] = {}
    for m in moves:
        key = (m.src, m.dst)
        out[key] = out.get(key, 0.0) + float(m.nbytes)
    return out


def interim_schedule(graph: OpGraph, old: Schedule, dead: Sequence[int],
                     n_devices: int) -> Optional[Schedule]:
    """Cheapest runnable schedule after a failure (overlapped migration).

    The old schedule with each dead stage's op segment merged into an
    adjacent *surviving* stage (the predecessor when one exists, else the
    first survivor downstream).  Segments are contiguous chain runs in stage
    order, so merging a run into its neighbour keeps every stage's sub-DAG
    connected.  Only the dead segments' state must stream in (from the
    broker's checkpoint store) before training resumes on this schedule;
    every other op stays put — the rest of the re-plan drains in the
    background.  Returns None when no stage survives.
    """
    dead_set = {int(d) for d in dead}
    out_devs: List[int] = []
    out_segs: List[List[str]] = []
    pending: List[str] = []    # dead segments preceding the first survivor
    for dev in old.stage_devices():
        seg = list(old.assignment[dev])
        if dev in dead_set:
            if out_segs:
                out_segs[-1].extend(seg)
            else:
                pending.extend(seg)
        else:
            out_devs.append(dev)
            out_segs.append(pending + seg)
            pending = []
    if not out_devs:
        return None
    a, s = _to_full_assignment(out_segs, out_devs, n_devices)
    return Schedule(assignment=a, stages=s, clusters=old.clusters)


def _anchored_schedule(graph: OpGraph, profiles: Mapping[str, OpProfile],
                       cluster: ClusterSpec, old_schedule: Schedule,
                       alive: Sequence[int], joined: Sequence[int],
                       edge_bytes_scale: Optional[Mapping[int, float]]
                       ) -> Optional[Schedule]:
    """Stability-preferring candidate: keep the surviving stage order from
    the old schedule (append joiners at the tail) and re-run only the DP
    split.  Most segment boundaries barely move, so the migration diff stays
    near the dead node's own shard instead of reshuffling the whole model —
    a fresh OP-Fence pass re-cuts every boundary and can move everything.
    """
    alive_set = set(int(a) for a in alive)
    order = [d for d in old_schedule.stage_devices() if d in alive_set]
    order += [int(j) for j in joined
              if j in alive_set and j not in set(order)]
    n_ops = len(op_chain(graph))
    order = order[:max(1, min(len(order), n_ops))]
    if not order:
        return None
    segs, pace = partition_min_bottleneck(graph, profiles, cluster, order,
                                          edge_bytes_scale=edge_bytes_scale)
    a, s = _to_full_assignment(segs, order, len(cluster))
    return Schedule(assignment=a, stages=s, clusters=old_schedule.clusters,
                    predicted_pace=pace)


def replan(graph: OpGraph, profiles: Mapping[str, OpProfile],
           cluster: ClusterSpec, old_schedule: Schedule,
           alive: Sequence[int], dead: Sequence[int] = (),
           joined: Sequence[int] = (), seed: int = 0,
           opt_state_mult: float = 2.0,
           checkpoint_link: LinkSpec = CHECKPOINT_LINK,
           edge_bytes_scale: Optional[Mapping[int, float]] = None,
           mode: str = "auto", amortize_steps: float = 100.0
           ) -> ReplanResult:
    """Incremental re-scheduling with a migration-aware candidate choice.

    Two candidates: ``full`` re-runs OP-Fence from scratch on the survivors
    (best steady-state pace, potentially huge migration); ``anchored`` keeps
    the surviving stage order and only re-cuts the DP split (near-minimal
    migration, possibly worse pace).  ``mode='auto'`` picks the one with the
    lower total cost  ``migration_seconds + amortize_steps · pace`` — i.e.
    a pace advantage must pay back its migration bill within
    ``amortize_steps`` future micro-batches or stability wins.

    ``cluster`` is the broker's *believed* topology (degraded λ_p for flagged
    stragglers already folded in via ``network.with_slowdowns``); ``alive``
    restricts placement; ``dead`` marks nodes whose state is unrecoverable
    from the node itself; ``joined`` lists newly admitted CompNodes (the
    anchored candidate appends them at the pipeline tail).
    """
    if mode not in ("auto", "full", "anchored"):
        raise ValueError(f"unknown replan mode {mode!r}")
    candidates: Dict[str, Schedule] = {}
    if mode in ("auto", "full"):
        candidates["full"] = schedule_opfence(
            graph, profiles, cluster, seed=seed,
            edge_bytes_scale=edge_bytes_scale, device_subset=alive)
    if mode in ("auto", "anchored"):
        anchored = _anchored_schedule(graph, profiles, cluster, old_schedule,
                                      alive, joined, edge_bytes_scale)
        if anchored is not None:
            candidates["anchored"] = anchored
    if not candidates:
        raise RuntimeError("no feasible re-plan candidate")

    best: Optional[Tuple[float, str, Schedule, List[OpMove], Any]] = None
    for name, sched in sorted(candidates.items()):
        moves = diff_schedules(old_schedule, sched, profiles, dead=dead,
                               opt_state_mult=opt_state_mult)
        sim = simulate_migration(_group_transfers(moves), cluster,
                                 checkpoint_link=checkpoint_link)
        pace = sched.predicted_pace if sched.predicted_pace is not None \
            else float("inf")
        cost = sim.seconds + amortize_steps * pace
        if best is None or cost < best[0]:
            best = (cost, name, sched, moves, sim)
    _, name, sched, moves, sim = best
    return ReplanResult(schedule=sched,
                        migration=MigrationPlan(moves=moves, sim=sim),
                        alive=sorted(int(a) for a in alive),
                        dead=sorted(int(d) for d in dead), mode=name)
