"""Incremental re-scheduling + migration planning (elastic runtime).

On a membership epoch change the broker re-runs OP-Fence on the surviving /
updated topology (``schedule_opfence(..., device_subset=alive)``), diffs the
old and new stage assignments, and emits the *minimal* migration plan: only
ops whose owner changed move, each carrying its parameters plus optimizer
state.  Transfer cost is estimated over the real α–β link specs by the
discrete-event :func:`repro.core.executor.simulate_migration`; ops stranded
on a dead CompNode stream from the broker's checkpoint store instead (a dead
node cannot send).

Migration payloads are never lossy-compressed: AdaTopK is for per-step
boundary tensors where error feedback and training itself absorb the loss;
migrated parameters/optimizer state must land bit-exact or the loss curve
jumps (see migrate.py).

``pin_boundaries=True`` hardens the anchored candidate: segment boundaries
are frozen at the old schedule's inter-cluster (WAN) cuts, and the DP re-cut
runs independently inside each bandwidth cluster — so no op (hence no
parameter/optimizer shard) ever migrates across a WAN link, the exact
traffic class overlapped migration cannot hide (the stream rides the same
wire the pipeline is bottlenecked by).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.costmodel import EdgeCostModel
from repro.core.estimator import ClusterSpec, LinkSpec
from repro.core.executor import (CHECKPOINT_LINK, MigrationSim,
                                 simulate_migration)
from repro.core.opgraph import OpGraph, OpProfile
from repro.core.opgraph import chain as op_chain
from repro.core.partition import (attach_sources, min_bottleneck_chain,
                                  partition_min_bottleneck)
from repro.core.scheduler import (Schedule, _to_full_assignment,
                                  louvain_communities, schedule_joint,
                                  schedule_opfence)


@dataclasses.dataclass(frozen=True)
class OpMove:
    """One op segment changing owner.  ``src=None`` — original owner dead,
    state comes from the broker's checkpoint store."""

    op: str
    src: Optional[int]
    dst: int
    nbytes: int          # params + optimizer state on the wire

    @property
    def from_checkpoint(self) -> bool:
        return self.src is None


@dataclasses.dataclass
class MigrationPlan:
    """Diff between two schedules, grouped into per-link bulk transfers."""

    moves: List[OpMove]
    sim: MigrationSim

    @property
    def total_bytes(self) -> float:
        return float(sum(m.nbytes for m in self.moves))

    @property
    def seconds(self) -> float:
        return self.sim.seconds

    def transfers(self) -> Dict[Tuple[Optional[int], int], float]:
        return _group_transfers(self.moves)


@dataclasses.dataclass
class ReplanResult:
    schedule: Schedule
    migration: MigrationPlan
    alive: List[int]
    dead: List[int]
    mode: str = "full"           # which candidate won: full | anchored | keep
    # every candidate as priced by the migration-aware choice, in scoring
    # order: {name, pace, migration_bytes, migration_seconds, score, winner}.
    # Plain dicts (not obs dataclasses) so this layer stays import-light; the
    # controller's flight recorder lifts them into CandidateScore records.
    scores: List[Dict[str, Any]] = dataclasses.field(default_factory=list)


def state_bytes(profile: OpProfile, opt_state_mult: float = 2.0,
                param_itemsize: int = 4) -> int:
    """Wire bytes to relocate one op: params + optimizer state (AdamW keeps
    two fp32 moments per parameter -> mult 2.0; SGD momentum 1.0)."""
    return int(profile.n_params * param_itemsize * (1.0 + opt_state_mult))


def diff_schedules(old: Schedule, new: Schedule,
                   profiles: Mapping[str, OpProfile],
                   dead: Sequence[int] = (),
                   opt_state_mult: float = 2.0) -> List[OpMove]:
    """Ops whose owner changed, in graph order.  Ops with no trainable state
    (placeholders, activations-only ops) still move but cost zero bytes —
    re-binding ownership is a control-plane action."""
    dead_set = set(int(d) for d in dead)
    old_place, new_place = old.placement, new.placement
    moves: List[OpMove] = []
    for op, src in old_place.items():
        dst = new_place.get(op)
        if dst is None or dst == src:
            continue
        nbytes = state_bytes(profiles[op], opt_state_mult) \
            if op in profiles else 0
        moves.append(OpMove(op=op, src=None if src in dead_set else src,
                            dst=dst, nbytes=nbytes))
    return moves


def _group_transfers(moves: Sequence[OpMove]
                     ) -> Dict[Tuple[Optional[int], int], float]:
    out: Dict[Tuple[Optional[int], int], float] = {}
    for m in moves:
        key = (m.src, m.dst)
        out[key] = out.get(key, 0.0) + float(m.nbytes)
    return out


def interim_schedule(graph: OpGraph, old: Schedule, dead: Sequence[int],
                     n_devices: int) -> Optional[Schedule]:
    """Cheapest runnable schedule after a failure (overlapped migration).

    The old schedule with each dead stage's op segment merged into an
    adjacent *surviving* stage (the predecessor when one exists, else the
    first survivor downstream).  Segments are contiguous chain runs in stage
    order, so merging a run into its neighbour keeps every stage's sub-DAG
    connected.  Only the dead segments' state must stream in (from the
    broker's checkpoint store) before training resumes on this schedule;
    every other op stays put — the rest of the re-plan drains in the
    background.  Returns None when no stage survives.
    """
    dead_set = {int(d) for d in dead}
    out_devs: List[int] = []
    out_segs: List[List[str]] = []
    pending: List[str] = []    # dead segments preceding the first survivor
    for dev in old.stage_devices():
        seg = list(old.assignment[dev])
        if dev in dead_set:
            if out_segs:
                out_segs[-1].extend(seg)
            else:
                pending.extend(seg)
        else:
            out_devs.append(dev)
            out_segs.append(pending + seg)
            pending = []
    if not out_devs:
        return None
    a, s = _to_full_assignment(out_segs, out_devs, n_devices)
    return Schedule(assignment=a, stages=s, clusters=old.clusters)


def _anchored_schedule(graph: OpGraph, profiles: Mapping[str, OpProfile],
                       cluster: ClusterSpec, old_schedule: Schedule,
                       alive: Sequence[int], joined: Sequence[int],
                       cost_model: Optional[EdgeCostModel]
                       ) -> Optional[Schedule]:
    """Stability-preferring candidate: keep the surviving stage order from
    the old schedule (append joiners at the tail) and re-run only the DP
    split.  Most segment boundaries barely move, so the migration diff stays
    near the dead node's own shard instead of reshuffling the whole model —
    a fresh OP-Fence pass re-cuts every boundary and can move everything.
    """
    alive_set = set(int(a) for a in alive)
    order = [d for d in old_schedule.stage_devices() if d in alive_set]
    order += [int(j) for j in joined
              if j in alive_set and j not in set(order)]
    n_ops = len(op_chain(graph))
    order = order[:max(1, min(len(order), n_ops))]
    if not order:
        return None
    segs, pace = partition_min_bottleneck(graph, profiles, cluster, order,
                                          cost_model=cost_model)
    a, s = _to_full_assignment(segs, order, len(cluster))
    return Schedule(assignment=a, stages=s, clusters=old_schedule.clusters,
                    predicted_pace=pace)


def _communities_for(cluster: ClusterSpec,
                     old_schedule: Schedule) -> List[List[int]]:
    """Bandwidth communities the WAN fences sit between: the old schedule's
    Louvain clusters when recorded, else a fresh Louvain pass over the full
    bandwidth matrix (devices the schedule never saw land in their natural
    community)."""
    if old_schedule.clusters:
        return [list(c) for c in old_schedule.clusters]
    return louvain_communities(cluster.bandwidth_matrix())


def _extend_communities(cluster: ClusterSpec,
                        communities: List[List[int]],
                        devices: Sequence[int]) -> List[List[int]]:
    """Map devices absent from the recorded communities (the old schedule
    was cut on a survivor subset) into the recorded community their
    full-matrix Louvain community overlaps most — i.e. the site they
    physically sit in.  A device whose full community shares no member with
    any recorded one belongs to a genuinely unseen site and stays unmapped
    (the caller must not place it: there is no fence to keep it behind)."""
    known = {d for c in communities for d in c}
    missing = [int(d) for d in devices if int(d) not in known]
    if not missing:
        return communities
    full = louvain_communities(cluster.bandwidth_matrix())
    out = [list(c) for c in communities]
    for d in missing:
        fc = next((set(c) for c in full if d in c), set())
        overlap, best = 0, None
        for ci, c in enumerate(out):
            ov = len(fc & set(c) & known)
            if ov > overlap:
                overlap, best = ov, ci
        if best is not None:
            out[best].append(d)
    return out


def cross_cluster_bytes(moves: Sequence[OpMove],
                        communities: Sequence[Sequence[int]]) -> float:
    """Migration bytes that ride an inter-cluster (WAN) link.  Checkpoint
    streams (``src=None``) are excluded — the broker store is not a WAN
    peer, and a dead node's shard has to stream from it regardless.  A
    device absent from ``communities`` cannot be proven co-located with
    anything, so transfers touching it count as crossing (conservative:
    this metric must never under-report the traffic pinning forbids)."""
    comm_of = {d: ci for ci, c in enumerate(communities) for d in c}

    def crosses(m: OpMove) -> bool:
        cs, cd = comm_of.get(m.src), comm_of.get(m.dst)
        return cs is None or cd is None or cs != cd

    return float(sum(m.nbytes for m in moves
                     if m.src is not None and crosses(m)))


def _pinned_anchored_schedule(graph: OpGraph,
                              profiles: Mapping[str, OpProfile],
                              cluster: ClusterSpec, old_schedule: Schedule,
                              alive: Sequence[int], joined: Sequence[int],
                              cost_model: Optional[EdgeCostModel]
                              ) -> Optional[Schedule]:
    """Boundary-pinned anchored candidate (closes the ROADMAP open item).

    The plain anchored candidate re-runs one DP over the whole chain, so a
    segment boundary can drift across the inter-cluster WAN link — exactly
    the migration traffic that cannot be hidden by overlapping (the bulk
    stream contends with the pipeline's own bottleneck wire).  Here the old
    schedule's cut positions at community boundaries are *frozen*: the chain
    is sliced at every point where consecutive old stages sit in different
    bandwidth clusters, surviving devices keep their old order inside each
    slice, and the min-bottleneck DP re-cuts each slice independently
    (charging the first stage of a slice for the pinned WAN edge feeding
    it).  Every op therefore stays inside its old community — zero
    cross-cluster migration bytes by construction.  A community whose
    devices all died merges its slice into the previous (else next) slice:
    that traffic is unavoidable.
    """
    if cost_model is None:
        cost_model = EdgeCostModel(graph, profiles, cluster)
    alive_set = set(int(a) for a in alive)
    communities = _extend_communities(
        cluster, _communities_for(cluster, old_schedule), joined)
    comm_of = {d: ci for ci, c in enumerate(communities) for d in c}
    order = list(op_chain(graph))
    pos = {op: i for i, op in enumerate(order)}

    # community runs over the old stage order, each with its chain slice
    runs: List[Dict[str, Any]] = []   # {comm, devices(alive), n_ops}
    for dev in old_schedule.stage_devices():
        n_ops = sum(1 for op in old_schedule.assignment[dev] if op in pos)
        c = comm_of.get(dev)
        if not runs or runs[-1]["comm"] != c:
            runs.append({"comm": c, "devices": [], "n_ops": 0})
        runs[-1]["n_ops"] += n_ops
        if dev in alive_set:
            runs[-1]["devices"].append(dev)
    if not runs:
        return None
    # joiners ride with their own community's run — only.  Unrecorded
    # joiners were mapped into the recorded community their site overlaps
    # (``_extend_communities``); one from a genuinely unseen site, or whose
    # community holds no pipeline slice, is *not* placed here: feeding it
    # state would cross a community boundary, the exact traffic class
    # pinning exists to forbid.  Under a pinned controller such a device
    # stays idle until the operator re-plans un-pinned — by construction
    # there is no fence-respecting way to stream state to it.
    seen = {d for r in runs for d in r["devices"]}
    for j in joined:
        j = int(j)
        if j not in alive_set or j in seen:
            continue
        host = next((r for r in runs if r["comm"] == comm_of.get(j)
                     and comm_of.get(j) is not None), None)
        if host is None:
            continue
        host["devices"].append(j)
        seen.add(j)
    # a run whose devices all died merges into its predecessor (else
    # successor) — cross-WAN movement of that slice is unavoidable
    merged: List[Dict[str, Any]] = []
    for r in runs:
        if r["devices"] or not merged:
            merged.append(r)
        else:
            merged[-1]["n_ops"] += r["n_ops"]
    while merged and not merged[0]["devices"]:
        if len(merged) == 1:
            return None
        merged[1]["n_ops"] += merged[0]["n_ops"]
        merged.pop(0)

    segments: List[List[str]] = []
    stage_devs: List[int] = []
    pace = 0.0
    lo = 0
    prev_dev: Optional[int] = None
    for r in merged:
        hi = lo + r["n_ops"]
        ops = order[lo:hi]
        if not ops:
            lo = hi
            continue
        devs = r["devices"][:len(ops)]
        inbound = (order[lo - 1], prev_dev) \
            if lo > 0 and prev_dev is not None else None
        segs, run_pace = min_bottleneck_chain(ops, profiles, cluster, devs,
                                              cost_model, inbound=inbound)
        segments.extend(segs)
        stage_devs.extend(devs)
        pace = max(pace, run_pace)
        prev_dev = devs[-1]
        lo = hi
    if not stage_devs:
        return None
    segments = attach_sources(graph, segments)
    a, s = _to_full_assignment(segments, stage_devs, len(cluster))
    return Schedule(assignment=a, stages=s, clusters=old_schedule.clusters,
                    predicted_pace=pace)


def replan(graph: OpGraph, profiles: Mapping[str, OpProfile],
           cluster: ClusterSpec, old_schedule: Schedule,
           alive: Sequence[int], dead: Sequence[int] = (),
           joined: Sequence[int] = (), seed: int = 0,
           opt_state_mult: float = 2.0,
           checkpoint_link: LinkSpec = CHECKPOINT_LINK,
           cost_model: Optional[EdgeCostModel] = None,
           mode: str = "auto", amortize_steps: float = 100.0,
           pin_boundaries: bool = False,
           planner: str = "opfence", joint_ratio: float = 100.0,
           verify: bool = True) -> ReplanResult:
    """Incremental re-scheduling with a migration-aware candidate choice.

    Two candidates: ``full`` re-runs OP-Fence from scratch on the survivors
    (best steady-state pace, potentially huge migration); ``anchored`` keeps
    the surviving stage order and only re-cuts the DP split (near-minimal
    migration, possibly worse pace).  ``mode='auto'`` picks the one with the
    lower total cost  ``migration_seconds + amortize_steps · pace`` — i.e.
    a pace advantage must pay back its migration bill within
    ``amortize_steps`` future micro-batches or stability wins.

    ``cluster`` is the broker's *believed* topology (degraded λ_p for flagged
    stragglers already folded in via ``network.with_slowdowns``); ``alive``
    restricts placement; ``dead`` marks nodes whose state is unrecoverable
    from the node itself; ``joined`` lists newly admitted CompNodes (the
    anchored candidate appends them at the pipeline tail).

    ``cost_model`` routes every byte account (DP re-cut, OP-Fence) through
    the unified :class:`repro.core.costmodel.EdgeCostModel` — pass a
    plan-bearing model to re-plan under compressed edge costs.
    ``pin_boundaries=True`` replaces the anchored candidate's chain-wide DP
    with the boundary-pinned per-cluster form
    (:func:`_pinned_anchored_schedule`) **and drops the unconstrained
    ``full`` candidate** — a from-scratch OP-Fence pass moves state across
    the WAN freely, which would silently void the zero-cross-WAN guarantee
    the flag exists for (``mode='full'`` is therefore rejected).

    ``planner="joint"`` makes :func:`repro.core.scheduler.schedule_joint`
    the ``full`` candidate generator — the OP-Fence × AdaTopK co-planner (at
    ``joint_ratio``) is then what actually produces epoch plans during
    training, not just a registry entry.  The anchored/pinned candidates
    already re-cut under ``cost_model``'s plan-bearing compressed costs, so
    the migration-aware choice compares like against like.

    When the old schedule is still feasible (no stage host dead or evicted),
    auto mode also offers it as the zero-migration ``keep`` candidate,
    re-scored under ``cost_model``.  Without it, a belief-change re-plan
    (straggler, calibration) is forced to move state even when every
    candidate's pace gain drowns in its migration bill — at GPT2-XL state
    sizes over WAN links the rational response to a degraded link is often
    "same cut, re-allocated compression", which costs zero bytes.
    """
    if mode not in ("auto", "full", "anchored"):
        raise ValueError(f"unknown replan mode {mode!r}")
    if planner not in ("opfence", "joint"):
        raise ValueError(f"unknown replan planner {planner!r}")
    if pin_boundaries and mode == "full":
        raise ValueError("pin_boundaries is incompatible with mode='full' — "
                         "the full re-plan cannot honor the pinned WAN cuts")
    candidates: Dict[str, Schedule] = {}
    alive_set = set(int(a) for a in alive)
    dead_set = set(int(d) for d in dead)
    old_devs = old_schedule.stage_devices()
    if mode == "auto" and old_devs and \
            all(d in alive_set and d not in dead_set for d in old_devs):
        # re-score against the CURRENT belief — the pace recorded at
        # original planning time predates whatever belief change (straggler,
        # calibration) triggered this re-plan, and a stale optimistic pace
        # plus a zero migration bill would let "keep" win the comparison the
        # re-plan exists to escape
        score_model = cost_model if cost_model is not None \
            else EdgeCostModel(graph, profiles, cluster)
        candidates["keep"] = dataclasses.replace(
            old_schedule, predicted_pace=score_model.stage_pace(old_schedule))
    if mode in ("auto", "anchored"):
        anchor_fn = _pinned_anchored_schedule if pin_boundaries \
            else _anchored_schedule
        anchored = anchor_fn(graph, profiles, cluster, old_schedule,
                             alive, joined, cost_model)
        if anchored is not None:
            candidates["anchored"] = anchored
    # the full candidate is suppressed while pinning EXCEPT as the auto-mode
    # fallback when no pinned candidate exists — that only happens when every
    # old stage host is gone, where all state comes from the checkpoint store
    # (src=None) and a fresh OP-Fence pass cannot move bytes across the WAN
    if mode in ("auto", "full") and \
            (not pin_boundaries or (mode == "auto" and not candidates)):
        if planner == "joint":
            candidates["full"] = schedule_joint(
                graph, profiles, cluster, ratio=joint_ratio, seed=seed,
                device_subset=alive, cost_model=cost_model,
                verify=False).schedule
        else:
            candidates["full"] = schedule_opfence(
                graph, profiles, cluster, seed=seed,
                cost_model=cost_model, device_subset=alive, verify=False)
    if not candidates:
        raise RuntimeError("no feasible re-plan candidate")

    best: Optional[Tuple[float, str, Schedule, List[OpMove], Any]] = None
    scores: List[Dict[str, Any]] = []
    for name, sched in sorted(candidates.items()):
        moves = diff_schedules(old_schedule, sched, profiles, dead=dead,
                               opt_state_mult=opt_state_mult)
        sim = simulate_migration(_group_transfers(moves), cluster,
                                 checkpoint_link=checkpoint_link)
        pace = sched.predicted_pace if sched.predicted_pace is not None \
            else float("inf")
        cost = sim.seconds + amortize_steps * pace
        scores.append({"name": name, "pace": pace,
                       "migration_bytes": float(sum(m.nbytes for m in moves)),
                       "migration_seconds": sim.seconds, "score": cost,
                       "winner": False})
        if best is None or cost < best[0]:
            best = (cost, name, sched, moves, sim)
    _, name, sched, moves, sim = best
    for s in scores:
        s["winner"] = s["name"] == name
    result = ReplanResult(schedule=sched,
                          migration=MigrationPlan(moves=moves, sim=sim),
                          alive=sorted(int(a) for a in alive),
                          dead=sorted(int(d) for d in dead), mode=name,
                          scores=scores)
    if verify:
        # static audit of the WINNING candidate only — the whole re-plan,
        # not each search state — so a diff/migration bug is rejected
        # before the controller ever installs it
        from repro.check.elastic import verify_replan
        communities = None
        if pin_boundaries:
            communities = _extend_communities(
                cluster, _communities_for(cluster, old_schedule), joined)
        verify_replan(graph, profiles, result, old_schedule,
                      cluster=cluster, opt_state_mult=opt_state_mult,
                      pinned=pin_boundaries, communities=communities)
    return result
