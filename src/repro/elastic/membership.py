"""Churn-tolerant membership view (elastic runtime, beyond-paper).

FusionLLM's broker assumes the CompNode set fixed for a whole job; geo-
distributed volunteers actually churn (ATOM, arXiv:2403.10504; "Go With The
Flow", arXiv:2509.21221).  This module provides the deterministic membership
substrate the elastic controller runs on:

* :class:`ChurnEvent` / :class:`ChurnTrace` — scripted join/leave/slowdown/
  recover event traces (JSON-serializable), the reproducible stand-in for
  real churn;
* :class:`MembershipView` — heartbeat/lease semantics over a trace.  A node
  that leaves at time ``t`` stops heartbeating; the broker only *detects*
  the loss when the lease expires at ``t + lease_s`` (the detection delay
  the simulator charges).  Joins announce themselves and are admitted
  immediately.  Every batch of detected membership changes bumps the epoch
  counter — one epoch == one stable schedule.

Determinism contract (tested): the same trace polled at the same times
yields the same epochs, alive sets, and slowdown factors.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

EVENT_KINDS = ("join", "leave", "slowdown", "slowlink", "recover")


@dataclasses.dataclass(frozen=True)
class ChurnEvent:
    """One scripted membership event.

    ``factor`` only matters for ``slowdown`` (multiplier on the node's
    effective compute speed) and ``slowlink`` (multiplier on the bandwidth of
    every link touching the node — its uplink silently congests below spec),
    both in (0, 1).  ``recover`` clears both.
    """

    time: float
    kind: str
    node: int
    factor: float = 1.0

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(f"unknown churn event kind {self.kind!r}")
        if self.kind in ("slowdown", "slowlink") \
                and not (0.0 < self.factor <= 1.0):
            raise ValueError(f"{self.kind} factor must be in (0, 1]")

    def to_dict(self) -> Dict:
        d = {"t": self.time, "kind": self.kind, "node": self.node}
        if self.kind in ("slowdown", "slowlink"):
            d["factor"] = self.factor
        return d

    @staticmethod
    def from_dict(d: Dict) -> "ChurnEvent":
        return ChurnEvent(time=float(d["t"]), kind=str(d["kind"]),
                          node=int(d["node"]),
                          factor=float(d.get("factor", 1.0)))


@dataclasses.dataclass(frozen=True)
class ChurnTrace:
    """Time-ordered scripted events (stable-sorted by time on construction)."""

    events: Tuple[ChurnEvent, ...]

    def __post_init__(self):
        object.__setattr__(self, "events",
                           tuple(sorted(self.events, key=lambda e: e.time)))

    def __len__(self) -> int:
        return len(self.events)

    def between(self, t0: float, t1: float) -> List[ChurnEvent]:
        """Events with t0 < time <= t1."""
        return [e for e in self.events if t0 < e.time <= t1]

    def to_json(self) -> str:
        return json.dumps([e.to_dict() for e in self.events])

    @staticmethod
    def from_json(text: str) -> "ChurnTrace":
        return ChurnTrace(tuple(ChurnEvent.from_dict(d)
                                for d in json.loads(text)))

    @staticmethod
    def build(events: Iterable[Dict]) -> "ChurnTrace":
        return ChurnTrace(tuple(ChurnEvent.from_dict(d) for d in events))


def single_failure_trace(node: int, at: float) -> ChurnTrace:
    """The acceptance-criteria trace: one node failure mid-training."""
    return ChurnTrace((ChurnEvent(time=at, kind="leave", node=node),))


@dataclasses.dataclass(frozen=True)
class MembershipDelta:
    """One detected change, stamped with when the broker learned of it."""

    event: ChurnEvent
    detected_at: float


class MembershipView:
    """Lease-based membership over a scripted trace.

    The broker's view, not ground truth: a departed node stays in ``alive``
    until its lease runs out.  ``poll(now)`` advances the view to ``now`` and
    returns the newly *detected* deltas; if any affect membership (join /
    leave), ``epoch`` increments once per poll (all changes detected together
    fold into one re-plan).

    ``slowdown`` / ``slowlink`` / ``recover`` events do NOT bump the epoch:
    they record the *ground-truth* factors (``slow_factor`` for compute,
    ``link_factor`` for a node's link bandwidths) the simulator degrades the
    real cluster by.  The broker is not told — its straggler detector has to
    notice compute drift from observed step times, and its link calibration
    has to notice bandwidth drift from observed transfers (that is the point
    of the exercise).
    """

    def __init__(self, n_nodes: int, trace: ChurnTrace,
                 lease_s: float = 10.0,
                 initial_alive: Optional[Sequence[int]] = None):
        if lease_s < 0:
            raise ValueError("lease_s must be >= 0")
        self.n_nodes = n_nodes
        self.trace = trace
        self.lease_s = float(lease_s)
        self.alive: List[int] = sorted(initial_alive) \
            if initial_alive is not None else list(range(n_nodes))
        self.slow_factor: Dict[int, float] = {}
        self.link_factor: Dict[int, float] = {}
        self.epoch = 0
        self.now = 0.0
        self._cursor = 0               # next undelivered trace event
        self._pending: List[MembershipDelta] = []   # leaves awaiting lease
        self.history: List[Tuple[int, MembershipDelta]] = []

    # ------------------------------------------------------------- polling
    def _detection_time(self, e: ChurnEvent) -> float:
        """Leaves are silent — detected at lease expiry.  Joins announce
        themselves; slowdowns are the straggler detector's job, surfaced
        here at event time so the ground-truth cluster degrades on cue."""
        return e.time + self.lease_s if e.kind == "leave" else e.time

    def poll(self, now: float) -> List[MembershipDelta]:
        if now < self.now:
            raise ValueError("time must be monotone")
        self.now = now
        while (self._cursor < len(self.trace.events)
               and self.trace.events[self._cursor].time <= now):
            e = self.trace.events[self._cursor]
            self._cursor += 1
            self._pending.append(MembershipDelta(e, self._detection_time(e)))
        ripe = [d for d in self._pending if d.detected_at <= now]
        self._pending = [d for d in self._pending if d.detected_at > now]
        changed = False
        for d in sorted(ripe, key=lambda d: d.detected_at):
            changed |= self._apply(d.event)
        if changed:
            self.epoch += 1
        for d in ripe:
            self.history.append((self.epoch, d))
        return ripe

    def _apply(self, e: ChurnEvent) -> bool:
        if e.kind == "leave":
            if e.node in self.alive:
                self.alive.remove(e.node)
                self.slow_factor.pop(e.node, None)
                self.link_factor.pop(e.node, None)
                return True
        elif e.kind == "join":
            if e.node not in self.alive:
                self.alive.append(e.node)
                self.alive.sort()
                return True
        elif e.kind == "slowdown":
            # ground truth only — the broker discovers this via the detector
            self.slow_factor[e.node] = e.factor
        elif e.kind == "slowlink":
            # ground truth only — link calibration has to measure it
            self.link_factor[e.node] = e.factor
        elif e.kind == "recover":
            self.slow_factor.pop(e.node, None)
            self.link_factor.pop(e.node, None)
        return False

    # ------------------------------------------------------------ snapshot
    def snapshot(self) -> Dict:
        """Deterministic state fingerprint (the determinism tests hash it)."""
        return {"epoch": self.epoch, "now": self.now,
                "alive": list(self.alive),
                "slow": sorted(self.slow_factor.items()),
                "slowlink": sorted(self.link_factor.items())}
