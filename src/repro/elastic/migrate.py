"""Bit-exact state migration (elastic runtime).

A re-plan moves op segments between CompNodes; each move ships the op's
parameters and optimizer state.  The payload travels in the checkpoint
subsystem's wire format (:func:`repro.checkpoint.serialize_state` — the
same flattened-path .npz envelope as on-disk checkpoints, held in memory),
so a migration is numerically identical to a checkpoint round-trip:
restored state is bit-exact, and the loss curve is continuous across a
fail-over (tested).

Optimizer-state layout is handled structurally: the repo's ``OptState``
holds either a per-op mapping (SGD momentum, Adafactor) or a mapping of
accumulators each keyed per-op (AdamW's ``{"m": {op: ...}, "v": ...}``);
:func:`extract_op_state` slices both shapes by op name.

Migration payloads are deliberately exempt from AdaTopK: Top-K loss on a
boundary activation is absorbed by training, Top-K loss on the weights
themselves is corruption.

Both migration modes go through :func:`apply_moves`: stop-the-world applies
the whole plan at once; overlapped migration applies the blocking
(checkpoint-restore) moves implicitly via the rollback restore, then the
background survivor moves at cut-over — in either case the wire round-trip
is bit-exact, so a loss curve is continuous across the hand-off.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Sequence, Set, Tuple

import jax
import numpy as np

from repro.checkpoint import deserialize_state, serialize_state
from repro.obs.trace import CAT_CHECKPOINT, CAT_MIGRATION
from .replan import OpMove


# ------------------------------------------------------------- tree slicing
def _extract_inner(inner: Any, ops: Set[str], op_names: Set[str]) -> Any:
    """Slice an optimizer inner-state tree down to ``ops``.

    Layouts: ``None`` (plain SGD); ``{op: state}`` (momentum/Adafactor);
    ``{acc: {op: state}}`` (AdamW moments).  The per-op level is recognized
    by key overlap with the model's op names.
    """
    if inner is None or not isinstance(inner, Mapping):
        return inner
    if set(inner) & op_names:
        return {k: v for k, v in inner.items() if k in ops}
    return {k: _extract_inner(v, ops, op_names) for k, v in inner.items()}


def _merge_inner(inner: Any, sub: Any, op_names: Set[str]) -> Any:
    """Write a slice produced by :func:`_extract_inner` back into ``inner``."""
    if inner is None or not isinstance(inner, Mapping):
        return inner
    if set(inner) & op_names:
        out = dict(inner)
        out.update(sub or {})
        return out
    return {k: _merge_inner(v, (sub or {}).get(k), op_names)
            for k, v in inner.items()}


def extract_op_state(params: Mapping[str, Any], opt_state: Any,
                     ops: Sequence[str]) -> Tuple[Dict[str, Any], Any]:
    """The (params, opt) sub-trees owned by ``ops`` (ops without trainable
    state are skipped — nothing to ship)."""
    op_set = set(ops)
    op_names = set(params)
    p_sub = {k: v for k, v in params.items() if k in op_set}
    o_sub = None
    if opt_state is not None:
        inner = _extract_inner(opt_state.inner, op_set, op_names)
        o_sub = opt_state._replace(inner=inner)
    return p_sub, o_sub


def pack_op_state(params: Mapping[str, Any], opt_state: Any,
                  ops: Sequence[str]) -> bytes:
    """One migration envelope: the ops' state in checkpoint wire format."""
    p_sub, o_sub = extract_op_state(params, opt_state, ops)
    return serialize_state(p_sub, o_sub)


def unpack_op_state(blob: bytes, params: Mapping[str, Any], opt_state: Any,
                    ops: Sequence[str]) -> Tuple[Dict[str, Any], Any]:
    """Decode an envelope using the live state as structure template."""
    p_t, o_t = extract_op_state(params, opt_state, ops)
    return deserialize_state(blob, p_t, o_t)


# ---------------------------------------------------------------- outcomes
@dataclasses.dataclass
class MigrationOutcome:
    params: Dict[str, Any]
    opt_state: Any
    wire_bytes: int              # actual serialized envelope bytes
    n_envelopes: int


def apply_moves(params: Mapping[str, Any], opt_state: Any,
                moves: Sequence[OpMove],
                trace: Optional[Any] = None) -> MigrationOutcome:
    """Execute a migration plan: one envelope per (src, dst) link, each op's
    state serialized, shipped, and restored through the checkpoint format.

    The single-process runtime holds the global state either way — what this
    proves (and the controller relies on) is that the wire round-trip is
    bit-exact, so a multi-process deployment of the same envelopes would
    reconstruct identical numerics.

    ``trace`` (a :class:`repro.obs.trace.TraceRecorder`) records one
    wall-clock span per envelope: ``checkpoint.restore`` for streams out of
    the broker's store (``src=None``), ``migrate.stream`` for peer-to-peer
    transfers, args carrying exact envelope bytes and op count.
    """
    groups: Dict[Tuple[Optional[int], int], List[str]] = {}
    for m in moves:
        groups.setdefault((m.src, m.dst), []).append(m.op)
    tracer = trace if getattr(trace, "enabled", False) else None

    new_params = dict(params)
    new_opt = opt_state
    op_names = set(params)
    wire = 0
    n_env = 0
    for key in sorted(groups, key=lambda k: (k[0] is None, k)):
        ops = [o for o in groups[key] if o in params]
        if not ops:
            continue
        src, dst = key
        token = None
        if tracer is not None:
            lbl = f"{'ckpt' if src is None else src}->{dst}"
            token = tracer.begin(
                CAT_CHECKPOINT if src is None else CAT_MIGRATION,
                lbl, f"migrate {lbl}", args={"n_ops": len(ops)})
        blob = pack_op_state(params, opt_state, ops)
        wire += len(blob)
        n_env += 1
        p_sub, o_sub = unpack_op_state(blob, params, opt_state, ops)
        if tracer is not None:
            tracer.end(token, args={"nbytes": len(blob)})
        new_params.update(p_sub)
        if new_opt is not None and o_sub is not None:
            new_opt = new_opt._replace(
                inner=_merge_inner(new_opt.inner, o_sub.inner, op_names))
    return MigrationOutcome(params=new_params, opt_state=new_opt,
                            wire_bytes=wire, n_envelopes=n_env)


# -------------------------------------------------------------- bit checks
def trees_bitexact(a: Any, b: Any) -> bool:
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    if ta != tb or len(la) != len(lb):
        return False
    for x, y in zip(la, lb):
        xa, ya = np.asarray(x), np.asarray(y)
        if xa.dtype != ya.dtype or xa.shape != ya.shape:
            return False
        if not np.array_equal(xa, ya, equal_nan=True):
            return False
    return True


def assert_bitexact(a: Any, b: Any, what: str = "state") -> None:
    if not trees_bitexact(a, b):
        raise AssertionError(f"{what} not bit-exact across migration")
