"""Straggler detection (elastic runtime).

The paper names stragglers as a core challenge of geo-distributed training
(§1) but schedules once and hopes; here the broker keeps watching.  Each
pipeline stage's *measured* per-step time — executor StepTiming samples
aggregated by :class:`repro.elastic.telemetry.TelemetryLog` (median-of-
window, outlier-rejected), never a fresh estimator sweep — is smoothed with
an EWMA and compared to the workload estimator's *prediction* for that
CompNode (:func:`repro.core.estimator.predict_step_times`, the reference
the schedule was built against).  A node whose smoothed time drifts past
``threshold ×`` its prediction is flagged; the controller then degrades the
node's believed λ_p and re-plans, so OP-Fence shifts ops off the straggler
in proportion to the measured slowdown.

Detection delay is explicit: ``min_observations`` steps must accumulate
before a flag fires (on top of the telemetry window's own lag), which the
simulator charges as wall-clock (the cost of noticing, on top of the cost
of migrating).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional


@dataclasses.dataclass
class StageStats:
    """EWMA state for one CompNode's step time."""

    predicted: float
    ewma: Optional[float] = None
    count: int = 0

    def observe(self, seconds: float, alpha: float) -> None:
        self.ewma = seconds if self.ewma is None \
            else alpha * seconds + (1.0 - alpha) * self.ewma
        self.count += 1

    @property
    def severity(self) -> float:
        """Observed/predicted ratio (1.0 = healthy, 4.0 = 4× too slow)."""
        if self.ewma is None or self.predicted <= 0.0:
            return 1.0
        return self.ewma / self.predicted


class StragglerDetector:
    """EWMA drift detector over per-stage step times.

    ``predicted`` maps CompNode index -> expected FP+BP seconds under the
    current schedule (from the estimator).  ``observe`` feeds one step's
    measured per-stage times; ``flagged`` lists nodes whose smoothed time
    exceeds ``threshold ×`` prediction after the warm-up.  ``reset`` installs
    fresh predictions after a re-plan (a new schedule changes every stage's
    expected time, so history must not carry over).
    """

    def __init__(self, predicted: Mapping[int, float],
                 alpha: float = 0.4, threshold: float = 1.8,
                 min_observations: int = 3):
        if not (0.0 < alpha <= 1.0):
            raise ValueError("alpha in (0, 1]")
        if threshold <= 1.0:
            raise ValueError("threshold must exceed 1.0")
        self.alpha = alpha
        self.threshold = threshold
        self.min_observations = int(min_observations)
        self.stats: Dict[int, StageStats] = {}
        self.reset(predicted)

    def reset(self, predicted: Mapping[int, float]) -> None:
        self.stats = {int(d): StageStats(predicted=float(t))
                      for d, t in predicted.items()}

    def reprice(self, predicted: Mapping[int, float]) -> None:
        """Install recalibrated reference predictions *without* dropping the
        EWMA observation history.  Used by closed-loop link calibration: the
        schedule (hence the observation stream) did not change, only the
        broker's cost model for it — a ``reset`` here would grant a genuine
        straggler a fresh ``min_observations`` warm-up every calibration
        window and let it hide indefinitely."""
        for d, t in predicted.items():
            st = self.stats.get(int(d))
            if st is None:
                self.stats[int(d)] = StageStats(predicted=float(t))
            else:
                st.predicted = float(t)

    def observe(self, stage_times: Mapping[int, float]) -> None:
        for d, t in stage_times.items():
            st = self.stats.get(int(d))
            if st is not None:
                st.observe(float(t), self.alpha)

    def flagged(self) -> List[int]:
        return sorted(d for d, st in self.stats.items()
                      if st.count >= self.min_observations
                      and st.severity > self.threshold)

    def severity(self, node: int) -> float:
        st = self.stats.get(int(node))
        return st.severity if st is not None else 1.0

    def believed_factors(self) -> Dict[int, float]:
        """Per-flagged-node speed factor (1/severity) — what the controller
        folds into the believed ClusterSpec before re-planning, so the DP
        split sizes segments against the node's *measured* pace."""
        return {d: 1.0 / self.severity(d) for d in self.flagged()}
