"""Elastic runtime: churn-tolerant membership, straggler detection, live
re-scheduling, and closed-loop cost calibration over the FusionLLM stack
(beyond-paper; see README §Elastic).

Composition: scripted :class:`ChurnTrace` -> lease-based
:class:`MembershipView` + executor :class:`StepTiming` / ``LinkTiming``
telemetry aggregated by :class:`TelemetryLog` into the EWMA
:class:`StragglerDetector`'s observations and the per-link calibration
windows -> :func:`replan` (keep / anchored / full candidates — OP-Fence or
the joint co-planner — minimal migration plan; :func:`interim_schedule` for
the overlapped mode's immediate restart) -> :mod:`migrate` (bit-exact state
movement over the checkpoint wire format) -> :class:`ElasticController`
(drives the runtime across epochs, auto-fits link corrections from the
telemetry with hysteresis, re-plans when the calibrated pace diverges, and
charges the discrete-event clock for detection, blocking migration, and
pipeline refill — background migration streams while training continues on
bandwidth-shared links).
"""
from .membership import (ChurnEvent, ChurnTrace, MembershipDelta,
                         MembershipView, single_failure_trace)
from .detector import StragglerDetector
from .telemetry import TelemetryLog
from .replan import (MigrationPlan, OpMove, ReplanResult, cross_cluster_bytes,
                     diff_schedules, interim_schedule, replan, state_bytes)
from .migrate import (apply_moves, assert_bitexact, extract_op_state,
                      pack_op_state, trees_bitexact, unpack_op_state)
from .controller import (ElasticController, ElasticRunResult, EpochRecord,
                         StepRecord)
