"""ElasticController: drives the FusionLLM runtime across membership epochs.

One epoch = one stable OP-Fence schedule.  Per training step the controller
(1) runs the real RAD numerics through :class:`DecentralizedRuntime` (unless
``train=False``), (2) advances a simulated wall-clock by the discrete-event
:func:`simulate_iteration` on the *ground-truth* cluster (scripted slowdowns
applied), (3) feeds the executor's telemetry samples through the broker's
:class:`TelemetryLog` and hands the aggregated per-CompNode step times to
the straggler detector — ``predict_step_times`` supplies only the detector's
reference *prediction*, never the observation — and (4) polls the
lease-based membership view.  On a detected failure, join, straggler, or
recovery it transitions epochs: re-plan via OP-Fence on the survivors,
migrate state bit-exactly through the checkpoint wire format, and charge the
simulated clock for what churn really costs:

    detection delay   — implicit: the clock kept running (wasted) between the
                        failure and its lease expiry / telemetry warm-up;
    lost work         — steps after the last checkpoint that predates the
                        failure are rolled back (their samples don't count);
    migration         — bulk state transfers over the real α–β links
                        (:func:`simulate_migration`);
    pipeline refill   — a fresh schedule starts cold (fill term of Eq. 3).

Two migration modes:

* ``migration_mode="stop"`` (PR 1 behaviour) — training halts while the
  whole migration plan streams, then the new schedule starts cold.
* ``migration_mode="overlap"`` — training *continues* while survivor-to-
  survivor state streams in the background over bandwidth-shared links
  (:func:`repro.core.network.with_shared_links` slows the foreground
  boundary traffic on the specific wires the stream rides, it does not block
  it; ``overlap_bandwidth_share`` is the fraction the *foreground* keeps on
  a contended link — default 0.75, training has priority and the stream
  scavenges the rest).  After a failure, only the dead
  CompNodes' shards block: they stream from the checkpoint store into an
  *interim* schedule (:func:`repro.elastic.replan.interim_schedule` — the
  old schedule with each dead segment merged into an adjacent surviving
  stage), training resumes on it, and the cut-over to the final re-planned
  schedule charges only the residual transfer (a hot hand-off between warm
  schedules — no second cold fill).  A broker-side cost model streams only
  when the target's pace pays for the foreground slowdown within
  ``amortize_steps``; otherwise the interim schedule simply becomes the
  epoch's schedule (fair-share conservation: bytes crossing the pipeline's
  own bottleneck wire cannot be hidden by overlapping).

Closed planning loop (beyond PR 3's passive cost model): the controller
periodically re-fits per-link corrections from the telemetry window's
MAD-filtered per-link transfer observations
(:meth:`repro.elastic.telemetry.TelemetryLog.link_samples` →
:func:`repro.core.costmodel.fit_link_corrections`, always against the
*uncorrected* base spec so re-fits replace rather than compound) and installs
the calibrated :class:`EdgeCostModel` everywhere the broker prices anything:
the detector's reference prediction (repriced in place, EWMA history kept),
the re-planner's candidate costs, the joint co-planner, and the
stream-vs-keep broker.  The same loop fits per-device codec costs
(:meth:`TelemetryLog.kernel_samples` →
:func:`repro.core.costmodel.fit_kernel_costs`) so the planner's
``compress_seconds`` term prices encode time from measured ``KernelTiming``
samples, not assumptions.  Hysteresis (``calibrate_hysteresis``) keeps a
single noisy window from thrashing; when the calibrated pace of the *active* plan
drifts more than ``replan_pace_margin`` past the pace it was installed at, a
``"calibration"`` epoch re-plans on the corrected costs (a re-plan that
returns the same assignment is a no-op — no migration, no refill).

``planner="joint"`` puts :func:`repro.core.scheduler.schedule_joint` in
charge of epoch plans end to end — initial schedule, full re-plan candidate,
and (by default) an AdaTopK plan factory at ``joint_ratio`` — so OP-Fence ×
AdaTopK co-planning is what actually runs during training.  With this PR
the planning loop is closed end to end, and ``pin_boundaries`` now defaults
to True in overlap mode for EVERY planner (a background stream cannot hide
cross-WAN bytes, so no overlap-mode re-cut should create any — the
rationale is the stream's, not the joint planner's); pass
``pin_boundaries=False`` to restore the old unpinned overlap behaviour.

Determinism contract: same graph/cluster/trace/seeds → identical epochs,
schedules, clocks, and (when training) identical losses.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.checkpoint import deserialize_state, serialize_state
from repro.core.compression import CompressionPlan, plan_adatopk, plan_none
from repro.core.costmodel import (EdgeCostModel, KernelCostModel,
                                  fit_kernel_costs, fit_link_corrections)
from repro.core.estimator import ClusterSpec, predict_step_times
from repro.core.executor import (DecentralizedRuntime, TelemetrySink,
                                 pipeline_fill_seconds, simulate_iteration,
                                 simulate_migration)
from repro.core.network import (with_link_slowdowns, with_shared_links,
                                with_slowdowns)
from repro.core.opgraph import OpGraph, OpProfile
from repro.core.scheduler import Schedule, schedule_joint, schedule_opfence
from repro.obs import (CalibrationRecord, CandidateScore, DetectorRecord,
                       EpochFlightRecord, FlightRecorder, MetricsRegistry,
                       MetricsTelemetrySink, ReplanRecord, TelemetryBus,
                       TraceRecorder)
from repro.obs.record import links_to_str
from repro.obs.trace import CAT_CHECKPOINT, CAT_CONTROLLER, CAT_MIGRATION
from repro.optim.optimizers import Optimizer

from .detector import StragglerDetector
from .membership import ChurnEvent, ChurnTrace, MembershipView
from .migrate import apply_moves, assert_bitexact
from .replan import (MigrationPlan, OpMove, ReplanResult, _group_transfers,
                     diff_schedules, interim_schedule, replan)
from .telemetry import TelemetryLog

PlanFactory = Callable[[OpGraph, Mapping[str, OpProfile], ClusterSpec,
                        Mapping[str, int]], CompressionPlan]


@dataclasses.dataclass
class StepRecord:
    step: int                  # data step index (replays after a rollback)
    epoch: int
    loss: Optional[float]
    step_seconds: float        # simulated iteration wall-clock
    clock: float               # cumulative simulated time at step end
    lost: bool = False         # rolled back by a later failure
    overlapping: bool = False  # executed while a background migration ran


@dataclasses.dataclass
class EpochRecord:
    epoch: int
    at_step: int               # first data step executed under this epoch
    clock: float               # sim time when the epoch began
    cause: str                 # initial | failure | join | straggler |
                               # recovery | cutover | calibration
    events: List[ChurnEvent]
    alive: List[int]
    stage_devices: List[int]
    n_moves: int
    moved_bytes: float
    detect_seconds: float      # event time -> broker noticing
    migrate_seconds: float     # blocking (foreground) migration wall-clock
    refill_seconds: float
    rollback_steps: int
    replan_mode: str = ""      # full | anchored | interim
    background_bytes: float = 0.0   # streamed while training continued
    overlap_seconds: float = 0.0    # trained wall-clock during the stream


@dataclasses.dataclass
class ElasticRunResult:
    steps: List[StepRecord]
    epochs: List[EpochRecord]
    params: Any
    opt_state: Any
    total_seconds: float

    @property
    def losses(self) -> List[Tuple[int, float]]:
        """(data step, loss) for surviving (non-rolled-back) steps."""
        return [(r.step, r.loss) for r in self.steps
                if not r.lost and r.loss is not None]

    @property
    def useful_steps(self) -> int:
        return sum(1 for r in self.steps if not r.lost)

    def samples_per_second(self, batch_size: int) -> float:
        if self.total_seconds <= 0:
            return float("inf")
        return self.useful_steps * batch_size / self.total_seconds

    def post_failure_throughput(self, batch_size: int) -> float:
        """Useful samples per second in the window after the first failure
        epoch began — the recovery-path metric overlapped migration targets.
        inf when no failure occurred."""
        fails = [e for e in self.epochs if e.cause == "failure"]
        if not fails:
            return float("inf")
        t0 = fails[0].clock - fails[0].migrate_seconds \
            - fails[0].refill_seconds
        useful = sum(1 for r in self.steps
                     if not r.lost and r.clock > t0)
        window = self.total_seconds - t0
        return useful * batch_size / window if window > 0 else float("inf")


@dataclasses.dataclass
class _Checkpoint:
    step: int                  # state AFTER this many data steps
    clock: float               # sim time when taken
    blob: Optional[bytes]      # None in sim-only mode


@dataclasses.dataclass
class _OverlapState:
    """Background migration in flight: the target schedule and its bulk
    transfers, draining while foreground training continues."""

    target: Schedule
    replan_mode: str
    moves: List[OpMove]
    bg_seconds: float          # total stream time at shared bandwidth
    busy: Tuple[Tuple[int, int], ...]   # links the stream contends on
    progressed: float = 0.0


class ElasticController:
    """Churn-tolerant training driver (see module docstring)."""

    def __init__(self, graph: OpGraph, profiles: Mapping[str, OpProfile],
                 cluster: ClusterSpec, trace: ChurnTrace,
                 optimizer: Optional[Optimizer] = None,
                 plan_factory: Optional[PlanFactory] = None,
                 n_micro: int = 2, seed: int = 0,
                 lease_s: float = 10.0,
                 checkpoint_interval: int = 1,
                 checkpoint_history: int = 8,
                 detector_alpha: float = 0.4,
                 detector_threshold: float = 1.8,
                 detector_min_obs: int = 3,
                 telemetry_window: int = 5,
                 telemetry_mad_k: float = 3.5,
                 opt_state_mult: float = 2.0,
                 replan_mode: str = "auto",
                 amortize_steps: float = 100.0,
                 migration_mode: str = "stop",
                 overlap_bandwidth_share: float = 0.75,
                 pin_boundaries: Optional[bool] = None,
                 planner: str = "opfence",
                 joint_ratio: float = 100.0,
                 calibrate_interval: int = 5,
                 calibrate_min_samples: int = 3,
                 calibrate_hysteresis: float = 0.2,
                 replan_pace_margin: float = 0.25,
                 use_kernel: bool = False,
                 kernel_costs: Optional[Mapping[int, KernelCostModel]] = None,
                 initial_alive: Optional[Sequence[int]] = None,
                 tracer: Optional[TraceRecorder] = None,
                 flight: Optional[FlightRecorder] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 watchdog: Optional[Any] = None,
                 verify: bool = True):
        if migration_mode not in ("stop", "overlap"):
            raise ValueError(f"unknown migration_mode {migration_mode!r}")
        if planner not in ("opfence", "joint"):
            raise ValueError(f"unknown planner {planner!r}")
        self.graph = graph
        self.profiles = profiles
        self.base_cluster = cluster
        self.optimizer = optimizer
        self.planner = planner
        self.joint_ratio = float(joint_ratio)
        if plan_factory is not None:
            self.plan_factory = plan_factory
        elif planner == "joint":
            # the joint co-planner's converged plan for a placement is
            # exactly plan_adatopk at the same ratio under the same base
            # model, so anchored/interim schedules get co-consistent plans
            self.plan_factory = self._joint_plan_factory
        else:
            self.plan_factory = \
                lambda g, prof, cl, placement: plan_none(g, placement)
        self.n_micro = int(n_micro)
        self.seed = int(seed)
        self.checkpoint_interval = max(1, int(checkpoint_interval))
        self.checkpoint_history = max(2, int(checkpoint_history))
        self.opt_state_mult = float(opt_state_mult)
        self.replan_mode = replan_mode
        self.amortize_steps = float(amortize_steps)
        self.migration_mode = migration_mode
        self.overlap_bandwidth_share = float(overlap_bandwidth_share)
        # with the joint planner driving epoch plans end-to-end, overlap mode
        # defaults to boundary pinning: the background stream cannot hide
        # cross-WAN traffic, so the re-cut must not create any
        self.pin_boundaries = (migration_mode == "overlap") \
            if pin_boundaries is None else bool(pin_boundaries)
        self.calibrate_interval = max(0, int(calibrate_interval))
        self.calibrate_min_samples = max(1, int(calibrate_min_samples))
        self.calibrate_hysteresis = float(calibrate_hysteresis)
        self.replan_pace_margin = float(replan_pace_margin)
        self.use_kernel = use_kernel
        # ground-truth per-device codec costs for the simulator (what encode
        # actually costs on each host); the broker's *belief* starts empty
        # and is fitted from KernelTiming telemetry by _calibrate — the same
        # truth-vs-belief split as compute slowdowns and link corrections
        self.kernel_costs: Dict[int, KernelCostModel] = dict(kernel_costs or {})
        self.kernel_cost_belief: Dict[int, KernelCostModel] = {}
        # static verification (repro.check) of every plan this controller
        # installs: schedules at install time, re-plans inside replan(),
        # compression plans against the installed placement.  verify=False
        # opts the whole runtime out (perf sweeps).
        self.verify = bool(verify)
        self._det_cfg = dict(alpha=detector_alpha,
                             threshold=detector_threshold,
                             min_observations=detector_min_obs)
        self.telemetry = TelemetryLog(window=telemetry_window,
                                      mad_k=telemetry_mad_k)
        # Observability (all optional, all no-ops when absent): the tracer
        # records sim-clock spans (named ``tracer`` — the 4th positional arg
        # ``trace`` is the churn script), the flight recorder logs every
        # broker decision, and the metrics registry aggregates counters.
        # Telemetry flows through a bus so the broker's TelemetryLog and the
        # metrics sink observe the exact same sample stream (parity tested).
        self.tracer = tracer
        self.flight = flight
        self.metrics = metrics
        self.telemetry_bus = TelemetryBus([self.telemetry])
        if metrics is not None:
            self.telemetry_bus.subscribe(MetricsTelemetrySink(metrics))
        # The watchdog is the *knowing* half of the control loop: it flags a
        # regime shift on the first degraded sample (flight record + slog
        # warning), steps before this controller's own windowed detector has
        # enough evidence to *act* — asserted in the churn acceptance test.
        self.watchdog = watchdog
        if watchdog is not None:
            if watchdog.flight is None:
                watchdog.flight = flight
            if watchdog.metrics is None:
                watchdog.metrics = metrics
            self.telemetry_bus.subscribe(watchdog)

        self.membership = MembershipView(len(cluster), trace, lease_s=lease_s,
                                         initial_alive=initial_alive)
        self.believed_factors: Dict[int, float] = {}
        self.link_corrections: Dict[Tuple[int, int], float] = {}
        self.calibration_count = 0       # hysteresis-passing fits installed
        self._steps_since_fit = 0
        self._installed_pace = 0.0       # believed pace of the active plan
        self.epoch_records: List[EpochRecord] = []
        self.step_records: List[StepRecord] = []
        self.clock = 0.0
        self._migrating: Optional[_OverlapState] = None
        self._deferred_deltas: List[Any] = []   # ripened during a stream
        self._install_schedule(cause="initial", events=[], dead=[],
                               at_step=0, detect_seconds=0.0,
                               migration=None, rollback_steps=0,
                               charge_refill=False)

    # ----------------------------------------------------------- topology --
    def believed_cluster(self) -> ClusterSpec:
        """What the broker schedules against: base sheets degraded by the
        detector's confirmed slowdowns.  Link-level belief lives in
        ``link_corrections`` (carried by :meth:`believed_model`), not here —
        the α–β sheets stay pristine so calibration always fits against the
        uncorrected spec."""
        return with_slowdowns(self.base_cluster, self.believed_factors)

    def believed_model(self, believed: Optional[ClusterSpec] = None,
                       plan: Optional[CompressionPlan] = None
                       ) -> EdgeCostModel:
        """The broker's full cost belief: believed compute sheets × the
        epoch's compression plan × telemetry-calibrated link corrections.
        Every planning-side consumer (detector reference prediction,
        re-planner, joint co-planner, pace checks) reads this one model."""
        return EdgeCostModel(self.graph, self.profiles,
                             believed if believed is not None
                             else self.believed_cluster(),
                             plan if plan is not None else self.plan,
                             self.link_corrections,
                             self.kernel_cost_belief)

    def true_cluster(self) -> ClusterSpec:
        """Ground truth for the simulator: scripted compute and link
        degradations in force now."""
        return with_link_slowdowns(
            with_slowdowns(self.base_cluster, self.membership.slow_factor),
            self.membership.link_factor)

    def _joint_plan_factory(self, graph: OpGraph,
                            profiles: Mapping[str, OpProfile],
                            cluster: ClusterSpec,
                            placement: Mapping[str, int]) -> CompressionPlan:
        """Default plan factory under ``planner='joint'``: AdaTopK at the
        co-planner's ratio, priced by the corrections-bearing model."""
        return plan_adatopk(graph, profiles, cluster, placement,
                            self.joint_ratio,
                            cost_model=EdgeCostModel(
                                graph, profiles, cluster, None,
                                self.link_corrections,
                                self.kernel_cost_belief))

    # ----------------------------------------------------------- epochs ----
    def _install_schedule(self, cause: str, events: List[ChurnEvent],
                          dead: Sequence[int], at_step: int,
                          detect_seconds: float,
                          migration: Optional[MigrationPlan],
                          rollback_steps: int,
                          replan_mode: str = "",
                          schedule: Optional[Schedule] = None,
                          migrate_seconds: Optional[float] = None,
                          charge_refill: bool = True,
                          background_bytes: float = 0.0,
                          overlap_seconds: float = 0.0) -> None:
        believed = self.believed_cluster()
        if schedule is not None:
            self.schedule = schedule
            if self.verify:
                # re-plans were verified inside replan(); this catches the
                # other installers (interim schedules, caller-built ones)
                from repro.check.schedule import verify_schedule
                verify_schedule(self.graph, self.schedule,
                                profiles=self.profiles, cluster=believed,
                                check_capacity=False)
        elif migration is None:   # initial epoch: schedule from scratch
            if self.planner == "joint":
                self.schedule = schedule_joint(
                    self.graph, self.profiles, believed,
                    ratio=self.joint_ratio, seed=self.seed,
                    device_subset=self.membership.alive,
                    cost_model=EdgeCostModel(
                        self.graph, self.profiles, believed, None,
                        self.link_corrections, self.kernel_cost_belief),
                    verify=self.verify).schedule
            else:
                self.schedule = schedule_opfence(
                    self.graph, self.profiles, believed, seed=self.seed,
                    device_subset=self.membership.alive,
                    verify=self.verify)
        placement = self.schedule.placement
        self.plan = self.plan_factory(self.graph, self.profiles, believed,
                                      placement)
        if self.verify:
            from repro.check.costs import verify_plan
            verify_plan(self.graph, self.profiles, self.plan,
                        placement=placement,
                        cost_model=self.believed_model(believed, self.plan))
        migrate_s = migration.seconds if migration is not None else 0.0
        if migrate_seconds is not None:   # caller-computed blocking cost
            migrate_s = migrate_seconds
        n_moves = len(migration.moves) if migration is not None else 0
        moved_bytes = migration.total_bytes if migration is not None else 0.0
        refill_s = pipeline_fill_seconds(
            self.graph, self.profiles, self.schedule,
            self.true_cluster(), self.plan) if charge_refill else 0.0
        clock_before = self.clock
        self.clock += migrate_s + refill_s
        self._obs_cache = None
        self.telemetry.clear()   # a new schedule invalidates old samples
        self.runtime = DecentralizedRuntime(self.graph, self.schedule,
                                            self.plan,
                                            use_kernel=self.use_kernel,
                                            trace=self.tracer)
        # the detector's reference prediction must share the epoch's
        # compression plan AND the calibrated link corrections with the
        # telemetry it is compared against — a dense or spec-priced reference
        # over-predicts/under-predicts comm and lets a genuinely slowed node
        # hide below threshold (or flags a healthy one on a slow-but-known
        # link)
        model = self.believed_model(believed)
        self.detector = StragglerDetector(
            predict_step_times(self.graph, self.profiles, believed,
                               placement, cost_model=model),
            **self._det_cfg)
        # the pace this plan was installed at, under the broker's current
        # belief — the reference the calibration re-plan trigger diverges from
        self._installed_pace = model.stage_pace(self.schedule)
        self._steps_since_fit = 0
        self.epoch_records.append(EpochRecord(
            epoch=len(self.epoch_records), at_step=at_step, clock=self.clock,
            cause=cause, events=list(events),
            alive=list(self.membership.alive),
            stage_devices=self.schedule.stage_devices(),
            n_moves=n_moves, moved_bytes=moved_bytes,
            detect_seconds=detect_seconds, migrate_seconds=migrate_s,
            refill_seconds=refill_s, rollback_steps=rollback_steps,
            replan_mode=replan_mode, background_bytes=background_bytes,
            overlap_seconds=overlap_seconds))
        self._observe_epoch(self.epoch_records[-1], migration, model,
                            clock_before)

    def _observe_epoch(self, rec: EpochRecord,
                       migration: Optional[MigrationPlan],
                       model: EdgeCostModel, clock_before: float) -> None:
        """Fold one installed epoch into the observability layer: a flight
        record, the per-cause metrics, and sim-clock spans for the blocking
        migration's bulk transfers (shifted from the migration simulator's
        local origin to where the stall actually sat on the run clock)."""
        if self.flight is not None:
            self.flight.log(EpochFlightRecord(
                step=rec.at_step, clock=self.clock, epoch=rec.epoch,
                cause=rec.cause, stage_devices=list(rec.stage_devices),
                n_moves=rec.n_moves, moved_bytes=rec.moved_bytes,
                migrate_seconds=rec.migrate_seconds,
                refill_seconds=rec.refill_seconds,
                rollback_steps=rec.rollback_steps,
                replan_mode=rec.replan_mode))
        if self.metrics is not None:
            self.metrics.counter("replan_count", cause=rec.cause).inc()
            if rec.rollback_steps:
                self.metrics.counter("rollback_steps").inc(rec.rollback_steps)
            if rec.moved_bytes:
                self.metrics.counter("migrated_bytes", kind="blocking").inc(
                    rec.moved_bytes)
            if rec.background_bytes:
                self.metrics.counter("migrated_bytes", kind="background").inc(
                    rec.background_bytes)
            planned, realized = self._compression_ratios(model)
            self.metrics.gauge("compression_ratio_planned").set(planned)
            self.metrics.gauge("compression_ratio_realized").set(realized)
        if self.tracer is not None and self.tracer.enabled:
            if migration is not None:
                for (t0, t1, label) in migration.sim.events:
                    self.tracer.span(
                        CAT_CHECKPOINT if "ckpt" in label else CAT_MIGRATION,
                        label, "migration", clock_before + t0,
                        clock_before + t1, args={"epoch": rec.epoch})
            self.tracer.instant(
                CAT_CONTROLLER, f"epoch:{rec.cause}", "controller",
                t=self.clock,
                args={"epoch": rec.epoch, "step": rec.at_step,
                      "mode": rec.replan_mode,
                      "stage_devices": list(rec.stage_devices)})

    def _compression_ratios(self, model: EdgeCostModel
                            ) -> Tuple[float, float]:
        """(planned, realized) aggregate compression over the installed
        plan's cross edges: planned is Σdense / Σ(dense/ratio) — what the
        plan asked for; realized is Σdense / Σwire at the exact integer wire
        encoding — what the wire actually carries (index overhead included).
        Both 1.0 for an uncompressed epoch."""
        dense = asked = wire = 0.0
        for (a, n) in model.cross_edges(self.schedule.placement):
            d = model.dense_bytes(a)
            dense += d
            asked += d / max(model.ratio(a, n), 1.0)
            wire += model.edge_wire_bytes(a, n)
        if dense <= 0.0:
            return 1.0, 1.0
        return dense / max(asked, 1e-12), dense / max(wire, 1e-12)

    def _cur_step(self) -> int:
        """Data step the run loop last completed (0 before any step)."""
        return self.step_records[-1].step if self.step_records else 0

    @property
    def epoch(self) -> int:
        return len(self.epoch_records) - 1

    # -------------------------------------------------------------- run ----
    def run(self, steps: int,
            data_fn: Optional[Callable[[int], Sequence[Mapping]]] = None,
            params: Any = None) -> ElasticRunResult:
        """Train (or simulate) ``steps`` useful data steps through churn.

        ``data_fn(step)`` must return the micro-batch list for that data step
        deterministically — after a rollback the controller replays step
        indices and must see identical batches.  ``params`` starts training;
        with ``data_fn=None`` the controller runs timing-only.
        """
        train = data_fn is not None
        if train and (params is None or self.optimizer is None):
            raise ValueError("training mode needs params and an optimizer")
        opt_state = self.optimizer.init(params) if train else None
        ckpts: List[_Checkpoint] = [_Checkpoint(
            step=0, clock=self.clock,
            blob=serialize_state(params, opt_state) if train else None)]

        step = 0          # next data step to execute
        while step < steps:
            loss_val = None
            if train:
                mbs = data_fn(step)
                loss, grads = self.runtime.train_step(params, mbs)
                params, opt_state = self.optimizer.update(grads, opt_state,
                                                          params)
                loss_val = float(loss)
            sim_time = self._step_timing(step)
            self.clock += sim_time
            if self.watchdog is not None:
                self.watchdog.observe_step(step, self.clock, sim_time)
            step += 1
            self.step_records.append(StepRecord(
                step=step, epoch=self.epoch, loss=loss_val,
                step_seconds=sim_time, clock=self.clock,
                overlapping=self._migrating is not None))
            if self.metrics is not None:
                self.metrics.histogram("step_seconds").observe(sim_time)
                ef = self.runtime.ef_state
                if ef:
                    for a in sorted(ef):
                        self.metrics.gauge("ef_residual_norm", edge=a).set(
                            float(np.linalg.norm(np.asarray(ef[a]))))
            # a degraded node shows up as aggregated telemetry > prediction
            self.detector.observe(self.telemetry.node_step_times())
            self._steps_since_fit += 1
            if step % self.checkpoint_interval == 0:
                ckpts.append(_Checkpoint(
                    step=step, clock=self.clock,
                    blob=serialize_state(params, opt_state) if train
                    else None))
                del ckpts[:-self.checkpoint_history]

            transition = None
            if self._migrating is not None:
                self._migrating.progressed += sim_time
                if self._migrating.progressed >= self._migrating.bg_seconds:
                    params, opt_state = self._cutover(
                        params, opt_state, train, residual=0.0, at_step=step)
                else:
                    # a membership change mid-stream forces the cut-over
                    # (residual charged blocking), then is handled normally;
                    # recover announcements that ripen mid-stream are
                    # deferred for the next _pending_transition poll
                    deltas = self.membership.poll(self.clock)
                    self._deferred_deltas.extend(
                        d for d in deltas if d.event.kind == "recover")
                    member = [d for d in deltas
                              if d.event.kind in ("leave", "join")]
                    if member:
                        residual = (self._migrating.bg_seconds
                                    - self._migrating.progressed)
                        params, opt_state = self._cutover(
                            params, opt_state, train, residual=residual,
                            at_step=step)
                        cause = "failure" if any(
                            d.event.kind == "leave" for d in member) \
                            else "join"
                        transition = (cause, member)
            else:
                transition = self._pending_transition()
            if transition is None:
                continue
            cause, deltas = transition
            dead = [d.event.node for d in deltas if d.event.kind == "leave"]
            detect_s = max((self.clock - d.event.time for d in deltas),
                           default=0.0)

            rollback_steps = 0
            failure_times = [d.event.time for d in deltas
                             if d.event.kind == "leave"]
            need_rollback = bool(failure_times) and any(
                self.schedule.assignment[n] for n in dead)
            if need_rollback:
                # state shards on the dead node are gone: recover from the
                # newest checkpoint that predates the failure
                t_fail = min(failure_times)
                valid = [c for c in ckpts if c.clock <= t_fail]
                if not valid:
                    raise RuntimeError(
                        "no checkpoint predates the failure — raise "
                        "checkpoint_history or lower checkpoint_interval")
                ck = valid[-1]
                rollback_steps = step - ck.step
                if train:
                    params, opt_state = deserialize_state(ck.blob, params,
                                                          opt_state)
                for r in self.step_records:
                    if r.step > ck.step:
                        r.lost = True
                step = ck.step
                ckpts = [c for c in ckpts if c.step <= ck.step]

            joined = [d.event.node for d in deltas if d.event.kind == "join"]
            rp = self._replan(dead, joined)
            plan_only = False
            if cause == "calibration":
                same_assign = \
                    rp.schedule.assignment == self.schedule.assignment
                new_plan = self.plan_factory(self.graph, self.profiles,
                                             self.believed_cluster(),
                                             rp.schedule.placement)
                if same_assign and new_plan == self.plan:
                    # calibration confirmed the active plan (schedule AND
                    # compression) is still the best response — no epoch
                    # change, no migration, no refill
                    self._record_replan(step, cause, dead, joined, rp,
                                        plan_only=False, confirmed=True)
                    continue
                # same cut, re-allocated compression: a hot plan swap moves
                # no state and never stalls the pipeline
                plan_only = same_assign
            self._record_replan(step, cause, dead, joined, rp,
                                plan_only=plan_only)
            if self.migration_mode == "overlap":
                self._begin_overlap(rp, cause=cause,
                                    events=[d.event for d in deltas],
                                    dead=dead, at_step=step,
                                    detect_seconds=detect_s,
                                    rollback_steps=rollback_steps)
            else:
                if train:
                    live = [m for m in rp.migration.moves
                            if not m.from_checkpoint]
                    before = params
                    out = apply_moves(params, opt_state, live)
                    assert_bitexact(before, out.params, "migrated params")
                    params, opt_state = out.params, out.opt_state
                self._install_schedule(cause=cause,
                                       events=[d.event for d in deltas],
                                       dead=dead, at_step=step,
                                       detect_seconds=detect_s,
                                       migration=rp.migration,
                                       rollback_steps=rollback_steps,
                                       replan_mode=rp.mode,
                                       schedule=rp.schedule,
                                       charge_refill=not plan_only)
        return ElasticRunResult(steps=self.step_records,
                                epochs=self.epoch_records,
                                params=params, opt_state=opt_state,
                                total_seconds=self.clock)

    # --------------------------------------------------- overlap machinery --
    def _begin_overlap(self, rp: ReplanResult, cause: str,
                       events: List[ChurnEvent], dead: Sequence[int],
                       at_step: int, detect_seconds: float,
                       rollback_steps: int) -> None:
        """Start an overlapped migration toward ``rp.schedule``.

        Blocking phase (foreground, training stopped): only the dead
        CompNodes' shards, streamed from the checkpoint store into the
        interim schedule's hosts.  Everything else drains in the background
        while training continues on the interim (or unchanged old) schedule
        over bandwidth-shared links; `_cutover` finishes the epoch change.

        Stream-vs-keep decision: streaming only pays when the target's
        steady-state pace covers the foreground slowdown during the stream
        within ``amortize_steps`` — fair-share conservation means bytes
        crossing the pipeline's own bottleneck wire cannot be hidden, so a
        pace-equivalent target is not worth migrating to at all and the
        interim schedule simply becomes the epoch's schedule
        (``replan_mode="interim-final"``).
        """
        old = self.schedule
        believed = self.believed_cluster()
        dead_with_ops = [d for d in dead if old.assignment[d]]
        if dead_with_ops:
            interim = interim_schedule(self.graph, old, dead,
                                       len(self.base_cluster))
            if interim is None:
                raise RuntimeError("no surviving stage to host the interim "
                                   "schedule")
            # only the dead segments differ between old and interim, so the
            # diff is exactly the blocking checkpoint-restore set
            blocking = diff_schedules(old, interim, self.profiles, dead=dead,
                                      opt_state_mult=self.opt_state_mult)
            migration = MigrationPlan(
                moves=blocking,
                sim=simulate_migration(_group_transfers(blocking), believed))
            charge_refill = True          # rollback left the pipeline cold
        else:
            interim = old                 # pipeline keeps running warm
            migration, charge_refill = None, False

        moves = diff_schedules(interim, rp.schedule, self.profiles,
                               dead=(), opt_state_mult=self.opt_state_mult)
        stream = None
        if moves:
            bg_sim = simulate_migration(
                _group_transfers(moves), believed,
                bandwidth_fraction=1.0 - self.overlap_bandwidth_share)
            # the stream contends per link: only the wires it actually
            # rides slow the foreground (a bulk flow on a fast intra-cluster
            # link must not throttle the WAN edge bounding the pipeline)
            busy = tuple(sorted({(m.src, m.dst) for m in moves
                                 if m.src is not None}))
            if self._stream_pays_off(interim, rp.schedule, believed, busy,
                                     bg_sim.seconds):
                stream = _OverlapState(
                    target=rp.schedule, replan_mode=rp.mode, moves=moves,
                    bg_seconds=bg_sim.seconds, busy=busy)
        self._install_schedule(
            cause=cause, events=events, dead=dead, at_step=at_step,
            detect_seconds=detect_seconds, migration=migration,
            rollback_steps=rollback_steps,
            replan_mode="interim" if stream is not None else "interim-final",
            schedule=interim, charge_refill=charge_refill)
        self._migrating = stream
        self._obs_cache = None   # foreground now runs on shared links

    def _stream_pays_off(self, interim: Schedule, target: Schedule,
                         believed: ClusterSpec,
                         busy: Tuple[Tuple[int, int], ...],
                         bg_seconds: float) -> bool:
        """Broker-side cost model (on the believed topology): stream when
        ``slowdown_waste + amortize_steps · pace(target)`` beats
        ``amortize_steps · pace(interim)``."""
        def pace(schedule: Schedule, cluster: ClusterSpec) -> float:
            plan = self.plan_factory(self.graph, self.profiles, believed,
                                     schedule.placement)
            return simulate_iteration(
                self.graph, self.profiles, schedule, cluster,
                n_micro=self.n_micro,
                cost_model=self.believed_model(cluster, plan)
            ).iteration_time

        t_interim = pace(interim, believed)
        t_target = pace(target, believed)
        t_shared = pace(interim, with_shared_links(
            believed, busy, self.overlap_bandwidth_share))
        n_stream_steps = bg_seconds / max(t_shared, 1e-12)
        waste = n_stream_steps * (t_shared - t_interim)
        return (waste + self.amortize_steps * t_target
                < self.amortize_steps * t_interim)

    def _cutover(self, params: Any, opt_state: Any, train: bool,
                 residual: float, at_step: int) -> Tuple[Any, Any]:
        """Finish an overlapped migration: charge the residual stream time
        (blocking), install the target schedule, and apply the background
        moves bit-exactly.

        No refill is charged here: a cut-over is a *hot* hand-off between
        two warm schedules at a step boundary, and the per-step simulator
        already replays a full GPipe fill+drain every iteration — unlike the
        blocking path, where the whole pipeline sat empty during the stall.
        """
        mig = self._migrating
        self._migrating = None
        if train:
            before = params
            out = apply_moves(params, opt_state, mig.moves)
            assert_bitexact(before, out.params, "migrated params")
            params, opt_state = out.params, out.opt_state
        self._install_schedule(
            cause="cutover", events=[], dead=[], at_step=at_step,
            detect_seconds=0.0,
            migration=MigrationPlan(moves=mig.moves, sim=simulate_migration(
                {}, self.base_cluster)),
            rollback_steps=0, replan_mode=mig.replan_mode,
            schedule=mig.target, migrate_seconds=residual,
            charge_refill=False,
            background_bytes=float(sum(m.nbytes for m in mig.moves)),
            overlap_seconds=min(mig.progressed, mig.bg_seconds))
        return params, opt_state

    def _step_timing(self, step: int) -> float:
        """Simulated iteration seconds under the ground-truth cluster (shared
        links while a background migration streams).  The simulator's
        per-stage StepTiming samples are recorded into the broker telemetry,
        stamped with the data step.  Pure function of (schedule, true
        slowdowns, background-busy set), which only change at churn events
        or re-plans — cached so the per-step hot loop skips the sweeps."""
        busy = self._migrating.busy if self._migrating is not None else ()
        key = (tuple(sorted(self.membership.slow_factor.items())),
               tuple(sorted(self.membership.link_factor.items())), busy)
        tracing = self.tracer is not None and self.tracer.enabled
        if self._obs_cache is None or self._obs_cache[0] != key:
            true_cl = self.true_cluster()
            if busy:
                true_cl = with_shared_links(
                    true_cl, busy, self.overlap_bandwidth_share)
            sink = TelemetrySink()
            # spans are captured once per regime into a local recorder at a
            # zero origin and replayed per step at the step's clock offset —
            # the simulator itself runs identically with tracing on or off
            span_rec = TraceRecorder() if tracing else None
            # ground-truth codec pricing: the sim charges what encode really
            # costs on each host (kernel_costs), never the broker's belief
            true_model = EdgeCostModel(
                self.graph, self.profiles, true_cl, self.plan,
                kernel_costs=self.kernel_costs) if self.kernel_costs else None
            sim = simulate_iteration(self.graph, self.profiles, self.schedule,
                                     true_cl, self.plan,
                                     n_micro=self.n_micro, telemetry=sink,
                                     trace=span_rec, cost_model=true_model)
            busy_totals = (float(sum(sim.device_busy)),
                           float(sim.link_busy),
                           float(sim.compress_busy))
            self._obs_cache = (key, sim.iteration_time, sink.samples,
                               sink.link_samples, sink.kernel_samples,
                               tuple(span_rec.events()) if span_rec else (),
                               busy_totals)
        (_, sim_time, samples, link_samples, kernel_samples, spans,
         busy_totals) = self._obs_cache
        if self.metrics is not None:
            # the simulator's own busy accounting, accumulated per step:
            # the critpath CLI's --expect-busy gate checks the trace-derived
            # attribution against these totals (CI fails on >1% drift)
            dev_busy, link_busy, codec_busy = busy_totals
            self.metrics.counter("sim_device_busy_seconds").inc(dev_busy)
            self.metrics.counter("sim_link_busy_seconds").inc(link_busy)
            self.metrics.counter("sim_compress_busy_seconds").inc(codec_busy)
        if tracing and spans:
            # (step, epoch) identifies one execution attempt: after a
            # rollback the same data step re-executes under the next epoch,
            # and the happens-before checker must not pair spans across the
            # two attempts
            self.tracer.replay(spans, dt=self.clock,
                               extra_args={"step": step,
                                           "epoch": len(self.epoch_records)})
        self.telemetry_bus.record_step(samples, step=step)
        # codec samples are device-local compute — unaffected by stream
        # contention on the wire, so they record even while migrating
        self.telemetry_bus.record_kernel_step(kernel_samples, step=step)
        if self._migrating is None:
            # link observations taken while a background stream contends on
            # the wire measure the (transient) shared bandwidth, not the
            # link's truth — calibrating on them would thrash
            self.telemetry_bus.record_link_step(link_samples, step=step)
        return sim_time

    # ------------------------------------------------------- transitions ---
    def _pending_transition(self):
        """Poll membership + detector; decide whether an epoch change is due.
        Returns (cause, deltas) or None."""
        deltas = self._deferred_deltas + self.membership.poll(self.clock)
        self._deferred_deltas = []
        member_deltas = [d for d in deltas
                         if d.event.kind in ("leave", "join")]
        if member_deltas:
            cause = "failure" if any(d.event.kind == "leave"
                                     for d in member_deltas) else "join"
            return cause, member_deltas
        flagged = {d: f for d, f in self.detector.believed_factors().items()
                   if self.believed_factors.get(d) is None}
        if flagged:
            self.believed_factors.update(flagged)
            if self.metrics is not None:
                self.metrics.counter("detector_trips").inc(len(flagged))
            for d, f in sorted(flagged.items()):
                if self.flight is not None:
                    self.flight.log(DetectorRecord(
                        step=self._cur_step(), clock=self.clock, node=int(d),
                        severity=float(self.detector.severity(d)),
                        believed_factor=float(f)))
                if self.tracer is not None and self.tracer.enabled:
                    self.tracer.instant(
                        CAT_CONTROLLER, f"detector:flag dev{int(d)}",
                        "controller", t=self.clock,
                        args={"node": int(d), "believed_factor": float(f)})
            return "straggler", []
        recovered = self._rehabilitated()
        # a node drained of ops has no observable stage time; trust its own
        # recovery announcement (the membership view surfaces the event)
        recovered += [d.event.node for d in deltas
                      if d.event.kind == "recover"
                      and d.event.node in self.believed_factors
                      and d.event.node not in recovered
                      and self.detector.stats.get(d.event.node) is None]
        if recovered:
            for d in recovered:
                del self.believed_factors[d]
            return "recovery", []
        if self._calibration_due():
            return "calibration", []
        return None

    # ------------------------------------------------------- calibration ---
    def _calibration_due(self) -> bool:
        """Run the periodic auto-calibration when its window has elapsed;
        True when the newly calibrated belief diverges from the active plan
        far enough that a re-plan is warranted."""
        if not self.calibrate_interval:
            return False
        if self._steps_since_fit < self.calibrate_interval:
            return False
        self._steps_since_fit = 0
        return self._calibrate()

    def _calibrate(self) -> bool:
        """Fit per-link corrections and per-device codec costs from the
        telemetry window and fold the survivors into the broker's belief.

        The fit always runs against the *uncorrected* base spec
        (``base_cluster``) — corrections are absolute and replace what is
        installed, so repeated re-fits converge on the measured ratio instead
        of compounding through the clamp (see
        :func:`repro.core.costmodel.fit_link_corrections`).  Hysteresis: a
        fitted value within ``calibrate_hysteresis`` (relative) of the
        installed one is noise, not drift — ignored, so a single noisy
        window cannot thrash the schedule.  Values that return to within the
        band of 1.0 drop their correction outright (the link healed).

        On any accepted change the detector is *repriced* in place (same
        schedule, new reference — EWMA history survives) and the active
        plan's calibrated pace is compared against the pace it was installed
        at: divergence beyond ``replan_pace_margin`` returns True, which the
        transition poll turns into a ``"calibration"`` epoch change.
        """
        samples = self.telemetry.link_samples(
            min_steps=self.calibrate_min_samples)
        kernel_window = self.telemetry.kernel_samples(
            min_steps=self.calibrate_min_samples)
        if not samples and not kernel_window:
            return False
        fitted = fit_link_corrections(samples, self.base_cluster) \
            if samples else {}
        changed = False
        verdicts: Dict[Tuple[int, int], str] = {}
        for lk in sorted(fitted):
            new = fitted[lk]
            old = self.link_corrections.get(lk, 1.0)
            if abs(new - old) <= self.calibrate_hysteresis * old:
                verdicts[lk] = "hysteresis"
                continue
            if abs(new - 1.0) <= self.calibrate_hysteresis:
                self.link_corrections.pop(lk, None)
                verdicts[lk] = "healed"
            else:
                self.link_corrections[lk] = new
                verdicts[lk] = "adopted"
            changed = True
        # per-device codec costs, same hysteresis discipline on the fitted
        # throughput: the first fit always installs (belief moves from "free"
        # to measured), later fits only when they drift past the band
        for dev, kc in sorted(fit_kernel_costs(kernel_window).items()):
            old_kc = self.kernel_cost_belief.get(dev)
            if old_kc is not None and abs(
                    kc.bytes_per_second - old_kc.bytes_per_second) \
                    <= self.calibrate_hysteresis * old_kc.bytes_per_second:
                continue
            self.kernel_cost_belief[dev] = kc
            changed = True
        installed_pace_before = self._installed_pace
        diverged = False
        pace = installed_pace_before
        if changed:
            self.calibration_count += 1
            believed = self.believed_cluster()
            model = self.believed_model(believed)
            self.detector.reprice(
                predict_step_times(self.graph, self.profiles, believed,
                                   self.schedule.placement, cost_model=model))
            pace = model.stage_pace(self.schedule)
            diverged = self._installed_pace > 0.0 and \
                pace > (1.0 + self.replan_pace_margin) * self._installed_pace
            # re-arm on the freshly calibrated pace either way: the next
            # trigger needs *further* divergence, not the same one
            # re-observed every window (and a re-plan that keeps the
            # schedule must not loop)
            self._installed_pace = pace
        if changed and self.metrics is not None:
            self.metrics.counter("calibration_fits").inc()
            for lk, v in sorted(self.link_corrections.items()):
                self.metrics.gauge("link_correction",
                                   link=f"{lk[0]}->{lk[1]}").set(float(v))
            for dev, kc in sorted(self.kernel_cost_belief.items()):
                self.metrics.gauge("kernel_bytes_per_second", node=dev).set(
                    float(kc.bytes_per_second))
        if self.flight is not None:
            self.flight.log(CalibrationRecord(
                step=self._cur_step(), clock=self.clock,
                window=links_to_str({k: len(v) for k, v in samples.items()}),
                fitted=links_to_str({k: float(v)
                                     for k, v in fitted.items()}),
                verdicts=links_to_str(verdicts),
                installed=links_to_str({k: float(v) for k, v in
                                        self.link_corrections.items()}),
                repriced=changed, installed_pace=installed_pace_before,
                calibrated_pace=pace, diverged=diverged))
        if self.tracer is not None and self.tracer.enabled:
            self.tracer.instant(
                CAT_CONTROLLER, "calibration", "controller", t=self.clock,
                args={"fitted": links_to_str({k: round(float(v), 4)
                                              for k, v in fitted.items()}),
                      "repriced": changed, "diverged": diverged})
        return diverged

    def _rehabilitated(self) -> List[int]:
        """Believed-degraded nodes whose observations say they are healthy
        again.  The detector predicts with the *believed* (degraded) speed,
        so a fully recovered node shows severity ≈ its believed factor f
        (observed = believed_prediction · f); severity near or below f means
        the degradation is gone."""
        out = []
        for d, f in list(self.believed_factors.items()):
            st = self.detector.stats.get(d)
            if (st is not None and st.count >= self.detector.min_observations
                    and self.detector.severity(d) <= f * 1.05):
                out.append(d)
        return out

    def _replan_reason(self, cause: str, dead: Sequence[int],
                       joined: Sequence[int]) -> str:
        """Human-readable trigger description for the flight log."""
        if cause == "failure":
            return f"lease expired: dead={sorted(int(d) for d in dead)}"
        if cause == "join":
            return f"admitted: joined={sorted(int(j) for j in joined)}"
        if cause == "straggler":
            flags = {int(d): round(float(f), 3)
                     for d, f in sorted(self.believed_factors.items())}
            return f"detector flagged believed factors {flags}"
        if cause == "recovery":
            return "believed stragglers rehabilitated"
        if cause == "calibration":
            return (f"calibrated pace of active plan diverged more than "
                    f"{self.replan_pace_margin:.0%} past its installed pace")
        return cause

    def _record_replan(self, at_step: int, cause: str, dead: Sequence[int],
                       joined: Sequence[int], rp: ReplanResult,
                       plan_only: bool, confirmed: bool = False) -> None:
        """One flight record per re-plan decision, every candidate priced —
        including the zero-migration ``keep`` when it was offered."""
        if self.flight is None and (
                self.tracer is None or not self.tracer.enabled):
            return
        reason = self._replan_reason(cause, dead, joined)
        if confirmed:
            reason += " (confirmed: same cut and plan — no epoch change)"
        if self.flight is not None:
            self.flight.log(ReplanRecord(
                step=at_step, clock=self.clock, cause=cause, reason=reason,
                dead=sorted(int(d) for d in dead),
                joined=sorted(int(j) for j in joined),
                candidates=[CandidateScore(**s) for s in rp.scores],
                winner=rp.mode, plan_only=plan_only))
        if self.tracer is not None and self.tracer.enabled:
            self.tracer.instant(
                CAT_CONTROLLER, f"replan:{cause}", "controller", t=self.clock,
                args={"winner": rp.mode, "reason": reason,
                      "scores": {s["name"]: round(s["score"], 6)
                                 for s in rp.scores}})

    def _replan(self, dead: Sequence[int],
                joined: Sequence[int] = ()) -> ReplanResult:
        for d in dead:
            self.believed_factors.pop(d, None)
        believed = self.believed_cluster()
        # re-plan under the epoch's compression plan AND the calibrated link
        # corrections: boundaries that persist across the re-cut keep their
        # compressed byte costs (edges the old plan never keyed fall back to
        # dense — the next epoch's plan_factory re-compresses them), and
        # every candidate is priced on the links as measured, not as spec'd
        model = self.believed_model(believed)
        return replan(self.graph, self.profiles, believed,
                      self.schedule, alive=self.membership.alive, dead=dead,
                      joined=joined, seed=self.seed,
                      opt_state_mult=self.opt_state_mult,
                      cost_model=model, mode=self.replan_mode,
                      amortize_steps=self.amortize_steps,
                      pin_boundaries=self.pin_boundaries,
                      planner=self.planner, joint_ratio=self.joint_ratio,
                      verify=self.verify)
