"""ElasticController: drives the FusionLLM runtime across membership epochs.

One epoch = one stable OP-Fence schedule.  Per training step the controller
(1) runs the real RAD numerics through :class:`DecentralizedRuntime` (unless
``train=False``), (2) advances a simulated wall-clock by the discrete-event
:func:`simulate_iteration` on the *ground-truth* cluster (scripted slowdowns
applied), (3) feeds observed per-stage times to the straggler detector, and
(4) polls the lease-based membership view.  On a detected failure, join,
straggler, or recovery it transitions epochs: re-plan via OP-Fence on the
survivors, migrate state bit-exactly through the checkpoint wire format, and
charge the simulated clock for what churn really costs:

    detection delay   — implicit: the clock kept running (wasted) between the
                        failure and its lease expiry / EWMA warm-up;
    lost work         — steps after the last checkpoint that predates the
                        failure are rolled back (their samples don't count);
    migration         — bulk state transfers over the real α–β links
                        (:func:`simulate_migration`);
    pipeline refill   — a fresh schedule starts cold (fill term of Eq. 3).

Determinism contract: same graph/cluster/trace/seeds → identical epochs,
schedules, clocks, and (when training) identical losses.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.checkpoint import deserialize_state, serialize_state
from repro.core.compression import CompressionPlan, plan_none
from repro.core.estimator import ClusterSpec, predict_step_times
from repro.core.executor import (DecentralizedRuntime, pipeline_fill_seconds,
                                 simulate_iteration)
from repro.core.network import with_slowdowns
from repro.core.opgraph import OpGraph, OpProfile
from repro.core.scheduler import Schedule, schedule_opfence
from repro.optim.optimizers import Optimizer

from .detector import StragglerDetector
from .membership import ChurnEvent, ChurnTrace, MembershipView
from .migrate import apply_moves, assert_bitexact
from .replan import MigrationPlan, ReplanResult, replan

PlanFactory = Callable[[OpGraph, Mapping[str, OpProfile], ClusterSpec,
                        Mapping[str, int]], CompressionPlan]


@dataclasses.dataclass
class StepRecord:
    step: int                  # data step index (replays after a rollback)
    epoch: int
    loss: Optional[float]
    step_seconds: float        # simulated iteration wall-clock
    clock: float               # cumulative simulated time at step end
    lost: bool = False         # rolled back by a later failure


@dataclasses.dataclass
class EpochRecord:
    epoch: int
    at_step: int               # first data step executed under this epoch
    clock: float               # sim time when the epoch began
    cause: str                 # initial | failure | join | straggler | recovery
    events: List[ChurnEvent]
    alive: List[int]
    stage_devices: List[int]
    n_moves: int
    moved_bytes: float
    detect_seconds: float      # event time -> broker noticing
    migrate_seconds: float
    refill_seconds: float
    rollback_steps: int
    replan_mode: str = ""      # auto-chosen candidate: full | anchored


@dataclasses.dataclass
class ElasticRunResult:
    steps: List[StepRecord]
    epochs: List[EpochRecord]
    params: Any
    opt_state: Any
    total_seconds: float

    @property
    def losses(self) -> List[Tuple[int, float]]:
        """(data step, loss) for surviving (non-rolled-back) steps."""
        return [(r.step, r.loss) for r in self.steps
                if not r.lost and r.loss is not None]

    @property
    def useful_steps(self) -> int:
        return sum(1 for r in self.steps if not r.lost)

    def samples_per_second(self, batch_size: int) -> float:
        if self.total_seconds <= 0:
            return float("inf")
        return self.useful_steps * batch_size / self.total_seconds


@dataclasses.dataclass
class _Checkpoint:
    step: int                  # state AFTER this many data steps
    clock: float               # sim time when taken
    blob: Optional[bytes]      # None in sim-only mode


class ElasticController:
    """Churn-tolerant training driver (see module docstring)."""

    def __init__(self, graph: OpGraph, profiles: Mapping[str, OpProfile],
                 cluster: ClusterSpec, trace: ChurnTrace,
                 optimizer: Optional[Optimizer] = None,
                 plan_factory: Optional[PlanFactory] = None,
                 n_micro: int = 2, seed: int = 0,
                 lease_s: float = 10.0,
                 checkpoint_interval: int = 1,
                 checkpoint_history: int = 8,
                 detector_alpha: float = 0.4,
                 detector_threshold: float = 1.8,
                 detector_min_obs: int = 3,
                 opt_state_mult: float = 2.0,
                 replan_mode: str = "auto",
                 amortize_steps: float = 100.0,
                 use_kernel: bool = False,
                 initial_alive: Optional[Sequence[int]] = None):
        self.graph = graph
        self.profiles = profiles
        self.base_cluster = cluster
        self.optimizer = optimizer
        self.plan_factory = plan_factory or (
            lambda g, prof, cl, placement: plan_none(g, placement))
        self.n_micro = int(n_micro)
        self.seed = int(seed)
        self.checkpoint_interval = max(1, int(checkpoint_interval))
        self.checkpoint_history = max(2, int(checkpoint_history))
        self.opt_state_mult = float(opt_state_mult)
        self.replan_mode = replan_mode
        self.amortize_steps = float(amortize_steps)
        self.use_kernel = use_kernel
        self._det_cfg = dict(alpha=detector_alpha,
                             threshold=detector_threshold,
                             min_observations=detector_min_obs)

        self.membership = MembershipView(len(cluster), trace, lease_s=lease_s,
                                         initial_alive=initial_alive)
        self.believed_factors: Dict[int, float] = {}
        self.epoch_records: List[EpochRecord] = []
        self.step_records: List[StepRecord] = []
        self.clock = 0.0
        self._install_schedule(cause="initial", events=[], dead=[],
                               at_step=0, detect_seconds=0.0,
                               migration=None, rollback_steps=0)

    # ----------------------------------------------------------- topology --
    def believed_cluster(self) -> ClusterSpec:
        """What the broker schedules against: base sheets degraded by the
        detector's confirmed slowdowns."""
        return with_slowdowns(self.base_cluster, self.believed_factors)

    def true_cluster(self) -> ClusterSpec:
        """Ground truth for the simulator: scripted slowdowns in force now."""
        return with_slowdowns(self.base_cluster,
                              self.membership.slow_factor)

    # ----------------------------------------------------------- epochs ----
    def _install_schedule(self, cause: str, events: List[ChurnEvent],
                          dead: Sequence[int], at_step: int,
                          detect_seconds: float,
                          migration: Optional[MigrationPlan],
                          rollback_steps: int,
                          replan_mode: str = "") -> None:
        believed = self.believed_cluster()
        if migration is None:     # initial epoch: schedule from scratch
            self.schedule = schedule_opfence(
                self.graph, self.profiles, believed, seed=self.seed,
                device_subset=self.membership.alive)
        placement = self.schedule.placement
        self.plan = self.plan_factory(self.graph, self.profiles, believed,
                                      placement)
        if migration is None:
            migrate_s = refill_s = 0.0
            n_moves, moved_bytes = 0, 0.0
        else:
            migrate_s = migration.seconds
            n_moves, moved_bytes = len(migration.moves), migration.total_bytes
            refill_s = pipeline_fill_seconds(self.graph, self.profiles,
                                             self.schedule,
                                             self.true_cluster(), self.plan)
            self.clock += migrate_s + refill_s
        self._obs_cache = None
        self.runtime = DecentralizedRuntime(self.graph, self.schedule,
                                            self.plan,
                                            use_kernel=self.use_kernel)
        self.detector = StragglerDetector(
            predict_step_times(self.graph, self.profiles, believed,
                               placement),
            **self._det_cfg)
        self.epoch_records.append(EpochRecord(
            epoch=len(self.epoch_records), at_step=at_step, clock=self.clock,
            cause=cause, events=list(events),
            alive=list(self.membership.alive),
            stage_devices=self.schedule.stage_devices(),
            n_moves=n_moves, moved_bytes=moved_bytes,
            detect_seconds=detect_seconds, migrate_seconds=migrate_s,
            refill_seconds=refill_s, rollback_steps=rollback_steps,
            replan_mode=replan_mode))

    @property
    def epoch(self) -> int:
        return len(self.epoch_records) - 1

    # -------------------------------------------------------------- run ----
    def run(self, steps: int,
            data_fn: Optional[Callable[[int], Sequence[Mapping]]] = None,
            params: Any = None) -> ElasticRunResult:
        """Train (or simulate) ``steps`` useful data steps through churn.

        ``data_fn(step)`` must return the micro-batch list for that data step
        deterministically — after a rollback the controller replays step
        indices and must see identical batches.  ``params`` starts training;
        with ``data_fn=None`` the controller runs timing-only.
        """
        train = data_fn is not None
        if train and (params is None or self.optimizer is None):
            raise ValueError("training mode needs params and an optimizer")
        opt_state = self.optimizer.init(params) if train else None
        ckpts: List[_Checkpoint] = [_Checkpoint(
            step=0, clock=self.clock,
            blob=serialize_state(params, opt_state) if train else None)]

        step = 0          # next data step to execute
        while step < steps:
            loss_val = None
            if train:
                mbs = data_fn(step)
                loss, grads = self.runtime.train_step(params, mbs)
                params, opt_state = self.optimizer.update(grads, opt_state,
                                                          params)
                loss_val = float(loss)
            sim_time, observed = self._step_timing()
            self.clock += sim_time
            step += 1
            self.step_records.append(StepRecord(
                step=step, epoch=self.epoch, loss=loss_val,
                step_seconds=sim_time, clock=self.clock))
            # a degraded node shows up as observed step time > prediction
            self.detector.observe(observed)
            if step % self.checkpoint_interval == 0:
                ckpts.append(_Checkpoint(
                    step=step, clock=self.clock,
                    blob=serialize_state(params, opt_state) if train
                    else None))
                del ckpts[:-self.checkpoint_history]

            transition = self._pending_transition()
            if transition is None:
                continue
            cause, deltas = transition
            dead = [d.event.node for d in deltas if d.event.kind == "leave"]
            detect_s = max((self.clock - d.event.time for d in deltas),
                           default=0.0)

            rollback_steps = 0
            failure_times = [d.event.time for d in deltas
                             if d.event.kind == "leave"]
            need_rollback = bool(failure_times) and any(
                self.schedule.assignment[n] for n in dead)
            if need_rollback:
                # state shards on the dead node are gone: recover from the
                # newest checkpoint that predates the failure
                t_fail = min(failure_times)
                valid = [c for c in ckpts if c.clock <= t_fail]
                if not valid:
                    raise RuntimeError(
                        "no checkpoint predates the failure — raise "
                        "checkpoint_history or lower checkpoint_interval")
                ck = valid[-1]
                rollback_steps = step - ck.step
                if train:
                    params, opt_state = deserialize_state(ck.blob, params,
                                                          opt_state)
                for r in self.step_records:
                    if r.step > ck.step:
                        r.lost = True
                step = ck.step
                ckpts = [c for c in ckpts if c.step <= ck.step]

            joined = [d.event.node for d in deltas if d.event.kind == "join"]
            rp = self._replan(dead, joined)
            if train:
                live = [m for m in rp.migration.moves
                        if not m.from_checkpoint]
                before = params
                out = apply_moves(params, opt_state, live)
                assert_bitexact(before, out.params, "migrated params")
                params, opt_state = out.params, out.opt_state
            self.schedule = rp.schedule
            self._install_schedule(cause=cause,
                                   events=[d.event for d in deltas],
                                   dead=dead, at_step=step,
                                   detect_seconds=detect_s,
                                   migration=rp.migration,
                                   rollback_steps=rollback_steps,
                                   replan_mode=rp.mode)
        return ElasticRunResult(steps=self.step_records,
                                epochs=self.epoch_records,
                                params=params, opt_state=opt_state,
                                total_seconds=self.clock)

    def _step_timing(self) -> Tuple[float, Dict[int, float]]:
        """(simulated iteration seconds, observed per-stage times) under the
        ground-truth cluster.  Both are pure functions of (schedule, true
        slowdowns), which only change at churn events or re-plans — cached
        so the per-step hot loop skips the estimator sweeps."""
        key = tuple(sorted(self.membership.slow_factor.items()))
        if self._obs_cache is not None and self._obs_cache[0] == key:
            return self._obs_cache[1], self._obs_cache[2]
        true_cl = self.true_cluster()
        sim = simulate_iteration(self.graph, self.profiles, self.schedule,
                                 true_cl, self.plan, n_micro=self.n_micro)
        observed = predict_step_times(self.graph, self.profiles, true_cl,
                                      self.schedule.placement)
        self._obs_cache = (key, sim.iteration_time, observed)
        return sim.iteration_time, observed

    # ------------------------------------------------------- transitions ---
    def _pending_transition(self):
        """Poll membership + detector; decide whether an epoch change is due.
        Returns (cause, deltas) or None."""
        deltas = self.membership.poll(self.clock)
        member_deltas = [d for d in deltas
                         if d.event.kind in ("leave", "join")]
        if member_deltas:
            cause = "failure" if any(d.event.kind == "leave"
                                     for d in member_deltas) else "join"
            return cause, member_deltas
        flagged = {d: f for d, f in self.detector.believed_factors().items()
                   if self.believed_factors.get(d) is None}
        if flagged:
            self.believed_factors.update(flagged)
            return "straggler", []
        recovered = self._rehabilitated()
        # a node drained of ops has no observable stage time; trust its own
        # recovery announcement (the membership view surfaces the event)
        recovered += [d.event.node for d in deltas
                      if d.event.kind == "recover"
                      and d.event.node in self.believed_factors
                      and d.event.node not in recovered
                      and self.detector.stats.get(d.event.node) is None]
        if recovered:
            for d in recovered:
                del self.believed_factors[d]
            return "recovery", []
        return None

    def _rehabilitated(self) -> List[int]:
        """Believed-degraded nodes whose observations say they are healthy
        again.  The detector predicts with the *believed* (degraded) speed,
        so a fully recovered node shows severity ≈ its believed factor f
        (observed = believed_prediction · f); severity near or below f means
        the degradation is gone."""
        out = []
        for d, f in list(self.believed_factors.items()):
            st = self.detector.stats.get(d)
            if (st is not None and st.count >= self.detector.min_observations
                    and self.detector.severity(d) <= f * 1.05):
                out.append(d)
        return out

    def _replan(self, dead: Sequence[int],
                joined: Sequence[int] = ()) -> ReplanResult:
        for d in dead:
            self.believed_factors.pop(d, None)
        return replan(self.graph, self.profiles, self.believed_cluster(),
                      self.schedule, alive=self.membership.alive, dead=dead,
                      joined=joined, seed=self.seed,
                      opt_state_mult=self.opt_state_mult,
                      mode=self.replan_mode,
                      amortize_steps=self.amortize_steps)
