"""Optimizers as pure pytree transforms (no optax dependency).

Each optimizer is an (init, update) pair packaged in :class:`Optimizer`;
state and params are arbitrary pytrees, so the same code drives the GSPMD
train step (sharded state), the FusionLLM decentralized runtime (per-
CompNode sub-trees — the paper's per-OP "Update" stage, §3.3), and unit
tests.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    inner: Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any], Tuple[Any, OptState]]
    # update(grads, state, params) -> (new_params, new_state)


def _tmap(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


# -------------------------------------------------------------- schedules --
def cosine_schedule(base_lr: float, total_steps: int, final_frac: float = 0.1
                    ) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        t = jnp.minimum(step.astype(jnp.float32), total_steps) / total_steps
        return base_lr * (final_frac + (1 - final_frac)
                          * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return lr


def linear_warmup_cosine(base_lr: float, warmup: int, total_steps: int,
                         final_frac: float = 0.1
                         ) -> Callable[[jax.Array], jax.Array]:
    cos = cosine_schedule(base_lr, max(total_steps - warmup, 1), final_frac)

    def lr(step):
        s = step.astype(jnp.float32)
        return jnp.where(s < warmup, base_lr * (s + 1) / warmup,
                         cos(jnp.maximum(s - warmup, 0)))
    return lr


def _as_sched(lr) -> Callable[[jax.Array], jax.Array]:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


# ------------------------------------------------------------------- clip --
def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return _tmap(lambda g: (g * scale).astype(g.dtype), grads), gn


# -------------------------------------------------------------------- SGD --
def sgd(lr=1e-2, momentum: float = 0.9, nesterov: bool = False,
        weight_decay: float = 0.0) -> Optimizer:
    sched = _as_sched(lr)

    def init(params):
        mom = _tmap(jnp.zeros_like, params) if momentum else None
        return OptState(step=jnp.zeros((), jnp.int32), inner=mom)

    def update(grads, state, params):
        lr_t = sched(state.step)
        if weight_decay:
            grads = _tmap(lambda g, p: g + weight_decay * p, grads, params)
        if momentum:
            mom = _tmap(lambda m, g: momentum * m + g, state.inner, grads)
            eff = _tmap(lambda m, g: momentum * m + g, mom, grads) \
                if nesterov else mom
            inner = mom
        else:
            eff, inner = grads, None
        new_p = _tmap(lambda p, g: (p - lr_t * g).astype(p.dtype), params, eff)
        return new_p, OptState(step=state.step + 1, inner=inner)

    return Optimizer(init=init, update=update)


# ------------------------------------------------------------------ AdamW --
def adamw(lr=3e-4, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    sched = _as_sched(lr)

    def init(params):
        return OptState(
            step=jnp.zeros((), jnp.int32),
            inner={"m": _tmap(lambda p: jnp.zeros_like(p, jnp.float32), params),
                   "v": _tmap(lambda p: jnp.zeros_like(p, jnp.float32), params)})

    def update(grads, state, params):
        t = state.step + 1
        lr_t = sched(state.step)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        m = _tmap(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                  state.inner["m"], grads)
        v = _tmap(lambda v_, g: b2 * v_ + (1 - b2)
                  * jnp.square(g.astype(jnp.float32)),
                  state.inner["v"], grads)

        def step_fn(p, m_, v_):
            upd = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            return (p - lr_t * (upd + weight_decay * p.astype(jnp.float32))
                    ).astype(p.dtype)
        new_p = _tmap(step_fn, params, m, v)
        return new_p, OptState(step=t, inner={"m": m, "v": v})

    return Optimizer(init=init, update=update)


# -------------------------------------------------------------- Adafactor --
def adafactor(lr=1e-3, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0) -> Optimizer:
    """Factored second moment for matrices (memory-lean option for the
    biggest configs); falls back to full accumulators on <2D leaves."""
    sched = _as_sched(lr)

    def _facts(p):
        if p.ndim < 2:
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}

    def init(params):
        return OptState(step=jnp.zeros((), jnp.int32),
                        inner=_tmap(_facts, params))

    def update(grads, state, params):
        t = state.step + 1
        lr_t = sched(state.step)
        beta = 1.0 - (t.astype(jnp.float32) + 1.0) ** (-decay)

        def upd(p, g, f):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if p.ndim < 2:
                v = beta * f["v"] + (1 - beta) * g2
                u = g32 / jnp.sqrt(v + eps)
                nf = {"v": v}
            else:
                r = beta * f["r"] + (1 - beta) * jnp.mean(g2, axis=-1)
                c = beta * f["c"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = (r[..., None] * c[..., None, :]
                         / jnp.maximum(jnp.mean(r, axis=-1, keepdims=True)
                                       [..., None], eps))
                u = g32 / jnp.sqrt(denom + eps)
                nf = {"r": r, "c": c}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (p - lr_t * u).astype(p.dtype), nf

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_f = tdef.flatten_up_to(state.inner)
        outs = [upd(p, g, f) for p, g, f in zip(flat_p, flat_g, flat_f)]
        new_p = tdef.unflatten([o[0] for o in outs])
        new_f = tdef.unflatten([o[1] for o in outs])
        return new_p, OptState(step=t, inner=new_f)

    return Optimizer(init=init, update=update)
