from .optimizers import (OptState, adamw, sgd, adafactor, clip_by_global_norm,
                         cosine_schedule, linear_warmup_cosine, Optimizer)
