"""Production mesh definitions.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before the
first jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: 16×16 = 256 chips per pod; the multi-pod
    variant adds a leading pod axis (2 × 256 = 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever this host has (1 CPU device in the container) — smoke tests
    and examples run on this."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
