"""Training launcher.

Two modes (DESIGN.md §4):
* ``gspmd``  — jitted train_step on the local mesh (the production path at
  container scale: 1 CPU device; on a pod the same code sees 256 chips);
* ``fusion`` — the paper's decentralized runtime: OP-Fence schedule over a
  simulated geo cluster, RAD executor with AdaTopK compression; reports the
  REAL loss curve plus the SIMULATED per-iteration wall time on the chosen
  testbed.

    PYTHONPATH=src python -m repro.launch.train --arch gpt2-xl --size smoke \
        --mode fusion --steps 50 --compress adatopk --ratio 100

Reporting goes through :mod:`repro.obs.slog` — ``event k=v`` lines on
stderr honoring ``--log-level``/``--quiet``, every numeric field mirrored
into a :class:`repro.obs.metrics.MetricsRegistry` gauge.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import MetricsRegistry
from repro.obs import slog


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-xl")
    ap.add_argument("--size", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--mode", choices=["gspmd", "fusion"], default="gspmd")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--compress", choices=["none", "uniform", "adatopk"],
                    default="none")
    ap.add_argument("--ratio", type=float, default=100.0)
    ap.add_argument("--testbed", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    slog.add_logging_args(ap)
    args = ap.parse_args()
    metrics = MetricsRegistry()
    log = slog.get_logger("train", metrics=metrics,
                          level=slog.level_from_args(args))

    from repro.configs import resolve
    from repro.data import SyntheticLM
    from repro.optim import adamw, linear_warmup_cosine
    from repro.checkpoint import save_checkpoint

    entry = resolve(args.arch)
    cfg = entry.smoke if args.size == "smoke" else entry.full
    cfg = cfg.replace(max_seq=max(cfg.max_seq, args.seq))
    ds = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq, seed=0)
    opt = adamw(linear_warmup_cosine(args.lr, 10, args.steps),
                weight_decay=0.0)

    if args.mode == "gspmd":
        losses = _train_gspmd(cfg, ds, opt, args, log)
    else:
        losses = _train_fusion(cfg, ds, opt, args, log)
    log.event("train_done", mode=args.mode, steps=args.steps,
              final_loss=losses[-1], start_loss=losses[0])


def _train_gspmd(cfg, ds, opt, args, log):
    from repro.distributed.steps import make_train_step
    from repro.models import causal_lm
    from repro.checkpoint import save_checkpoint

    params = causal_lm.init(cfg, jax.random.PRNGKey(0))
    state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt))
    losses = []
    t0 = time.time()
    for i in range(args.steps):
        b = ds.batch(args.batch, i)
        batch = {"tokens": jnp.asarray(b["tokens"]),
                 "labels": jnp.asarray(b["labels"])}
        params, state, metrics = step_fn(params, state, batch)
        losses.append(float(metrics["loss"]))
        if i % args.log_every == 0:
            log.event("train_step", step=i, loss=losses[-1],
                      s_per_step=(time.time() - t0) / (i + 1))
        if args.ckpt_dir and args.ckpt_every and (i + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, i + 1, params,
                            metadata={"arch": cfg.name, "mode": "gspmd"})
    return losses


def _train_fusion(cfg, ds, opt, args, log):
    from repro.core import (network, plan_adatopk, plan_none, plan_uniform,
                            schedule_opfence, simulate_iteration,
                            PipelineProgram, pipeline_loss_and_grad)
    from repro.models.opgraph_models import gpt_opgraph

    graph = gpt_opgraph(cfg, args.batch, args.seq)
    shapes = {"tokens": (args.batch, args.seq),
              "labels": (args.batch, args.seq)}
    prof = graph.annotate(shapes)
    cluster = network.paper_testbed(args.testbed, seed=0)
    sch = schedule_opfence(graph, prof, cluster)
    plan = {"none": lambda: plan_none(graph, sch.placement),
            "uniform": lambda: plan_uniform(graph, sch.placement, args.ratio),
            "adatopk": lambda: plan_adatopk(graph, prof, cluster,
                                            sch.placement, args.ratio)
            }[args.compress]()
    sim = simulate_iteration(graph, prof, sch, cluster, plan, n_micro=2)
    log.event("fusion_plan", testbed=args.testbed,
              stages=len(sch.stage_devices()),
              sim_iteration_s=sim.iteration_time,
              comm_mb=sim.comm_bytes / 1e6)
    prog = PipelineProgram.build(graph, sch.pipeline_subdags(graph))
    params = graph.init(jax.random.PRNGKey(0), shapes)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        loss, grads = pipeline_loss_and_grad(prog, params, batch, plan)
        params, state = opt.update(grads, state, params)
        return params, state, loss

    losses = []
    for i in range(args.steps):
        b = ds.batch(args.batch, i)
        batch = {"tokens": jnp.asarray(b["tokens"]),
                 "labels": jnp.asarray(b["labels"])}
        params, state, loss = step(params, state, batch)
        losses.append(float(loss))
        if i % args.log_every == 0:
            log.event("train_step", step=i, loss=losses[-1],
                      sim_wall_s=sim.iteration_time * (i + 1))
    return losses


if __name__ == "__main__":
    main()
