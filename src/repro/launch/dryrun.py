import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape) the step is lowered and compiled on
the production mesh — 16×16 single pod AND 2×16×16 multi-pod — with
ShapeDtypeStruct inputs (no allocation).  ``memory_analysis()`` proves the
per-device footprint; ``cost_analysis()`` + the HLO collective parse feed
§Roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both

The two os.environ lines above MUST stay before any other import: jax locks
the device count at first initialization.

Sweep progress is reported as :mod:`repro.obs.slog` structured events
(``--log-level``/``--quiet`` apply); per-run JSON artifacts are unchanged.
"""
import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp


def run_one(arch_id: str, shape_name: str, multi_pod: bool,
            out_dir: Optional[str] = None, save_hlo: bool = False,
            unroll: bool = False, overrides_name: Optional[str] = None,
            dtype: str = "bfloat16") -> dict:
    from repro.analysis.hlo import collective_bytes, collective_breakdown
    from repro.analysis.model_flops import model_flops
    from repro.analysis.roofline import roofline_terms
    from repro.configs import INPUT_SHAPES, resolve
    from repro.distributed.sharding import use_mesh
    from repro.distributed.steps import build_jitted
    from repro.launch.mesh import make_production_mesh
    from repro.models.scan_config import set_unroll
    from repro.perf import overrides as perf_overrides

    entry = resolve(arch_id)
    shape = INPUT_SHAPES[shape_name]
    if shape_name not in entry.shapes:
        return {"arch": arch_id, "shape": shape_name, "status": "skipped",
                "note": entry.skip_notes}
    dt = jnp.bfloat16 if dtype == "bfloat16" else jnp.float32
    cfg = entry.full.replace(dtype=dt, param_dtype=dt,
                             remat=(shape.kind == "train"))
    from repro.perf import overrides as _ov
    _povr = _ov.get(overrides_name) if overrides_name else None
    if _povr and _povr.get("cfg"):
        cfg = cfg.replace(**_povr["cfg"])
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
           "chips": mesh.size, "kind": shape.kind, "unroll": bool(unroll)}
    t0 = time.time()
    try:
        rules = {}
        if shape.global_batch < mesh.shape.get("data", 1):
            rules["batch"] = None
        povr = perf_overrides.get(overrides_name) if overrides_name else None
        rules.update((povr or {}).get("rules", {}))
        with use_mesh(mesh, rules), set_unroll(True if unroll else 1):
            fn, args, _meta = build_jitted(
                cfg, mesh, shape,
                param_overrides=(povr or {}).get("param_overrides"))
            lowered = fn.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        text = compiled.as_text()
        coll = collective_bytes(text)
        rec.update({
            "status": "ok",
            "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            "mem": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_per_device": (ma.argument_size_in_bytes
                                    + ma.temp_size_in_bytes
                                    + ma.output_size_in_bytes
                                    - ma.alias_size_in_bytes),
            },
            "flops_per_device": float(ca.get("flops", 0.0)),
            "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
            "collective_bytes_per_device": coll,
            "collective_breakdown": collective_breakdown(text),
            "model_flops_total": model_flops(cfg, shape),
        })
        terms = roofline_terms(rec["flops_per_device"],
                               rec["bytes_per_device"], coll,
                               rec["model_flops_total"], chips=mesh.size)
        rec["roofline"] = terms.as_row()
        if save_hlo and out_dir:
            os.makedirs(out_dir, exist_ok=True)
            hp = os.path.join(out_dir, f"{arch_id}__{shape_name}__{mesh_name}"
                              + ("__unroll" if unroll else "") + ".hlo.txt")
            with open(hp, "w") as f:
                f.write(text)
            rec["hlo_path"] = hp
    except Exception as e:  # noqa: BLE001 — record and keep sweeping
        rec.update({"status": "fail", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-3000:]})
    rec["total_s"] = round(time.time() - t0, 2)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = ("__unroll" if unroll else "") + \
            (f"__{overrides_name}" if overrides_name else "")
        path = os.path.join(
            out_dir, f"{arch_id}__{shape_name}__{mesh_name}{suffix}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=2, default=str)
    return rec


def main() -> None:
    from repro.configs import ARCH_IDS, INPUT_SHAPES, resolve
    from repro.obs import MetricsRegistry
    from repro.obs import slog

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"],
                    default="off")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="fully unroll layer scans (exact cost analysis; "
                         "slow compiles)")
    ap.add_argument("--overrides", default=None,
                    help="named perf-override set (repro.perf.overrides)")
    ap.add_argument("--skip-existing", action="store_true")
    slog.add_logging_args(ap)
    args = ap.parse_args()
    log = slog.get_logger("dryrun", metrics=MetricsRegistry(),
                          level=slog.level_from_args(args))

    assigned = [a for a in ARCH_IDS if a != "gpt2-xl"]
    archs = assigned if (args.all or not args.arch) else [args.arch]
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    pods = {"off": [False], "on": [True], "both": [False, True]}[
        args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                mesh_name = "2x16x16" if mp else "16x16"
                if args.skip_existing:
                    suffix = ("__unroll" if args.unroll else "") + \
                        (f"__{args.overrides}" if args.overrides else "")
                    p = os.path.join(args.out,
                                     f"{arch}__{shape}__{mesh_name}{suffix}.json")
                    if os.path.exists(p):
                        log.event("dryrun_cached", arch=arch, shape=shape,
                                  mesh=mesh_name)
                        continue
                rec = run_one(arch, shape, mp, args.out, args.save_hlo,
                              args.unroll, args.overrides)
                results.append(rec)
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    log.event("dryrun_ok", arch=arch, shape=shape,
                              mesh=mesh_name, compile_s=rec["compile_s"],
                              mem_gib=rec["mem"]["peak_per_device"] / 2**30,
                              dominant=r["dominant"],
                              compute_s=r["compute_s"],
                              memory_s=r["memory_s"],
                              collective_s=r["collective_s"])
                elif rec["status"] == "skipped":
                    log.event("dryrun_skip", arch=arch, shape=shape,
                              mesh=mesh_name, note=rec["note"][:60])
                else:
                    log.error("dryrun_fail", arch=arch, shape=shape,
                              mesh=mesh_name, error=rec["error"][:140])
    n_fail = sum(r["status"] == "fail" for r in results)
    log.event("dryrun_done", runs=len(results), failures=n_fail)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
