"""Swarm serving launcher: stage-sharded decode over a simulated cluster.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b \
        --requests 8 --rate 200 --n-stages 2 --churn

Thin CLI over :class:`repro.serving.ServingRuntime`: builds the model from
a committed architecture config, stage-shards it across a simulated
cluster, replays a Poisson request trace through the continuous-batching
loop, and reports tokens/s + per-token latency percentiles.  ``--churn``
scripts a mid-session stage-replica failure (derived from a dry run so it
is guaranteed to interrupt a live session) and re-runs the same offered
load through the re-route + KV-replay path.

Artifacts: ``--trace``/``--flight`` write the span log and the routing
decision log (render with ``python -m repro.obs.report TRACE --flight
FLIGHT``).  Timing events go through :mod:`repro.obs.slog`.
"""
from __future__ import annotations

import argparse

import jax

from repro.obs import (FlightRecorder, MetricsRegistry, TraceRecorder,
                       slog, write_jsonl)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--size", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--devices", type=int, default=6,
                    help="simulated cluster size")
    ap.add_argument("--cluster", choices=["lan", "geo"], default="geo",
                    help="homogeneous LAN or geo-distributed sites")
    ap.add_argument("--n-stages", type=int, default=2)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="Poisson arrival rate (req/s, simulated)")
    ap.add_argument("--prompt-len", type=int, nargs=2, default=(4, 12),
                    metavar=("LO", "HI"))
    ap.add_argument("--gen", type=int, nargs=2, default=(16, 32),
                    metavar=("LO", "HI"), help="per-request new tokens")
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=4,
                    help="KV slots per stage replica")
    ap.add_argument("--churn", action="store_true",
                    help="also run a scripted mid-session failure leg")
    ap.add_argument("--lease", type=float, default=1e-5,
                    help="failure-detection lease (simulated s)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", metavar="PATH",
                    help="write span log JSONL (churn leg when --churn)")
    ap.add_argument("--flight", metavar="PATH",
                    help="write routing decision log JSONL")
    slog.add_logging_args(ap)
    return ap


def main() -> None:
    args = build_parser().parse_args()
    log = slog.get_logger("serve", metrics=MetricsRegistry(),
                          level=slog.level_from_args(args))

    from repro.configs import resolve
    from repro.core.network import geo_random, homogeneous_lan
    from repro.elastic.membership import ChurnTrace, MembershipView
    from repro.models import causal_lm
    from repro.serving import (ServingCostModel, ServingRuntime,
                               churn_trace_for, derive_midsession_failure,
                               plan_serving, poisson_trace)

    cfg = resolve(args.arch).smoke if args.size == "smoke" \
        else resolve(args.arch).full
    params = causal_lm.init(cfg, jax.random.PRNGKey(args.seed))
    cluster = homogeneous_lan(args.devices) if args.cluster == "lan" \
        else geo_random(args.devices, seed=args.seed)
    costs = ServingCostModel(cfg, cluster)
    plan = plan_serving(cfg, costs, list(range(args.devices)),
                        n_stages=args.n_stages, cache_len=args.cache_len,
                        max_batch=args.max_batch)
    for line in plan.describe().splitlines():
        log.debug("plan", line=line)
    requests = poisson_trace(args.requests, rate=args.rate, vocab=cfg.vocab,
                             prompt_len=tuple(args.prompt_len),
                             gen_len=tuple(args.gen), seed=args.seed)

    def leg(name: str, trace_events):
        view = MembershipView(args.devices, trace_events,
                              lease_s=args.lease)
        tr = TraceRecorder()
        fl = FlightRecorder()
        runtime = ServingRuntime(cfg, params, plan, view, trace=tr,
                                 flight=fl)
        report = runtime.run(list(requests))
        log.event(name, **report.to_dict())
        return report, tr, fl

    report, tr, fl = leg("no_churn", ChurnTrace(()))
    if args.churn:
        victim, at, _, _ = derive_midsession_failure(
            cfg, params, plan, requests, args.devices, lease_s=args.lease)
        log.event("scripted_failure", victim=victim, at=at)
        report, tr, fl = leg("churn", churn_trace_for(victim, at))
        if not report.all_completed:
            raise SystemExit("churn leg dropped sessions — "
                             "re-route failed to recover")
    if args.trace:
        write_jsonl(tr.events(), args.trace)
        log.event("artifact", kind="trace", path=args.trace)
    if args.flight:
        fl.to_jsonl(args.flight)
        log.event("artifact", kind="flight", path=args.flight)


if __name__ == "__main__":
    main()
