"""Serving launcher: batched prefill + greedy/temperature decode.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b \
        --size smoke --batch 4 --prompt-len 16 --gen 24

Timing goes through :mod:`repro.obs.slog` structured events (respects
``--log-level``/``--quiet``); sampled generations print at debug level.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import MetricsRegistry
from repro.obs import slog


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--size", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    slog.add_logging_args(ap)
    args = ap.parse_args()
    log = slog.get_logger("serve", metrics=MetricsRegistry(),
                          level=slog.level_from_args(args))

    from repro.configs import resolve
    from repro.models import causal_lm

    cfg = resolve(args.arch).smoke if args.size == "smoke" \
        else resolve(args.arch).full
    if cfg.family == "encdec":
        raise SystemExit("use an enc-dec specific driver for seamless")
    cache_len = args.prompt_len + args.gen + cfg.n_prefix

    params = causal_lm.init(cfg, jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(1)
    prompts = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)
    prefix = None
    if cfg.n_prefix:
        prefix = jax.random.normal(rng, (args.batch, cfg.n_prefix,
                                         cfg.d_frontend))

    prefill = jax.jit(lambda p, t, pe: causal_lm.prefill(
        cfg, p, t, cache_len=cache_len, prefix_embeds=pe))
    decode = jax.jit(lambda p, c, t: causal_lm.decode_step(cfg, p, c, t),
                     donate_argnums=(1,))

    t0 = time.time()
    logits, cache = prefill(params, prompts, prefix)
    t_prefill = time.time() - t0

    def sample(logits, key):
        if args.temperature <= 0:
            return jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1)
        return jax.random.categorical(key, logits[:, -1, :cfg.vocab]
                                      / args.temperature)

    tok = sample(logits, rng)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        rng, k = jax.random.split(rng)
        logits, cache = decode(params, cache, tok[:, None])
        tok = sample(logits, k)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = (time.time() - t0) / max(args.gen - 1, 1)
    gen = np.stack([np.asarray(t) for t in out], axis=1)
    log.event("prefill", ms=t_prefill * 1e3, batch=args.batch,
              prompt_len=args.prompt_len)
    log.event("decode", ms_per_token=t_decode * 1e3,
              tok_per_s=args.batch / max(t_decode, 1e-9))
    for b in range(min(args.batch, 2)):
        log.debug("sample", req=b,
                  prompt=np.asarray(prompts[b])[:8].tolist(),
                  generated=gen[b][:12].tolist())


if __name__ == "__main__":
    main()
