"""Step builders + abstract input specs for every (arch × input-shape).

This is the GSPMD execution path (DESIGN.md §4 path 1): one ``jax.jit`` per
step with explicit in/out shardings over the production mesh.  The same
builders drive the multi-pod dry-run (ShapeDtypeStruct lowering — deliverable
e), real CPU-scale training (launch/train.py), and serving (launch/serve.py).

Step kinds per input shape:
* train_4k    -> ``train_step(params, opt_state, batch)``
* prefill_32k -> ``prefill_step(params, batch)``
* decode_32k / long_500k -> ``serve_step(params, cache, tokens)`` — ONE new
  token against a seq_len-deep cache (cache donated).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelCfg
from repro.models import causal_lm, encdec
from repro.optim import Optimizer, clip_by_global_norm
from .params import batch_spec, generic_spec, param_shardings, tree_path_str

SDS = jax.ShapeDtypeStruct


# ============================================================ input specs ==
def src_len_for(cfg: ModelCfg, seq_len: int) -> int:
    """Audio source frames for enc-dec shapes (8 tokens/frame heuristic)."""
    return max(seq_len // 8, 16)


def input_specs(cfg: ModelCfg, shape: InputShape) -> Dict[str, SDS]:
    """Abstract batch for train/prefill shapes (decode builds caches too —
    see :func:`decode_state_specs`)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if cfg.family == "encdec":
        spec = {"src_embeds": SDS((B, src_len_for(cfg, S), cfg.d_frontend),
                                  cfg.dtype),
                "tokens": SDS((B, S), i32)}
        if shape.kind == "train":
            spec["labels"] = SDS((B, S), i32)
        return spec
    S_text = S - cfg.n_prefix if cfg.n_prefix else S
    spec = {"tokens": SDS((B, S_text), i32)}
    if shape.kind == "train":
        spec["labels"] = SDS((B, S_text), i32)
    if cfg.n_prefix:
        spec["prefix_embeds"] = SDS((B, cfg.n_prefix, cfg.d_frontend),
                                    cfg.dtype)
    return spec


def decode_window(cfg: ModelCfg, shape: InputShape) -> Optional[int]:
    if shape.name == "long_500k":
        return cfg.long_window or cfg.window
    return cfg.window


def decode_state_specs(cfg: ModelCfg, shape: InputShape) -> Any:
    """Abstract decode cache for serve_step lowering."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        return jax.eval_shape(
            lambda: encdec.cache_init(cfg, B, S, src_len_for(cfg, S)))
    return jax.eval_shape(lambda: causal_lm.cache_init(cfg, B, S))


# ======================================================== cache shardings ==
def cache_shardings(cache_shapes: Any, mesh: Mesh, global_batch: int) -> Any:
    """Path-aware sharding for decode caches.

    KV caches shard batch + sequence-over-model ("kvseq"); SSM/xLSTM states
    shard batch + the widest feature dim over model.  Anything indivisible
    replicates — correctness never depends on these choices.
    """
    bspec = batch_spec(global_batch, mesh)
    baxes = bspec[0] if len(bspec) and bspec[0] is not None else None
    msz = mesh.shape["model"] if "model" in mesh.axis_names else 1

    def shard_dim(spec, shape, idx, axis, size):
        if size > 1 and shape[idx] % size == 0 and shape[idx] >= size:
            spec[idx] = axis

    def rule(path, leaf):
        pstr = tree_path_str(path)
        name = pstr.rsplit("/", 1)[-1]
        shape = leaf.shape
        nd = len(shape)
        spec: list = [None] * nd
        in_slstm = "/s/" in pstr or pstr.endswith("/s")

        def setb(idx):
            if baxes is not None and nd >= -idx and shape[idx] == global_batch:
                spec[idx] = baxes

        if name == "pos" or nd == 0:
            return NamedSharding(mesh, P())
        if name in ("k", "v", "xk", "xv"):
            # NOT the sequence dim: decode writes it via dynamic_update_slice
            # at a traced position, which GSPMD can only partition by fully
            # rematerializing the cache (measured: 2 GiB of all-gather per
            # layer per step).  head_dim shards cleanly: the only cost is an
            # all-reduce of the (B,H,1,S) scores over the contraction.
            setb(-4)
            shard_dim(spec, shape, -1, "model", msz)      # head_dim
        elif name == "h" and not in_slstm:                # mamba state
            setb(-4)
            shard_dim(spec, shape, -3, "model", msz)      # ssm heads
        elif name == "conv":
            setb(-3)
            shard_dim(spec, shape, -1, "model", msz)      # channels
        elif name == "C":                                  # mLSTM matrix mem
            setb(-4)
            shard_dim(spec, shape, -1, "model", msz)
        elif name in ("n", "c", "h", "m"):                 # vector states
            setb(-3)
            shard_dim(spec, shape, -1, "model", msz)
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(rule, cache_shapes)


def batch_shardings(batch_shapes: Dict[str, SDS], mesh: Mesh,
                    global_batch: int) -> Dict[str, Any]:
    bspec = batch_spec(global_batch, mesh)

    def rule(_, leaf):
        spec = list(bspec) + [None] * (len(leaf.shape) - len(bspec))
        return NamedSharding(mesh, P(*spec[:len(leaf.shape)]))

    return jax.tree_util.tree_map_with_path(rule, batch_shapes)


# ================================================================== steps ==
def make_train_step(cfg: ModelCfg, optimizer: Optimizer,
                    grad_clip: float = 1.0) -> Callable:
    loss_mod = encdec if cfg.family == "encdec" else causal_lm

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return loss_mod.train_loss(cfg, p, batch)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        if grad_clip:
            grads, gnorm = clip_by_global_norm(grads, grad_clip)
        else:
            gnorm = jnp.zeros((), jnp.float32)
        params, opt_state = optimizer.update(grads, opt_state, params)
        out_metrics = {"loss": loss, "grad_norm": gnorm}
        out_metrics.update(metrics)
        return params, opt_state, out_metrics

    return train_step


def make_prefill_step(cfg: ModelCfg, cache_len: int,
                      window: Optional[int] = None) -> Callable:
    if cfg.family == "encdec":
        def prefill_step(params, batch):
            return encdec.prefill(cfg, params, batch["src_embeds"],
                                  batch["tokens"], cache_len)
    else:
        def prefill_step(params, batch):
            return causal_lm.prefill(cfg, params, batch["tokens"],
                                     cache_len=cache_len,
                                     prefix_embeds=batch.get("prefix_embeds"),
                                     window=window)
    return prefill_step


def make_decode_step(cfg: ModelCfg, window: Optional[int] = None) -> Callable:
    if cfg.family == "encdec":
        def serve_step(params, cache, tokens):
            return encdec.decode_step(cfg, params, cache, tokens)
    else:
        def serve_step(params, cache, tokens):
            return causal_lm.decode_step(cfg, params, cache, tokens,
                                         window=window)
    return serve_step


# ============================================================== assembler ==
def abstract_params(cfg: ModelCfg) -> Any:
    mod = encdec if cfg.family == "encdec" else causal_lm
    return jax.eval_shape(functools.partial(mod.init, cfg),
                          jax.random.PRNGKey(0))


def build_jitted(cfg: ModelCfg, mesh: Mesh, shape: InputShape,
                 optimizer: Optional[Optimizer] = None,
                 param_overrides: Optional[Dict[str, P]] = None,
                 remat: bool = False):
    """Assemble the jitted step + abstract example args for one
    (arch × input-shape × mesh).  Returns (jit_fn, args, meta)."""
    from repro.optim import adafactor
    optimizer = optimizer or adafactor(1e-3)
    p_abs = abstract_params(cfg)
    p_sh = param_shardings(p_abs, mesh, overrides=param_overrides)

    if shape.kind == "train":
        step = make_train_step(cfg, optimizer)
        if remat:
            # remat the whole loss; scan-over-layers already bounds liveness,
            # this additionally frees intra-block activations
            step = make_train_step(cfg, optimizer)  # remat handled in model
        opt_abs = jax.eval_shape(optimizer.init, p_abs)
        opt_sh = jax.tree_util.tree_map(
            lambda l: NamedSharding(mesh, generic_spec(np.shape(l), mesh)),
            opt_abs)
        batch_abs = input_specs(cfg, shape)
        b_sh = batch_shardings(batch_abs, mesh, shape.global_batch)
        fn = jax.jit(step, in_shardings=(p_sh, opt_sh, b_sh),
                     out_shardings=(p_sh, opt_sh, None),
                     donate_argnums=(0, 1))
        return fn, (p_abs, opt_abs, batch_abs), {"param_sh": p_sh}

    if shape.kind == "prefill":
        step = make_prefill_step(cfg, cache_len=shape.seq_len,
                                 window=cfg.window)
        batch_abs = input_specs(cfg, shape)
        b_sh = batch_shardings(batch_abs, mesh, shape.global_batch)
        cache_abs = decode_state_specs(cfg, shape)
        c_sh = cache_shardings(cache_abs, mesh, shape.global_batch)
        fn = jax.jit(step, in_shardings=(p_sh, b_sh),
                     out_shardings=(None, c_sh))
        return fn, (p_abs, batch_abs), {"param_sh": p_sh}

    # decode
    step = make_decode_step(cfg, window=decode_window(cfg, shape))
    cache_abs = decode_state_specs(cfg, shape)
    c_sh = cache_shardings(cache_abs, mesh, shape.global_batch)
    tok_abs = SDS((shape.global_batch, 1), jnp.int32)
    t_sh = batch_shardings({"t": tok_abs}, mesh, shape.global_batch)["t"]
    fn = jax.jit(step, in_shardings=(p_sh, c_sh, t_sh),
                 out_shardings=(None, c_sh), donate_argnums=(1,))
    return fn, (p_abs, cache_abs, tok_abs), {"param_sh": p_sh}
