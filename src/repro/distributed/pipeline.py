"""FusionLLM pipeline on a TPU mesh (DESIGN.md §4 path 2).

The paper's runtime — inter-layer stages, boundary activations/gradients on
links, Top-K compression on the *slowest* links — mapped onto jax-native
constructs:

* stage axis = the mesh's ``model`` axis (single pod) or the flattened
  ``pod × model`` axes (multi-pod): consecutive stages sit on neighbouring
  chips, and exactly the stage boundaries that cross the pod boundary ride
  the slow links;
* boundary transfer = ``jax.lax.ppermute`` inside ``shard_map``;
* AdaTopK = :func:`repro.core.compression.boundary_compress` applied to the
  boundary tensor *before* the permute, with a per-edge ratio from Eq. 7 —
  pod-crossing edges get ``3r``, intra-pod edges ratio 1 (no compression),
  exactly the adaptive schedule the paper derives for heterogeneous links;
* schedule = GPipe (paper Eq. 3): ``n_micro + n_stages - 1`` ticks, stage s
  processes micro-batch ``t - s`` at tick t;
* RAD = ``jax.grad`` *through* the shard_map — each stage's backward runs
  where its forward ran and boundary gradients flow over the reversed
  permute, compressed by the same per-edge plan (``boundary_compress`` is a
  custom_vjp whose backward sparsifies the cotangent).

Supports the dense/GPT-2 family (homogeneous blocks — the paper's own
workload).  n_layers must divide evenly into stages.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelCfg
from repro.core.compression import boundary_compress, ratio_to_k
from repro.models import causal_lm
from repro.models.causal_lm import _dense_block
from repro.models.layers import cross_entropy, dense, embed, norm_apply


def stage_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "model") if "pod" in mesh.axis_names else ("model",)


def n_stages(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in stage_axes(mesh)]))


def pod_edge_ratios(mesh: Mesh, base_ratio: float,
                    index_overhead: float = 3.0) -> np.ndarray:
    """Per-boundary compression ratio (edge s -> s+1), Eq. 7.

    R_i for an intra-pod ICI edge vs a pod-crossing edge differs by ~the
    bandwidth gap; with only two tiers, Eq. 7 degenerates to: slowest edges
    get ``3r``, fast edges get 1 (max(1, 3r·R_i/R_max) with R_i ≪ R_max).
    """
    ns = n_stages(mesh)
    ratios = np.ones(ns)            # edge i: stage i -> i+1 (cyclic unused)
    if "pod" in mesh.axis_names:
        per_pod = mesh.shape["model"]
        for s in range(ns - 1):
            if (s + 1) % per_pod == 0:           # crossing into next pod
                ratios[s] = max(1.0, index_overhead * base_ratio)
    return ratios


def _split_stage_params(cfg: ModelCfg, params: Dict[str, Any], ns: int):
    """Reshape stacked block params (L, ...) -> (ns, L/ns, ...); embed/head
    replicated (stage 0 / last stage use them)."""
    L = cfg.n_layers
    if L % ns:
        raise ValueError(f"{L} layers not divisible into {ns} stages")
    blocks = jax.tree_util.tree_map(
        lambda a: a.reshape((ns, L // ns) + a.shape[1:]), params["blocks"])
    rest = {k: v for k, v in params.items() if k != "blocks"}
    return blocks, rest


def make_pipeline_train_fn(cfg: ModelCfg, mesh: Mesh, n_micro: int,
                           base_ratio: float = 1.0,
                           use_kernel: bool = False) -> Callable:
    """Returns loss_fn(params, batch) running the GPipe schedule under
    shard_map.  batch tokens: (n_micro, mb, S)."""
    if cfg.family not in ("dense",):
        raise NotImplementedError("pipeline path covers the dense family "
                                  "(the paper's GPT-2 workload)")
    axes = stage_axes(mesh)
    ns = n_stages(mesh)
    ratios = pod_edge_ratios(mesh, base_ratio)
    perm_fwd = [(i, i + 1) for i in range(ns - 1)]

    def loss_fn(params, batch):
        blocks, rest = _split_stage_params(cfg, params, ns)
        tokens, labels = batch["tokens"], batch["labels"]
        mb, S = tokens.shape[1], tokens.shape[2]
        d = cfg.d_model

        blk_specs = jax.tree_util.tree_map(lambda _: P(axes), blocks)
        rest_specs = jax.tree_util.tree_map(lambda _: P(), rest)

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(blk_specs, rest_specs, P(), P()),
            out_specs=P(*axes),
            check_rep=False)
        def run(blocks_l, rest_l, tok, lab):
            # blocks_l leaves: (1, L/ns, ...) — this stage's layers
            my = jax.tree_util.tree_map(lambda a: a[0], blocks_l)
            stage = jax.lax.axis_index(axes[0])
            if len(axes) == 2:
                # psum(1, axis) == axis size; jax.lax.axis_size does not
                # exist on the pinned jax (0.4.x)
                stage = stage * jax.lax.psum(1, axes[1]) \
                    + jax.lax.axis_index(axes[1])
            is_first = stage == 0
            is_last = stage == ns - 1

            def embed_mb(i):
                x = embed(rest_l["embed"], tok[i], cfg.dtype)
                if cfg.rope_fraction == 0.0:
                    x = x + embed(rest_l["pos_embed"], jnp.arange(S),
                                  cfg.dtype)[None]
                return x

            def run_blocks(x):
                def body(h, pl):
                    return _dense_block(cfg, pl, h, cfg.window), None
                h, _ = jax.lax.scan(body, x, my)
                return h

            def head_loss(x, i):
                h = norm_apply(cfg.norm, rest_l["final_norm"], x)
                logits = h @ rest_l["head"]["w"].astype(h.dtype) \
                    if "head" in rest_l else \
                    h @ rest_l["embed"]["table"].astype(h.dtype).T
                return cross_entropy(logits.astype(jnp.float32), lab[i])

            # Eq. 7 per-edge compression of the OUTGOING boundary.  With two
            # bandwidth tiers every slow (pod-crossing) edge shares one
            # ratio 3r, so one static k suffices; whether THIS stage's edge
            # is slow is a traced predicate (lax.cond — one branch runs).
            slow_edges = ratios > 1.0

            def compress_boundary(x):
                if not slow_edges.any():
                    return x
                k_comp = ratio_to_k(mb * S * d, float(ratios[slow_edges][0]))
                flag = jnp.asarray(slow_edges)[jnp.minimum(stage, ns - 2)]
                return jax.lax.cond(
                    flag,
                    lambda v: boundary_compress(v, k_comp, k_comp,
                                                use_kernel),
                    lambda v: v, x)

            total_ticks = n_micro + ns - 1
            state0 = jnp.zeros((mb, S, d), cfg.dtype)   # incoming boundary

            def tick(carry, t):
                state, loss_acc = carry
                mb_idx = t - stage
                active = (mb_idx >= 0) & (mb_idx < n_micro)
                mb_safe = jnp.clip(mb_idx, 0, n_micro - 1)
                x_in = jnp.where(is_first, embed_mb(mb_safe), state)
                y = run_blocks(x_in)
                loss_mb = jnp.where(is_last & active,
                                    head_loss(y, mb_safe), 0.0)
                y = compress_boundary(y)
                nxt = jax.lax.ppermute(y, axes, perm_fwd)
                return (nxt, loss_acc + loss_mb), None

            (state, loss_acc), _ = jax.lax.scan(
                tick, (state0, jnp.zeros((), jnp.float32)),
                jnp.arange(total_ticks))
            # one scalar shard per stage (only the last is non-zero); summed
            # OUTSIDE the shard_map — transposing an in-map psum trips the
            # pinned jax 0.4.x shard_map under check_rep=False
            return loss_acc.reshape((1,) * len(axes))

        # remat the sharded region: grad-of-shard_map on the pinned jax
        # 0.4.x mis-names scalar residuals (raises _SpecError); with
        # checkpoint the only cross-boundary residuals are the inputs.
        return jax.checkpoint(run)(blocks, rest, tokens, labels).sum() \
            / n_micro

    # checkpoint-of-shard_map requires a surrounding jit (eager closed_call
    # under shard_map is unimplemented on jax 0.4.x)
    loss_fn = jax.jit(loss_fn)

    return loss_fn


def make_pipeline_train_step(cfg: ModelCfg, mesh: Mesh, optimizer,
                             n_micro: int, base_ratio: float = 1.0):
    loss_fn = make_pipeline_train_fn(cfg, mesh, n_micro, base_ratio)

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch))(params)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss}

    return step


def microbatch(batch: Dict[str, jax.Array], n_micro: int
               ) -> Dict[str, jax.Array]:
    out = {}
    for k, v in batch.items():
        B = v.shape[0]
        if B % n_micro:
            raise ValueError(f"batch {B} not divisible by n_micro {n_micro}")
        out[k] = v.reshape((n_micro, B // n_micro) + v.shape[1:])
    return out
