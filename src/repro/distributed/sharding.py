"""Sharding helpers: logical-axis annotations that no-op off-mesh.

Model code annotates activations with *logical* axes (``"batch"``,
``"model"``, ``"seq"``); the launcher binds them to physical mesh axes via
:func:`use_mesh`.  Off-mesh (unit tests, smoke tests on one CPU device) the
annotations vanish, so model code is identical in both worlds.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

# logical name -> physical mesh axis (or tuple of axes)
DEFAULT_RULES: Dict[str, Union[str, Tuple[str, ...], None]] = {
    "batch": ("pod", "data"),
    "model": "model",
    "seq": None,
    "kvseq": "model",      # decode KV caches shard sequence over model axis
    "expert": "model",
    "vocab": "model",
    "ff": "model",
    "heads": "model",
    # --- perf-iteration levers (OFF in the baseline; §Perf flips them) ---
    "act_seq": None,       # Megatron sequence parallelism: residual-stream
                           # activations sharded over 'model' between blocks
    "expert_dispatch": None,  # expert-parallel (E,C,d) dispatch buffers
}


def _current() -> Tuple[Optional[Mesh], Dict[str, Union[str, Tuple[str, ...], None]]]:
    return (getattr(_state, "mesh", None),
            getattr(_state, "rules", DEFAULT_RULES))


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Optional[Dict[str, Union[str, Tuple[str, ...], None]]] = None):
    """Activate a mesh + logical-axis rules for model-internal constraints."""
    prev = (getattr(_state, "mesh", None), getattr(_state, "rules", DEFAULT_RULES))
    _state.mesh = mesh
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    # drop axes the mesh does not have (e.g. "pod" on the single-pod mesh)
    def _filter(v):
        if v is None:
            return None
        axes = (v,) if isinstance(v, str) else tuple(v)
        kept = tuple(a for a in axes if a in mesh.axis_names)
        if not kept:
            return None
        return kept[0] if len(kept) == 1 else kept
    _state.rules = {k: _filter(v) for k, v in merged.items()}
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev


def logical_to_spec(axes: Sequence[Optional[str]]) -> P:
    _, rules = _current()
    return P(*[rules.get(a) if a is not None else None for a in axes])


def constrain(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint by logical axes; identity off-mesh, on a
    1-device mesh, or when every logical axis resolves to None (an all-None
    spec would PIN replication — perf levers like 'expert_dispatch' must be
    true no-ops while off)."""
    mesh, _ = _current()
    if mesh is None or mesh.size == 1:
        return x
    if x.ndim != len(axes):
        raise ValueError(f"rank {x.ndim} vs axes {axes}")
    spec = logical_to_spec(axes)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, *axes: Optional[str]) -> NamedSharding:
    with use_mesh(mesh):
        return NamedSharding(mesh, logical_to_spec(axes))
