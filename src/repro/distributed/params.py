"""Parameter / optimizer-state sharding rules.

Generic 2D rule (FSDP × TP, MaxText-style "fsdp+tensor"):
* last dim  -> ``model`` axis when divisible (output features / experts' ff)
* 2nd-last  -> ``data``  axis when divisible (input features; ZeRO-3-like)
* everything else replicated; the ``pod`` axis never shards weights
  (DP across pods — the paper's geo-hierarchy maps compression, not weight
  sharding, onto the slow axis).

Per-path overrides let the hillclimb change individual tensors without
touching model code.
"""
from __future__ import annotations

import re
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def generic_spec(shape: Tuple[int, ...], mesh: Mesh,
                 model_axis: str = "model", data_axis: str = "data") -> P:
    msz = _axis_size(mesh, model_axis)
    dsz = _axis_size(mesh, data_axis)
    spec = [None] * len(shape)
    if len(shape) >= 1 and msz > 1 and shape[-1] % msz == 0 \
            and shape[-1] >= msz:
        spec[-1] = model_axis
    if len(shape) >= 2 and dsz > 1 and shape[-2] % dsz == 0 \
            and shape[-2] >= dsz:
        spec[-2] = data_axis
    return P(*spec)


def tree_path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


def row_spec(shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Row-parallel (Megatron): contraction dim (−2) on ``model`` so the
    matmul psums activation partials instead of all-gathering the full
    activation; output features (−1) FSDP-shard on ``data``.

    Without this, a column-parallel down-projection forces GSPMD to gather
    the f-sharded MLP hidden — measured 3.5 GiB of all-gather per llama3
    layer per train step."""
    msz = _axis_size(mesh, "model")
    dsz = _axis_size(mesh, "data")
    spec = [None] * len(shape)
    if len(shape) >= 2 and msz > 1 and shape[-2] % msz == 0 \
            and shape[-2] >= msz:
        spec[-2] = "model"
    if len(shape) >= 1 and dsz > 1 and shape[-1] % dsz == 0 \
            and shape[-1] >= dsz:
        spec[-1] = "data"
    return P(*spec)


# Projections whose *input* features carry the model-sharded activation:
# attention output, MLP down, Mamba out, mLSTM down, sLSTM FFN down, MoE down.
ROW_PARALLEL_PATTERNS = (
    r".*/(?:wo|out_proj|ffn_down|down)/w",
    r".*/moe/down",
    r".*/shared/down/w",
)


def param_shardings(tree: Any, mesh: Mesh,
                    overrides: Optional[Dict[str, P]] = None,
                    rule: Callable = generic_spec) -> Any:
    """ShapeDtypeStruct/array pytree -> NamedSharding pytree.

    ``overrides``: regex (fullmatch on '/'-joined path) -> PartitionSpec,
    applied before the row-parallel defaults and the generic rule.
    """
    overrides = overrides or {}
    compiled = [(re.compile(k), v) for k, v in overrides.items()]
    rows = [re.compile(p) for p in ROW_PARALLEL_PATTERNS]

    def assign(path, leaf):
        pstr = tree_path_str(path)
        for rx, spec in compiled:
            if rx.fullmatch(pstr):
                return NamedSharding(mesh, spec)
        for rx in rows:
            if rx.fullmatch(pstr):
                return NamedSharding(mesh, row_spec(np.shape(leaf), mesh))
        return NamedSharding(mesh, rule(np.shape(leaf), mesh))

    return jax.tree_util.tree_map_with_path(assign, tree)


def replicated(tree: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), tree)


def batch_spec(global_batch: int, mesh: Mesh) -> P:
    """Shard the batch over ('pod','data') when divisible, else 'data',
    else replicate (long_500k has batch=1)."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if axes and global_batch % size == 0 and global_batch >= size:
        # single axis: scalar form, so the spec compares equal to P("data")
        return P(axes[0] if len(axes) == 1 else tuple(axes))
    if "data" in mesh.axis_names and global_batch % mesh.shape["data"] == 0 \
            and global_batch >= mesh.shape["data"]:
        return P("data")
    return P(None)
