from . import sharding
