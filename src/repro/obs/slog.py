"""Structured logging for the launchers (train / serve / dryrun).

Replaces the launchers' ad-hoc ``print()`` reporting with one consistent
``event key=value ...`` line format routed through the stdlib ``logging``
machinery (so ``--log-level``/``--quiet`` behave as expected), and mirrors
numeric fields into a :class:`repro.obs.metrics.MetricsRegistry` so a
launcher run ends with a queryable metrics snapshot for free::

    log = get_logger("repro.train", metrics=registry)
    log.event("step", step=i, loss=0.42, sps=3.1)
    # -> "step step=10 loss=0.4200 sps=3.100"  (INFO)
    # registry gauge step{field=loss} := 0.42

Numbers are formatted tersely (4 significant decimals for floats); field
order is the caller's keyword order, which keeps related lines aligned and
diffs stable.  ``configure(level)`` installs a stderr handler once —
repeated calls just adjust the level, so libraries can call it safely.
"""
from __future__ import annotations

import logging
import sys
from typing import Any, Optional

from .metrics import MetricsRegistry

_CONFIGURED = False
_ROOT = "repro"


def _fmt_value(v: Any) -> str:
    if isinstance(v, float):
        if v == 0 or 1e-3 <= abs(v) < 1e5:
            return f"{v:.4f}".rstrip("0").rstrip(".") or "0"
        return f"{v:.4g}"
    return str(v)


class _StderrHandler(logging.StreamHandler):
    """StreamHandler that re-reads ``sys.stderr`` at emit time, so capture
    or redirect wrappers installed *after* :func:`configure` (pytest capsys,
    ``contextlib.redirect_stderr``) still receive output."""

    def __init__(self):
        super().__init__(sys.stderr)

    @property
    def stream(self):
        return sys.stderr

    @stream.setter
    def stream(self, value):   # base-class ctor assigns; stay late-bound
        pass


def configure(level: str = "info", stream=None) -> None:
    """Install (once) a plain ``message``-only handler on the ``repro``
    logger hierarchy and set its level.  ``level`` accepts the usual names
    plus ``"quiet"`` (alias for warning)."""
    global _CONFIGURED
    name = {"quiet": "warning"}.get(level.lower(), level.lower())
    lvl = getattr(logging, name.upper(), None)
    if not isinstance(lvl, int):
        raise ValueError(f"unknown log level {level!r}")
    logger = logging.getLogger(_ROOT)
    if not _CONFIGURED:
        handler = logging.StreamHandler(stream) if stream is not None \
            else _StderrHandler()
        handler.setFormatter(logging.Formatter("%(message)s"))
        logger.addHandler(handler)
        logger.propagate = False
        _CONFIGURED = True
    logger.setLevel(lvl)


class StructuredLogger:
    """Thin wrapper over a stdlib logger emitting ``event k=v`` lines and
    mirroring numeric fields into a metrics registry."""

    def __init__(self, logger: logging.Logger,
                 metrics: Optional[MetricsRegistry] = None):
        self._log = logger
        self.metrics = metrics

    def _mirror(self, event: str, fields) -> None:
        if self.metrics is None:
            return
        for k, v in fields.items():
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                continue
            self.metrics.gauge(event, field=k).set(float(v))

    def _emit(self, level: int, event: str, fields) -> None:
        self._mirror(event, fields)
        if not self._log.isEnabledFor(level):
            return
        parts = [event] + [f"{k}={_fmt_value(v)}" for k, v in fields.items()]
        self._log.log(level, " ".join(parts))

    def event(self, event: str, **fields) -> None:
        self._emit(logging.INFO, event, fields)

    def debug(self, event: str, **fields) -> None:
        self._emit(logging.DEBUG, event, fields)

    def warn(self, event: str, **fields) -> None:
        self._emit(logging.WARNING, event, fields)

    def error(self, event: str, **fields) -> None:
        self._emit(logging.ERROR, event, fields)


def get_logger(name: str = _ROOT,
               metrics: Optional[MetricsRegistry] = None,
               level: Optional[str] = None) -> StructuredLogger:
    """Structured logger under the ``repro`` hierarchy.  ``level`` (when
    given) also configures the shared handler — the launchers' one-liner:
    ``log = get_logger("repro.train", metrics=reg, level=args.log_level)``.
    """
    if level is not None:
        configure(level)
    elif not _CONFIGURED:
        configure("info")
    if not name.startswith(_ROOT):
        name = f"{_ROOT}.{name}"
    return StructuredLogger(logging.getLogger(name), metrics)


def add_logging_args(parser) -> None:
    """Attach the shared ``--log-level`` / ``--quiet`` flags to an
    argparse parser (launcher convention)."""
    parser.add_argument("--log-level", default="info",
                        choices=["debug", "info", "warning", "error"],
                        help="structured-log verbosity")
    parser.add_argument("--quiet", action="store_true",
                        help="alias for --log-level warning")


def level_from_args(args) -> str:
    return "warning" if getattr(args, "quiet", False) else args.log_level
