"""Streaming SLO and anomaly watchdogs over the telemetry stream.

The elastic controller's own straggler detector is deliberately slow to act:
it windows telemetry, MAD-filters it, waits for calibration hysteresis, and
only then re-plans.  That is the right speed for *acting* (re-plans cost
migration bytes) but the wrong speed for *knowing*.  A :class:`Watchdog` is
the knowing half: a cheap streaming monitor that flags a regime shift on the
first degraded sample, emits a typed
:class:`~repro.obs.record.WatchdogRecord` into the
:class:`~repro.obs.record.FlightRecorder`, a ``slog`` warning, and a
``watchdog_trips`` metric — so the flight log shows *when the symptom
started*, steps before the controller's ``replan`` record shows when the
cure was applied (asserted in the churn acceptance test).

Three rule families:

* **SLO rules** — hard bounds the operator states up front: step-time p99
  (``step_slo_p99``, checked against the streaming
  :meth:`~repro.obs.metrics.Histogram.percentile` once warm) and a serving
  tokens/s floor (``tokens_floor``).
* **EWMA anomaly** — exponentially weighted mean/variance per signal; a
  sample ``k`` standard deviations above the mean trips.  Deterministic
  sims have near-zero variance, so the std is floored at ``rel_floor`` of
  the mean: a trip therefore means "moved more than ~``k * rel_floor``
  relative to steady state", not "moved at all".
* **MAD anomaly** — median/MAD over a sliding window, robust to the level
  shifts EWMA absorbs; same relative floor.

The watchdog speaks the :class:`~repro.obs.bus.TelemetryBus` sink protocol
(``record`` / ``record_link``), so subscribing it to the controller's bus
gives per-stage and per-link coverage — a per-link EWMA *names* the degraded
wire in its record, the same label the blame table and the calibrator use.

Trips de-duplicate per ``(rule, signal)`` with a ``holdoff`` of observations
so one regime shift logs one record, not one per step, while still re-arming
after the holdoff in case the shift worsens.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Any, Deque, Dict, List, Optional

from .metrics import Histogram, MetricsRegistry
from .record import FlightRecorder, WatchdogRecord
from .slog import StructuredLogger, get_logger

# Relative std/MAD floor: deterministic replay has zero variance, and a
# zero-width reference band would trip on any float jitter.  2% of the
# running mean means "a trip is a >~8% move" at the default k.
_REL_FLOOR = 0.02


class _Ewma:
    """Streaming mean/variance (exponentially weighted), tested *before*
    updating so the sample that breaks the regime is judged against the old
    regime."""

    __slots__ = ("alpha", "k", "rel_floor", "warmup", "n", "mean", "var")

    def __init__(self, alpha: float = 0.3, k: float = 4.0,
                 rel_floor: float = _REL_FLOOR, warmup: int = 3):
        self.alpha = float(alpha)
        self.k = float(k)
        self.rel_floor = float(rel_floor)
        self.warmup = int(warmup)
        self.n = 0
        self.mean = 0.0
        self.var = 0.0

    def observe(self, x: float) -> Optional[float]:
        """Returns the violated reference (the EWMA mean) if ``x`` trips."""
        trip: Optional[float] = None
        if self.n >= self.warmup:
            std = max(math.sqrt(self.var), self.rel_floor * abs(self.mean))
            if abs(x - self.mean) > self.k * std:
                trip = self.mean
        if self.n == 0:
            self.mean = x
        else:
            d = x - self.mean
            self.mean += self.alpha * d
            self.var = (1.0 - self.alpha) * (self.var + self.alpha * d * d)
        self.n += 1
        return trip


class _MadWindow:
    """Median/MAD over a sliding window, tested before the sample enters
    the window."""

    __slots__ = ("window", "k", "rel_floor", "warmup", "buf")

    def __init__(self, window: int = 16, k: float = 3.5,
                 rel_floor: float = _REL_FLOOR, warmup: int = 3):
        self.window = int(window)
        self.k = float(k)
        self.rel_floor = float(rel_floor)
        self.warmup = int(warmup)
        self.buf: Deque[float] = deque(maxlen=self.window)

    @staticmethod
    def _median(xs: List[float]) -> float:
        s = sorted(xs)
        n = len(s)
        mid = n // 2
        return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])

    def observe(self, x: float) -> Optional[float]:
        trip: Optional[float] = None
        if len(self.buf) >= self.warmup:
            med = self._median(list(self.buf))
            mad = self._median([abs(v - med) for v in self.buf])
            scale = max(1.4826 * mad, self.rel_floor * abs(med))
            if abs(x - med) > self.k * scale:
                trip = med
        self.buf.append(x)
        return trip


class Watchdog:
    """Streaming SLO/anomaly monitor emitting typed flight records.

    Feed it explicitly (:meth:`observe_step`, :meth:`observe_tokens`) or
    subscribe it to a :class:`~repro.obs.bus.TelemetryBus` (it implements
    ``record`` / ``record_link``).  ``step_slo_p99`` / ``tokens_floor`` are
    optional hard SLOs; anomaly detection always runs.
    """

    def __init__(self,
                 flight: Optional[FlightRecorder] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 log: Optional[StructuredLogger] = None,
                 step_slo_p99: Optional[float] = None,
                 tokens_floor: Optional[float] = None,
                 k: float = 4.0,
                 rel_floor: float = _REL_FLOOR,
                 warmup: int = 3,
                 holdoff: int = 8):
        self.flight = flight
        self.metrics = metrics
        self.log = log if log is not None else get_logger("repro.watchdog")
        self.step_slo_p99 = step_slo_p99
        self.tokens_floor = tokens_floor
        self.k = float(k)
        self.rel_floor = float(rel_floor)
        self.warmup = int(warmup)
        self.holdoff = int(holdoff)
        self.records: List[WatchdogRecord] = []
        self._ewma: Dict[str, _Ewma] = {}
        self._mad: Dict[str, _MadWindow] = {}
        self._p99 = Histogram(base=1.01)  # ~1% streaming percentile error
        self._last_trip: Dict[tuple, int] = {}
        self._seen: Dict[str, int] = {}
        # context stamped onto bus-fed records (the controller sets these
        # via observe_step; raw bus samples carry only the step)
        self._clock = 0.0

    # ----------------------------------------------------------- plumbing --
    def _trip(self, rule: str, signal: str, step: int, clock: float,
              value: float, reference: float, message: str = "") -> None:
        n = self._seen.get(signal, 0)
        key = (rule, signal)
        last = self._last_trip.get(key)
        if last is not None and n - last < self.holdoff:
            return
        self._last_trip[key] = n
        denom = abs(reference) if reference else 1.0
        rec = WatchdogRecord(step=int(step), clock=float(clock), rule=rule,
                             signal=signal, value=float(value),
                             reference=float(reference),
                             severity=abs(value - reference) / denom,
                             message=message)
        self.records.append(rec)
        if self.flight is not None:
            self.flight.log(rec)
        if self.metrics is not None:
            self.metrics.counter("watchdog_trips", rule=rule,
                                 signal=signal).inc()
        self.log.warn("watchdog", rule=rule, signal=signal, step=int(step),
                      value=float(value), reference=float(reference),
                      severity=rec.severity)

    def _anomaly(self, signal: str, step: int, clock: float,
                 value: float, low_is_bad: bool = False) -> None:
        """Run both streaming detectors on one (signal, value) sample."""
        self._seen[signal] = self._seen.get(signal, 0) + 1
        ew = self._ewma.get(signal)
        if ew is None:
            ew = self._ewma[signal] = _Ewma(k=self.k,
                                            rel_floor=self.rel_floor,
                                            warmup=self.warmup)
        md = self._mad.get(signal)
        if md is None:
            md = self._mad[signal] = _MadWindow(k=self.k,
                                               rel_floor=self.rel_floor,
                                               warmup=self.warmup)
        ref = ew.observe(value)
        if ref is not None and (low_is_bad or value > ref):
            self._trip("ewma", signal, step, clock, value, ref)
        ref = md.observe(value)
        if ref is not None and (low_is_bad or value > ref):
            self._trip("mad", signal, step, clock, value, ref)

    # --------------------------------------------------------- entrypoints --
    def observe_step(self, step: int, clock: float, seconds: float) -> None:
        """One training step took ``seconds`` of simulated time."""
        self._clock = float(clock)
        self._anomaly("step_seconds", step, clock, float(seconds))
        self._p99.observe(float(seconds))
        if self.step_slo_p99 is not None and self._p99.count >= self.warmup:
            p99 = self._p99.percentile(99.0)
            if p99 > self.step_slo_p99:
                self._trip("slo", "step_seconds_p99", step, clock, p99,
                           self.step_slo_p99,
                           message="step-time p99 SLO violated")

    def observe_link(self, step: int, clock: float, src: int, dst: int,
                     seconds: float) -> None:
        """One transfer on the directed link ``src -> dst``."""
        self._anomaly(f"link {int(src)}->{int(dst)}", step, clock,
                      float(seconds))

    def observe_tokens(self, step: int, clock: float,
                       tokens_per_s: float) -> None:
        """One serving round's aggregate decode rate."""
        self._clock = float(clock)
        rate = float(tokens_per_s)
        if self.tokens_floor is not None:
            sig = "tokens_per_s"
            self._seen[sig] = self._seen.get(sig, 0) + 1
            if rate < self.tokens_floor:
                self._trip("slo", sig, step, clock, rate, self.tokens_floor,
                           message="serving tokens/s floor violated")
        # invert: a *drop* in throughput is the anomaly
        self._anomaly("tokens_per_s_dip", step, clock, -rate,
                      low_is_bad=False)

    # ------------------------------------------- TelemetrySink protocol --
    def record(self, sample: Any) -> None:
        """Bus hook for :class:`~repro.core.executor.StepTiming` samples:
        watches each stage's total seconds."""
        self._anomaly(f"stage{int(sample.node)}_seconds",
                      int(getattr(sample, "step", 0)), self._clock,
                      float(sample.compute_seconds)
                      + float(getattr(sample, "comm_seconds", 0.0)))

    def record_link(self, sample: Any) -> None:
        """Bus hook for :class:`~repro.core.executor.LinkTiming` samples:
        per-link anomaly detection normalized to seconds-per-byte so
        micro-batch size changes don't masquerade as link shifts."""
        nbytes = float(getattr(sample, "nbytes", 0.0))
        if nbytes <= 0.0:
            return
        self._anomaly(f"link {int(sample.src)}->{int(sample.dst)}",
                      int(getattr(sample, "step", 0)), self._clock,
                      float(sample.seconds) / nbytes)

    # -------------------------------------------------------------- query --
    def first_trip(self, rule: Optional[str] = None,
                   signal_prefix: str = "") -> Optional[WatchdogRecord]:
        """Earliest trip (optionally filtered), or ``None``."""
        for rec in self.records:
            if rule is not None and rec.rule != rule:
                continue
            if signal_prefix and not rec.signal.startswith(signal_prefix):
                continue
            return rec
        return None
