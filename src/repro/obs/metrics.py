"""Lightweight metrics registry (counters / gauges / histograms).

No external dependency, no exposition server — just a thread-safe in-process
registry the runtime increments and the run report snapshots.  Metrics are
identified by ``(name, sorted label items)`` so one logical metric fans out
per link / per edge / per cause without pre-registration::

    reg = MetricsRegistry()
    reg.counter("wire_bytes", link="3->5").inc(1.2e6)
    reg.gauge("link_correction", link="3->5").set(2.0)
    reg.histogram("step_seconds").observe(0.41)
    reg.snapshot()   # JSON-ready dict

The glossary the elastic runtime populates (see README §Observability):

* ``wire_bytes{link}``            — counter, bytes on the wire per directed
                                    CompNode link (from LinkTiming telemetry)
* ``link_seconds{link}``          — counter, transport seconds per link
* ``compression_ratio_planned``   — gauge, the plan's requested ratio
* ``compression_ratio_realized``  — gauge, dense bytes / wire bytes actually
                                    achieved by the installed plan
* ``ef_residual_norm{edge}``      — gauge, error-feedback residual L2 norm
* ``replan_count{cause}``         — counter, epoch transitions by cause
* ``detector_trips``              — counter, straggler detector flags
* ``calibration_fits``            — counter, hysteresis-passing fits
* ``rollback_steps``              — counter, steps lost to failures
* ``migrated_bytes{kind}``        — counter, blocking vs background state
* ``step_seconds``                — histogram, simulated per-step wall-clock
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Any, Dict, List, Mapping, Optional, Tuple

_Key = Tuple[str, Tuple[Tuple[str, str], ...]]


def _key(name: str, labels: Mapping[str, Any]) -> _Key:
    return (name, tuple(sorted((str(k), str(v)) for k, v in labels.items())))


class Counter:
    """Monotonically increasing value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        self.value += float(amount)


class Gauge:
    """Last-write-wins value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += float(amount)


class Histogram:
    """Streaming summary: count / sum / min / max plus fixed log-scale
    bucket counts (powers of ``base`` around 1.0) — enough for the report's
    distribution lines without keeping every sample."""

    __slots__ = ("count", "total", "min", "max", "buckets", "base")

    def __init__(self, base: float = 2.0, n_buckets: int = 40):
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.base = float(base)
        self.buckets: Dict[int, int] = {}
        del n_buckets  # buckets are sparse; kept for API stability

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        b = int(math.floor(math.log(v, self.base))) if v > 0 else -10 ** 6
        self.buckets[b] = self.buckets.get(b, 0) + 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Approximate ``q``-th percentile (``0 < q <= 100``) from the
        log-scale buckets.

        Error bound: a bucket ``b`` holds samples in ``(base**b,
        base**(b+1)]``; this returns the bucket's upper edge (clamped into
        ``[self.min, self.max]``), so the result is **within one factor of
        ``base`` above** the true sample percentile — e.g. at most 2× with
        the default ``base=2.0``, and within ~1% with ``base=1.01``.
        Non-positive samples share one underflow bucket reported as
        ``min(0.0, self.max)`` clamped the same way."""
        if not 0.0 < q <= 100.0:
            raise ValueError(f"percentile q={q!r} not in (0, 100]")
        if self.count == 0:
            raise ValueError("percentile of an empty histogram")
        rank = max(1, math.ceil(q / 100.0 * self.count))
        seen = 0
        for b in sorted(self.buckets):
            seen += self.buckets[b]
            if seen >= rank:
                edge = 0.0 if b <= -10 ** 6 else self.base ** (b + 1)
                return min(max(edge, self.min), self.max)
        return self.max


class MetricsRegistry:
    """Thread-safe, lazily-populated metric store."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[_Key, Any] = {}
        self._kinds: Dict[_Key, str] = {}

    def _get(self, kind: str, factory, name: str, labels: Mapping[str, Any]):
        k = _key(name, labels)
        with self._lock:
            m = self._metrics.get(k)
            if m is None:
                m = self._metrics[k] = factory()
                self._kinds[k] = kind
            elif self._kinds[k] != kind:
                raise TypeError(f"metric {name!r} already registered as "
                                f"{self._kinds[k]}, not {kind}")
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get("histogram", Histogram, name, labels)

    # ------------------------------------------------------------ reading --
    def snapshot(self) -> Dict[str, Any]:
        """JSON-ready dict: ``name{k=v,...}`` -> value (counters/gauges) or
        summary dict (histograms).  Deterministic key order."""
        with self._lock:
            items = sorted(self._metrics.items())
            kinds = dict(self._kinds)
        out: Dict[str, Any] = {}
        for (name, labels), m in items:
            label_s = ",".join(f"{k}={v}" for k, v in labels)
            full = f"{name}{{{label_s}}}" if label_s else name
            if kinds[(name, labels)] == "histogram":
                out[full] = {"count": m.count, "sum": m.total,
                             "min": (None if m.count == 0 else m.min),
                             "max": (None if m.count == 0 else m.max),
                             "mean": m.mean}
            else:
                out[full] = m.value
        return out

    def __len__(self) -> int:
        with self._lock:
            return len(self._metrics)
