"""Span-based trace recording (observability spine).

A :class:`TraceRecorder` collects *spans* (begin/end or complete intervals)
and *instant* events into a thread-safe ring buffer.  Two clock domains
coexist in one recorder:

* ``clock="sim"``  — simulated seconds (the discrete-event executor's
  timeline: :func:`repro.core.executor.simulate_iteration` spans, the
  ElasticController's epoch machinery).  Timestamps are supplied by the
  caller in simulated seconds.
* ``clock="wall"`` — host wall-clock via ``time.perf_counter()`` (the real
  RAD executor's stage/compression timings).  Timestamps default to *now*,
  relative to the recorder's construction instant.

Each domain exports as its own Perfetto *process* so the two timelines never
interleave on one track (simulated seconds and wall microseconds share no
origin).  Within a domain, events carry a named *track* (device, link,
controller lane) that export maps to a Perfetto thread.

Categories (the ``cat`` field — what the report CLI groups by)::

    stage.fwd / stage.bwd   pipeline stage compute, one span per micro-batch
    link.transfer           one cross-stage boundary transfer on a wire
    compress.encode/.decode AdaTopK wire encode / decode inside RAD
    migrate.stream          bulk state migration transfers (fore+background)
    checkpoint.restore      state restored out of the broker's store
    controller              epochs, churn events, detector trips, re-plans
    serve.prefill           serving: prompt forward through one stage replica
    serve.replay            serving: KV-prefix replay onto a replacement
                            replica after a mid-session re-route

Guarantees the rest of the repo relies on:

* **Disabled ⇒ no-op**: ``TraceRecorder(enabled=False)`` (or passing
  ``trace=None`` to any instrumented function) records nothing and adds no
  measurable work to the hot path — instrumented code must behave
  identically with tracing on or off (pinned in tests).
* **Deterministic ordering**: every event gets a monotonically increasing
  sequence number; :meth:`events` returns a snapshot sorted by
  ``(clock, ts, seq)``, so two runs of the same simulation produce the same
  event list byte for byte.
* **Bounded memory**: the buffer is a ring (default 2^16 events); the oldest
  spans fall off first and ``n_dropped`` counts them.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple

# ------------------------------------------------------------- categories --
CAT_FWD = "stage.fwd"
CAT_BWD = "stage.bwd"
CAT_TRANSFER = "link.transfer"
CAT_ENCODE = "compress.encode"
CAT_DECODE = "compress.decode"
CAT_MIGRATION = "migrate.stream"
CAT_CHECKPOINT = "checkpoint.restore"
CAT_CONTROLLER = "controller"
CAT_SERVE_PREFILL = "serve.prefill"
CAT_SERVE_REPLAY = "serve.replay"

CATEGORIES = (CAT_FWD, CAT_BWD, CAT_TRANSFER, CAT_ENCODE, CAT_DECODE,
              CAT_MIGRATION, CAT_CHECKPOINT, CAT_CONTROLLER,
              CAT_SERVE_PREFILL, CAT_SERVE_REPLAY)

CLOCK_SIM = "sim"
CLOCK_WALL = "wall"


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One recorded event.  ``ts``/``dur`` are *seconds* in the event's clock
    domain; export converts to trace_event microseconds.  ``phase`` follows
    the Chrome convention: ``"X"`` complete span, ``"i"`` instant."""

    seq: int
    clock: str                 # CLOCK_SIM | CLOCK_WALL
    phase: str                 # "X" | "i"
    cat: str
    name: str
    track: str
    ts: float
    dur: float = 0.0
    args: Optional[Mapping[str, Any]] = None

    def shifted(self, dt: float, seq: int,
                extra_args: Optional[Mapping[str, Any]] = None
                ) -> "TraceEvent":
        args = self.args
        if extra_args:
            args = {**(args or {}), **extra_args}
        return dataclasses.replace(self, ts=self.ts + dt, seq=seq, args=args)


class _OpenSpan:
    """Token returned by :meth:`TraceRecorder.begin`; close with ``end``."""

    __slots__ = ("clock", "cat", "name", "track", "ts", "args")

    def __init__(self, clock, cat, name, track, ts, args):
        self.clock = clock
        self.cat = cat
        self.name = name
        self.track = track
        self.ts = ts
        self.args = args


class _NullRegion:
    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_REGION = _NullRegion()


class TraceRecorder:
    """Thread-safe ring buffer of spans and instants (see module docstring).

    All recording methods are no-ops when ``enabled=False`` — callers may
    keep a disabled recorder wired through hot paths without cost.
    """

    def __init__(self, enabled: bool = True, capacity: int = 65536):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.enabled = bool(enabled)
        self.capacity = int(capacity)
        self._buf: deque = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self._seq = 0
        self._n_total = 0
        self._wall0 = time.perf_counter()

    # ------------------------------------------------------------ plumbing --
    def _push(self, clock: str, phase: str, cat: str, name: str, track: str,
              ts: float, dur: float, args) -> None:
        with self._lock:
            seq = self._seq
            self._seq += 1
            self._n_total += 1
            self._buf.append(TraceEvent(
                seq=seq, clock=clock, phase=phase, cat=cat, name=name,
                track=track, ts=float(ts), dur=float(dur),
                args=dict(args) if args else None))

    def wall_now(self) -> float:
        """Seconds since recorder construction on the wall clock domain."""
        return time.perf_counter() - self._wall0

    # ----------------------------------------------------------- recording --
    def span(self, cat: str, name: str, track: str, t0: float, t1: float,
             args: Optional[Mapping[str, Any]] = None,
             clock: str = CLOCK_SIM) -> None:
        """Record a complete span [t0, t1] (seconds, caller-supplied clock)."""
        if not self.enabled:
            return
        self._push(clock, "X", cat, name, track, t0, max(0.0, t1 - t0), args)

    def instant(self, cat: str, name: str, track: str,
                t: Optional[float] = None,
                args: Optional[Mapping[str, Any]] = None,
                clock: str = CLOCK_SIM) -> None:
        if not self.enabled:
            return
        if t is None:
            t = self.wall_now()
            clock = CLOCK_WALL
        self._push(clock, "i", cat, name, track, t, 0.0, args)

    def begin(self, cat: str, name: str, track: str,
              t: Optional[float] = None,
              args: Optional[Mapping[str, Any]] = None,
              clock: str = CLOCK_SIM) -> Optional[_OpenSpan]:
        """Open a span; pair with :meth:`end`.  ``t=None`` stamps the wall
        clock (the begin/end pair must then stay in the wall domain)."""
        if not self.enabled:
            return None
        if t is None:
            return _OpenSpan(CLOCK_WALL, cat, name, track, self.wall_now(),
                             args)
        return _OpenSpan(clock, cat, name, track, float(t), args)

    def end(self, token: Optional[_OpenSpan], t: Optional[float] = None,
            args: Optional[Mapping[str, Any]] = None) -> None:
        if not self.enabled or token is None:
            return
        t1 = self.wall_now() if t is None else float(t)
        merged = dict(token.args or {})
        if args:
            merged.update(args)
        self._push(token.clock, "X", token.cat, token.name, token.track,
                   token.ts, max(0.0, t1 - token.ts), merged or None)

    def region(self, cat: str, name: str, track: str,
               args: Optional[Mapping[str, Any]] = None):
        """Context manager recording a wall-clock span around its body."""
        if not self.enabled:
            return _NULL_REGION
        return _Region(self, cat, name, track, args)

    def complete_wall(self, cat: str, name: str, track: str, seconds: float,
                      args: Optional[Mapping[str, Any]] = None) -> None:
        """Record a wall-clock span that just finished and took ``seconds``
        (the shape of rad.py's timing callbacks: duration known only at
        completion)."""
        if not self.enabled:
            return
        now = self.wall_now()
        self._push(CLOCK_WALL, "X", cat, name, track,
                   max(0.0, now - seconds), max(0.0, seconds), args)

    def replay(self, events: Iterable[TraceEvent], dt: float,
               extra_args: Optional[Mapping[str, Any]] = None) -> None:
        """Re-emit recorded events shifted by ``dt`` seconds — the
        controller's path for cached per-iteration span sets: the simulator
        runs once per regime, its spans replay every step at the step's
        clock offset."""
        if not self.enabled:
            return
        with self._lock:
            for ev in events:
                seq = self._seq
                self._seq += 1
                self._n_total += 1
                self._buf.append(ev.shifted(dt, seq, extra_args))

    # ------------------------------------------------------------- reading --
    def events(self) -> List[TraceEvent]:
        """Deterministic snapshot: sorted by (clock, ts, seq)."""
        with self._lock:
            snap = list(self._buf)
        return sorted(snap, key=lambda e: (e.clock, e.ts, e.seq))

    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)

    @property
    def n_dropped(self) -> int:
        with self._lock:
            return self._n_total - len(self._buf)

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self._n_total = 0
            self._seq = 0


class _Region:
    __slots__ = ("_rec", "_cat", "_name", "_track", "_args", "_t0")

    def __init__(self, rec, cat, name, track, args):
        self._rec = rec
        self._cat = cat
        self._name = name
        self._track = track
        self._args = args

    def __enter__(self):
        self._t0 = self._rec.wall_now()
        return self

    def __exit__(self, *exc):
        self._rec._push(CLOCK_WALL, "X", self._cat, self._name, self._track,
                        self._t0, max(0.0, self._rec.wall_now() - self._t0),
                        self._args)
        return False
