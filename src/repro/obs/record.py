"""ElasticController flight recorder.

Every broker decision becomes one structured, JSON-serializable record, so a
run can be *replayed*: why did a re-plan fire, what did the telemetry window
look like, which corrections passed hysteresis, what did each candidate
(including ``keep``) predict, and who won.  The record stream is the
debugging artifact the closed loop was missing — `churn.closed_loop`
recovering 1.41× is now fully explained by its own flight log (asserted in
tests).

Record kinds::

    calibration   one fit attempt: telemetry window snapshot, fitted values,
                  per-link hysteresis verdict (adopted | hysteresis | healed),
                  installed corrections after, detector repriced?, installed
                  vs calibrated pace, diverged verdict
    replan        one epoch transition: trigger cause + reason, dead/joined,
                  every candidate's predicted pace + migration bytes/seconds
                  + total score, the winner, plan-only hot swaps
    epoch         the installed epoch (mirrors EpochRecord, JSON-ready)
    detector      a straggler flag: node, severity, believed factor
    route         a serving router decision: session admitted onto a chain
                  of stage replicas, or re-routed mid-session around dead
                  replicas (with the replayed-KV token count and what the
                  alternative KV shipment would have cost on the wire)
    watchdog      a streaming SLO/anomaly trip: which rule fired, on which
                  signal (step seconds, a link's seconds, serving tokens/s),
                  the observed value vs the reference it violated

All records share ``kind``, ``step`` (data step) and ``clock`` (simulated
seconds).  :meth:`FlightRecorder.to_jsonl` / :func:`read_jsonl` round-trip
the log; the report CLI renders it next to the Perfetto trace.
"""
from __future__ import annotations

import dataclasses
import json
from collections import deque
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple


def _link_key(link: Tuple[int, int]) -> str:
    return f"{link[0]}->{link[1]}"


@dataclasses.dataclass(frozen=True)
class CandidateScore:
    """One re-plan candidate as the broker priced it."""

    name: str                   # keep | anchored | full
    pace: float                 # predicted Eq. 3 steady-state pace (s)
    migration_bytes: float
    migration_seconds: float
    score: float                # migration_seconds + amortize_steps * pace
    winner: bool = False

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class CalibrationRecord:
    step: int
    clock: float
    window: Dict[str, int]            # link -> samples in the fit window
    fitted: Dict[str, float]          # link -> fitted correction
    verdicts: Dict[str, str]          # link -> adopted | hysteresis | healed
    installed: Dict[str, float]       # corrections in force after this fit
    repriced: bool                    # detector reference updated in place
    installed_pace: float             # pace the active plan was adopted at
    calibrated_pace: float            # the same plan under the new belief
    diverged: bool                    # past replan_pace_margin -> re-plan
    kind: str = "calibration"

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class ReplanRecord:
    step: int
    clock: float
    cause: str                        # failure | join | straggler | ...
    reason: str                       # human-readable trigger description
    dead: List[int]
    joined: List[int]
    candidates: List[CandidateScore]  # every candidate the broker priced
    winner: str
    plan_only: bool = False           # same cut, hot compression swap
    kind: str = "replan"

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["candidates"] = [c.to_dict() if isinstance(c, CandidateScore)
                           else dict(c) for c in self.candidates]
        return d


@dataclasses.dataclass(frozen=True)
class EpochFlightRecord:
    step: int
    clock: float
    epoch: int
    cause: str
    stage_devices: List[int]
    n_moves: int
    moved_bytes: float
    migrate_seconds: float
    refill_seconds: float
    rollback_steps: int
    replan_mode: str = ""
    kind: str = "epoch"

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class RouteRecord:
    """One serving-router decision (``cause``: admit | reroute)."""

    step: int                         # decode round
    clock: float                      # simulated seconds
    session: str
    cause: str                        # admit | reroute
    dead: List[int]                   # replicas detected dead (reroute)
    old_chain: List[int]              # device per stage before the decision
    chain: List[int]                  # device per stage after
    replay_tokens: int                # tokens replayed onto replacements
    kv_ship_bytes: int                # what shipping the KV instead would cost
    kind: str = "route"

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class DetectorRecord:
    step: int
    clock: float
    node: int
    severity: float
    believed_factor: float
    kind: str = "detector"

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class WatchdogRecord:
    """One watchdog trip (``rule``: slo | ewma | mad)."""

    step: int                         # training step or serving round
    clock: float                      # simulated seconds
    rule: str                         # slo | ewma | mad
    signal: str                       # step_seconds | link 3->5 | tokens_per_s
    value: float                      # the observation that tripped
    reference: float                  # SLO bound / EWMA mean / window median
    severity: float                   # |value - reference| / reference
    message: str = ""
    kind: str = "watchdog"

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


class FlightRecorder:
    """Bounded, ordered log of broker decisions.

    Always cheap enough to leave on: records are tiny dataclasses, the
    buffer is a ring (default 4096 records) and nothing is serialized until
    :meth:`to_jsonl` is called.
    """

    def __init__(self, capacity: int = 4096):
        self._buf: deque = deque(maxlen=int(capacity))

    def log(self, record) -> None:
        self._buf.append(record)

    def records(self, kind: Optional[str] = None) -> List[Any]:
        if kind is None:
            return list(self._buf)
        return [r for r in self._buf if r.kind == kind]

    def __len__(self) -> int:
        return len(self._buf)

    # -------------------------------------------------------- serialization --
    def to_dicts(self) -> List[Dict[str, Any]]:
        return [r.to_dict() for r in self._buf]

    def to_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for r in self.to_dicts():
                f.write(json.dumps(r, sort_keys=True) + "\n")


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    """Load a flight log written by :meth:`FlightRecorder.to_jsonl`."""
    out: List[Dict[str, Any]] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def links_to_str(mapping: Mapping[Tuple[int, int], Any]) -> Dict[str, Any]:
    """JSON-friendly link keys: ``(i, j)`` -> ``"i->j"``, sorted."""
    return {_link_key(k): mapping[k] for k in sorted(mapping)}
