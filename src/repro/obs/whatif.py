"""What-if engine: re-price a recorded scenario under counterfactual edits.

The blame table (:mod:`repro.obs.critpath`) names the bottleneck; this module
answers the follow-up question — *which fix pays most?* — by replaying the
same scenario through :func:`repro.core.executor.simulate_iteration` under
counterfactual edits expressed through :class:`repro.core.costmodel.
EdgeCostModel` variants:

* ``link_speedup`` — a directed link ``k``× faster (a calibrated
  ``link_corrections`` entry divided by ``k``: the exact channel the
  closed-loop calibrator uses, so a what-if "restore the degraded wire"
  prices identically to the controller adopting the fitted correction);
* ``node_links_speedup`` — every link touching a node ``k``× faster (the
  counterfactual for "this volunteer's uplink recovered");
* ``codec_free`` — compression codec priced at zero
  (``with_kernel_costs({})``, the pre-PR-8 assumption);
* ``ratio_change`` — re-run AdaTopK at a different target ratio on the same
  placement and transport under the new plan;
* ``drop_device`` — remove a device and re-plan on the survivors
  (``device_subset``), the counterfactual behind the elastic controller's
  leave handling.

:func:`rank` prices each intervention with the discrete-event simulator
itself — predictions are *exact* by construction for cost-model edits (the
sim consumes the same :class:`EdgeCostModel`), and the ISSUE's 5% acceptance
bound only absorbs α/β asymmetries when a counterfactual is compared against
a ground-truth cluster edit (``with_link_slowdowns`` scales β only, while a
correction scales the whole link time).

No byte arithmetic happens here: every counterfactual is an
``EdgeCostModel`` variant, never a hand-scaled β.
"""
from __future__ import annotations

import dataclasses
import re
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple)

_LINK_TRACK_RE = re.compile(r"^link (\d+)->(\d+)$")


@dataclasses.dataclass
class Scenario:
    """Everything needed to re-simulate one recorded training step.

    ``cost_model`` carries the calibrated link corrections / kernel costs in
    force when the trace was recorded; ``cluster`` is the (believed or true)
    cluster the step priced against.  Build one from an
    :class:`~repro.core.scheduler.JointPlan` with :meth:`from_joint`.
    """

    graph: Any
    profiles: Mapping[str, Any]
    schedule: Any
    cluster: Any
    plan: Optional[Any] = None
    cost_model: Optional[Any] = None
    n_micro: int = 1

    @classmethod
    def from_joint(cls, graph, profiles, cluster, joint, n_micro: int = 1
                   ) -> "Scenario":
        return cls(graph=graph, profiles=profiles, schedule=joint.schedule,
                   cluster=cluster, plan=joint.plan,
                   cost_model=joint.cost_model, n_micro=n_micro)

    def model(self):
        """The scenario's effective cost model (built lazily if absent)."""
        if self.cost_model is not None:
            return self.cost_model
        from repro.core.costmodel import EdgeCostModel
        return EdgeCostModel(self.graph, self.profiles, self.cluster,
                             plan=self.plan)

    def price(self) -> float:
        """Step seconds under this scenario — the simulator's ground truth."""
        from repro.core.executor import simulate_iteration
        sim = simulate_iteration(self.graph, self.profiles, self.schedule,
                                 self.cluster, plan=self.plan,
                                 n_micro=self.n_micro,
                                 cost_model=self.model())
        return float(sim.iteration_time)


@dataclasses.dataclass(frozen=True)
class Intervention:
    """One named counterfactual edit: ``apply(scenario) -> scenario``."""

    name: str
    detail: str
    apply: Callable[[Scenario], Scenario]


@dataclasses.dataclass(frozen=True)
class WhatIfResult:
    """One priced intervention, comparable against the recorded baseline."""

    name: str
    detail: str
    baseline_seconds: float
    predicted_seconds: float

    @property
    def delta_seconds(self) -> float:
        return self.baseline_seconds - self.predicted_seconds

    @property
    def speedup(self) -> float:
        if self.predicted_seconds <= 0.0:
            return float("inf")
        return self.baseline_seconds / self.predicted_seconds

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "detail": self.detail,
                "baseline_seconds": self.baseline_seconds,
                "predicted_seconds": self.predicted_seconds,
                "delta_seconds": self.delta_seconds,
                "speedup": self.speedup}


# ------------------------------------------------------------ edit builders --
def _scaled_corrections(model, pairs: Sequence[Tuple[int, int]],
                        factor: float) -> Dict[Tuple[int, int], float]:
    corr = dict(model.link_corrections)
    for pair in pairs:
        corr[pair] = corr.get(pair, 1.0) * factor
    return corr


def link_speedup(src: int, dst: int, k: float = 2.0) -> Intervention:
    """Directed link ``src -> dst`` priced ``k``× faster."""
    def _apply(sc: Scenario) -> Scenario:
        model = sc.model()
        corr = _scaled_corrections(model, [(src, dst)], 1.0 / k)
        return dataclasses.replace(
            sc, cost_model=model.with_link_corrections(corr))
    return Intervention(name=f"link {src}->{dst} {k:g}x",
                        detail=f"price directed link {src}->{dst} {k:g}x "
                               f"faster via a link correction",
                        apply=_apply)


def node_links_speedup(node: int, k: float = 2.0,
                       peers: Optional[Sequence[int]] = None) -> Intervention:
    """Every directed link touching ``node`` priced ``k``× faster (both
    directions, against ``peers`` or every other device in the cluster)."""
    def _apply(sc: Scenario) -> Scenario:
        model = sc.model()
        others = list(peers) if peers is not None \
            else [d for d in range(len(sc.cluster)) if d != node]
        pairs = [(node, p) for p in others] + [(p, node) for p in others]
        corr = _scaled_corrections(model, pairs, 1.0 / k)
        return dataclasses.replace(
            sc, cost_model=model.with_link_corrections(corr))
    return Intervention(name=f"node {node} links {k:g}x",
                        detail=f"price every link touching node {node} "
                               f"{k:g}x faster",
                        apply=_apply)


def codec_free() -> Intervention:
    """Compression codec priced at zero (drop all fitted kernel costs)."""
    def _apply(sc: Scenario) -> Scenario:
        return dataclasses.replace(sc, cost_model=sc.model().with_kernel_costs({}))
    return Intervention(name="codec free",
                        detail="price the compression codec at zero seconds",
                        apply=_apply)


def ratio_change(ratio: float) -> Intervention:
    """Re-run AdaTopK at ``ratio`` on the *same* placement and transport
    under the resulting plan."""
    def _apply(sc: Scenario) -> Scenario:
        from repro.core.compression import plan_adatopk
        model = sc.model()
        plan = plan_adatopk(sc.graph, sc.profiles, sc.cluster,
                            sc.schedule.placement, float(ratio),
                            cost_model=model.with_plan(None))
        return dataclasses.replace(sc, plan=plan,
                                   cost_model=model.with_plan(plan))
    return Intervention(name=f"ratio {ratio:g}",
                        detail=f"re-plan AdaTopK at target ratio {ratio:g} "
                               f"on the recorded placement",
                        apply=_apply)


def drop_device(dev: int, ratio: Optional[float] = None) -> Intervention:
    """Remove a device and re-plan the pipeline on the survivors (joint
    re-plan when the scenario compresses, plain OP-Fence otherwise)."""
    def _apply(sc: Scenario) -> Scenario:
        survivors = [d for d in range(len(sc.cluster)) if d != dev]
        model = sc.model()
        base = model.with_plan(None)
        r = ratio if ratio is not None \
            else (sc.plan.base_ratio if sc.plan is not None else None)
        if r is not None and r > 1.0:
            from repro.core.scheduler import schedule_joint
            joint = schedule_joint(sc.graph, sc.profiles, sc.cluster,
                                   float(r), device_subset=survivors,
                                   cost_model=base)
            return dataclasses.replace(sc, schedule=joint.schedule,
                                       plan=joint.plan,
                                       cost_model=joint.cost_model)
        from repro.core.scheduler import schedule_opfence
        sched = schedule_opfence(sc.graph, sc.profiles, sc.cluster,
                                 cost_model=base, device_subset=survivors)
        return dataclasses.replace(sc, schedule=sched, plan=None,
                                   cost_model=base)
    return Intervention(name=f"drop dev{dev}",
                        detail=f"remove device {dev} and re-plan on the "
                               f"survivors",
                        apply=_apply)


# ----------------------------------------------------------------- ranking --
def rank(scenario: Scenario,
         interventions: Sequence[Intervention]) -> List[WhatIfResult]:
    """Price every intervention against the scenario baseline and return
    results best-first (largest predicted step-time reduction)."""
    baseline = scenario.price()
    out: List[WhatIfResult] = []
    for iv in interventions:
        predicted = iv.apply(scenario).price()
        out.append(WhatIfResult(name=iv.name, detail=iv.detail,
                                baseline_seconds=baseline,
                                predicted_seconds=predicted))
    out.sort(key=lambda r: (r.predicted_seconds, r.name))
    return out


def default_interventions(scenario: Scenario, blame_rows: Sequence[Any],
                          k: float = 2.0, top: int = 4
                          ) -> List[Intervention]:
    """Candidate fixes suggested by a blame table: a ``k``× speedup for each
    of the worst ``top`` critical-path links, plus ``codec free`` whenever
    codec time appears on the path, plus a 2× coarser / 2× finer AdaTopK
    ratio when the scenario compresses."""
    out: List[Intervention] = []
    n_links = 0
    saw_codec = False
    for row in blame_rows:
        if row.kind == "wire" and n_links < top:
            m = _LINK_TRACK_RE.match(row.track)
            if m:
                out.append(link_speedup(int(m.group(1)), int(m.group(2)), k))
                n_links += 1
        elif row.kind == "codec" and not saw_codec:
            saw_codec = True
            out.append(codec_free())
    if scenario.plan is not None and scenario.plan.base_ratio > 1.0:
        base = float(scenario.plan.base_ratio)
        out.append(ratio_change(base * 2.0))
        if base > 2.0:
            out.append(ratio_change(base / 2.0))
    return out
