"""Trace export: Chrome/Perfetto ``trace_event`` JSON and raw JSONL.

Two artifacts from one :class:`repro.obs.trace.TraceRecorder`:

* :func:`write_chrome_trace` — the Trace Event Format Chrome's
  ``chrome://tracing`` and https://ui.perfetto.dev open directly:
  ``{"traceEvents": [...]}`` with complete (``"ph": "X"``) and instant
  (``"ph": "i"``) events plus ``"M"`` metadata naming the processes (one per
  clock domain: ``sim``, ``wall``) and threads (one per recorded track).
  Timestamps are microseconds (sim seconds × 1e6).
* :func:`write_jsonl` / :func:`read_jsonl` — the raw recorder events, one
  JSON object per line, loss-free (the report CLI's preferred input; it
  round-trips through :func:`events_from_dicts`).

:func:`validate_trace_events` checks the schema CI gates the trace artifact
on: every event has string ``name``/``ph``, integer ``pid``/``tid``, numeric
non-negative ``ts``; ``X`` events have numeric non-negative ``dur``; ``M``
events carry their ``args.name``.  Returns a list of violation strings
(empty = valid).

CLI::

    python -m repro.obs.export --validate TRACE.json      # or .jsonl
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence

from .trace import CLOCK_SIM, CLOCK_WALL, TraceEvent, TraceRecorder

_CLOCK_PID = {CLOCK_SIM: 1, CLOCK_WALL: 2}
_VALID_PH = {"X", "i", "M", "C"}


# ------------------------------------------------------------ trace_event --
def _track_ids(events: Sequence[TraceEvent]) -> Dict[tuple, int]:
    """Deterministic (clock, track) -> tid assignment: sorted name order."""
    keys = sorted({(e.clock, e.track) for e in events})
    return {k: i + 1 for i, k in enumerate(keys)}


def to_trace_events(recorder_or_events) -> List[Dict[str, Any]]:
    """Convert recorder events to Chrome trace_event dicts (µs timestamps)."""
    events = recorder_or_events.events() \
        if isinstance(recorder_or_events, TraceRecorder) \
        else list(recorder_or_events)
    tids = _track_ids(events)
    out: List[Dict[str, Any]] = []
    for clock, pid in sorted(_CLOCK_PID.items()):
        if any(e.clock == clock for e in events):
            out.append({"ph": "M", "name": "process_name", "pid": pid,
                        "tid": 0, "ts": 0,
                        "args": {"name": f"{clock} clock"}})
    for (clock, track), tid in sorted(tids.items(), key=lambda kv: kv[1]):
        out.append({"ph": "M", "name": "thread_name",
                    "pid": _CLOCK_PID[clock], "tid": tid, "ts": 0,
                    "args": {"name": track}})
    for e in events:
        rec: Dict[str, Any] = {
            "ph": e.phase, "name": e.name, "cat": e.cat,
            "pid": _CLOCK_PID[e.clock], "tid": tids[(e.clock, e.track)],
            "ts": e.ts * 1e6,
        }
        if e.phase == "X":
            rec["dur"] = e.dur * 1e6
        elif e.phase == "i":
            rec["s"] = "t"          # thread-scoped instant
        if e.args:
            rec["args"] = dict(e.args)
        out.append(rec)
    return out


def write_chrome_trace(recorder_or_events, path: str, metrics=None) -> int:
    """Write the Perfetto-loadable JSON; returns the event count."""
    if isinstance(recorder_or_events, TraceRecorder):
        surface_drops(recorder_or_events, metrics)
    events = to_trace_events(recorder_or_events)
    with open(path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
    return len(events)


# ------------------------------------------------------------------ JSONL --
JSONL_SCHEMA = "repro.obs/trace.v1"


def surface_drops(recorder: TraceRecorder, metrics=None) -> int:
    """Make ring-buffer overflow loud: when the recorder dropped events,
    emit a ``slog`` warning and bump the ``trace_dropped_events`` counter
    (a truncated trace silently breaks every attribution built on it).
    Returns the drop count."""
    dropped = int(recorder.n_dropped)
    if dropped > 0:
        from .slog import get_logger
        get_logger("repro.obs").warn(
            "trace_ring_overflow", dropped=dropped,
            capacity=int(recorder.capacity), kept=len(recorder.events()))
        if metrics is not None:
            c = metrics.counter("trace_dropped_events")
            c.inc(max(0, dropped - int(c.value)))
    return dropped


def write_jsonl(recorder_or_events, path: str, metrics=None) -> int:
    """Raw recorder events, one JSON object per line (loss-free), preceded
    by one header line stamping the recorder's drop accounting::

        {"header": "repro.obs/trace.v1", "n_events": ..., "n_dropped": ...,
         "capacity": ...}

    so downstream consumers (:mod:`repro.obs.critpath`, the report CLI) can
    refuse silently-truncated inputs.  Returns the *event* count (the
    header line is metadata, not an event)."""
    is_rec = isinstance(recorder_or_events, TraceRecorder)
    events = recorder_or_events.events() if is_rec \
        else list(recorder_or_events)
    dropped = surface_drops(recorder_or_events, metrics) if is_rec else 0
    header = {"header": JSONL_SCHEMA, "n_events": len(events),
              "n_dropped": dropped,
              "capacity": (int(recorder_or_events.capacity)
                           if is_rec else None)}
    with open(path, "w") as f:
        f.write(json.dumps(header, sort_keys=True) + "\n")
        for e in events:
            f.write(json.dumps({
                "seq": e.seq, "clock": e.clock, "ph": e.phase, "cat": e.cat,
                "name": e.name, "track": e.track, "ts": e.ts, "dur": e.dur,
                "args": dict(e.args) if e.args else None},
                sort_keys=True) + "\n")
    return len(events)


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    out: List[Dict[str, Any]] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def read_header(dicts: Iterable[Mapping[str, Any]]
                ) -> Optional[Mapping[str, Any]]:
    """The JSONL header from :func:`read_jsonl` output, or ``None`` for
    pre-header files (which by construction never reported drops)."""
    for d in dicts:
        if "header" in d and "seq" not in d:
            return d
        break
    return None


def events_from_dicts(dicts: Iterable[Mapping[str, Any]]) -> List[TraceEvent]:
    """Rebuild TraceEvents from :func:`read_jsonl` output (round-trip).
    Header/metadata lines (no ``seq``) are skipped."""
    return [TraceEvent(seq=int(d["seq"]), clock=d["clock"], phase=d["ph"],
                       cat=d["cat"], name=d["name"], track=d["track"],
                       ts=float(d["ts"]), dur=float(d.get("dur") or 0.0),
                       args=d.get("args"))
            for d in dicts if "seq" in d]


# ------------------------------------------------------------- validation --
def validate_trace_events(events: Iterable[Mapping[str, Any]]) -> List[str]:
    """Schema check for trace_event dicts; returns violation strings."""
    errors: List[str] = []
    n = 0
    for i, e in enumerate(events):
        n += 1
        where = f"event[{i}]"
        if not isinstance(e, Mapping):
            errors.append(f"{where}: not an object")
            continue
        ph = e.get("ph")
        if not isinstance(ph, str) or ph not in _VALID_PH:
            errors.append(f"{where}: ph={ph!r} not in {sorted(_VALID_PH)}")
            continue
        if not isinstance(e.get("name"), str) or not e["name"]:
            errors.append(f"{where}: missing/empty name")
        for field in ("pid", "tid"):
            if not isinstance(e.get(field), int):
                errors.append(f"{where}: {field} must be an integer, got "
                              f"{e.get(field)!r}")
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"{where}: ts must be a non-negative number, got "
                          f"{ts!r}")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{where}: X event needs non-negative dur, "
                              f"got {dur!r}")
        if ph == "M":
            args = e.get("args")
            if not isinstance(args, Mapping) or "name" not in args:
                errors.append(f"{where}: M event needs args.name")
    if n == 0:
        errors.append("empty trace: no events")
    return errors


def load_trace_file(path: str) -> List[Dict[str, Any]]:
    """Load trace_event dicts from a chrome-trace .json or a recorder
    .jsonl (the latter is converted through :func:`to_trace_events`)."""
    if path.endswith(".jsonl"):
        return to_trace_events(events_from_dicts(read_jsonl(path)))
    with open(path) as f:
        payload = json.load(f)
    if isinstance(payload, Mapping):
        return list(payload.get("traceEvents", []))
    return list(payload)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("path", help="TRACE .json (chrome trace) or .jsonl")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check the artifact; non-zero exit on "
                         "violations")
    ap.add_argument("--check-order", action="store_true",
                    help="additionally run the repro.check happens-before "
                         "checker (overlapping sends, compute before "
                         "inbound transfer)")
    args = ap.parse_args(argv)
    events = load_trace_file(args.path)
    errors = validate_trace_events(events)
    order_errors: List[str] = []
    if args.check_order:
        # function-local import: repro.obs stays stdlib-only importable;
        # the happens-before pass is the check layer's
        from repro.check.traceorder import (check_trace_order,
                                            load_trace_events)
        order_errors = [str(f)
                        for f in check_trace_order(load_trace_events(
                            args.path))
                        if f.severity == "error"]
    if args.validate or args.check_order:
        bad = errors + order_errors
        if bad:
            print(f"{args.path}: INVALID ({len(bad)} violations)",
                  file=sys.stderr)
            for e in bad[:20]:
                print(f"  - {e}", file=sys.stderr)
            return 1
        ordered = ", happens-before ok" if args.check_order else ""
        print(f"{args.path}: OK ({len(events)} trace events, schema valid"
              f"{ordered})")
        return 0
    print(f"{len(events)} trace events, {len(errors)} violations")
    return 0


if __name__ == "__main__":
    sys.exit(main())
