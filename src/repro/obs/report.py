"""Run report: one readable artifact from a trace + flight-recorder pair.

    PYTHONPATH=src python -m repro.obs.report TRACE.jsonl \
        [--flight FLIGHT.jsonl] [--width 100]

Renders, from the recorder's loss-free JSONL events (see
:mod:`repro.obs.export`):

* a per-track ASCII **timeline** of the simulated clock (stage compute spans
  and link transfers, bucketed to the terminal width);
* the **comm/compute overlap fraction** — how much wire time was hidden
  under stage compute, the overlap Eq. 3 banks on;
* a **straggler heatmap** — per device × step busy seconds, row-normalized,
  so a degraded node shows as a bright row the moment it slows;
* the **critical path** — the blame table from
  :mod:`repro.obs.critpath`: which device/link/codec the step time is
  actually waiting on, and for how many seconds per step;
* **top interventions** — Amdahl upper bounds per blamed resource ("if
  this link were free the step could shrink by at most X s"); exact
  counterfactual pricing lives in :mod:`repro.obs.whatif`;
* the **decision log** — the flight recorder's calibration / re-plan /
  epoch / detector / watchdog records, one line each, in order.

All rendering is pure (lists in, string out) so tests assert on content, and
the CLI is a thin wrapper.
"""
from __future__ import annotations

import argparse
import sys
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from .trace import (CAT_BWD, CAT_FWD, CAT_TRANSFER, CLOCK_SIM, TraceEvent)
from .export import events_from_dicts, read_jsonl
from . import record as flight_record

_RAMP = " .:-=+*#%@"


# ------------------------------------------------------------- interval math
def _union(intervals: List[Tuple[float, float]]) -> List[Tuple[float, float]]:
    """Merged, sorted union of [start, end) intervals."""
    out: List[Tuple[float, float]] = []
    for s, e in sorted(intervals):
        if e <= s:
            continue
        if out and s <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], e))
        else:
            out.append((s, e))
    return out


def _measure(intervals: List[Tuple[float, float]]) -> float:
    return sum(e - s for s, e in intervals)


def _intersect(a: List[Tuple[float, float]],
               b: List[Tuple[float, float]]) -> float:
    """Total length of the intersection of two *merged* interval unions."""
    i = j = 0
    total = 0.0
    while i < len(a) and j < len(b):
        s = max(a[i][0], b[j][0])
        e = min(a[i][1], b[j][1])
        if e > s:
            total += e - s
        if a[i][1] <= b[j][1]:
            i += 1
        else:
            j += 1
    return total


# --------------------------------------------------------------- aggregates
def sim_events(events: Iterable[TraceEvent]) -> List[TraceEvent]:
    return [e for e in events if e.clock == CLOCK_SIM and e.phase == "X"]


def overlap_fraction(events: Iterable[TraceEvent]) -> Optional[float]:
    """Fraction of link-transfer wall-time that overlapped stage compute on
    the simulated clock (None when the trace has no transfers)."""
    evs = sim_events(events)
    compute = _union([(e.ts, e.ts + e.dur) for e in evs
                      if e.cat in (CAT_FWD, CAT_BWD)])
    comm = _union([(e.ts, e.ts + e.dur) for e in evs
                   if e.cat == CAT_TRANSFER])
    wire = _measure(comm)
    if wire <= 0.0:
        return None
    return _intersect(compute, comm) / wire


def stage_summary(events: Iterable[TraceEvent]
                  ) -> Dict[str, Dict[str, float]]:
    """Per sim-clock track: busy seconds by category group."""
    out: Dict[str, Dict[str, float]] = {}
    for e in sim_events(events):
        row = out.setdefault(e.track, {})
        key = {CAT_FWD: "fwd", CAT_BWD: "bwd"}.get(e.cat, e.cat)
        row[key] = row.get(key, 0.0) + e.dur
    return out


def straggler_matrix(events: Iterable[TraceEvent]
                     ) -> Tuple[List[str], List[int], List[List[float]]]:
    """(device tracks, steps, busy-seconds matrix) from compute spans whose
    args carry a ``step`` stamp (the controller's per-step replay)."""
    busy: Dict[Tuple[str, int], float] = {}
    for e in sim_events(events):
        if e.cat not in (CAT_FWD, CAT_BWD) or not e.args:
            continue
        step = e.args.get("step")
        if step is None:
            continue
        busy[(e.track, int(step))] = busy.get((e.track, int(step)), 0.0) \
            + e.dur
    tracks = sorted({t for t, _ in busy})
    steps = sorted({s for _, s in busy})
    matrix = [[busy.get((t, s), 0.0) for s in steps] for t in tracks]
    return tracks, steps, matrix


def render_heatmap(tracks: Sequence[str], steps: Sequence[int],
                   matrix: Sequence[Sequence[float]]) -> str:
    """Straggler heatmap: rows = devices, columns = steps, shade = busy
    seconds normalized by the *global* max (so a slowed device brightens
    relative to its healthy peers, column-wise drift shows re-plans)."""
    if not tracks:
        return "(no per-step compute spans in trace)"
    peak = max((v for row in matrix for v in row), default=0.0)
    lines = [f"steps {steps[0]}..{steps[-1]} ({len(steps)} cols), "
             f"peak {peak:.4g}s/step"]
    for t, row in zip(tracks, matrix):
        cells = "".join(
            _RAMP[min(len(_RAMP) - 1,
                      int(v / peak * (len(_RAMP) - 1)))] if peak > 0 else " "
            for v in row)
        lines.append(f"{t:>10s} |{cells}|")
    return "\n".join(lines)


def render_timeline(events: Iterable[TraceEvent], width: int = 80) -> str:
    """Per-track occupancy bars over the sim-clock extent, bucketed to
    ``width`` columns (a cell is shaded by its busy fraction)."""
    evs = sim_events(events)
    if not evs:
        return "(no sim-clock spans in trace)"
    t0 = min(e.ts for e in evs)
    t1 = max(e.ts + e.dur for e in evs)
    span = max(t1 - t0, 1e-12)
    tracks = sorted({e.track for e in evs})
    lines = [f"sim clock {t0:.4g}s .. {t1:.4g}s "
             f"({span:.4g}s across {width} cols)"]
    for t in tracks:
        frac = [0.0] * width
        for e in evs:
            if e.track != t:
                continue
            lo = (e.ts - t0) / span * width
            hi = (e.ts + e.dur - t0) / span * width
            c0, c1 = int(lo), min(width - 1, int(hi))
            for c in range(c0, c1 + 1):
                cell_lo, cell_hi = c, c + 1
                frac[c] += max(0.0, min(hi, cell_hi) - max(lo, cell_lo))
        cells = "".join(
            _RAMP[min(len(_RAMP) - 1, int(min(1.0, f) * (len(_RAMP) - 1)))]
            for f in frac)
        lines.append(f"{t:>14s} |{cells}|")
    return "\n".join(lines)


# ------------------------------------------------------------- decision log
def render_flight(records: Sequence[Mapping[str, Any]]) -> str:
    """One line per flight-recorder record, in log order."""
    if not records:
        return "(no flight records)"
    lines: List[str] = []
    for r in records:
        kind = r.get("kind", "?")
        head = f"[{r.get('step', '?'):>4}] t={float(r.get('clock', 0.0)):9.3f}s {kind:<11s}"
        if kind == "calibration":
            fits = ", ".join(f"{k}={v:.3g}({r['verdicts'].get(k, '?')})"
                             for k, v in sorted(r.get("fitted", {}).items()))
            lines.append(
                f"{head} fits: {fits or '(none)'}  installed="
                f"{ {k: round(v, 3) for k, v in sorted(r.get('installed', {}).items())} } "
                f"pace {r.get('installed_pace', 0.0):.4g}->"
                f"{r.get('calibrated_pace', 0.0):.4g} "
                f"{'DIVERGED -> re-plan' if r.get('diverged') else 'within margin'}")
        elif kind == "replan":
            cands = "  ".join(
                f"{c['name']}{'*' if c.get('winner') else ''}"
                f"(pace={c['pace']:.4g},mig={c['migration_seconds']:.3g}s"
                f"/{c['migration_bytes'] / 1e6:.3g}MB,"
                f"score={c['score']:.4g})"
                for c in r.get("candidates", []))
            lines.append(f"{head} cause={r.get('cause')} "
                         f"reason={r.get('reason')!r} "
                         f"dead={r.get('dead')} joined={r.get('joined')} "
                         f"-> {r.get('winner')}"
                         f"{' [plan-only hot swap]' if r.get('plan_only') else ''}\n"
                         f"{'':>32s}{cands}")
        elif kind == "epoch":
            lines.append(
                f"{head} #{r.get('epoch')} cause={r.get('cause')} "
                f"stages={r.get('stage_devices')} moves={r.get('n_moves')} "
                f"({float(r.get('moved_bytes', 0.0)) / 1e6:.3g}MB, "
                f"migrate {float(r.get('migrate_seconds', 0.0)):.3g}s + "
                f"refill {float(r.get('refill_seconds', 0.0)):.3g}s, "
                f"rollback {r.get('rollback_steps', 0)})")
        elif kind == "detector":
            lines.append(f"{head} node={r.get('node')} "
                         f"severity={float(r.get('severity', 0.0)):.3g} "
                         f"believed={float(r.get('believed_factor', 0.0)):.3g}")
        elif kind == "watchdog":
            lines.append(f"{head} {r.get('rule')} on {r.get('signal')!r}: "
                         f"value={float(r.get('value', 0.0)):.4g} vs "
                         f"ref={float(r.get('reference', 0.0)):.4g} "
                         f"(severity {float(r.get('severity', 0.0)):.3g})"
                         f"{' ' + r['message'] if r.get('message') else ''}")
        elif kind == "route":
            arrow = "" if r.get("cause") != "reroute" \
                else f" {r.get('old_chain')} ->"
            lines.append(
                f"{head} {r.get('cause')} s={r.get('session')}"
                f"{arrow} chain={r.get('chain')} dead={r.get('dead')} "
                f"replay={r.get('replay_tokens', 0)}tok "
                f"(kv-ship alt {int(r.get('kv_ship_bytes', 0)) / 1e6:.3g}MB)")
        else:
            lines.append(f"{head} {dict(r)}")
    return "\n".join(lines)


# ----------------------------------------------------------- critical path
def render_interventions(rows: Sequence[Any], n_attempts: int,
                         top: int = 5) -> str:
    """Amdahl upper bounds from blame rows: eliminating a resource outright
    can shave at most its critical-path seconds off each step.  (The exact
    counterfactual number — re-planned, re-overlapped — comes from
    :mod:`repro.obs.whatif`; this section ranks what is *worth* re-pricing.)
    """
    ranked = [r for r in rows if r.kind != "stall"][:top]
    if not ranked or n_attempts == 0:
        return "(nothing on the critical path to intervene on)"
    lines = []
    for i, r in enumerate(ranked):
        lines.append(f"{i + 1}. if {r.kind} {r.track or '?'} were free: "
                     f"<= {r.mean_seconds:.4g} s/step back "
                     f"({r.share * 100:.1f}% of the critical path, "
                     f"on-path {r.steps_on_path}/{r.n_steps} steps)")
    return "\n".join(lines)


def render_critpath(events: Sequence[TraceEvent], top: int = 8
                    ) -> Tuple[str, str]:
    """(blame-table text, interventions text) for :func:`build_report`."""
    from . import critpath
    decomps = critpath.analyze(events)
    if not decomps:
        return ("(no attributable sim spans in trace)",
                "(nothing on the critical path to intervene on)")
    rows = critpath.blame(decomps)
    mean_make = sum(d.makespan for d in decomps) / len(decomps)
    header = (f"{len(decomps)} step attempt(s), "
              f"mean makespan {mean_make:.4g}s")
    return (header + "\n" + critpath.render_blame(rows, top=top),
            render_interventions(rows, len(decomps)))


# ------------------------------------------------------------------ report
def build_report(events: Sequence[TraceEvent],
                 flight: Optional[Sequence[Mapping[str, Any]]] = None,
                 width: int = 80) -> str:
    """The full run report (pure: render only, no I/O)."""
    parts: List[str] = []
    parts.append("== timeline " + "=" * max(0, width - 12))
    parts.append(render_timeline(events, width=width))
    ov = overlap_fraction(events)
    parts.append("")
    parts.append("== comm/compute overlap " + "=" * max(0, width - 24))
    parts.append("no link transfers traced" if ov is None else
                 f"{ov * 100:.1f}% of wire seconds overlapped stage compute")
    summary = stage_summary(events)
    if summary:
        parts.append("")
        parts.append("== per-track busy seconds " + "=" * max(0, width - 26))
        for track in sorted(summary):
            row = summary[track]
            cells = "  ".join(f"{k}={v:.4g}s" for k, v in sorted(row.items()))
            parts.append(f"{track:>14s}  {cells}")
    tracks, steps, matrix = straggler_matrix(events)
    parts.append("")
    parts.append("== straggler heatmap " + "=" * max(0, width - 21))
    parts.append(render_heatmap(tracks, steps, matrix))
    blame_text, iv_text = render_critpath(events)
    parts.append("")
    parts.append("== critical path " + "=" * max(0, width - 17))
    parts.append(blame_text)
    parts.append("")
    parts.append("== top interventions " + "=" * max(0, width - 21))
    parts.append(iv_text)
    parts.append("")
    parts.append("== decision log " + "=" * max(0, width - 16))
    parts.append(render_flight(flight or []))
    return "\n".join(parts)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="recorder JSONL (from obs.export.write_jsonl)")
    ap.add_argument("--flight", default=None,
                    help="flight-recorder JSONL (FlightRecorder.to_jsonl)")
    ap.add_argument("--width", type=int, default=80)
    ap.add_argument("--allow-truncated", action="store_true",
                    help="render even when the trace header reports dropped "
                         "events (ring-buffer overflow)")
    args = ap.parse_args(argv)
    dicts = read_jsonl(args.trace)
    from .export import read_header
    header = read_header(dicts)
    dropped = int((header or {}).get("n_dropped", 0))
    if dropped > 0 and not args.allow_truncated:
        print(f"{args.trace}: REFUSED — header reports {dropped} dropped "
              f"events (ring-buffer overflow); pass --allow-truncated to "
              f"render anyway.", file=sys.stderr)
        return 2
    events = events_from_dicts(dicts)
    flight = flight_record.read_jsonl(args.flight) if args.flight else None
    print(build_report(events, flight, width=args.width))
    return 0


if __name__ == "__main__":
    sys.exit(main())
