"""Telemetry event bus: one emission stream, many subscribers.

PRs 2–4 wired executors straight into the broker's
:class:`repro.elastic.telemetry.TelemetryLog`.  The observability layer
wants the *same* StepTiming/LinkTiming stream (for per-link wire-byte
metrics, trace instants, user sinks) without the executor knowing who
listens — so the stream becomes a bus.  Anything implementing the
``TelemetrySink`` protocol (``record(StepTiming)`` and optionally
``record_link(LinkTiming)`` / ``record_kernel(KernelTiming)``) subscribes;
the bus itself implements the protocol, so it drops in wherever a sink was
passed before.

Parity contract (tested): a TelemetryLog fed through the bus reports
bit-identical ``node_step_times()`` / ``link_samples()`` to one fed
directly — the bus adds fan-out, never transformation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, List, Optional

from .metrics import MetricsRegistry


class TelemetryBus:
    """Fan-out for StepTiming / LinkTiming samples.

    Subscribers are notified in subscription order (deterministic).  A
    subscriber without ``record_link`` simply never sees link samples —
    mirroring how executors probe sinks today.
    """

    def __init__(self, subscribers: Iterable[Any] = ()):
        self._subs: List[Any] = []
        for s in subscribers:
            self.subscribe(s)

    def subscribe(self, sink: Any) -> None:
        if not hasattr(sink, "record"):
            raise TypeError(f"{sink!r} lacks record(StepTiming)")
        self._subs.append(sink)

    @property
    def subscribers(self) -> List[Any]:
        return list(self._subs)

    # ------------------------------------------------- TelemetrySink protocol
    def record(self, sample) -> None:
        for s in self._subs:
            s.record(sample)

    def record_link(self, sample) -> None:
        for s in self._subs:
            rl = getattr(s, "record_link", None)
            if rl is not None:
                rl(sample)

    def record_kernel(self, sample) -> None:
        for s in self._subs:
            rk = getattr(s, "record_kernel", None)
            if rk is not None:
                rk(sample)

    # ------------------------------------------------- bulk (controller path)
    def record_step(self, samples: Iterable[Any], step: int) -> None:
        for s in samples:
            self.record(dataclasses.replace(s, step=step))

    def record_link_step(self, samples: Iterable[Any], step: int) -> None:
        for s in samples:
            self.record_link(dataclasses.replace(s, step=step))

    def record_kernel_step(self, samples: Iterable[Any], step: int) -> None:
        for s in samples:
            self.record_kernel(dataclasses.replace(s, step=step))


class MetricsTelemetrySink:
    """Bus subscriber that folds the telemetry stream into a
    :class:`repro.obs.metrics.MetricsRegistry`:

    * ``wire_bytes{link=i->j}`` / ``link_seconds{link=i->j}`` counters per
      directed link (the "bytes on wire per link" metric);
    * ``stage_compute_seconds{node}`` / ``stage_comm_seconds{node}``
      counters per CompNode.
    """

    def __init__(self, metrics: MetricsRegistry):
        self.metrics = metrics

    def record(self, sample) -> None:
        node = int(sample.node)
        self.metrics.counter("stage_compute_seconds", node=node).inc(
            float(sample.compute_seconds))
        if sample.comm_seconds:
            self.metrics.counter("stage_comm_seconds", node=node).inc(
                float(sample.comm_seconds))

    def record_link(self, sample) -> None:
        link = f"{int(sample.src)}->{int(sample.dst)}"
        self.metrics.counter("wire_bytes", link=link).inc(
            float(sample.nbytes))
        self.metrics.counter("link_seconds", link=link).inc(
            float(sample.seconds))
