"""Swarm observability layer.

One spine, four artifacts:

* :mod:`repro.obs.trace`   — span-based :class:`TraceRecorder` (dual sim /
  wall clocks, thread-safe ring buffer, deterministic ordering);
* :mod:`repro.obs.metrics` — in-process counters / gauges / histograms;
* :mod:`repro.obs.record`  — the ElasticController flight recorder (every
  broker decision as a structured, replayable record);
* :mod:`repro.obs.export`  — Chrome/Perfetto ``trace_event`` JSON + raw
  JSONL export and the schema validator CI gates on;
* :mod:`repro.obs.report`  — the run-report CLI rendering timeline, overlap,
  straggler heatmap, and decision log from the artifacts;
* :mod:`repro.obs.bus`     — telemetry fan-out so the broker's TelemetryLog,
  the metrics registry, and user sinks all subscribe to one stream;
* :mod:`repro.obs.slog`    — structured ``event k=v`` logging for launchers;
* :mod:`repro.obs.watchdog` — streaming SLO rules + EWMA/MAD anomaly
  detectors emitting typed :class:`WatchdogRecord` trips;
* :mod:`repro.obs.critpath` / :mod:`repro.obs.whatif` — critical-path
  bottleneck attribution over span logs and counterfactual re-pricing
  (imported explicitly, not re-exported: they pull in :mod:`repro.check`
  and :mod:`repro.core` lazily).

Everything here is dependency-free (stdlib + the repo's own dataclasses) and
no-ops when disabled, so instrumented hot paths cost nothing in production
runs that don't ask for a trace.
"""
from .bus import MetricsTelemetrySink, TelemetryBus
from .export import (events_from_dicts, read_header, read_jsonl,
                     surface_drops, to_trace_events, validate_trace_events,
                     write_chrome_trace, write_jsonl)
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .record import (CalibrationRecord, CandidateScore, DetectorRecord,
                     EpochFlightRecord, FlightRecorder, ReplanRecord,
                     RouteRecord, WatchdogRecord)
from .slog import StructuredLogger, add_logging_args, get_logger
from .watchdog import Watchdog
from .trace import (CAT_BWD, CAT_CHECKPOINT, CAT_CONTROLLER, CAT_DECODE,
                    CAT_ENCODE, CAT_FWD, CAT_MIGRATION, CAT_SERVE_PREFILL,
                    CAT_SERVE_REPLAY, CAT_TRANSFER, CATEGORIES, CLOCK_SIM,
                    CLOCK_WALL, TraceEvent, TraceRecorder)

__all__ = [
    "CAT_BWD", "CAT_CHECKPOINT", "CAT_CONTROLLER", "CAT_DECODE",
    "CAT_ENCODE", "CAT_FWD", "CAT_MIGRATION", "CAT_SERVE_PREFILL",
    "CAT_SERVE_REPLAY", "CAT_TRANSFER", "CATEGORIES",
    "CLOCK_SIM", "CLOCK_WALL", "CalibrationRecord", "CandidateScore",
    "Counter", "DetectorRecord", "EpochFlightRecord", "FlightRecorder",
    "Gauge", "Histogram", "MetricsRegistry", "MetricsTelemetrySink",
    "ReplanRecord", "RouteRecord", "StructuredLogger", "TelemetryBus",
    "TraceEvent", "TraceRecorder", "Watchdog", "WatchdogRecord",
    "add_logging_args", "events_from_dicts", "get_logger", "read_header",
    "read_jsonl", "surface_drops", "to_trace_events", "validate_trace_events",
    "write_chrome_trace", "write_jsonl",
]
