"""Critical-path analyzer: bottleneck attribution over recorded span logs.

    PYTHONPATH=src python -m repro.obs.critpath TRACE.jsonl \
        [--top 10] [--json CRITPATH.json] [--expect-busy METRICS.json]

The simulator (and the controller's per-step replay) emits a span per stage
compute window, per link transfer, and per codec encode.  Those spans are a
complete happens-before DAG of one training step: every span's start time
equals the finish time of whatever it waited on — an inbound transfer, the
same device's previous micro-batch, the link's previous send, the codec
stream — because the discrete-event executor computes starts exactly that
way.  This module inverts that construction:

* :func:`analyze` groups spans by *execution attempt* (the ``(step, epoch)``
  arg pair, the same grouping :mod:`repro.check.traceorder` uses) and walks
  the chain of binding waits backwards from the last-finishing span,
  decomposing each step's makespan into per-device **compute**, per-link
  **wire**, per-codec-stream **codec** and residual **stall** seconds;
* :func:`blame` aggregates the decompositions into a blame table — "link
  3->5 is on the critical path 62% of steps, 1.8 s/step of slack behind
  it" — the objective-gradient the planner's what-if engine
  (:mod:`repro.obs.whatif`) re-prices;
* :func:`busy_accounting` sums *all* spans (critical or not) per resource,
  and :func:`check_sim_busy` gates that total against the simulator's own
  ``SimResult`` busy accounting (CI fails the trace artifact when the two
  disagree beyond 1% — a drifted span vocabulary would silently rot every
  report built on it).

Attribution refuses silently-truncated inputs: a JSONL whose header stamps
``n_dropped > 0`` (ring-buffer overflow, see
:func:`repro.obs.export.write_jsonl`) is rejected unless
``--allow-truncated`` is passed — a blame table over a partial step is worse
than none.

The module is import-light (stdlib only at import time; the traceorder edge
rules are pulled lazily) so ``import repro.obs`` stays dependency-free.
"""
from __future__ import annotations

import argparse
import bisect
import dataclasses
import json
import sys
from typing import (Any, Dict, Iterable, List, Mapping, Optional, Sequence,
                    Tuple)

from .trace import (CAT_BWD, CAT_ENCODE, CAT_FWD, CAT_SERVE_PREFILL,
                    CAT_SERVE_REPLAY, CAT_TRANSFER, CLOCK_SIM, TraceEvent)

# span kind on the critical path (and in the blame table)
KIND_COMPUTE = "compute"
KIND_WIRE = "wire"
KIND_CODEC = "codec"
KIND_STALL = "stall"

_CAT_KIND = {CAT_FWD: KIND_COMPUTE, CAT_BWD: KIND_COMPUTE,
             CAT_SERVE_PREFILL: KIND_COMPUTE, CAT_SERVE_REPLAY: KIND_COMPUTE,
             CAT_TRANSFER: KIND_WIRE, CAT_ENCODE: KIND_CODEC}

# relative float tolerance for "span A's finish *is* span B's start" — the
# same budget the trace-order checker grants replay shifts and the µs
# round-trip through the Chrome export
_EPS = 1e-9


def _edge_rules():
    """The traceorder name/track regexes (lazy: obs stays import-light,
    and the two modules cannot drift — one source of truth for the span
    vocabulary)."""
    from repro.check.traceorder import (CODEC_RE, COMP_RE, DEV_RE, ENC_RE,
                                        LINK_RE, XFER_RE)
    return XFER_RE, LINK_RE, COMP_RE, DEV_RE, ENC_RE, CODEC_RE


@dataclasses.dataclass(frozen=True)
class CritSegment:
    """One span (or gap) on a step's critical path."""

    kind: str                  # compute | wire | codec | stall
    track: str                 # dev3 | link 3->5 | codec3 | "" for stall
    name: str
    start: float
    end: float

    @property
    def seconds(self) -> float:
        return self.end - self.start

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "track": self.track, "name": self.name,
                "start": self.start, "end": self.end,
                "seconds": self.seconds}


@dataclasses.dataclass
class StepDecomposition:
    """One execution attempt's makespan, split along its critical path."""

    attempt: Tuple[Any, Any]             # (step, epoch) args, or (None, None)
    t0: float                            # earliest span start
    t1: float                            # latest span finish
    segments: List[CritSegment]
    compute: Dict[str, float]            # dev track -> critical seconds
    wire: Dict[str, float]               # link track -> critical seconds
    codec: Dict[str, float]              # codec track -> critical seconds
    stall: float                         # makespan not covered by any span

    @property
    def makespan(self) -> float:
        return self.t1 - self.t0

    def total(self) -> float:
        return (sum(self.compute.values()) + sum(self.wire.values())
                + sum(self.codec.values()) + self.stall)

    def to_dict(self) -> Dict[str, Any]:
        return {"attempt": {"step": self.attempt[0], "epoch": self.attempt[1]},
                "t0": self.t0, "t1": self.t1, "makespan": self.makespan,
                "compute": dict(sorted(self.compute.items())),
                "wire": dict(sorted(self.wire.items())),
                "codec": dict(sorted(self.codec.items())),
                "stall": self.stall,
                "path": [s.to_dict() for s in self.segments]}


@dataclasses.dataclass(frozen=True)
class BlameRow:
    """One resource's share of the critical path across analyzed steps."""

    kind: str                 # compute | wire | codec | stall
    track: str
    crit_seconds: float       # total critical-path seconds attributed
    steps_on_path: int        # attempts where the resource appears at all
    n_steps: int              # attempts analyzed
    mean_seconds: float       # crit_seconds / n_steps — s/step of slack
    share: float              # fraction of all critical seconds

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------- parsing --
def _dag_spans(events: Iterable[TraceEvent]) -> List[TraceEvent]:
    """The sim-clock complete spans that participate in the step DAG."""
    return [e for e in events
            if e.clock == CLOCK_SIM and e.phase == "X" and e.cat in _CAT_KIND]


def _attempt_of(e: TraceEvent) -> Tuple[Any, Any]:
    args = e.args or {}
    return (args.get("step"), args.get("epoch"))


class _Meta:
    """Parsed identity of one span: enough to test 'does p feed s?'."""

    __slots__ = ("ev", "kind", "tag", "mb", "src", "dst", "dev")

    def __init__(self, ev: TraceEvent, rules):
        xfer_re, link_re, comp_re, dev_re, enc_re, codec_re = rules
        self.ev = ev
        self.kind = _CAT_KIND[ev.cat]
        self.tag = self.mb = self.src = self.dst = self.dev = None
        if self.kind == KIND_COMPUTE:
            mc, md = comp_re.match(ev.name), dev_re.match(ev.track)
            if md:
                self.dev = int(md.group(1))
            if mc:
                self.tag, self.mb = mc.group(1), int(mc.group(3))
        elif self.kind == KIND_WIRE:
            mx, ml = xfer_re.match(ev.name), link_re.match(ev.track)
            if ml:
                self.src, self.dst = int(ml.group(1)), int(ml.group(2))
            if mx:
                self.tag, self.mb = mx.group(1), int(mx.group(2))
        elif self.kind == KIND_CODEC:
            me, mc = enc_re.match(ev.name), codec_re.match(ev.track)
            if mc:
                self.src = int(mc.group(1))
            if me:
                self.tag, self.mb = me.group(1), int(me.group(2))

    @property
    def end(self) -> float:
        return self.ev.ts + self.ev.dur

    def feeds(self, s: "_Meta") -> bool:
        """True when this span is a *causal* producer of ``s`` under the
        executor's construction (not merely earlier on the same resource)."""
        if self.tag is None or s.tag is None or self.tag != s.tag \
                or self.mb != s.mb:
            return False
        if s.kind == KIND_COMPUTE:
            # inbound transfer into the consuming device
            return self.kind == KIND_WIRE and self.dst == s.dev
        if s.kind == KIND_WIRE:
            # producer compute on the source device, or its codec stream
            if self.kind == KIND_COMPUTE:
                return self.dev == s.src
            if self.kind == KIND_CODEC:
                return self.src == s.src
            return False
        if s.kind == KIND_CODEC:
            # codec encodes the producing stage's output on the same device
            return self.kind == KIND_COMPUTE and self.dev == s.src
        return False


def _walk_attempt(metas: List[_Meta], tol: float
                  ) -> Tuple[List[CritSegment], float, float, float]:
    """Critical path of one attempt: start from the last-finishing span and
    repeatedly jump to the predecessor whose finish time *is* the current
    span's start (causal feeds preferred, then same-track serial order).
    Residual gaps (no span ends at the current start) are stalls."""
    t0 = min(m.ev.ts for m in metas)
    t1 = max(m.end for m in metas)
    by_end = sorted(metas, key=lambda m: (m.end, m.ev.seq))
    ends = [m.end for m in by_end]
    segments: List[CritSegment] = []
    stall = 0.0
    cur = by_end[-1]
    visited = set()
    while True:
        visited.add(id(cur))
        segments.append(CritSegment(
            kind=cur.kind, track=cur.ev.track, name=cur.ev.name,
            start=cur.ev.ts, end=cur.end))
        if cur.ev.ts <= t0 + tol:
            break
        # spans finishing at (or before) the current start
        hi = bisect.bisect_right(ends, cur.ev.ts + tol)
        cands = [m for m in by_end[:hi] if id(m) not in visited]
        if not cands:
            stall += cur.ev.ts - t0
            break
        best_end = max(m.end for m in cands)
        exact = [m for m in cands if m.end >= best_end - tol]
        # binding wait: a causal feed beats serial-resource order beats any
        nxt = next((m for m in exact if m.feeds(cur)), None) \
            or next((m for m in exact if m.ev.track == cur.ev.track), None) \
            or exact[0]
        gap = cur.ev.ts - nxt.end
        if gap > tol:
            stall += gap
            segments.append(CritSegment(
                kind=KIND_STALL, track="", name="(stall)",
                start=nxt.end, end=cur.ev.ts))
        cur = nxt
    segments.reverse()
    return segments, t0, t1, stall


def analyze(events: Iterable[TraceEvent]) -> List[StepDecomposition]:
    """Per-attempt critical-path decompositions, sorted by attempt key."""
    rules = _edge_rules()
    attempts: Dict[Tuple[Any, Any], List[_Meta]] = {}
    for e in _dag_spans(events):
        attempts.setdefault(_attempt_of(e), []).append(_Meta(e, rules))
    out: List[StepDecomposition] = []
    for key in sorted(attempts, key=repr):
        metas = attempts[key]
        hi = max((abs(m.ev.ts) + abs(m.ev.dur) for m in metas), default=1.0)
        tol = _EPS * max(1.0, hi)
        segments, t0, t1, stall = _walk_attempt(metas, tol)
        compute: Dict[str, float] = {}
        wire: Dict[str, float] = {}
        codec: Dict[str, float] = {}
        sink = {KIND_COMPUTE: compute, KIND_WIRE: wire, KIND_CODEC: codec}
        for seg in segments:
            if seg.kind == KIND_STALL:
                continue
            bucket = sink[seg.kind]
            bucket[seg.track] = bucket.get(seg.track, 0.0) + seg.seconds
        out.append(StepDecomposition(
            attempt=key, t0=t0, t1=t1, segments=segments,
            compute=compute, wire=wire, codec=codec, stall=stall))
    return out


# ------------------------------------------------------------ aggregation --
def blame(decomps: Sequence[StepDecomposition]) -> List[BlameRow]:
    """Blame table: per (kind, track) critical seconds across all attempts,
    sorted by total critical seconds (the what-if upper bound) descending."""
    n = len(decomps)
    totals: Dict[Tuple[str, str], float] = {}
    steps_on: Dict[Tuple[str, str], int] = {}
    for d in decomps:
        for kind, bucket in ((KIND_COMPUTE, d.compute), (KIND_WIRE, d.wire),
                             (KIND_CODEC, d.codec)):
            for track, secs in bucket.items():
                key = (kind, track)
                totals[key] = totals.get(key, 0.0) + secs
                steps_on[key] = steps_on.get(key, 0) + 1
        if d.stall > 0.0:
            key = (KIND_STALL, "")
            totals[key] = totals.get(key, 0.0) + d.stall
            steps_on[key] = steps_on.get(key, 0) + 1
    grand = sum(totals.values()) or 1.0
    rows = [BlameRow(kind=k, track=t, crit_seconds=v,
                     steps_on_path=steps_on[(k, t)], n_steps=n,
                     mean_seconds=v / n if n else 0.0, share=v / grand)
            for (k, t), v in totals.items()]
    rows.sort(key=lambda r: (-r.crit_seconds, r.kind, r.track))
    return rows


def busy_accounting(events: Iterable[TraceEvent]) -> Dict[str, float]:
    """Total busy seconds per kind over *all* DAG spans (not just critical
    ones) — the quantity that must agree with the simulator's own
    ``SimResult`` accounting (``device_busy`` / ``link_busy`` /
    ``compress_busy`` summed over the traced steps)."""
    out = {KIND_COMPUTE: 0.0, KIND_WIRE: 0.0, KIND_CODEC: 0.0}
    for e in _dag_spans(events):
        out[_CAT_KIND[e.cat]] += e.dur
    return out


def audit(decomps: Sequence[StepDecomposition],
          rel: float = 0.01) -> List[str]:
    """Internal consistency: each attempt's decomposition must cover its
    makespan within ``rel`` — an uncovered remainder means the walker lost
    the chain (a span vocabulary drift, exactly what CI should catch)."""
    problems: List[str] = []
    for d in decomps:
        span = d.makespan
        if span <= 0.0:
            continue
        err = abs(d.total() - span) / span
        if err > rel:
            problems.append(
                f"attempt {d.attempt}: critical-path decomposition covers "
                f"{d.total():.6g}s of a {span:.6g}s makespan "
                f"({err * 100:.2f}% off, budget {rel * 100:.0f}%)")
    return problems


_SIM_BUSY_KEYS = {KIND_COMPUTE: "sim_device_busy_seconds",
                  KIND_WIRE: "sim_link_busy_seconds",
                  KIND_CODEC: "sim_compress_busy_seconds"}


def check_sim_busy(busy: Mapping[str, float], totals: Mapping[str, float],
                   rel: float = 0.01) -> List[str]:
    """Gate the trace-derived busy accounting against the simulator's own
    totals (the ``sim_*_busy_seconds`` counters the ElasticController feeds
    from each step's ``SimResult``).  Returns violation strings."""
    problems: List[str] = []
    for kind, key in _SIM_BUSY_KEYS.items():
        if key not in totals:
            continue
        want = float(totals[key])
        got = float(busy.get(kind, 0.0))
        scale = max(abs(want), abs(got))
        if scale == 0.0:
            continue
        err = abs(got - want) / scale
        if err > rel:
            problems.append(
                f"{kind}: trace busy {got:.6g}s vs sim {key} {want:.6g}s "
                f"({err * 100:.2f}% apart, budget {rel * 100:.0f}%)")
    return problems


# -------------------------------------------------------------- rendering --
def render_blame(rows: Sequence[BlameRow], top: int = 10,
                 width: int = 80) -> str:
    """The blame table, one resource per line, worst first."""
    if not rows:
        return "(no attributable spans in trace)"
    n = rows[0].n_steps
    lines = [f"{'kind':<8} {'track':<14} {'s/step':>10} {'on-path':>8} "
             f"{'share':>7}",
             "-" * min(width, 52)]
    for r in rows[:top]:
        frac = r.steps_on_path / n if n else 0.0
        lines.append(f"{r.kind:<8} {r.track or '-':<14} "
                     f"{r.mean_seconds:>10.4g} {frac * 100:>7.0f}% "
                     f"{r.share * 100:>6.1f}%")
    if len(rows) > top:
        rest = sum(r.crit_seconds for r in rows[top:])
        lines.append(f"... {len(rows) - top} more rows, {rest:.4g}s total")
    return "\n".join(lines)


def to_artifact(decomps: Sequence[StepDecomposition],
                rows: Sequence[BlameRow],
                busy: Mapping[str, float],
                source: str = "",
                extra: Optional[Mapping[str, Any]] = None) -> Dict[str, Any]:
    """JSON payload for ``CRITPATH_<name>.json`` (full per-step paths are
    summarized — the blame table is the artifact, the trace is the detail)."""
    payload: Dict[str, Any] = {
        "schema": "repro.obs/critpath.v1",
        "source": source,
        "n_attempts": len(decomps),
        "blame": [r.to_dict() for r in rows],
        "busy_seconds": dict(busy),
        "attempts": [{k: v for k, v in d.to_dict().items() if k != "path"}
                     for d in decomps],
    }
    if extra:
        payload.update(extra)
    return payload


# -------------------------------------------------------------------- CLI --
def _load(path: str) -> Tuple[List[TraceEvent], Optional[Mapping[str, Any]]]:
    """(events, header) from a recorder JSONL (preferred) or Chrome JSON."""
    if path.endswith(".jsonl"):
        from .export import events_from_dicts, read_header, read_jsonl
        dicts = read_jsonl(path)
        return events_from_dicts(dicts), read_header(dicts)
    from repro.check.traceorder import load_trace_events
    return load_trace_events(path), None


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="TRACE .jsonl (recorder) or .json (chrome)")
    ap.add_argument("--top", type=int, default=10,
                    help="blame-table rows to print")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write the attribution artifact here")
    ap.add_argument("--expect-busy", default=None, metavar="METRICS",
                    help="metrics-snapshot JSON carrying the simulator's "
                         "sim_*_busy_seconds counters; attribution must "
                         "agree within --busy-tol")
    ap.add_argument("--busy-tol", type=float, default=0.01,
                    help="relative busy-accounting budget (default 1%%)")
    ap.add_argument("--allow-truncated", action="store_true",
                    help="analyze even when the trace header reports "
                         "dropped events (ring-buffer overflow)")
    args = ap.parse_args(argv)

    events, header = _load(args.trace)
    dropped = int((header or {}).get("n_dropped", 0))
    if dropped > 0 and not args.allow_truncated:
        print(f"{args.trace}: REFUSED — header reports {dropped} dropped "
              f"events (ring-buffer overflow); attribution over a truncated "
              f"step would misassign blame.  Re-record with a larger "
              f"TraceRecorder capacity, or pass --allow-truncated.",
              file=sys.stderr)
        return 2

    decomps = analyze(events)
    if not decomps:
        print(f"{args.trace}: no attributable sim spans", file=sys.stderr)
        return 2
    rows = blame(decomps)
    busy = busy_accounting(events)
    print(f"critical path over {len(decomps)} attempt(s), "
          f"mean makespan {sum(d.makespan for d in decomps) / len(decomps):.4g}s")
    print(render_blame(rows, top=args.top))

    problems = audit(decomps, rel=args.busy_tol)
    extra: Dict[str, Any] = {"audit": problems}
    if args.expect_busy:
        with open(args.expect_busy) as f:
            totals = json.load(f)
        sim_problems = check_sim_busy(busy, totals, rel=args.busy_tol)
        problems += sim_problems
        extra["sim_busy_check"] = sim_problems
    if args.json:
        with open(args.json, "w") as f:
            json.dump(to_artifact(decomps, rows, busy, source=args.trace,
                                  extra=extra), f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.json}")
    if problems:
        print("ATTRIBUTION GATE FAILED:", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    print(f"attribution consistent (budget {args.busy_tol * 100:.0f}%)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
