"""Decentralized swarm serving (Petals-style inference path).

The serving twin of the training stack, built on the same substrates: the
decoder stage-sharded over the membership view
(:mod:`~repro.serving.stages`), KV/activation wire pricing through the
calibrated cost-model semantics (:mod:`~repro.serving.costs`), replica
placement with memory-feasible KV slots (:mod:`~repro.serving.plan`),
session routing with mid-session re-route + bit-exact KV replay
(:mod:`~repro.serving.router`, :mod:`~repro.serving.session`), a
continuous-batching request queue (:mod:`~repro.serving.batching`) over
simulated Poisson traffic (:mod:`~repro.serving.reqtrace`), all driven by
the lockstep :class:`~repro.serving.runtime.ServingRuntime` with spans,
metrics, and flight-recorder routing decisions.

See ``docs/serving.md`` for the user guide and ``benchmarks/serving.py``
for the closed-loop churn benchmark.
"""
from .batching import RequestQueue
from .costs import ServingCostModel, StageCost
from .plan import ServingPlan, ServingPlanError, plan_serving
from .reqtrace import Request, poisson_trace
from .router import NoChainError, SessionRouter
from .runtime import ServingReport, ServingRuntime
from .scenario import churn_trace_for, derive_midsession_failure
from .session import Session, StageState, summarize
from .stages import (STAGE_FAMILIES, StageExecutor, StageSpec,
                     check_shardable, split_stages, stage_decode,
                     stage_params, stage_prefill)

__all__ = [
    "NoChainError", "Request", "RequestQueue", "STAGE_FAMILIES",
    "ServingCostModel", "ServingPlan", "ServingPlanError", "ServingReport",
    "ServingRuntime", "Session", "SessionRouter", "StageCost",
    "StageExecutor", "StageSpec", "StageState", "check_shardable",
    "churn_trace_for", "derive_midsession_failure", "plan_serving",
    "poisson_trace", "split_stages", "stage_decode", "stage_params",
    "stage_prefill", "summarize",
]
