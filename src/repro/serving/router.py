"""Session router: pick a live chain of stage replicas, re-route on death.

The Petals client routes every session through one server per block range
and swaps a dead server out mid-generation (``RemoteSequential`` +
``InferenceSession``, SNIPPETS.md 1–2).  :class:`SessionRouter` is that
logic against our :class:`~repro.serving.plan.ServingPlan`:

* **admission routing** — greedy front-to-back over the stages, scoring
  each alive replica by ``stage_seconds × (1 + active sessions)`` (a
  load-scaled Eq. 1 compute term) plus the inbound hop priced by the
  calibrated cost model.  Load-scaling keeps the fastest replica from
  absorbing every session while its siblings idle.
* **mid-session re-routing** — when the membership view detects a dead
  replica, only the dead hops are replaced (survivors keep their KV; no
  gratuitous replays).  The replacement's KV prefix is rebuilt by the
  runtime via input replay; the router prices that replay (and what the
  alternative KV shipment would have cost) and logs both in the decision.

Every decision lands in the :class:`~repro.obs.record.FlightRecorder` as a
:class:`~repro.obs.record.RouteRecord`, so a serving run's flight log
explains each session's path the way training logs explain re-plans.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.obs import FlightRecorder, MetricsRegistry, RouteRecord

from .plan import ServingPlan
from .session import Session


class NoChainError(RuntimeError):
    """Some stage has no alive replica — the swarm cannot serve."""


class SessionRouter:
    """Routes sessions over a plan's replica sets, tracking per-replica load."""

    def __init__(self, plan: ServingPlan,
                 flight: Optional[FlightRecorder] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.plan = plan
        self.flight = flight
        self.metrics = metrics
        self.load: Dict[int, int] = {d: 0 for d in plan.devices()}

    # ---------------------------------------------------------- capacity --
    def alive_replicas(self, stage: int, alive: Sequence[int]) -> List[int]:
        live = set(alive)
        return [d for d in self.plan.replicas[stage] if d in live]

    def has_capacity(self, alive: Sequence[int]) -> bool:
        """Can one more session be admitted right now?  True iff every stage
        has an alive replica with a free slot (admit-on-slot-free)."""
        for spec in self.plan.stages:
            if not any(self.load[d] < self.plan.max_batch
                       for d in self.alive_replicas(spec.index, alive)):
                return False
        return True

    # ----------------------------------------------------------- scoring --
    def _score(self, device: int, stage: int, prev: Optional[int]) -> float:
        spec = self.plan.stages[stage]
        compute = self.plan.costs.stage_seconds(device, spec,
                                                self.plan.cache_len)
        hop = 0.0 if prev is None \
            else self.plan.costs.hop_seconds(prev, device, spec)
        return compute * (1 + self.load[device]) + hop

    def _pick_stage(self, stage: int, prev: Optional[int],
                    alive: Sequence[int], require_slot: bool = True) -> int:
        cands = self.alive_replicas(stage, alive)
        if require_slot:
            cands = [d for d in cands if self.load[d] < self.plan.max_batch]
        if not cands:
            raise NoChainError(
                f"stage {stage} has no alive replica with a free slot "
                f"(replicas={self.plan.replicas[stage]}, alive={list(alive)})")
        return min(cands, key=lambda d: (self._score(d, stage, prev), d))

    # ---------------------------------------------------------- admission --
    def pick_chain(self, alive: Sequence[int]) -> List[int]:
        """Greedy front-to-back chain, one alive replica per stage."""
        chain: List[int] = []
        prev: Optional[int] = None
        for spec in self.plan.stages:
            dev = self._pick_stage(spec.index, prev, alive)
            chain.append(dev)
            prev = dev
        return chain

    def acquire(self, chain: Sequence[int]) -> None:
        for d in chain:
            self.load[d] += 1

    def release(self, chain: Sequence[int]) -> None:
        for d in chain:
            self.load[d] = max(0, self.load[d] - 1)

    def log_route(self, session: Session, cause: str, old_chain: List[int],
                  dead: List[int], replay_tokens: int,
                  now: float, step: int) -> None:
        if self.metrics is not None:
            self.metrics.counter("serve.routes", cause=cause).inc()
        if self.flight is None:
            return
        kv_ship = sum(
            self.plan.costs.kv_bytes_per_token(self.plan.stages[s])
            * session.pos
            for s, (o, n) in enumerate(zip(old_chain, session.chain))
            if o != n) if cause == "reroute" else 0
        self.flight.log(RouteRecord(
            step=step, clock=now, session=session.rid, cause=cause,
            dead=list(dead), old_chain=list(old_chain),
            chain=list(session.chain), replay_tokens=int(replay_tokens),
            kv_ship_bytes=int(kv_ship)))

    # ---------------------------------------------------------- rerouting --
    def reroute(self, session: Session, dead: Sequence[int],
                alive: Sequence[int]) -> Dict[int, int]:
        """Replace dead hops in ``session.chain``; survivors keep their KV.

        Returns ``{stage: new_device}`` for the replaced hops (the runtime
        replays the session's input history onto each).  Replacements are
        admitted even at full ``max_batch`` (an evicted replica's sessions
        outrank new admissions; the queue absorbs the pressure).
        """
        dead_set = set(dead)
        replaced: Dict[int, int] = {}
        prev: Optional[int] = None
        for spec in self.plan.stages:
            s = spec.index
            cur = session.chain[s]
            if cur in dead_set:
                new = self._pick_stage(s, prev, alive, require_slot=False)
                self.load[new] += 1          # dead device's slot moves over
                if cur in self.load:
                    self.load[cur] = max(0, self.load[cur] - 1)
                session.chain[s] = new
                replaced[s] = new
            prev = session.chain[s]
        if replaced:
            session.n_reroutes += 1
        return replaced
