"""Simulated request traces: the workload side of the serving benchmark.

A deterministic stand-in for "heavy traffic from millions of users": Poisson
arrivals (exponential inter-arrival gaps at ``rate`` requests/s — the
heavy-traffic arrival process of queueing theory) with per-request prompt
lengths and generation lengths drawn uniformly from closed ranges.  Seeded
``numpy`` RNG end to end, so a trace is a pure function of its arguments and
the churn/no-churn benchmark legs replay *exactly* the same offered load.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    """One user request: a prompt and a per-request generation budget."""

    rid: str
    arrival: float                    # simulated seconds
    prompt: Tuple[int, ...]           # token ids
    max_new_tokens: int

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)


def poisson_trace(n_requests: int, rate: float, vocab: int,
                  prompt_len: Tuple[int, int] = (4, 12),
                  gen_len: Tuple[int, int] = (4, 16),
                  seed: int = 0) -> List[Request]:
    """``n_requests`` Poisson arrivals at ``rate`` req/s, sorted by time."""
    if n_requests <= 0:
        return []
    if rate <= 0:
        raise ValueError("rate must be > 0")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n_requests)
    arrivals = np.cumsum(gaps)
    out: List[Request] = []
    for i in range(n_requests):
        plen = int(rng.integers(prompt_len[0], prompt_len[1] + 1))
        glen = int(rng.integers(gen_len[0], gen_len[1] + 1))
        toks = rng.integers(0, vocab, size=plen)
        out.append(Request(rid=f"r{i}", arrival=float(arrivals[i]),
                           prompt=tuple(int(t) for t in toks),
                           max_new_tokens=glen))
    return out
