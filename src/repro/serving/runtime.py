"""The swarm serving runtime: continuous-batched stage-chained decode.

One :class:`ServingRuntime` closes the loop over the other serving modules:
real JAX compute through the stage executors, simulated-clock timing priced
by :class:`~repro.serving.costs.ServingCostModel`, membership churn from the
elastic :class:`~repro.elastic.membership.MembershipView`, routing decisions
from :class:`~repro.serving.router.SessionRouter`, and observability through
the same span/metrics/flight-recorder spine training uses.

The loop is lockstep *rounds* on the simulated clock (the serving analogue
of the training simulator's discrete-event steps):

1. **poll membership** — newly detected leaves evict replicas; every active
   session with a dead hop is re-routed (survivor hops keep their KV) and
   the replacement's KV prefix is rebuilt by **replaying the session's
   recorded inputs through the same jitted stage functions** — bit-exact,
   so churn never changes greedy output (pinned in tests);
2. **admit** — pop due requests while the router finds a chain with free
   slots on every stage (continuous batching: slots free per round, not per
   batch), run the real prefill along the chain, emit the first token;
3. **decode round** — every active session advances one token through its
   chain; per-device busy time, per-link batched transfer bytes and
   per-session token latency are accumulated from the cost model;
4. **advance** — the round takes as long as its bottleneck resource; spans
   land on ``dev<i>`` / ``link i->j`` tracks (the trace-order checker's
   serial-track invariants apply to serving timelines exactly as to
   training ones).

Timing semantics (simulated seconds — deliberately simple, documented so
the benchmark numbers are interpretable): per-token stage compute is
Eq. 1 ``C(f,p)`` at full-cache attention; a session's token latency is the
sum of its chain's compute + per-hop wire terms (plus any replay it waited
on this round); a round advances by the max over per-device busy and
per-link batched-transfer seconds.  The return hop (last stage back to the
client) and client links are not modeled.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.configs.base import ModelCfg
from repro.elastic.membership import MembershipView
from repro.obs import (CAT_CONTROLLER, CAT_FWD, CAT_SERVE_PREFILL,
                       CAT_SERVE_REPLAY, CAT_TRANSFER, FlightRecorder,
                       Histogram, MetricsRegistry, TraceRecorder, Watchdog)

from .batching import RequestQueue
from .plan import ServingPlan
from .reqtrace import Request
from .router import NoChainError, SessionRouter
from .session import Session, StageState, summarize
from .stages import StageExecutor, stage_params

OnToken = Callable[[str, int, float], None]


@dataclasses.dataclass(frozen=True)
class ServingReport:
    """Closed-loop run summary (the benchmark's per-scenario payload)."""

    n_sessions: int
    n_completed: int
    all_completed: bool
    n_reroutes: int
    tokens: int
    sim_seconds: float
    tokens_per_s: float
    p50_ms: float
    p99_ms: float
    rounds: int

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def _percentiles(latencies: Sequence[float]) -> Tuple[float, float]:
    """p50/p99 in ms via the obs Histogram's bucketed percentile (base 1.01:
    within ~1% of the exact sample percentile) — one percentile
    implementation across serving and watchdogs, not a second hand-rolled
    np.percentile path."""
    if not latencies:
        return 0.0, 0.0
    h = Histogram(base=1.01)
    for lt in latencies:
        h.observe(float(lt))
    return h.percentile(50) * 1e3, h.percentile(99) * 1e3


class ServingRuntime:
    """Drives sessions over a :class:`ServingPlan` against scripted churn."""

    def __init__(self, cfg: ModelCfg, params: Dict[str, Any],
                 plan: ServingPlan, view: MembershipView,
                 trace: Optional[TraceRecorder] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 flight: Optional[FlightRecorder] = None,
                 on_token: Optional[OnToken] = None,
                 watchdog: Optional[Watchdog] = None,
                 max_rounds: int = 100_000):
        self.cfg = cfg
        self.plan = plan
        self.view = view
        self.trace = trace
        self.metrics = metrics
        self.flight = flight
        self.on_token = on_token
        self.max_rounds = int(max_rounds)
        # streaming SLO/anomaly monitor: fed one aggregate tokens/s sample
        # per decode round (trips land in the shared flight recorder)
        self.watchdog = watchdog
        if watchdog is not None:
            if watchdog.flight is None:
                watchdog.flight = flight
            if watchdog.metrics is None:
                watchdog.metrics = metrics
        self.router = SessionRouter(plan, flight=flight, metrics=metrics)
        # one executor per stage, shared by all its replicas (identical
        # parameters => identical jitted computation)
        self.executors: Dict[int, StageExecutor] = {
            spec.index: StageExecutor(cfg, spec,
                                      stage_params(cfg, params, spec),
                                      plan.cache_len)
            for spec in plan.stages}

    # ------------------------------------------------------------ helpers --
    def _greedy(self, logits) -> int:
        return int(jnp.argmax(logits[0, -1, :]))

    def _emit(self, sess: Session, tok: int, now: float) -> None:
        if self.metrics is not None:
            self.metrics.counter("serve.tokens").inc()
        if self.on_token is not None:
            self.on_token(sess.rid, tok, now)

    def _span(self, spans: List[Tuple], cat: str, name: str, track: str,
              t0: float, t1: float, rnd: int, **args) -> None:
        if self.trace is not None:
            spans.append((cat, name, track, t0, t1,
                          {"step": rnd, "epoch": self.view.epoch, **args}))

    def _flush_spans(self, spans: List[Tuple]) -> None:
        if self.trace is None:
            return
        for cat, name, track, t0, t1, args in sorted(
                spans, key=lambda s: (s[3], s[2], s[4])):
            self.trace.span(cat, name, track, t0, t1, args=args)

    # ------------------------------------------------------------- replay --
    def _replay_stage(self, sess: Session, stage: int) -> None:
        """Rebuild one stage's KV on its replacement replica by replaying
        the recorded inputs through the shared jitted stage functions —
        the op and reduction order of the original computation, so the
        rebuilt cache is bit-identical."""
        st: StageState = sess.stages[stage]
        ex = self.executors[stage]
        _, kv = ex.prefill(st.prefill_input)
        plen = int(st.prefill_input.shape[1])
        for i, inp in enumerate(st.step_inputs):
            _, kv = ex.decode(inp, kv, plen + i)
        st.kv = kv

    def _replay_seconds(self, sess: Session, stage: int, new_dev: int) -> float:
        """Simulated cost of the replay: recompute every historical token on
        the replacement, plus shipping the recorded boundary inputs in from
        the upstream hop (stage 0 replays client-held token ids: no modeled
        wire)."""
        spec = self.plan.stages[stage]
        n = sess.replay_len(stage)
        secs = n * self.plan.costs.stage_seconds(new_dev, spec,
                                                 self.plan.cache_len)
        if stage > 0:
            prev = sess.chain[stage - 1]
            nbytes = n * self.plan.costs.stage_in_bytes_per_token(spec)
            secs += self.plan.costs.link_seconds(prev, new_dev, nbytes)
        return secs

    # ---------------------------------------------------------------- run --
    def run(self, requests: List[Request]) -> ServingReport:
        queue = RequestQueue(requests)
        active: List[Session] = []
        completed: List[Session] = []
        latencies: List[float] = []
        now = 0.0
        rnd = 0
        total_tokens = 0

        while active or not queue.empty:
            rnd += 1
            tokens_at_round_start = total_tokens
            if rnd > self.max_rounds:
                raise RuntimeError(
                    f"serving made no progress after {self.max_rounds} "
                    "rounds — a stage likely lost all replicas")
            # idle: fast-forward the sim clock to the next arrival
            if not active and not queue.due(now):
                nxt = queue.next_arrival()
                if nxt is not None:
                    now = max(now, nxt)
            self.view.poll(now)
            alive = set(self.view.alive)
            spans: List[Tuple] = []
            dev_cursor: Dict[int, float] = {}
            replay_penalty: Dict[str, float] = {}

            # -- 1. re-route sessions whose chain lost a replica ----------
            for sess in active:
                dead = sorted({d for d in sess.chain if d not in alive})
                if not dead:
                    continue
                old_chain = list(sess.chain)
                replaced = self.router.reroute(sess, dead, sorted(alive))
                replay_tokens = 0
                pen = 0.0
                for stage, new_dev in sorted(replaced.items()):
                    replay_tokens += sess.replay_len(stage)
                    secs = self._replay_seconds(sess, stage, new_dev)
                    pen += secs
                    t0 = dev_cursor.get(new_dev, now)
                    self._span(spans, CAT_SERVE_REPLAY,
                               f"replay.{sess.rid}.s{stage}",
                               f"dev{new_dev}", t0, t0 + secs, rnd,
                               session=sess.rid,
                               tokens=sess.replay_len(stage))
                    dev_cursor[new_dev] = t0 + secs
                    self._replay_stage(sess, stage)
                replay_penalty[sess.rid] = pen
                self.router.log_route(sess, "reroute", old_chain, dead,
                                      replay_tokens, now, rnd)
                if self.trace is not None:
                    self.trace.instant(
                        CAT_CONTROLLER, f"reroute.{sess.rid}", "controller",
                        t=now, args={"dead": dead, "chain": list(sess.chain),
                                     "replay_tokens": replay_tokens})

            # -- 2. continuous-batching admission -------------------------
            admitted_now: List[Session] = []
            while queue.due(now) and self.router.has_capacity(sorted(alive)):
                req = queue.pop(now)
                chain = self.router.pick_chain(sorted(alive))
                self.router.acquire(chain)
                sess = Session(request=req, chain=list(chain),
                               admitted_at=now)
                lat = 0.0
                x = jnp.asarray(req.prompt, jnp.int32)[None, :]
                for stage, dev in enumerate(chain):
                    spec = self.plan.stages[stage]
                    out, kv = self.executors[stage].prefill(x)
                    sess.stages[stage].record_prefill(x, kv)
                    S = len(req.prompt)
                    secs = S * self.plan.costs.stage_seconds(
                        dev, spec, self.plan.cache_len)
                    if stage > 0:
                        secs += self.plan.costs.link_seconds(
                            chain[stage - 1], dev,
                            S * self.plan.costs.stage_in_bytes_per_token(spec))
                    t0 = dev_cursor.get(dev, now)
                    self._span(spans, CAT_SERVE_PREFILL,
                               f"prefill.{req.rid}.s{stage}", f"dev{dev}",
                               t0, t0 + secs, rnd, session=req.rid, S=S)
                    dev_cursor[dev] = t0 + secs
                    lat += secs
                    x = out
                sess.pos = len(req.prompt)
                tok = self._greedy(x)
                sess.generated.append(tok)
                sess.token_latencies.append(lat)
                latencies.append(lat)
                total_tokens += 1
                self._emit(sess, tok, now)
                if self.metrics is not None:
                    self.metrics.counter("serve.requests",
                                         event="admitted").inc()
                self.router.log_route(sess, "admit", list(chain), [], 0,
                                      now, rnd)
                active.append(sess)
                admitted_now.append(sess)

            # -- 3. lockstep decode round ---------------------------------
            dev_busy: Dict[int, float] = {}
            link_bytes: Dict[Tuple[int, int], float] = {}
            for sess in active:
                if sess in admitted_now or sess.done:
                    continue   # prefill already produced this round's token
                lat = replay_penalty.pop(sess.rid, 0.0)
                x = jnp.asarray([[sess.generated[-1]]], jnp.int32)
                for stage, dev in enumerate(sess.chain):
                    spec = self.plan.stages[stage]
                    st = sess.stages[stage]
                    out, kv = self.executors[stage].decode(
                        x, st.kv, sess.pos)
                    st.record_step(x, kv)
                    secs = self.plan.costs.stage_seconds(
                        dev, spec, self.plan.cache_len)
                    dev_busy[dev] = dev_busy.get(dev, 0.0) + secs
                    lat += secs
                    if stage > 0:
                        link = (sess.chain[stage - 1], dev)
                        if link[0] != link[1]:
                            nb = self.plan.costs.stage_in_bytes_per_token(spec)
                            link_bytes[link] = link_bytes.get(link, 0.0) + nb
                            lat += self.plan.costs.link_seconds(*link, nb)
                    x = out
                tok = self._greedy(x)
                sess.generated.append(tok)
                sess.pos += 1
                sess.token_latencies.append(lat)
                latencies.append(lat)
                total_tokens += 1
                self._emit(sess, tok, now)

            # -- 4. advance the clock by the bottleneck resource ----------
            round_end = now
            for dev, busy in sorted(dev_busy.items()):
                t0 = dev_cursor.get(dev, now)
                self._span(spans, CAT_FWD, f"decode.r{rnd}", f"dev{dev}",
                           t0, t0 + busy, rnd,
                           sessions=sum(1 for s in active
                                        if dev in s.chain))
                dev_cursor[dev] = t0 + busy
            for (i, j), nb in sorted(link_bytes.items()):
                secs = self.plan.costs.link_seconds(i, j, nb)
                self._span(spans, CAT_TRANSFER, f"hop.r{rnd}",
                           f"link {i}->{j}", now, now + secs, rnd,
                           bytes=nb)
                round_end = max(round_end, now + secs)
            for dev, t in dev_cursor.items():
                round_end = max(round_end, t)
            self._flush_spans(spans)

            # -- 5. retire finished sessions ------------------------------
            still: List[Session] = []
            for sess in active:
                if sess.done:
                    sess.finished_at = round_end
                    self.router.release(sess.chain)
                    completed.append(sess)
                    if self.metrics is not None:
                        self.metrics.counter("serve.requests",
                                             event="completed").inc()
                else:
                    still.append(sess)
            active = still
            prev_now = now
            now = round_end if round_end > now else now + 1e-9
            if self.watchdog is not None:
                made = total_tokens - tokens_at_round_start
                dt = now - prev_now
                if made > 0 and dt > 0.0:
                    self.watchdog.observe_tokens(rnd, now, made / dt)

        if self.metrics is not None:
            h = self.metrics.histogram("serve.token_latency_ms")
            for lt in latencies:
                h.observe(lt * 1e3)
        stats = summarize(completed)
        p50, p99 = _percentiles(latencies)
        return ServingReport(
            n_sessions=stats["n_sessions"],
            n_completed=stats["n_completed"],
            all_completed=stats["all_completed"] and queue.empty,
            n_reroutes=stats["n_reroutes"],
            tokens=total_tokens,
            sim_seconds=now,
            tokens_per_s=total_tokens / now if now > 0 else 0.0,
            p50_ms=p50, p99_ms=p99, rounds=rnd)


__all__ = ["NoChainError", "OnToken", "ServingReport", "ServingRuntime"]
