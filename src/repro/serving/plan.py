"""Serving plan: stage split + replica placement over the membership view.

``plan_serving`` turns "which nodes are alive" into "who hosts which stage":

* split the decoder into ``n_stages`` contiguous layer runs
  (:func:`repro.serving.stages.split_stages`);
* deal the alive devices across stages round-robin in descending
  ``DeviceSpec.speed`` order, so every stage gets a replica before any gets
  two and fast devices spread instead of clustering (Petals servers pick the
  most-wanted block range; our planner is the centralized equivalent);
* gate each assignment on **KV-cache placement feasibility** priced by
  :class:`repro.serving.costs.ServingCostModel`: resident stage weights +
  ``max_batch`` session slots of KV at ``cache_len`` must fit the device's
  ``mem_bytes``.  An infeasible swarm raises :class:`ServingPlanError` with
  the exact byte arithmetic in the message, it never silently over-commits.

The plan is static per membership epoch; the router
(:mod:`repro.serving.router`) handles per-session choice *within* the
replica sets and mid-session re-routing when a replica dies.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.configs.base import ModelCfg

from .costs import ServingCostModel
from .stages import StageSpec, split_stages


class ServingPlanError(ValueError):
    """The swarm cannot host the model (no devices, or memory infeasible)."""


@dataclasses.dataclass(frozen=True)
class ServingPlan:
    """Immutable placement: which devices replicate which stage."""

    cfg: ModelCfg
    stages: List[StageSpec]
    replicas: Dict[int, List[int]]       # stage index -> device ids
    cache_len: int
    max_batch: int                       # concurrent sessions per replica
    costs: ServingCostModel

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    def devices(self) -> List[int]:
        return sorted({d for ds in self.replicas.values() for d in ds})

    def stage_of(self, device: int) -> Optional[int]:
        for s, ds in self.replicas.items():
            if device in ds:
                return s
        return None

    def describe(self) -> str:
        lines = [f"serving plan: {self.n_stages} stages, "
                 f"cache_len={self.cache_len}, max_batch={self.max_batch}"]
        for spec in self.stages:
            ds = self.replicas[spec.index]
            kvb = self.costs.kv_bytes(spec, self.cache_len)
            lines.append(f"  {spec}: replicas={ds} "
                         f"kv/slot={kvb} B params="
                         f"{self.costs.stage_param_bytes(spec)} B")
        return "\n".join(lines)


def _check_memory(costs: ServingCostModel, spec: StageSpec, device: int,
                  cache_len: int, max_batch: int) -> None:
    need = costs.stage_param_bytes(spec) \
        + max_batch * costs.kv_bytes(spec, cache_len)
    have = costs.cluster.devices[device].mem_bytes
    if need > have:
        raise ServingPlanError(
            f"device {device} cannot host {spec}: needs {need} B "
            f"(params {costs.stage_param_bytes(spec)} + {max_batch} slots × "
            f"{costs.kv_bytes(spec, cache_len)} B KV) "
            f"but has {have:.3g} B — lower max_batch/cache_len or add stages")


def plan_serving(cfg: ModelCfg, costs: ServingCostModel,
                 alive: Sequence[int], n_stages: int,
                 cache_len: int, max_batch: int = 4) -> ServingPlan:
    """Place ``n_stages`` stage replicas on the ``alive`` devices.

    Every stage must end up with at least one replica, so
    ``len(alive) >= n_stages``; extra devices become additional replicas,
    fastest-first round-robin so replica counts differ by at most one.
    """
    alive = sorted(set(alive))
    if not alive:
        raise ServingPlanError("no alive devices to serve on")
    if len(alive) < n_stages:
        raise ServingPlanError(
            f"{len(alive)} alive devices cannot host {n_stages} stages "
            "(need >= 1 replica per stage)")
    specs = split_stages(cfg, n_stages)

    by_speed = sorted(alive,
                      key=lambda d: (-costs.cluster.devices[d].speed, d))
    replicas: Dict[int, List[int]] = {s.index: [] for s in specs}
    for i, dev in enumerate(by_speed):
        spec = specs[i % n_stages]
        _check_memory(costs, spec, dev, cache_len, max_batch)
        replicas[spec.index].append(dev)
    for s in replicas:
        replicas[s].sort()
    return ServingPlan(cfg=cfg, stages=specs, replicas=replicas,
                       cache_len=int(cache_len), max_batch=int(max_batch),
                       costs=costs)
