"""Scripted churn scenarios for serving tests, CLI, and benchmark.

The interesting serving failure is the one that lands *mid-session*: a
stage replica dies while sessions whose chains cross it still have tokens
to emit, forcing the router to re-route and the runtime to replay KV onto
the replacement.  A failure time picked blindly usually misses — short
sessions drain between arrivals and the runtime's idle fast-forward jumps
the clock straight over the detection window, so nobody ever holds a dead
hop.

:func:`derive_midsession_failure` makes the scenario deterministic: run
the offered load once with no churn, read the first sufficiently long
multi-stage session's admit record off the flight log, and schedule the
death of its stage-1 replica at the midpoint of that session's own token
timeline.  The same requests replayed against the resulting
:class:`~repro.elastic.membership.ChurnTrace` are then guaranteed (for a
detection lease much shorter than the remaining half of the session) to
hit a live session mid-decode.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence, Tuple

from repro.elastic.membership import (ChurnTrace, MembershipView,
                                      single_failure_trace)
from repro.obs import FlightRecorder

from .plan import ServingPlan
from .reqtrace import Request
from .runtime import ServingReport, ServingRuntime


def derive_midsession_failure(
        cfg, params: Dict[str, Any], plan: ServingPlan,
        requests: Sequence[Request], n_devices: int,
        lease_s: float = 1e-5, min_tokens: int = 4, stage: int = 1,
) -> Tuple[int, float, ServingReport, Dict[str, List[int]]]:
    """Dry no-churn run; pick the failure that must interrupt a session.

    Returns ``(victim, at, baseline_report, baseline_tokens)``: the device
    serving stage ``stage`` of the first admitted session that spans at
    least ``min_tokens`` decode rounds, and the simulated time halfway
    through that session's token stream.  The baseline report/tokens come
    for free from the dry run — benchmarks use them as the no-churn leg.
    """
    if stage >= plan.n_stages:
        raise ValueError(f"stage {stage} out of range for "
                         f"{plan.n_stages}-stage plan")
    view = MembershipView(n_devices, ChurnTrace(()), lease_s=lease_s)
    flight = FlightRecorder()
    tokens: Dict[str, List[int]] = {}
    times: Dict[str, List[float]] = {}

    def on_token(rid: str, tok: int, now: float) -> None:
        tokens.setdefault(rid, []).append(tok)
        times.setdefault(rid, []).append(now)

    runtime = ServingRuntime(cfg, params, plan, view, flight=flight,
                             on_token=on_token)
    report = runtime.run(list(requests))
    for rec in flight.records("route"):
        if rec.cause != "admit":
            continue
        ts = times.get(rec.session, [])
        if len(ts) >= min_tokens and len(rec.chain) > stage:
            victim = rec.chain[stage]
            at = (ts[0] + ts[-1]) / 2.0
            return victim, at, report, tokens

    raise ValueError(
        "no admitted session long enough to interrupt — lengthen "
        "generations or raise the arrival rate")


def churn_trace_for(victim: int, at: float) -> ChurnTrace:
    """The scripted trace killing ``victim`` at ``at`` simulated seconds."""
    return single_failure_trace(victim, at=at)
