"""Stage-sharded decoder: the model side of the serving swarm.

Training pipelines this repo already shards at the *op* granularity
(OP-Fence over the metadata OP-DAG).  Serving wants something coarser and
replica-friendly: the Petals deployment unit is a contiguous *run of
transformer blocks* a volunteer can host, with embeddings on the first hop
and the LM head on the last (SNIPPETS.md 1–2: ``RemoteSequential`` holds
the block run, the client owns sampling).  This module slices the unified
:mod:`repro.models.causal_lm` decoder the same way:

* :class:`StageSpec` — one contiguous ``[lo, hi)`` layer slice of the
  scanned block stack, plus whether this stage embeds tokens (first) and
  applies the final norm + head (last);
* :func:`split_stages` — near-equal contiguous split of ``cfg.n_layers``;
* :func:`stage_params` — the parameter subtree one stage replica hosts
  (block slice + embed table on the first stage, head on the last; a tied
  head means the last stage also carries the embed table);
* :func:`stage_prefill` / :func:`stage_decode` — the per-stage forward
  paths.  They reuse the *same* block bodies and scan machinery as the
  monolithic ``prefill`` / ``decode_step``, so a chain of stages is
  **bit-identical** to the single-process model (pinned in
  ``tests/test_serving.py``) — which is what makes mid-session re-routing
  testable: replaying a session's inputs through a replacement replica must
  reproduce its KV cache exactly.

Families supported: ``dense`` and ``moe`` — the KV-cache families whose
block stack is a single scanned segment.  Recurrent-state families
(hybrid/xLSTM) and prefix-fed VLMs keep the monolithic path for now.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelCfg
from repro.models import attention as attn
from repro.models.causal_lm import (_dense_block_decode, _dense_block_prefill,
                                    _head, _moe_block_decode,
                                    _moe_block_prefill, segments)
from repro.models.layers import embed, norm_apply
from repro.models.scan_config import scan as _scan

STAGE_FAMILIES = ("dense", "moe")


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """One contiguous slice ``[lo, hi)`` of the scanned block stack."""

    index: int
    n_stages: int
    lo: int
    hi: int

    @property
    def first(self) -> bool:
        return self.index == 0

    @property
    def last(self) -> bool:
        return self.index == self.n_stages - 1

    @property
    def n_layers(self) -> int:
        return self.hi - self.lo

    def __str__(self) -> str:
        return f"stage{self.index}[{self.lo}:{self.hi}]"


def check_shardable(cfg: ModelCfg) -> None:
    """Raise unless ``cfg`` is a single-segment KV-cache decoder."""
    if cfg.family not in STAGE_FAMILIES:
        raise ValueError(
            f"{cfg.name}: stage-sharded serving supports {STAGE_FAMILIES}, "
            f"not family {cfg.family!r} (recurrent-state caches cannot be "
            "sliced per layer range yet)")
    if cfg.n_prefix > 0:
        raise ValueError(f"{cfg.name}: prefix-fed models (n_prefix="
                         f"{cfg.n_prefix}) keep the monolithic path")
    segs = segments(cfg)
    if len(segs) != 1 or segs[0].name != "blocks":
        raise ValueError(f"{cfg.name}: expected one scanned 'blocks' "
                         f"segment, got {[s.name for s in segs]}")


def split_stages(cfg: ModelCfg, n_stages: int) -> List[StageSpec]:
    """Near-equal contiguous layer split (earlier stages take the
    remainder, matching the pipeline convention)."""
    check_shardable(cfg)
    if not (1 <= n_stages <= cfg.n_layers):
        raise ValueError(f"n_stages must be in [1, {cfg.n_layers}], "
                         f"got {n_stages}")
    base, rem = divmod(cfg.n_layers, n_stages)
    out: List[StageSpec] = []
    lo = 0
    for i in range(n_stages):
        hi = lo + base + (1 if i < rem else 0)
        out.append(StageSpec(index=i, n_stages=n_stages, lo=lo, hi=hi))
        lo = hi
    return out


def _slice_blocks(tree, lo: int, hi: int):
    return jax.tree_util.tree_map(lambda a: a[lo:hi], tree)


def stage_params(cfg: ModelCfg, params: Dict[str, Any],
                 spec: StageSpec) -> Dict[str, Any]:
    """The parameter subtree one replica of ``spec`` hosts."""
    sp: Dict[str, Any] = {"blocks": _slice_blocks(params["blocks"],
                                                  spec.lo, spec.hi)}
    if spec.first or (spec.last and cfg.tie_embeddings):
        sp["embed"] = params["embed"]
    if spec.first and cfg.rope_fraction == 0.0:
        sp["pos_embed"] = params["pos_embed"]
    if spec.last:
        sp["final_norm"] = params["final_norm"]
        if not cfg.tie_embeddings:
            sp["head"] = params["head"]
    return sp


def _embed_first(cfg: ModelCfg, sp, tokens: jax.Array, pos0) -> jax.Array:
    x = embed(sp["embed"], tokens, cfg.dtype)
    if cfg.rope_fraction == 0.0:
        S = tokens.shape[1]
        pos = pos0 + jnp.arange(S)
        x = x + embed(sp["pos_embed"], pos, cfg.dtype)[None]
    return x


def stage_prefill(cfg: ModelCfg, spec: StageSpec, sp: Dict[str, Any],
                  inp: jax.Array, cache_len: int,
                  window: Optional[int] = None
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Prompt forward through one stage.

    ``inp`` is ``(B, S)`` int tokens on the first stage, ``(B, S, d)``
    hidden states otherwise.  Returns ``(out, kv)`` where ``out`` is the
    boundary hidden states ``(B, S, d)`` (last-position logits ``(B, 1, V)``
    on the last stage) and ``kv`` the stage's stacked
    ``{"k", "v"}: (n_layers, B, cache_len, H_kv, hd)`` cache.
    """
    window = window if window is not None else cfg.window
    x = _embed_first(cfg, sp, inp, 0) if spec.first else inp

    def body(h, pl):
        if cfg.family == "dense":
            h2, kvc = _dense_block_prefill(cfg, pl, h, window, cache_len)
        else:
            h2, _, kvc = _moe_block_prefill(cfg, pl, h, window, cache_len)
        return h2, {"k": kvc.k, "v": kvc.v}

    x, kv = _scan(body, x, sp["blocks"])
    if spec.last:
        h = norm_apply(cfg.norm, sp["final_norm"], x[:, -1:, :])
        return _head(cfg, sp, h), kv
    return x, kv


def stage_decode(cfg: ModelCfg, spec: StageSpec, sp: Dict[str, Any],
                 inp: jax.Array, kv: Dict[str, jax.Array], pos: jax.Array,
                 window: Optional[int] = None
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode through one stage.

    ``inp`` is ``(B, 1)`` int tokens on the first stage, ``(B, 1, d)``
    hidden states otherwise; ``pos`` the scalar index of this token.
    Returns ``(out, kv)`` with ``out`` the boundary hidden ``(B, 1, d)``
    (logits ``(B, 1, V)`` on the last stage).
    """
    window = window if window is not None else cfg.window
    if spec.first:
        x = embed(sp["embed"], inp, cfg.dtype)
        if cfg.rope_fraction == 0.0:
            x = x + embed(sp["pos_embed"], pos[None], cfg.dtype)[None]
    else:
        x = inp

    def body(h, xs):
        pl, c = xs
        kvc = attn.KVCache(c["k"], c["v"])
        if cfg.family == "dense":
            h2, kvc = _dense_block_decode(cfg, pl, h, kvc, pos, window)
        else:
            h2, kvc = _moe_block_decode(cfg, pl, h, kvc, pos, window)
        return h2, {"k": kvc.k, "v": kvc.v}

    x, new_kv = _scan(body, x, (sp["blocks"], kv))
    if spec.last:
        h = norm_apply(cfg.norm, sp["final_norm"], x)
        return _head(cfg, sp, h), new_kv
    return x, new_kv


class StageExecutor:
    """Jitted prefill/decode for one :class:`StageSpec`.

    One executor is shared by every replica of a stage (replicas host
    byte-identical parameters), so each distinct ``(stage, input shape)``
    compiles once per process regardless of swarm size.
    """

    def __init__(self, cfg: ModelCfg, spec: StageSpec,
                 sp: Dict[str, Any], cache_len: int):
        self.cfg = cfg
        self.spec = spec
        self.params = sp
        self.cache_len = int(cache_len)
        self._prefill = jax.jit(
            lambda p, inp: stage_prefill(cfg, spec, p, inp, self.cache_len))
        self._decode = jax.jit(
            lambda p, inp, kv, pos: stage_decode(cfg, spec, p, inp, kv, pos))

    def prefill(self, inp: jax.Array
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        return self._prefill(self.params, inp)

    def decode(self, inp: jax.Array, kv: Dict[str, jax.Array],
               pos: int) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        return self._decode(self.params, inp, kv, jnp.asarray(pos, jnp.int32))
