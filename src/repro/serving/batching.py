"""Continuous batching: the admit-on-slot-free request queue.

Static batching pads every request to the batch's longest generation and
wastes slots on finished sequences; continuous batching (the unchecked back
half of the tLLM roadmap, SNIPPETS.md 3) admits a waiting request the moment
a slot frees, and every request carries its own generation length.

:class:`RequestQueue` is the deterministic core: arrival-ordered FIFO with
simulated-clock visibility (``due(now)`` only surfaces requests that have
actually arrived).  The admission *policy* lives in
:meth:`repro.serving.router.SessionRouter.has_capacity` — a request is
admitted when every stage of some chain has a free slot — and the decode
loop in :class:`repro.serving.runtime.ServingRuntime` re-checks admission at
the top of every round, so a session finishing in round *k* frees its slots
for a new admission in round *k+1*, never at an epoch/batch boundary.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, List, Optional

from .reqtrace import Request


class RequestQueue:
    """Arrival-ordered FIFO over a simulated clock."""

    def __init__(self, requests: List[Request]):
        self._q: Deque[Request] = deque(
            sorted(requests, key=lambda r: (r.arrival, r.rid)))
        self.n_admitted = 0

    def __len__(self) -> int:
        return len(self._q)

    @property
    def empty(self) -> bool:
        return not self._q

    def peek(self) -> Optional[Request]:
        return self._q[0] if self._q else None

    def due(self, now: float) -> bool:
        """Is the head request's arrival time <= now?"""
        return bool(self._q) and self._q[0].arrival <= now

    def next_arrival(self) -> Optional[float]:
        """Arrival time of the head request (None when drained) — the idle
        runtime fast-forwards the sim clock to this."""
        return self._q[0].arrival if self._q else None

    def pop(self, now: float) -> Request:
        if not self.due(now):
            raise RuntimeError("pop() with no due request — check due(now)")
        self.n_admitted += 1
        return self._q.popleft()
