"""Inference sessions: per-request state, including what re-routing needs.

A :class:`Session` is the server-side mirror of Petals'
``InferenceSession`` (SNIPPETS.md 2): the KV caches it has accumulated on
each stage of its chain, plus the **input history** each stage consumed —
the prompt (token ids into stage 0, boundary hiddens into later stages) and
every per-token decode input since.

The history is what makes mid-session re-routing *exact*: when a replica
dies, the replacement rebuilds the session's KV prefix by replaying the
recorded inputs through the **same jitted stage functions** that produced
the original cache — same op order, same reduction order, bit-identical KV
(pinned in ``tests/test_serving.py``: churn and no-churn runs emit identical
tokens under greedy decode).  Shipping the surviving KV tensors instead
would cost ``kv_bytes_per_token × pos`` on the wire; the router charges
whichever the cost model says is cheaper (see
:meth:`repro.serving.router.SessionRouter.reroute`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax

from .reqtrace import Request


@dataclasses.dataclass
class StageState:
    """One stage's view of a session: its KV plus the inputs that built it."""

    kv: Optional[Dict[str, jax.Array]] = None
    prefill_input: Optional[jax.Array] = None   # tokens (1,S) or hiddens
    step_inputs: List[jax.Array] = dataclasses.field(default_factory=list)

    def record_prefill(self, inp: jax.Array, kv: Dict[str, jax.Array]) -> None:
        self.prefill_input = inp
        self.kv = kv

    def record_step(self, inp: jax.Array, kv: Dict[str, jax.Array]) -> None:
        self.step_inputs.append(inp)
        self.kv = kv


@dataclasses.dataclass
class Session:
    """One admitted request's live state across its chain of replicas."""

    request: Request
    chain: List[int]                     # device id per stage
    admitted_at: float
    stages: List[StageState] = dataclasses.field(default_factory=list)
    pos: int = 0                         # tokens consumed (prompt + decoded)
    generated: List[int] = dataclasses.field(default_factory=list)
    token_latencies: List[float] = dataclasses.field(default_factory=list)
    n_reroutes: int = 0
    finished_at: Optional[float] = None

    def __post_init__(self):
        if not self.stages:
            self.stages = [StageState() for _ in self.chain]

    @property
    def rid(self) -> str:
        return self.request.rid

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.request.max_new_tokens

    @property
    def next_pos(self) -> int:
        """Cache position the next decode token writes at."""
        return self.pos

    def replay_len(self, stage: int) -> int:
        """Tokens the replacement replica must re-consume to rebuild this
        stage's KV: the prefill prompt plus every decode step so far."""
        st = self.stages[stage]
        plen = 0 if st.prefill_input is None \
            else int(st.prefill_input.shape[1])
        return plen + len(st.step_inputs)


def summarize(sessions: List[Session]) -> Dict[str, Any]:
    """Completion stats over a run's sessions (benchmark reporting)."""
    done = [s for s in sessions if s.done]
    return {
        "n_sessions": len(sessions),
        "n_completed": len(done),
        "all_completed": len(done) == len(sessions),
        "n_reroutes": sum(s.n_reroutes for s in sessions),
        "tokens": sum(len(s.generated) for s in sessions),
    }
