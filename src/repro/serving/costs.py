"""Serving cost model: KV/activation wire pricing + per-stage decode pace.

This is the serving twin of :class:`repro.core.costmodel.EdgeCostModel`, and
it deliberately prices bytes the same way training does:

* **wire bytes** come from the activation dtype's itemsize (dtype-aware, not
  a hard-coded fp32) — a bf16 swarm ships half the boundary bytes of an fp32
  one, exactly as the training cost model's profile-derived ``itemsize``;
* **link seconds** go through ``ClusterSpec.comm_time`` (the α–β primitive)
  scaled by the same telemetry-calibrated ``link_corrections`` the training
  loop fits with :func:`repro.core.costmodel.fit_link_corrections` — a
  correction learned during training reprices serving routes for free
  (:meth:`ServingCostModel.from_cost_model` lifts corrections straight off a
  live ``EdgeCostModel``);
* **compute seconds** are analytic decode FLOPs over ``DeviceSpec.speed``
  (S(p) = λ_p·S*(p)), the paper's Eq. 1 ``C(f,p)`` term.

Byte quantities priced here, per session:

* ``act_bytes_per_token`` — one boundary hidden vector ``(1, 1, d_model)``,
  the per-hop payload of stage-chained decode;
* ``kv_bytes_per_token(spec)`` — the K+V rows one token appends across a
  stage's layers: what a mid-session re-route would have to *move* if we
  shipped the cache instead of replaying it (the router charges the cheaper
  replay; the planner uses this for per-stage KV placement feasibility);
* ``stage_param_bytes(spec)`` — the resident weights a replica hosts, for
  the memory-feasibility gate in :func:`repro.serving.plan.plan_serving`.

This module is sanctioned for raw itemsize arithmetic (``repro.check``
lint ``_ITEMSIZE_OK``) — everything downstream must price through it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Tuple

import jax.numpy as jnp

from repro.configs.base import ModelCfg
from repro.core.costmodel import EdgeCostModel
from repro.core.estimator import ClusterSpec

from .stages import StageSpec

TOKEN_ID_BYTES = 4   # int32 token ids on the client->stage0 hop


@dataclasses.dataclass(frozen=True)
class StageCost:
    """Resolved per-stage serving costs (one replica's view)."""

    spec: StageSpec
    param_bytes: int
    kv_bytes_per_token: int
    decode_flops: float          # one token through the stage (cache_len att)
    in_bytes_per_token: int      # payload arriving at this stage per token


class ServingCostModel:
    """Prices the serving swarm on a cluster: bytes per hop, seconds per
    stage, KV placement feasibility.  Immutable by convention, like
    ``EdgeCostModel``."""

    def __init__(self, cfg: ModelCfg, cluster: ClusterSpec,
                 link_corrections: Optional[Mapping[Tuple[int, int],
                                                   float]] = None):
        self.cfg = cfg
        self.cluster = cluster
        self.link_corrections: Dict[Tuple[int, int], float] = \
            dict(link_corrections or {})
        self._act_itemsize = int(jnp.dtype(cfg.dtype).itemsize)
        self._param_itemsize = int(jnp.dtype(cfg.param_dtype).itemsize)

    @staticmethod
    def from_cost_model(cfg: ModelCfg, model: EdgeCostModel
                        ) -> "ServingCostModel":
        """Adopt a training loop's calibrated belief: same α–β cluster, same
        fitted link corrections — serving routes are priced on what the
        training telemetry actually measured."""
        return ServingCostModel(cfg, model.cluster, model.link_corrections)

    def with_link_corrections(self, corrections: Mapping[Tuple[int, int],
                                                         float]
                              ) -> "ServingCostModel":
        return ServingCostModel(self.cfg, self.cluster, corrections)

    # ------------------------------------------------------------- bytes --
    @property
    def act_itemsize(self) -> int:
        return self._act_itemsize

    def act_bytes_per_token(self) -> int:
        """One boundary hidden state (1, 1, d_model) at the activation
        dtype — the per-token stage-to-stage payload."""
        return self.cfg.d_model * self._act_itemsize

    def stage_in_bytes_per_token(self, spec: StageSpec) -> int:
        """Per-token payload arriving at a stage: raw token ids into the
        first stage (the client hop), boundary hiddens everywhere else."""
        return TOKEN_ID_BYTES if spec.first else self.act_bytes_per_token()

    def kv_bytes_per_token(self, spec: StageSpec) -> int:
        """K+V rows one token appends across the stage's layers."""
        cfg = self.cfg
        per_layer = 2 * cfg.n_kv_heads * cfg.head_dim * self._act_itemsize
        return spec.n_layers * per_layer

    def kv_bytes(self, spec: StageSpec, cache_len: int) -> int:
        """Resident KV cache of one session slot at full ``cache_len``."""
        return self.kv_bytes_per_token(spec) * int(cache_len)

    def stage_param_bytes(self, spec: StageSpec) -> int:
        """Analytic resident weight bytes of one replica (mirrors
        ``causal_lm.count_params`` for the dense/moe block, plus the
        embed/head tables on the boundary stages)."""
        cfg = self.cfg
        d, V = cfg.d_model, cfg.vocab_padded
        nrm = 2 * d if cfg.norm == "layernorm" else d
        attn_p = (d * cfg.n_heads * cfg.head_dim * 2
                  + d * cfg.n_kv_heads * cfg.head_dim * 2
                  + (cfg.n_heads * cfg.head_dim
                     + 2 * cfg.n_kv_heads * cfg.head_dim
                     if cfg.qkv_bias else 0))
        mults = 3 if cfg.act in ("silu", "swiglu") else 2
        if cfg.family == "moe":
            ffn_p = (d * cfg.n_experts
                     + cfg.n_experts * d * cfg.d_ff * 3
                     + (d * cfg.n_shared_experts * cfg.d_ff * 3
                        if cfg.n_shared_experts else 0))
        else:
            ffn_p = d * cfg.d_ff * mults
        per_layer = attn_p + ffn_p + 2 * nrm
        total = spec.n_layers * per_layer
        if spec.first or (spec.last and cfg.tie_embeddings):
            total += V * d
        if spec.first and cfg.rope_fraction == 0.0:
            total += cfg.max_seq * d
        if spec.last:
            total += nrm
            if not cfg.tie_embeddings:
                total += d * V
        return total * self._param_itemsize

    # ----------------------------------------------------------- seconds --
    def stage_decode_flops(self, spec: StageSpec, cache_len: int) -> float:
        """One token through the stage, attending over ``cache_len`` keys
        (the conservative full-cache bound; decode FLOPs grow with position
        but the planner prices the steady state)."""
        cfg = self.cfg
        d = cfg.d_model
        qk = cfg.n_heads * cfg.head_dim
        kv = cfg.n_kv_heads * cfg.head_dim
        attn = (2 * d * (qk + 2 * kv)            # qkv projections
                + 2 * qk * d                     # output projection
                + 4 * cfg.n_heads * cfg.head_dim * cache_len)  # scores+mix
        mults = 3 if cfg.act in ("silu", "swiglu") else 2
        if cfg.family == "moe":
            active = cfg.top_k + cfg.n_shared_experts
            ffn = 2 * d * cfg.n_experts + active * 3 * 2 * d * cfg.d_ff
        else:
            ffn = mults * 2 * d * cfg.d_ff
        total = spec.n_layers * (attn + ffn)
        if spec.last:
            total += 2 * d * cfg.vocab_padded    # LM head
        return float(total)

    def stage_seconds(self, device: int, spec: StageSpec,
                      cache_len: int) -> float:
        """Eq. 1 C(f,p): one token's compute on a replica of ``spec``."""
        return self.cluster.compute_time(
            self.stage_decode_flops(spec, cache_len), device)

    def link_seconds(self, src: int, dst: int, nbytes: float) -> float:
        """α–β seconds on the directed (src, dst) link, scaled by the
        calibrated correction — identical semantics to
        ``EdgeCostModel.link_seconds``."""
        if src == dst:
            return 0.0
        t = self.cluster.comm_time(src, dst, nbytes)
        return t * self.link_corrections.get((src, dst), 1.0)

    def hop_seconds(self, src: int, dst: int, spec: StageSpec) -> float:
        """One token's boundary payload into a replica of ``spec``."""
        return self.link_seconds(src, dst, self.stage_in_bytes_per_token(spec))

    def stage_costs(self, spec: StageSpec, cache_len: int) -> StageCost:
        return StageCost(
            spec=spec,
            param_bytes=self.stage_param_bytes(spec),
            kv_bytes_per_token=self.kv_bytes_per_token(spec),
            decode_flops=self.stage_decode_flops(spec, cache_len),
            in_bytes_per_token=self.stage_in_bytes_per_token(spec))
