"""Bench-result / committed-baseline schema checks (satellite of PR 7).

``benchmarks/compare.py`` gates CI on
``benchmarks/baselines/BENCH_baseline_joint.json``; a hand-edited or
truncated baseline must fail loudly instead of silently gating against
garbage.  :func:`check_bench_result` accepts either the envelope shape
(``{"result": {...}, ...}``) or a bare ``{system: {metric: value}}``
mapping and verifies:

* the result is a non-empty mapping of non-empty per-system mappings,
* every metric value is a finite number,
* every *tracked* metric (the ones the perf gate keys on) is > 0, and
  at least one system actually carries one — a baseline with no tracked
  metric would make the gate vacuously pass.
"""
from __future__ import annotations

import math
from typing import Any, List, Mapping, Sequence, Tuple

from .errors import BaselineCheckError, Finding, raise_findings

TRACKED_DEFAULT: Tuple[str, ...] = ("pace", "phi")


def check_bench_result(payload: Any,
                       tracked: Sequence[str] = TRACKED_DEFAULT,
                       source: str = "") -> List[Finding]:
    where = source or "<payload>"
    if not isinstance(payload, Mapping):
        return [Finding("not-a-mapping", where,
                        f"bench payload is {type(payload).__name__}, "
                        "expected a JSON object")]
    result = payload.get("result", payload)
    if not isinstance(result, Mapping) or not result:
        return [Finding("empty-result", where,
                        "no per-system results (truncated baseline?)")]
    out: List[Finding] = []
    seen_tracked = False
    for system, metrics in result.items():
        sw = f"{where}:{system}"
        if not isinstance(metrics, Mapping):
            # scalar harness annotations (wall_seconds, notes) ride along
            # at system level; the gate skips them, so does the schema —
            # unless they are something structurally wrong
            if not isinstance(metrics, (int, float, str)) \
                    or isinstance(metrics, bool):
                out.append(Finding("bad-system", sw,
                                   f"system {system!r} carries "
                                   f"{metrics!r}, expected a metric mapping "
                                   "or a scalar annotation"))
            continue
        if not metrics:
            out.append(Finding("bad-system", sw,
                               f"system {system!r} carries an empty metric "
                               "mapping (truncated baseline?)"))
            continue
        for metric, v in metrics.items():
            mw = f"{sw}.{metric}"
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                out.append(Finding("non-numeric-metric", mw,
                                   f"{system}.{metric} = {v!r} is not a "
                                   "number"))
                continue
            if not math.isfinite(v):
                out.append(Finding("non-finite-metric", mw,
                                   f"{system}.{metric} = {v!r}"))
                continue
            if metric in tracked:
                seen_tracked = True
                if v <= 0:
                    out.append(Finding(
                        "bad-tracked-metric", mw,
                        f"tracked metric {system}.{metric} = {v!r} must "
                        "be > 0 for ratio gating"))
    if not seen_tracked:
        out.append(Finding(
            "no-tracked-metric", where,
            f"no system carries any tracked metric {tuple(tracked)!r} — "
            "the perf gate would vacuously pass"))
    return out


def verify_bench_result(payload: Any,
                        tracked: Sequence[str] = TRACKED_DEFAULT,
                        source: str = "",
                        strict: bool = False) -> List[Finding]:
    findings = check_bench_result(payload, tracked=tracked, source=source)
    return raise_findings(
        findings, BaselineCheckError,
        f"bench baseline {source or '<payload>'} failed validation",
        strict=strict)
