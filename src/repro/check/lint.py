"""Repo-custom AST lint (repro.check, component 6).

Five rules that encode hard-won repo conventions generic linters cannot
know, run over every ``.py`` under ``src/repro/``:

* ``raw-byte-math`` — wire-byte / link-time arithmetic
  (``.itemsize`` or an ``itemsize`` variable, ``.beta`` / ``.bandwidth``
  inside a binary expression) outside the sanctioned modules.  PR 3
  unified every byte account behind :class:`EdgeCostModel`; a stray
  ``numel * itemsize`` elsewhere is exactly the estimator/simulator
  divergence that model exists to kill.  Sanctioned: the cost model, the
  encoding arithmetic it delegates to, the profile layer that derives
  itemsize, the α–β primitives, and the migration byte accounting.
* ``wallclock-in-sim`` — ``time.time()`` anywhere in ``core/`` or
  ``elastic/``.  Those layers run on the simulated clock; a wall-clock
  read silently couples sim results to host speed.  (The ``launch/``
  entry points are wall-clock programs and are exempt.)
* ``bare-print`` — ``print()`` outside a ``main`` function, an
  ``if __name__ == "__main__"`` block, or a ``__main__.py`` entry
  module.  Library output goes through ``repro.obs``; prints in
  import-time or library code corrupt piped CLI output.
* ``kernel-dispatch-bypass`` — a ``topk_mask``/``topk_select`` call with
  no ``use_kernel=`` keyword inside ``distributed/`` or ``core/rad.py``.
  Those are the step's hot paths: compression there must flow through the
  kernel dispatch policy so the Pallas fast path (and its pricing
  telemetry) is reachable; a bare call silently pins the legacy global
  top-k and makes the planner's ``compress_seconds`` term a lie.
* ``missing-module-docstring`` — a module under ``serving/`` with no
  docstring.  The serving package is the newest subsystem and
  ``docs/architecture.md`` links into it by module purpose; every file
  there must say what it is for.

Findings use code=rule and ``where="path:line"`` so CI can upload them
as an artifact and tests can key on them.
"""
from __future__ import annotations

import ast
import os
from typing import Iterable, List, Optional, Sequence, Tuple

from .errors import CheckError, Finding, raise_findings

# modules allowed to do raw itemsize arithmetic (profile/encoding layer)
_ITEMSIZE_OK = {
    "core/costmodel.py", "core/compression.py", "core/opgraph.py",
    "elastic/replan.py", "serving/costs.py",
}
# modules allowed to touch .beta / .bandwidth in arithmetic (α–β layer)
_LINKMATH_OK = {
    "core/costmodel.py", "core/estimator.py", "core/network.py",
}
_WALLCLOCK_SCOPES = ("core/", "elastic/", "serving/")
_LINK_ATTRS = {"beta", "bandwidth"}
# hot-path modules where compression calls must honour the kernel dispatch
# policy (pass use_kernel= through) instead of silently pinning legacy XLA
_DISPATCH_SCOPES = ("distributed/", "core/rad.py")
_DISPATCH_FNS = {"topk_mask", "topk_select"}
# packages where every module must open with a docstring
_DOCSTRING_SCOPES = ("serving/",)


class LintError(CheckError):
    """Repo-convention lint rule violated."""


def _is_itemsize(node: ast.AST) -> bool:
    return (isinstance(node, ast.Attribute) and node.attr == "itemsize") \
        or (isinstance(node, ast.Name) and node.id == "itemsize")


def _is_link_attr(node: ast.AST) -> bool:
    return isinstance(node, ast.Attribute) and node.attr in _LINK_ATTRS


def _is_main_guard(node: ast.AST) -> bool:
    if not isinstance(node, ast.If):
        return False
    t = node.test
    return isinstance(t, ast.Compare) \
        and isinstance(t.left, ast.Name) and t.left.id == "__name__"


class _Visitor(ast.NodeVisitor):
    def __init__(self, rel: str):
        self.rel = rel
        self.findings: List[Finding] = []
        self._fn_stack: List[str] = []
        self._guard_depth = 0
        self.itemsize_ok = rel in _ITEMSIZE_OK
        self.linkmath_ok = rel in _LINKMATH_OK
        self.sim_scope = rel.startswith(_WALLCLOCK_SCOPES)
        self.dispatch_scope = rel.startswith(_DISPATCH_SCOPES)
        # a __main__.py IS the CLI entry point — all of it is "main"
        self.entry_point = rel.endswith("__main__.py")

    def _hit(self, rule: str, node: ast.AST, msg: str) -> None:
        self.findings.append(
            Finding(rule, f"{self.rel}:{node.lineno}", msg))

    # ---------------------------------------------------- scope tracking --
    def visit_FunctionDef(self, node):
        self._fn_stack.append(node.name)
        self.generic_visit(node)
        self._fn_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_If(self, node):
        if _is_main_guard(node):
            self._guard_depth += 1
            self.generic_visit(node)
            self._guard_depth -= 1
        else:
            self.generic_visit(node)

    # ------------------------------------------------------------- rules --
    def visit_BinOp(self, node):
        for side in (node.left, node.right):
            if not self.itemsize_ok and _is_itemsize(side):
                self._hit("raw-byte-math", node,
                          "itemsize arithmetic outside the cost-model "
                          "layer — derive bytes via EdgeCostModel / "
                          "wire_bytes instead")
            if not self.linkmath_ok and _is_link_attr(side):
                self._hit("raw-byte-math", node,
                          f".{side.attr} arithmetic outside the α–β "
                          "layer — price transfers via LinkSpec.time / "
                          "EdgeCostModel instead")
        self.generic_visit(node)

    def visit_Call(self, node):
        f = node.func
        if self.sim_scope and isinstance(f, ast.Attribute) \
                and f.attr == "time" and isinstance(f.value, ast.Name) \
                and f.value.id == "time":
            self._hit("wallclock-in-sim", node,
                      "time.time() in a sim-clock layer — thread the "
                      "simulated clock through instead")
        if isinstance(f, ast.Name) and f.id == "print" \
                and "main" not in self._fn_stack and not self._guard_depth \
                and not self.entry_point:
            self._hit("bare-print", node,
                      "bare print() in library code — route output "
                      "through repro.obs or a main() entry point")
        if self.dispatch_scope:
            name = f.id if isinstance(f, ast.Name) else \
                (f.attr if isinstance(f, ast.Attribute) else None)
            if name in _DISPATCH_FNS and not any(
                    kw.arg == "use_kernel" for kw in node.keywords):
                self._hit("kernel-dispatch-bypass", node,
                          f"{name}() on a hot path without use_kernel= — "
                          "thread the kernel dispatch policy through so "
                          "the Pallas fast path and its cost telemetry "
                          "stay reachable")
        self.generic_visit(node)


def lint_source(source: str, rel: str) -> List[Finding]:
    """Lint one file's source; ``rel`` is its path relative to
    ``src/repro`` (posix separators)."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding("syntax-error", f"{rel}:{e.lineno or 0}",
                        f"cannot parse: {e.msg}")]
    v = _Visitor(rel)
    v.visit(tree)
    if rel.startswith(_DOCSTRING_SCOPES) and ast.get_docstring(tree) is None:
        v.findings.append(Finding(
            "missing-module-docstring", f"{rel}:1",
            "serving module without a docstring — state the module's "
            "purpose so docs/architecture.md stays navigable"))
    return v.findings


def repro_root() -> str:
    """The ``src/repro`` package directory this module is installed in."""
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def lint_tree(root: Optional[str] = None) -> List[Finding]:
    """Lint every ``.py`` under ``root`` (default: the live ``src/repro``
    package), findings sorted by location."""
    root = root or repro_root()
    findings: List[Finding] = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames
                             if d not in ("__pycache__",))
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            with open(path, encoding="utf-8") as f:
                findings += lint_source(f.read(), rel)
    return findings


def verify_lint(root: Optional[str] = None,
                strict: bool = False) -> List[Finding]:
    return raise_findings(lint_tree(root), LintError,
                          "repo-convention lint failed", strict=strict)
