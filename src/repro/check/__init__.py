"""``repro.check`` — static plan-verifier for the OP-DAG planning stack.

One entry point per planning artifact, each returning typed
:class:`Finding` lists (``check_*``) or raising the matching
:class:`CheckError` subclass (``verify_*``):

=================  ========================================================
artifact           entry points
=================  ========================================================
OP-DAG + profiles  :func:`check_graph`, :func:`check_profiles`,
                   :func:`verify_graph`
schedule           :func:`check_schedule`, :func:`verify_schedule`
cost model         :func:`check_cost_model`, :func:`verify_cost_model`
compression plan   :func:`check_compression_plan`, :func:`verify_plan`
elastic re-plan    :func:`check_moves`, :func:`check_pinned_moves`,
                   :func:`check_replan`, :func:`verify_replan`
span traces        :func:`check_trace_order`, :func:`verify_trace`,
                   :func:`load_trace_events`
bench baselines    :func:`check_bench_result`, :func:`verify_bench_result`
repo conventions   :func:`lint_tree`, :func:`lint_source`,
                   :func:`verify_lint`
=================  ========================================================

The planners (``schedule_opfence`` / ``schedule_joint``) and the
``ElasticController`` call the verifiers on every plan they install;
pass ``verify=False`` to opt out.  CLI: ``python -m repro.check``.

Only :mod:`repro.check.errors` is imported eagerly — the core IR raises
:class:`GraphCheckError` at graph-construction time, so this package
must be importable while ``repro.core`` is still initialising.  Every
checker module loads lazily on first attribute access (PEP 562).
"""
from __future__ import annotations

from .errors import (BaselineCheckError, CheckError, CompressionCheckError,
                     CostCheckError, ElasticCheckError, Finding,
                     GraphCheckError, ScheduleCheckError, SEV_ERROR,
                     SEV_WARN, TraceOrderError, errors_only, fmt_findings,
                     raise_findings)

_LAZY = {
    "check_graph": "graph", "check_profiles": "graph",
    "verify_graph": "graph",
    "check_schedule": "schedule", "verify_schedule": "schedule",
    "check_cost_model": "costs", "verify_cost_model": "costs",
    "check_compression_plan": "costs", "verify_plan": "costs",
    "check_moves": "elastic", "check_pinned_moves": "elastic",
    "check_replan": "elastic", "verify_replan": "elastic",
    "check_trace_order": "traceorder", "verify_trace": "traceorder",
    "load_trace_events": "traceorder",
    "check_bench_result": "bench", "verify_bench_result": "bench",
    "lint_tree": "lint", "lint_source": "lint", "verify_lint": "lint",
    "LintError": "lint",
}

__all__ = [
    "BaselineCheckError", "CheckError", "CompressionCheckError",
    "CostCheckError", "ElasticCheckError", "Finding", "GraphCheckError",
    "ScheduleCheckError", "SEV_ERROR", "SEV_WARN", "TraceOrderError",
    "errors_only", "fmt_findings", "raise_findings",
] + sorted(_LAZY)


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    import importlib
    value = getattr(importlib.import_module(f".{mod}", __name__), name)
    globals()[name] = value
    return value


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
