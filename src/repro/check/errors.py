"""Typed findings + error hierarchy for the static plan-verifier.

Every checker in :mod:`repro.check` returns a list of :class:`Finding`
records — one per violated invariant, each naming the artifact element
(op, edge, device, track, metric) it indicts — and each ``verify_*``
wrapper raises the matching :class:`CheckError` subclass when any
error-severity finding survives.

This module is import-light on purpose (stdlib + dataclasses only): the
core IR (:mod:`repro.core.opgraph`) raises :class:`GraphCheckError` at
graph-construction time, so nothing here may import back into
``repro.core`` / ``repro.elastic``.  All error types subclass
:class:`ValueError` — call sites that predate the typed hierarchy keep
catching what they always caught.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Sequence, Tuple

SEV_ERROR = "error"
SEV_WARN = "warn"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violated invariant.

    ``code`` is a stable kebab-case identifier (tests and CI key on it);
    ``where`` names the offending element — an op, an ``a->b`` edge, a
    ``dev3`` device, a trace track, a ``system.metric`` pair; ``message``
    is the human-readable explanation.
    """

    code: str
    where: str
    message: str
    severity: str = SEV_ERROR

    def __str__(self) -> str:
        tag = "" if self.severity == SEV_ERROR else f" [{self.severity}]"
        return f"{self.code} @ {self.where}: {self.message}{tag}"


def errors_only(findings: Iterable[Finding]) -> List[Finding]:
    return [f for f in findings if f.severity == SEV_ERROR]


class CheckError(ValueError):
    """Base of the typed check hierarchy; carries its findings."""

    def __init__(self, message: str = "",
                 findings: Sequence[Finding] = ()):
        self.findings: Tuple[Finding, ...] = tuple(findings)
        if not message:
            message = "; ".join(str(f) for f in self.findings) \
                or "check failed"
        elif self.findings:
            message = message + ": " + \
                "; ".join(str(f) for f in self.findings)
        super().__init__(message)

    @property
    def codes(self) -> Tuple[str, ...]:
        return tuple(f.code for f in self.findings)


class GraphCheckError(CheckError):
    """OP-DAG structural invariant violated (cycle, dangling dep,
    duplicate name, shape inconsistency, unreachable op)."""


class ScheduleCheckError(CheckError):
    """Schedule invariant violated (coverage, contiguity, membership,
    capacity)."""


class CostCheckError(CheckError):
    """EdgeCostModel self-consistency violated (underivable bytes,
    wire inflation, out-of-clamp correction, missing link)."""


class CompressionCheckError(CheckError):
    """AdaTopK plan invariant violated (ratio below break-even, wire
    inflation, unknown encoding/op)."""


class ElasticCheckError(CheckError):
    """Re-plan invariant violated (candidate misses ops, non-conserving
    move-set, pinned boundary crossed)."""


class TraceOrderError(CheckError):
    """Happens-before violated in a span log (overlapping sends on one
    link, compute before its inbound transfer, non-monotonic track)."""


class BaselineCheckError(CheckError):
    """Committed bench baseline malformed (truncated, non-numeric,
    no tracked metric)."""


def raise_findings(findings: Sequence[Finding], exc_type=CheckError,
                   context: str = "",
                   strict: bool = False) -> List[Finding]:
    """Raise ``exc_type`` when any error-severity finding is present
    (``strict=True`` also promotes warnings).  Returns the findings when
    nothing raises, so verify wrappers can hand survivors back."""
    bad = list(findings) if strict else errors_only(findings)
    if bad:
        raise exc_type(context, findings=bad)
    return list(findings)


def fmt_findings(findings: Sequence[Finding],
                 header: Optional[str] = None) -> str:
    lines = [header] if header else []
    lines += [f"  - {f}" for f in findings]
    return "\n".join(lines)
