"""``python -m repro.check`` — static plan-verification CLI.

Modes (composable; no flags runs ``--all-configs --lint``):

* ``--all-configs`` / ``--config ARCH`` — build each committed
  architecture's smoke OP-DAG, profile it, co-plan a joint
  schedule + AdaTopK plan on the paper testbed, and run every checker
  (graph, profiles, schedule, compression plan, cost model) over the
  artifacts.  A config that cannot even plan is itself a finding.
* ``--lint`` — the repo-custom AST lint over ``src/repro/``
  (``--lint-json PATH`` additionally writes the findings as JSON for
  the CI artifact).
* ``--docs`` — relative-link check over README/ROADMAP/docs/ markdown
  (jax-free; see :mod:`repro.check.docs`).
* ``--trace PATH`` — happens-before check on a recorded span log
  (``.jsonl`` or Chrome-trace ``.json``), repeatable.
* ``--bench PATH`` — schema-validate a BENCH result/baseline JSON
  (repeatable); ``--bench-tracked METRIC`` overrides the tracked-metric
  set the gate keys on (repeatable, default ``pace``/``phi``).

Exit status 1 when any error-severity finding survives; warnings print
but do not fail (``--strict`` promotes them).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional, Sequence

from .errors import Finding, SEV_ERROR


def check_config(arch: str, batch: int = 2, seq: int = 128,
                 seed: int = 0, ratio: float = 100.0) -> List[Finding]:
    """Full checker sweep over one committed architecture: smoke config
    -> metadata OP-DAG -> profiles -> joint (OP-Fence x AdaTopK) plan on
    paper testbed 1 -> every invariant."""
    from repro.configs import resolve
    from repro.core.network import paper_testbed
    from repro.core.scheduler import schedule_joint
    from repro.models.opgraph_models import profile_opgraph

    from .costs import check_compression_plan, check_cost_model
    from .graph import check_graph, check_profiles
    from .schedule import check_schedule

    cfg = resolve(arch).smoke
    shapes = {"tokens": (batch, seq), "labels": (batch, seq)}
    try:
        graph = profile_opgraph(cfg, batch, seq)
    except Exception as e:   # a config that cannot build is a finding
        return [Finding("config-build", arch,
                        f"profile_opgraph failed: {e}")]
    findings = check_graph(graph, shapes)
    profiles = graph.annotate(shapes)
    findings += check_profiles(graph, profiles, shapes)
    if any(f.severity == SEV_ERROR for f in findings):
        return findings      # planning over a broken graph is noise
    cluster = paper_testbed(1, seed=seed)
    try:
        jp = schedule_joint(graph, profiles, cluster, ratio=ratio,
                            seed=seed, verify=False)
    except Exception as e:
        return findings + [Finding("config-plan", arch,
                                   f"schedule_joint failed: {e}")]
    findings += check_schedule(graph, jp.schedule, profiles=profiles,
                               cluster=cluster)
    findings += check_compression_plan(graph, profiles, jp.plan,
                                       jp.schedule.placement)
    findings += check_cost_model(jp.cost_model, jp.schedule.placement)
    return findings


def _report(label: str, findings: Sequence[Finding]) -> int:
    errs = [f for f in findings if f.severity == SEV_ERROR]
    warns = [f for f in findings if f.severity != SEV_ERROR]
    if errs:
        print(f"{label}: FAIL ({len(errs)} errors"
              + (f", {len(warns)} warnings" if warns else "") + ")")
    else:
        print(f"{label}: OK"
              + (f" ({len(warns)} warnings)" if warns else ""))
    for f in list(errs) + list(warns):
        print(f"  - {f}")
    return len(errs)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.check",
        description=__doc__.splitlines()[0])
    ap.add_argument("--all-configs", action="store_true",
                    help="verify every committed architecture config")
    ap.add_argument("--config", action="append", default=[],
                    metavar="ARCH", help="verify one arch (repeatable)")
    ap.add_argument("--lint", action="store_true",
                    help="run the repo-custom AST lint over src/repro/")
    ap.add_argument("--lint-json", metavar="PATH",
                    help="also write lint findings as JSON (CI artifact)")
    ap.add_argument("--docs", action="store_true",
                    help="link-check README/ROADMAP/docs/ markdown")
    ap.add_argument("--trace", action="append", default=[], metavar="PATH",
                    help="happens-before check a span log (repeatable)")
    ap.add_argument("--bench", action="append", default=[], metavar="PATH",
                    help="schema-validate a BENCH result JSON (repeatable)")
    ap.add_argument("--bench-tracked", action="append", default=[],
                    metavar="METRIC",
                    help="tracked metric the bench gate keys on "
                         "(repeatable; default: pace, phi)")
    ap.add_argument("--strict", action="store_true",
                    help="treat warnings as errors")
    args = ap.parse_args(argv)

    if not (args.all_configs or args.config or args.lint
            or args.lint_json or args.docs or args.trace or args.bench):
        args.all_configs = args.lint = True

    n_errors = 0
    if args.all_configs or args.config:
        from repro.configs import ARCH_IDS
        archs = list(ARCH_IDS) if args.all_configs else []
        archs += [a for a in args.config if a not in archs]
        for arch in archs:
            findings = check_config(arch)
            if args.strict:
                findings = [Finding(f.code, f.where, f.message)
                            for f in findings]
            n_errors += _report(f"config {arch}", findings)

    if args.lint or args.lint_json:
        from .lint import lint_tree
        findings = lint_tree()
        if args.strict:
            findings = [Finding(f.code, f.where, f.message)
                        for f in findings]
        n_errors += _report("lint src/repro", findings)
        if args.lint_json:
            with open(args.lint_json, "w") as f:
                json.dump([{"code": x.code, "where": x.where,
                            "message": x.message, "severity": x.severity}
                           for x in findings], f, indent=2)
            print(f"lint findings written to {args.lint_json}")

    if args.docs:
        from .docs import check_docs
        findings = check_docs()
        if args.strict:
            findings = [Finding(f.code, f.where, f.message)
                        for f in findings]
        n_errors += _report("docs markdown links", findings)

    for path in args.bench:
        from .bench import TRACKED_DEFAULT, check_bench_result
        tracked = tuple(args.bench_tracked) or TRACKED_DEFAULT
        try:
            with open(path) as f:
                payload = json.load(f)
        except Exception as e:
            n_errors += _report(f"bench {path}",
                                [Finding("bench-load", path,
                                         f"cannot load: {e}")])
            continue
        findings = check_bench_result(payload, tracked=tracked, source=path)
        if args.strict:
            findings = [Finding(f.code, f.where, f.message)
                        for f in findings]
        n_errors += _report(f"bench {path}", findings)

    for path in args.trace:
        from .traceorder import check_trace_order, load_trace_events
        try:
            events = load_trace_events(path)
        except Exception as e:
            n_errors += _report(f"trace {path}",
                                [Finding("trace-load", path,
                                         f"cannot load: {e}")])
            continue
        findings = check_trace_order(events)
        if args.strict:
            findings = [Finding(f.code, f.where, f.message)
                        for f in findings]
        n_errors += _report(f"trace {path} ({len(events)} events)",
                            findings)

    return 1 if n_errors else 0


if __name__ == "__main__":
    sys.exit(main())
