"""Elastic re-plan checks (repro.check, component 4).

A :class:`repro.elastic.replan.ReplanResult` is only installable when

* the winning candidate is itself a valid schedule over the survivors
  (delegated to :func:`repro.check.schedule.check_schedule` with
  ``alive=result.alive``),
* the migration move-set conserves parameter state **bit-for-bit in byte
  accounting**: every op that changed owner has exactly one move carrying
  ``state_bytes`` (params + optimizer state) from its true old owner —
  or from the checkpoint store (``src=None``) iff that owner is dead —
  and no move relocates an op that did not change owner,
* under ``pin_boundaries`` no node-to-node move crosses a bandwidth
  community (WAN) fence — the zero-cross-WAN guarantee the flag exists
  for,
* the candidate score table names exactly one winner and it is the mode
  the result claims.

:func:`verify_replan` raises :class:`ElasticCheckError` naming the
offending op/move.
"""
from __future__ import annotations

import math
from typing import List, Mapping, Optional, Sequence

from repro.core.estimator import ClusterSpec
from repro.core.opgraph import OpGraph, OpProfile
from repro.core.scheduler import Schedule
from repro.elastic.replan import OpMove, ReplanResult, state_bytes

from .errors import ElasticCheckError, Finding, raise_findings
from .schedule import check_schedule


def check_moves(old: Schedule, new: Schedule,
                profiles: Mapping[str, OpProfile],
                moves: Sequence[OpMove],
                dead: Sequence[int] = (),
                opt_state_mult: float = 2.0) -> List[Finding]:
    """Byte-conservation audit: the move-set must be exactly the owner
    diff between ``old`` and ``new``, each move carrying the op's full
    parameter + optimizer state."""
    dead_set = {int(d) for d in dead}
    old_place, new_place = old.placement, new.placement
    by_op = {}
    out: List[Finding] = []
    for m in moves:
        if m.op in by_op:
            out.append(Finding("duplicate-move", m.op,
                               f"op {m.op!r} appears in two moves — state "
                               "would be double-counted"))
            continue
        by_op[m.op] = m
    for op, src in old_place.items():
        dst = new_place.get(op)
        moved = dst is not None and dst != src
        m = by_op.pop(op, None)
        if not moved:
            if m is not None:
                out.append(Finding(
                    "phantom-move", op,
                    f"op {op!r} did not change owner (stays on CompNode "
                    f"{src}) but the plan moves {m.nbytes} bytes "
                    f"{m.src}->{m.dst}"))
            continue
        if m is None:
            expect = state_bytes(profiles[op], opt_state_mult) \
                if op in profiles else 0
            out.append(Finding(
                "missing-move", op,
                f"op {op!r} changed owner {src}->{dst} but no move carries "
                f"its {expect} state bytes — parameters would be dropped"))
            continue
        want_src = None if src in dead_set else src
        if m.src != want_src:
            code = "dead-source-send" if want_src is None else "wrong-source"
            out.append(Finding(
                code, op,
                f"op {op!r} moves from {m.src!r} but its state lives "
                + ("in the checkpoint store (owner "
                   f"{src} is dead)" if want_src is None
                   else f"on CompNode {src}")))
        if m.dst != dst:
            out.append(Finding(
                "wrong-destination", op,
                f"op {op!r} is shipped to CompNode {m.dst} but the new "
                f"schedule places it on {dst}"))
        expect = state_bytes(profiles[op], opt_state_mult) \
            if op in profiles else 0
        if int(m.nbytes) != int(expect):
            out.append(Finding(
                "state-bytes-mismatch", op,
                f"op {op!r} move carries {m.nbytes} bytes but its state is "
                f"{expect} (n_params x 4 x (1+{opt_state_mult:g})) — "
                "migration would not conserve parameter state"))
    for op, m in by_op.items():
        out.append(Finding(
            "phantom-move", op,
            f"move for op {op!r} ({m.src}->{m.dst}, {m.nbytes} bytes) "
            "matches no op in the old placement"))
    return out


def check_pinned_moves(moves: Sequence[OpMove],
                       communities: Sequence[Sequence[int]]
                       ) -> List[Finding]:
    """Under ``pin_boundaries`` no node-to-node transfer may cross a
    bandwidth community; checkpoint streams (``src=None``) are exempt."""
    comm_of = {int(d): ci for ci, c in enumerate(communities) for d in c}
    out: List[Finding] = []
    for m in moves:
        if m.src is None:
            continue
        cs, cd = comm_of.get(int(m.src)), comm_of.get(int(m.dst))
        if cs is None or cd is None or cs != cd:
            out.append(Finding(
                "cross-cluster-migration", m.op,
                f"op {m.op!r} migrates {m.nbytes} bytes across the WAN "
                f"fence (CompNode {m.src} in community {cs} -> "
                f"{m.dst} in {cd}) — pin_boundaries forbids this"))
    return out


def _score_findings(result: ReplanResult) -> List[Finding]:
    out: List[Finding] = []
    if not result.scores:
        return out
    winners = [s.get("name") for s in result.scores if s.get("winner")]
    if winners != [result.mode]:
        out.append(Finding(
            "score-winner-mismatch", result.mode,
            f"result claims mode {result.mode!r} but the score table marks "
            f"{winners!r} as winner(s)"))
    for s in result.scores:
        for k in ("pace", "migration_bytes", "migration_seconds", "score"):
            v = s.get(k)
            if not isinstance(v, (int, float)) or math.isnan(v) or v < 0:
                out.append(Finding(
                    "bad-score", f"{s.get('name')}.{k}",
                    f"candidate {s.get('name')!r} has {k}={v!r}"))
    return out


def check_replan(graph: OpGraph, profiles: Mapping[str, OpProfile],
                 result: ReplanResult, old_schedule: Schedule,
                 cluster: Optional[ClusterSpec] = None,
                 opt_state_mult: float = 2.0,
                 pinned: bool = False,
                 communities: Optional[Sequence[Sequence[int]]] = None,
                 check_capacity: bool = False) -> List[Finding]:
    """Full audit of a :class:`ReplanResult` against the schedule it
    replaces: winner validity (op coverage over the survivors), move-set
    conservation, pinning, score-table consistency.

    ``check_capacity`` defaults off here: after heavy churn the survivors
    may *have* to over-subscribe memory to keep training at all — that is
    a planning-quality concern for the CLI sweep, not an installability
    invariant."""
    findings = check_schedule(graph, result.schedule, profiles=profiles,
                              cluster=cluster, alive=result.alive,
                              check_capacity=check_capacity)
    findings += check_moves(old_schedule, result.schedule, profiles,
                            result.migration.moves, dead=result.dead,
                            opt_state_mult=opt_state_mult)
    if pinned:
        comms = communities if communities is not None \
            else old_schedule.clusters or ()
        if comms:
            findings += check_pinned_moves(result.migration.moves, comms)
    findings += _score_findings(result)
    return findings


def verify_replan(graph: OpGraph, profiles: Mapping[str, OpProfile],
                  result: ReplanResult, old_schedule: Schedule,
                  cluster: Optional[ClusterSpec] = None,
                  opt_state_mult: float = 2.0,
                  pinned: bool = False,
                  communities: Optional[Sequence[Sequence[int]]] = None,
                  check_capacity: bool = False,
                  strict: bool = False) -> List[Finding]:
    findings = check_replan(graph, profiles, result, old_schedule,
                            cluster=cluster, opt_state_mult=opt_state_mult,
                            pinned=pinned, communities=communities,
                            check_capacity=check_capacity)
    return raise_findings(
        findings, ElasticCheckError,
        f"re-plan (mode {result.mode!r}) failed verification",
        strict=strict)
