"""Happens-before checker for ``obs`` span logs (repro.check, component 5).

PR 6's ``validate_trace_events`` is schema-only: it accepts a trace whose
spans are causally impossible.  This module replays a recorded span log
and verifies the ordering the sim executor promises:

* **monotonic tracks** — on every serial sim-clock resource track
  (``dev<i>`` compute, ``link a->b`` transfer, ``codec<i>`` encode)
  span starts are non-decreasing in record (``seq``) order,
* **serial links/devices** — within one training step no two spans on
  one such track overlap: a link never carries two sends at once, a
  device never computes two micro-batches at once,
* **compute-after-inbound** — a stage compute span
  (``F<st>.mb<m>`` / ``B<st>.mb<m>`` on ``dev<d>``) never starts before
  every inbound transfer feeding it (``Fxfer.mb<m>`` on
  ``link s-><d>`` of the same direction and step) has closed.

Step-scoped rules group spans by *execution attempt* — the
``(step, epoch)`` arg pair — because a rolled-back data step re-executes
under the next epoch with a different schedule and clock offset; pairing
the two attempts would be a false positive, not a causality bug.

Cross-step overlap is *not* flagged: the controller replays per-step
executor traces onto the broker clock, and overlapped migration
deliberately runs concurrently with training.  The ``migration`` track
is exempt from the serial rules by design (disjoint endpoint pairs
stream in parallel, so starts are not seq-monotonic there).

All comparisons use a relative tolerance — replay shifts and the µs
round-trip through the Chrome export cost a few ulps.
"""
from __future__ import annotations

import math
import re
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.obs.export import events_from_dicts, read_jsonl
from repro.obs.trace import (CAT_BWD, CAT_FWD, CAT_TRANSFER, CLOCK_SIM,
                             TraceEvent)

from .errors import Finding, SEV_WARN, TraceOrderError, raise_findings

# The sim span vocabulary, public: these regexes are the single source of
# truth for parsing executor traces — the critical-path analyzer
# (repro.obs.critpath) builds its happens-before DAG from the same rules,
# so the two layers cannot drift apart.
XFER_RE = re.compile(r"^([FB])xfer\.mb(\d+)$")
LINK_RE = re.compile(r"^link (\d+)->(\d+)$")
COMP_RE = re.compile(r"^([FB])(\d+)\.mb(\d+)$")
DEV_RE = re.compile(r"^dev(\d+)$")
ENC_RE = re.compile(r"^([FB])enc\.mb(\d+)$")
CODEC_RE = re.compile(r"^codec(\d+)$")

# backwards-compatible private aliases (pre-PR-10 names)
_XFER_RE, _LINK_RE, _COMP_RE, _DEV_RE = XFER_RE, LINK_RE, COMP_RE, DEV_RE


def _is_serial_track(e: TraceEvent) -> bool:
    return e.clock == CLOCK_SIM and (
        DEV_RE.match(e.track) is not None
        or LINK_RE.match(e.track) is not None
        or CODEC_RE.match(e.track) is not None)


def _attempt_of(e: TraceEvent) -> Any:
    """One *execution attempt* of a data step: after a rollback the same
    step number re-executes under the next epoch, so spans are grouped by
    (step, epoch) — pairing across attempts would compare two different
    schedules' clocks."""
    args = e.args or {}
    return (args.get("step"), args.get("epoch"))


def _tolerance(spans: Sequence[TraceEvent], eps: float) -> float:
    hi = max((abs(e.ts) + abs(e.dur) for e in spans), default=1.0)
    return eps * max(1.0, hi)


def check_trace_order(events: Sequence[TraceEvent],
                      eps: float = 1e-9) -> List[Finding]:
    """Happens-before audit over recorder events (``phase == "X"`` spans
    drive the ordering rules; instants are only sanity-checked)."""
    out: List[Finding] = []
    spans: List[TraceEvent] = []
    for e in events:
        if not math.isfinite(e.ts) or not math.isfinite(e.dur) or e.dur < 0:
            out.append(Finding("bad-span", f"{e.track}/{e.name}",
                               f"span {e.name!r} on {e.track!r} has "
                               f"ts={e.ts!r} dur={e.dur!r}"))
            continue
        if e.phase == "X":
            spans.append(e)
    tol = _tolerance(spans, eps)

    # Rule A1: serial sim tracks are seq-monotonic in start time
    by_track: Dict[Tuple[str, str], List[TraceEvent]] = {}
    for e in spans:
        if _is_serial_track(e):
            by_track.setdefault((e.clock, e.track), []).append(e)
    for (clock, track), evs in sorted(by_track.items()):
        evs_seq = sorted(evs, key=lambda e: e.seq)
        for a, b in zip(evs_seq, evs_seq[1:]):
            if b.ts < a.ts - tol:
                out.append(Finding(
                    "nonmonotonic-track", track,
                    f"track {track!r}: span {b.name!r} (seq {b.seq}) starts "
                    f"at {b.ts:.6g}s, before the earlier-recorded "
                    f"{a.name!r} (seq {a.seq}) at {a.ts:.6g}s"))
                break
        # Rule A2: within one execution attempt the resource is serial
        by_step: Dict[Any, List[TraceEvent]] = {}
        for e in evs:
            by_step.setdefault(_attempt_of(e), []).append(e)
        for step, sevs in sorted(by_step.items(),
                                 key=lambda kv: repr(kv[0])):
            sevs = sorted(sevs, key=lambda e: (e.ts, e.seq))
            for a, b in zip(sevs, sevs[1:]):
                if b.ts < a.ts + a.dur - tol:
                    if track.startswith("link"):
                        what = "two sends in flight"
                    elif track.startswith("codec"):
                        what = "two encodes in flight"
                    else:
                        what = "two compute windows"
                    out.append(Finding(
                        "overlap", track,
                        f"track {track!r}"
                        + (f" step {step[0]}" if step[0] is not None else "")
                        + f": {what} — {b.name!r} starts at {b.ts:.6g}s "
                        f"inside {a.name!r} [{a.ts:.6g}, "
                        f"{a.ts + a.dur:.6g}]s"))
                    break

    # Rule B: no compute span starts before its inbound transfers close
    computes: Dict[Any, List[Tuple[TraceEvent, str, int, int]]] = {}
    for e in spans:
        if e.cat not in (CAT_FWD, CAT_BWD):
            continue
        mc, md = _COMP_RE.match(e.name), _DEV_RE.match(e.track)
        if mc and md:
            computes.setdefault((e.clock, _attempt_of(e)), []).append(
                (e, mc.group(1), int(mc.group(3)), int(md.group(1))))
    for e in spans:
        if e.cat != CAT_TRANSFER:
            continue
        mx, ml = _XFER_RE.match(e.name), _LINK_RE.match(e.track)
        if not (mx and ml):
            continue
        tag, mb = mx.group(1), int(mx.group(2))
        dst = int(ml.group(2))
        close = e.ts + e.dur
        cands = [c for (c, ctag, cmb, cdev)
                 in computes.get((e.clock, _attempt_of(e)), [])
                 if ctag == tag and cmb == mb and cdev == dst]
        if not cands:
            out.append(Finding(
                "orphan-transfer", f"{e.track}/{e.name}",
                f"transfer {e.name!r} on {e.track!r} feeds no recorded "
                f"compute span on dev{dst}", severity=SEV_WARN))
            continue
        consumer = min(cands, key=lambda c: c.ts)
        if consumer.ts < close - tol:
            out.append(Finding(
                "compute-before-transfer", f"dev{dst}/{consumer.name}",
                f"compute {consumer.name!r} on dev{dst} starts at "
                f"{consumer.ts:.6g}s before its inbound {e.name!r} on "
                f"{e.track!r} closes at {close:.6g}s"))
    return out


def load_trace_events(path: str) -> List[TraceEvent]:
    """Recorder events from a loss-free ``.jsonl`` or a Chrome-trace
    ``.json`` (clock/track reconstructed from the ``M`` metadata; ``seq``
    is the file order, which the exporter writes in ``(clock, ts, seq)``
    order)."""
    if path.endswith(".jsonl"):
        return events_from_dicts(read_jsonl(path))
    import json
    with open(path) as f:
        payload = json.load(f)
    raw = payload.get("traceEvents", []) \
        if isinstance(payload, Mapping) else payload
    pid_clock: Dict[int, str] = {}
    tid_track: Dict[Tuple[int, int], str] = {}
    for e in raw:
        if e.get("ph") != "M":
            continue
        name = (e.get("args") or {}).get("name", "")
        if e.get("name") == "process_name":
            pid_clock[e["pid"]] = str(name).split()[0]
        elif e.get("name") == "thread_name":
            tid_track[(e["pid"], e["tid"])] = str(name)
    out: List[TraceEvent] = []
    for i, e in enumerate(raw):
        ph = e.get("ph")
        if ph not in ("X", "i"):
            continue
        out.append(TraceEvent(
            seq=i, clock=pid_clock.get(e.get("pid"), CLOCK_SIM),
            phase=ph, cat=e.get("cat", ""), name=e.get("name", ""),
            track=tid_track.get((e.get("pid"), e.get("tid")), "?"),
            ts=float(e.get("ts", 0.0)) / 1e6,
            dur=float(e.get("dur", 0.0)) / 1e6,
            args=e.get("args")))
    return out


def verify_trace(events_or_path, eps: float = 1e-9,
                 strict: bool = False) -> List[Finding]:
    """Raise :class:`TraceOrderError` on any ordering violation.  Accepts
    a recorder, an event list, or a trace-file path."""
    if isinstance(events_or_path, str):
        events = load_trace_events(events_or_path)
    elif hasattr(events_or_path, "events"):
        events = events_or_path.events()
    else:
        events = list(events_or_path)
    findings = check_trace_order(events, eps=eps)
    return raise_findings(findings, TraceOrderError,
                          "trace failed happens-before verification",
                          strict=strict)
