"""Cost-model and compression-plan checks (repro.check, component 3).

The whole planning stack promises that **every transported byte is
derivable from the** :class:`repro.core.costmodel.EdgeCostModel` — the
estimator and the discrete-event simulator read the same
``edge_wire_bytes``/``link_seconds``, so their parity is structural, not
a numerical coincidence.  :func:`check_cost_model` re-derives each cross
edge from first principles (profile numel x dtype itemsize x wire
encoding x alpha-beta link x correction) and flags any edge whose model
answer cannot be reproduced, any edge whose wire bytes exceed the dense
payload (the break-even guarantee of PR 2), and any calibrated link
correction outside :func:`fit_link_corrections`' clamp.

:func:`check_compression_plan` validates an AdaTopK
:class:`CompressionPlan` on its own: known encoding, finite ratios, every
ratio above its edge's dtype-exact break-even, no integer-rounding wire
inflation, and (when a placement is given) every planned edge actually
crossing CompNodes.

Since the Pallas codec fast path landed, compression also costs *compute*:
when a ``cost_model`` carrying calibrated per-device
:class:`repro.core.costmodel.KernelCostModel` entries is supplied, both
checkers enforce the FusionLLM §6 premise that compression must outrun the
bandwidth it buys back — any planned edge whose encode seconds meet or
exceed the wire seconds saved is a ``compression-unprofitable`` finding
(the planner's profitability guard should have dropped it).
"""
from __future__ import annotations

import math
from typing import List, Mapping, Optional

from repro.core.compression import (CompressionPlan, encoding_break_even,
                                    wire_bytes)
from repro.core.costmodel import EdgeCostModel
from repro.core.opgraph import OpGraph, OpProfile

from .errors import (CompressionCheckError, CostCheckError, Finding,
                     SEV_WARN, raise_findings)

_ENCODINGS = ("paper", "mask", "none")
_CORRECTION_CLAMP = (0.25, 4.0)
_REL_TOL = 1e-9


def _close(a: float, b: float) -> bool:
    return math.isclose(a, b, rel_tol=_REL_TOL, abs_tol=1e-12)


def check_cost_model(model: EdgeCostModel,
                     placement: Mapping[str, int]) -> List[Finding]:
    """Structural estimator/simulator parity for every cross edge under
    ``placement``, plus correction-clamp sanity."""
    out: List[Finding] = []
    for dev, kc in sorted(model.kernel_costs.items()):
        if not (math.isfinite(kc.alpha) and kc.alpha >= 0.0) \
                or not kc.bytes_per_second > 0.0:
            out.append(Finding(
                "bad-kernel-cost", f"dev{dev}",
                f"device {dev}: kernel cost alpha={kc.alpha!r} "
                f"bytes_per_second={kc.bytes_per_second!r} — alpha must be "
                "finite and >= 0, throughput positive (inf = free)"))
    for (i, j), c in sorted(model.link_corrections.items()):
        if not math.isfinite(c) or not \
                _CORRECTION_CLAMP[0] <= c <= _CORRECTION_CLAMP[1]:
            out.append(Finding(
                "correction-out-of-clamp", f"dev{i}->dev{j}",
                f"link correction {c!r} outside the fit clamp "
                f"{_CORRECTION_CLAMP} — not a fit_link_corrections product"))
    for (a, n) in model.cross_edges(placement):
        edge = f"{a}->{n}"
        src, dst = placement[a], placement[n]
        dense = model.dense_bytes(a)
        wire = model.edge_wire_bytes(a, n)
        if not (math.isfinite(wire) and wire >= 0.0
                and math.isfinite(dense) and dense >= 0.0):
            out.append(Finding("bad-edge-bytes", edge,
                               f"edge {edge}: dense={dense!r} "
                               f"wire={wire!r} must be finite and >= 0"))
            continue
        # re-derive the wire bytes from the plan's ratio + the producer's
        # profile dtype — the only sanctioned arithmetic
        r = model.ratio(a, n)
        if r <= 1.0 or model.encoding == "none":
            expect = dense
        else:
            expect = wire_bytes(model.numel(a), r, model.encoding,
                                itemsize=model.itemsize(a))
        if not _close(wire, expect):
            out.append(Finding(
                "wire-bytes-underivable", edge,
                f"edge {edge}: model says {wire} wire bytes but the "
                f"encoding arithmetic gives {expect} (ratio {r:g}, "
                f"encoding {model.encoding!r})"))
        if wire > dense and not _close(wire, dense):
            out.append(Finding(
                "wire-inflation", edge,
                f"edge {edge}: {wire:.0f} wire bytes exceed the dense "
                f"{dense:.0f} — the break-even clamp is broken"))
        try:
            base = model.cluster.comm_time(src, dst, wire)
        except KeyError:
            out.append(Finding(
                "missing-link", f"dev{src}->dev{dst}",
                f"edge {edge} crosses CompNodes {src}->{dst} with no link "
                "in the cluster spec"))
            continue
        expect_s = base * model.link_corrections.get((src, dst), 1.0)
        got_s = model.edge_seconds(a, n, src, dst)
        if not _close(got_s, expect_s):
            out.append(Finding(
                "seconds-underivable", edge,
                f"edge {edge}: model prices {got_s!r}s but "
                f"alpha-beta x correction gives {expect_s!r}s"))
        enc_s = model.compress_seconds(a, n, src)
        if enc_s > 0.0:
            saved = model.link_seconds(src, dst, dense) - got_s
            if enc_s >= saved and not _close(enc_s, saved):
                out.append(Finding(
                    "compression-unprofitable", edge,
                    f"edge {edge}: encode costs {enc_s:.3g}s on dev{src}'s "
                    f"codec but saves only {saved:.3g}s of wire time — "
                    "compressing this edge slows the step down"))
    return out


def check_compression_plan(graph: OpGraph,
                           profiles: Mapping[str, OpProfile],
                           plan: Optional[CompressionPlan],
                           placement: Optional[Mapping[str, int]] = None,
                           cost_model: Optional[EdgeCostModel] = None
                           ) -> List[Finding]:
    """AdaTopK plan invariants; ``plan=None`` (dense transport) passes.

    ``cost_model`` (needs ``placement`` too) additionally enforces encode
    profitability per planned cross edge: with calibrated kernel costs, an
    edge whose codec seconds meet or exceed the wire seconds its ratio saves
    is a ``compression-unprofitable`` finding.  A model without kernel
    costs prices encode as free, so the check passes vacuously (legacy)."""
    if plan is None:
        return []
    out: List[Finding] = []
    if plan.encoding not in _ENCODINGS:
        out.append(Finding("unknown-encoding", plan.encoding,
                           f"encoding {plan.encoding!r} not in "
                           f"{_ENCODINGS}"))
        return out
    if not math.isfinite(plan.base_ratio) or plan.base_ratio < 1.0:
        out.append(Finding("bad-base-ratio", f"{plan.base_ratio!r}",
                           f"base_ratio {plan.base_ratio!r} must be finite "
                           "and >= 1"))
    for (a, n), r in sorted(plan.edge_ratio.items()):
        edge = f"{a}->{n}"
        if a not in graph.nodes or n not in graph.nodes:
            out.append(Finding("unknown-op", edge,
                               f"planned edge {edge} references an op "
                               "absent from the graph"))
            continue
        if not math.isfinite(r) or r < 1.0:
            out.append(Finding("ratio-invalid", edge,
                               f"edge {edge}: ratio {r!r} must be finite "
                               "and >= 1"))
            continue
        prof = profiles.get(a)
        if prof is None:
            out.append(Finding("missing-profile", edge,
                               f"planned edge {edge}: producer {a!r} has "
                               "no OpProfile to derive bytes from"))
            continue
        numel = 1
        for d in prof.out_shape:
            numel *= int(d)
        itemsize = max(1, int(round(prof.out_bytes / numel))) \
            if numel > 0 and prof.out_bytes else 4
        if r > 1.0 and plan.encoding != "none":
            be = encoding_break_even(plan.encoding, itemsize)
            if r <= be:
                out.append(Finding(
                    "ratio-below-break-even", edge,
                    f"edge {edge}: ratio {r:g} <= break-even {be:g} for "
                    f"{plan.encoding!r}@itemsize {itemsize} — this edge "
                    "INFLATES wire traffic"))
                continue
            wire = wire_bytes(numel, r, plan.encoding, itemsize=itemsize)
            dense = float(prof.out_bytes)
            if wire >= dense and dense > 0:
                out.append(Finding(
                    "wire-inflation", edge,
                    f"edge {edge}: ratio {r:g} encodes to {wire:.0f} wire "
                    f"bytes >= dense {dense:.0f} (ceil rounding "
                    "re-inflated it)"))
        if placement is not None:
            pa, pn = placement.get(a), placement.get(n)
            if pa is None or pn is None:
                out.append(Finding("unknown-op", edge,
                                   f"planned edge {edge} references an op "
                                   "absent from the placement"))
            elif pa == pn:
                out.append(Finding(
                    "plan-edge-not-cross", edge,
                    f"planned edge {edge} does not cross CompNodes under "
                    "this placement (stale plan?)", severity=SEV_WARN))
    if cost_model is not None and placement is not None:
        m = cost_model.with_plan(plan)
        for (a, n) in m.cross_edges(placement):
            if (a, n) not in plan.edge_ratio:
                continue
            src, dst = placement[a], placement[n]
            enc_s = m.compress_seconds(a, n, src)
            if enc_s <= 0.0:
                continue
            try:
                wire_s = m.edge_seconds(a, n, src, dst)
                dense_s = m.link_seconds(src, dst, m.dense_bytes(a))
            except KeyError:
                continue   # missing-link is check_cost_model's finding
            saved = dense_s - wire_s
            if enc_s >= saved and not _close(enc_s, saved):
                out.append(Finding(
                    "compression-unprofitable", f"{a}->{n}",
                    f"planned edge {a}->{n}: encode costs {enc_s:.3g}s on "
                    f"dev{src}'s codec but saves only {saved:.3g}s of wire "
                    "time — the plan slows the step down"))
    return out


def verify_plan(graph: OpGraph, profiles: Mapping[str, OpProfile],
                plan: Optional[CompressionPlan],
                placement: Optional[Mapping[str, int]] = None,
                cost_model: Optional[EdgeCostModel] = None,
                strict: bool = False) -> List[Finding]:
    findings = check_compression_plan(graph, profiles, plan, placement,
                                      cost_model)
    return raise_findings(findings, CompressionCheckError,
                          "compression plan failed verification",
                          strict=strict)


def verify_cost_model(model: EdgeCostModel, placement: Mapping[str, int],
                      strict: bool = False) -> List[Finding]:
    findings = check_cost_model(model, placement)
    return raise_findings(findings, CostCheckError,
                          "edge-cost model failed verification",
                          strict=strict)
