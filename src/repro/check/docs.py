"""Markdown link checker (docs CI gate).

Walks the repo's user-facing markdown — ``README.md``, ``ROADMAP.md`` and
everything under ``docs/`` by default — and verifies every **relative**
link resolves:

* ``[text](path/to/file.md)``      — the target file/directory exists;
* ``[text](file.md#anchor)``       — the file exists *and* contains a
  heading whose GitHub slug matches the anchor;
* ``[text](#anchor)``              — same-file heading exists.

``http(s)://`` and ``mailto:`` links are skipped (no network in CI), as
are links inside fenced code blocks.  Stdlib only, jax-free, so the docs
CI job runs without the accelerator stack.

Findings use ``code="dead-link"`` / ``"dead-anchor"`` with
``where="file.md:line"`` so CI artifacts and tests key on them.
"""
from __future__ import annotations

import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set

from .errors import CheckError, Finding, raise_findings

# [text](target) — non-greedy target, no nested parens; images share the form
_LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")
_FENCE_RE = re.compile(r"^(```|~~~)")
_SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")

DEFAULT_DOCS = ("README.md", "ROADMAP.md", "PAPER.md", "docs")


class DocsCheckError(CheckError):
    """A relative markdown link points at nothing."""


def github_slug(heading: str) -> str:
    """GitHub's anchor slug for a heading: lowercase, strip punctuation
    (keep word chars, spaces, hyphens), spaces -> hyphens."""
    # drop inline code/emphasis markers and trailing anchors first
    text = re.sub(r"[`*_]", "", heading.strip())
    text = re.sub(r"[^\w\- ]", "", text.lower())
    return text.replace(" ", "-")


def _markdown_lines(path: str) -> List[str]:
    with open(path, encoding="utf-8") as f:
        return f.read().splitlines()


def anchors_in(path: str) -> Set[str]:
    """All heading slugs in a markdown file (GitHub duplicate suffixes
    ``-1``, ``-2``… included)."""
    seen: Dict[str, int] = {}
    out: Set[str] = set()
    in_fence = False
    for line in _markdown_lines(path):
        if _FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = _HEADING_RE.match(line)
        if not m:
            continue
        slug = github_slug(m.group(1))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        out.add(slug if n == 0 else f"{slug}-{n}")
    return out


def check_markdown_file(path: str, repo_root: str) -> List[Finding]:
    """Check every relative link in one markdown file."""
    findings: List[Finding] = []
    rel = os.path.relpath(path, repo_root).replace(os.sep, "/")
    base = os.path.dirname(path)
    in_fence = False
    for lineno, line in enumerate(_markdown_lines(path), start=1):
        if _FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in _LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(_SKIP_SCHEMES) or target.startswith("<"):
                continue
            frag = ""
            if "#" in target:
                target, frag = target.split("#", 1)
            if target:
                dest = os.path.normpath(os.path.join(base, target))
                if not os.path.exists(dest):
                    findings.append(Finding(
                        "dead-link", f"{rel}:{lineno}",
                        f"link target {target!r} does not exist"))
                    continue
            else:
                dest = path       # same-file anchor
            if frag:
                if not (os.path.isfile(dest) and dest.endswith(".md")):
                    continue      # anchors into non-markdown: not checked
                if frag.lower() not in anchors_in(dest):
                    findings.append(Finding(
                        "dead-anchor", f"{rel}:{lineno}",
                        f"anchor #{frag} not found in "
                        f"{os.path.relpath(dest, repo_root)}"))
    return findings


def _walk_markdown(entry: str) -> Iterable[str]:
    if os.path.isfile(entry):
        yield entry
        return
    for dirpath, dirnames, filenames in os.walk(entry):
        dirnames[:] = sorted(d for d in dirnames if not d.startswith("."))
        for fn in sorted(filenames):
            if fn.endswith(".md"):
                yield os.path.join(dirpath, fn)


def check_docs(repo_root: Optional[str] = None,
               entries: Sequence[str] = DEFAULT_DOCS) -> List[Finding]:
    """Link-check the repo's markdown set; missing entries are skipped
    (PAPER.md is optional), findings sorted by location."""
    if repo_root is None:
        # src/repro/check/docs.py -> repo root is three levels up from src
        here = os.path.dirname(os.path.abspath(__file__))
        repo_root = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    findings: List[Finding] = []
    for entry in entries:
        full = os.path.join(repo_root, entry)
        if not os.path.exists(full):
            continue
        for path in _walk_markdown(full):
            findings += check_markdown_file(path, repo_root)
    return sorted(findings, key=lambda f: f.where)


def verify_docs(repo_root: Optional[str] = None,
                strict: bool = False) -> List[Finding]:
    return raise_findings(check_docs(repo_root), DocsCheckError,
                          "markdown link check failed", strict=strict)
