"""OP-DAG structural checks (repro.check, component 1).

Validates the graph invariants everything downstream assumes:

* acyclicity (Kahn's algorithm; cycle members named),
* no dangling or duplicate deps,
* shape/dtype inference consistency along every edge,
* every compute op reachable *from the loss* along reverse edges —
  an op no gradient can flow to silently trains nothing.

All checks return :class:`repro.check.errors.Finding` lists;
:func:`verify_graph` raises :class:`GraphCheckError`.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.opgraph import OpGraph, OpProfile, OpType

from .errors import (Finding, GraphCheckError, SEV_WARN, raise_findings)

Shape = Tuple[int, ...]


def _dep_findings(graph: OpGraph) -> List[Finding]:
    out: List[Finding] = []
    for n, node in graph.nodes.items():
        seen: set = set()
        for a in node.args:
            if a not in graph.nodes:
                out.append(Finding("dangling-dep", n,
                                   f"op {n!r} depends on absent op {a!r}"))
            elif a in seen:
                out.append(Finding("duplicate-dep", n,
                                   f"op {n!r} lists dep {a!r} twice"))
            seen.add(a)
        if n != node.name:
            out.append(Finding("name-key-mismatch", n,
                               f"node keyed {n!r} but named {node.name!r}"))
    return out


def _cycle_findings(graph: OpGraph) -> List[Finding]:
    """Kahn's over the known-dep subgraph; leftover nodes sit on a cycle."""
    known = set(graph.nodes)
    indeg = {n: sum(1 for a in graph.nodes[n].args if a in known)
             for n in known}
    users: Dict[str, List[str]] = {n: [] for n in known}
    for n, node in graph.nodes.items():
        for a in node.args:
            if a in known:
                users[a].append(n)
    ready = [n for n in graph.nodes if indeg[n] == 0]
    done = 0
    while ready:
        n = ready.pop(0)
        done += 1
        for u in users[n]:
            indeg[u] -= 1
            if indeg[u] == 0:
                ready.append(u)
    if done == len(graph.nodes):
        return []
    stuck = sorted(n for n in graph.nodes if indeg[n] > 0)
    return [Finding("cycle", stuck[0] if stuck else "<graph>",
                    f"OP-DAG contains a cycle through {stuck}")]


def _reachability_findings(graph: OpGraph) -> List[Finding]:
    """Compute ops from which no path reaches a loss node get no gradient.
    Graphs without a LOSS node (inference graphs) skip this check."""
    losses = graph.loss_nodes()
    if not losses:
        return []
    # ancestors-of-loss via reverse BFS over args
    reach = set(losses)
    frontier = list(losses)
    while frontier:
        n = frontier.pop()
        for a in graph.nodes[n].args:
            if a in graph.nodes and a not in reach:
                reach.add(a)
                frontier.append(a)
    out: List[Finding] = []
    for n, node in graph.nodes.items():
        if n in reach:
            continue
        sev = SEV_WARN if node.op_type in (OpType.PLACEHOLDER,
                                           OpType.VARIABLE) else "error"
        out.append(Finding("unreachable-from-loss", n,
                           f"op {n!r} ({node.op_type.value}) has no path "
                           f"to any loss node {losses}", severity=sev))
    return out


def _shape_findings(graph: OpGraph,
                    input_shapes: Mapping[str, Shape]) -> List[Finding]:
    out: List[Finding] = []
    shapes: Dict[str, Shape] = {}
    try:
        order = graph.topo_order()
    except ValueError:
        return out      # cycle already reported; inference cannot run
    for n in order:
        node = graph.nodes[n]
        try:
            if node.op_type is OpType.PLACEHOLDER:
                if n not in input_shapes:
                    out.append(Finding("missing-input-shape", n,
                                       f"placeholder {n!r} has no entry in "
                                       "input_shapes"))
                    continue
                shapes[n] = tuple(input_shapes[n])
            elif node.op_type is OpType.VARIABLE:
                shapes[n] = tuple(node.meta["shape"])
            else:
                ins = [shapes[a] for a in node.args if a in shapes]
                if len(ins) != len(node.args):
                    continue     # upstream already failed
                shapes[n] = node.infer_out_shape(*ins)
        except (KeyError, ValueError, TypeError) as e:
            out.append(Finding("shape-inference", n,
                               f"op {n!r}: shape inference failed: {e}"))
            continue
        shp = shapes.get(n)
        if shp is not None and not all(
                isinstance(d, (int, np.integer)) and d >= 0 for d in shp):
            out.append(Finding("bad-shape", n,
                               f"op {n!r} inferred shape {shp!r} is not a "
                               "tuple of non-negative ints"))
        try:
            np.dtype(node.out_dtype)
        except TypeError:
            out.append(Finding("bad-dtype", n,
                               f"op {n!r} out_dtype {node.out_dtype!r} is "
                               "not a valid dtype"))
    return out


def check_graph(graph: OpGraph,
                input_shapes: Optional[Mapping[str, Shape]] = None
                ) -> List[Finding]:
    """All structural graph checks; shape checks only when
    ``input_shapes`` is supplied."""
    findings = _dep_findings(graph)
    findings += _cycle_findings(graph)
    if not findings:   # reachability over a broken edge set is noise
        findings += _reachability_findings(graph)
    if input_shapes is not None and not findings:
        findings += _shape_findings(graph, input_shapes)
    return findings


def check_profiles(graph: OpGraph, profiles: Mapping[str, OpProfile],
                   input_shapes: Optional[Mapping[str, Shape]] = None
                   ) -> List[Finding]:
    """Broker-side :class:`OpProfile` consistency: every op profiled, all
    numbers finite and non-negative, ``out_bytes`` an integral itemsize
    multiple of the shape's numel, and (when ``input_shapes`` is given)
    the profiled shape equal to the freshly inferred one."""
    out: List[Finding] = []
    inferred: Optional[Dict[str, Shape]] = None
    if input_shapes is not None:
        try:
            inferred = graph.infer_shapes(input_shapes)
        except ValueError:
            inferred = None    # reported by check_graph
    for n in graph.nodes:
        p = profiles.get(n)
        if p is None:
            out.append(Finding("missing-profile", n,
                               f"op {n!r} has no OpProfile"))
            continue
        for field, v in (("fwd_flops", p.fwd_flops),
                         ("out_bytes", p.out_bytes),
                         ("n_params", p.n_params)):
            if not np.isfinite(v) or v < 0:
                out.append(Finding("bad-profile-value", n,
                                   f"op {n!r} profile {field}={v!r} must be "
                                   "finite and >= 0"))
        numel = int(np.prod(p.out_shape)) if p.out_shape else 0
        if numel > 0 and p.out_bytes > 0:
            item = p.out_bytes / numel
            if abs(item - round(item)) > 1e-9 or not 1 <= round(item) <= 32:
                out.append(Finding(
                    "profile-bytes-inconsistent", n,
                    f"op {n!r} out_bytes={p.out_bytes} over numel={numel} "
                    f"gives itemsize {item:.3g}, not an integer in [1, 32]"))
        if inferred is not None and n in inferred \
                and tuple(p.out_shape) != tuple(inferred[n]):
            out.append(Finding(
                "profile-shape-mismatch", n,
                f"op {n!r} profiled shape {tuple(p.out_shape)} != inferred "
                f"{tuple(inferred[n])}"))
    return out


def verify_graph(graph: OpGraph,
                 input_shapes: Optional[Mapping[str, Shape]] = None,
                 profiles: Optional[Mapping[str, OpProfile]] = None,
                 strict: bool = False) -> List[Finding]:
    """Raise :class:`GraphCheckError` on any error-severity finding
    (``strict=True`` promotes warnings too); returns the findings."""
    findings = check_graph(graph, input_shapes)
    if profiles is not None:
        findings += check_profiles(graph, profiles, input_shapes)
    return raise_findings(findings, GraphCheckError,
                          f"OP-DAG {graph.name!r} failed verification",
                          strict=strict)
